//! End-to-end serving driver (the EXPERIMENTS.md §E2E workload).
//!
//!   cargo run --release --example serve_longcontext [-- --requests 96 --rps 6]
//!
//! Boots the full L3 stack — engine, router, admission, dynamic batcher,
//! worker pool, KV pool — and pushes an open-loop Poisson trace of mixed
//! long-context requests through it twice: once under dense attention,
//! once under Stem. Reports TTFT percentiles, throughput, mean budget and
//! answer accuracy for both, demonstrating the paper's claim end-to-end:
//! same accuracy, ~4× less attention work, lower TTFT.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use stem::coordinator::{Coordinator, CoordinatorConfig, Method};
use stem::eval::{score_sample, Evaluator};
use stem::runtime::Engine;
use stem::util::cli::Args;
use stem::workload::{load_eval_set, poisson_trace, EvalSample};

struct RunStats {
    label: String,
    served: usize,
    wall_s: f64,
    em_pct: f64,
    ttft_p50_ms: f64,
    ttft_p95_ms: f64,
    exec_mean_ms: f64,
    budget_pct: f64,
}

fn run_trace(
    coord: &Arc<Coordinator>,
    pool: &[EvalSample],
    method_name: &str,
    n_requests: usize,
    rps: f64,
    seed: u64,
) -> Result<RunStats> {
    let man = coord.manifest().clone();
    let trace = poisson_trace(seed, n_requests, rps, pool.len());
    let start = Instant::now();
    let mut rxs = vec![];
    for item in &trace {
        let now = start.elapsed();
        if item.at > now {
            std::thread::sleep(item.at - now);
        }
        let s = &pool[item.sample];
        let bucket = man.bucket_for(s.ids.len()).ok_or_else(|| anyhow!("no bucket"))?;
        let method = if method_name == "dense" {
            Method::Dense
        } else {
            Evaluator::method_for(method_name, man.defaults_for(bucket)?)
        };
        let rx = coord.submit("base", method, s.ids.clone(), false)?;
        rxs.push((rx, item.sample));
    }
    let mut ttfts = vec![];
    let mut execs = vec![];
    let mut budgets = vec![];
    let mut em = 0usize;
    let mut served = 0usize;
    for (rx, si) in rxs {
        let resp = rx.recv().map_err(|_| anyhow!("channel closed"))??;
        let sc = score_sample(&resp, &pool[si]);
        em += sc.exact_match as usize;
        served += 1;
        ttfts.push((resp.queue_us + resp.exec_us) as f64 / 1e3);
        execs.push(resp.exec_us as f64 / 1e3);
        budgets.push(resp.budget_fraction as f64);
    }
    let wall = start.elapsed().as_secs_f64();
    ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| ttfts[((ttfts.len() - 1) as f64 * p) as usize];
    Ok(RunStats {
        label: method_name.to_string(),
        served,
        wall_s: wall,
        em_pct: 100.0 * em as f64 / served.max(1) as f64,
        ttft_p50_ms: pct(0.50),
        ttft_p95_ms: pct(0.95),
        exec_mean_ms: execs.iter().sum::<f64>() / execs.len().max(1) as f64,
        budget_pct: 100.0 * budgets.iter().sum::<f64>() / budgets.len().max(1) as f64,
    })
}

fn main() -> Result<()> {
    let args = Args::from_env(false);
    let n_requests = args.usize_or("requests", 96);
    let rps = args.f64_or("rps", 6.0);

    let artifacts = stem::artifacts_dir();
    let engine = Arc::new(Engine::new(&artifacts)?);
    let coord = Arc::new(Coordinator::new(engine, CoordinatorConfig::default()));
    let man = coord.manifest().clone();

    // mixed long-context pool: every LongBench-proxy family and bucket
    let mut pool = vec![];
    for set in &man.eval_sets {
        if set.suite == "longbench" {
            pool.extend(load_eval_set(&man.root.join(&set.file))?);
        }
    }
    println!("sample pool: {} prompts across {} eval sets", pool.len(), man.eval_sets.len());

    // compile everything up front so the trace measures serving, not JIT
    if let Some(engine) = coord.engine() {
        engine.warmup(&["prefill_dense", "prefill_stem"], &[512, 1024, 2048])?;
    }

    let mut rows = vec![];
    for m in ["dense", "stem"] {
        println!("\n=== {m}: {n_requests} requests, open-loop {rps} req/s ===");
        let st = run_trace(&coord, &pool, m, n_requests, rps, 42)?;
        println!("{}", coord.report());
        rows.push(st);
    }

    println!("\n===== end-to-end summary =====");
    println!(
        "{:<8} {:>8} {:>9} {:>10} {:>10} {:>10} {:>8}",
        "method", "served", "req/s", "TTFT p50", "TTFT p95", "exec mean", "budget"
    );
    for st in &rows {
        println!(
            "{:<8} {:>8} {:>9.2} {:>8.1}ms {:>8.1}ms {:>8.1}ms {:>7.1}%  em={:.1}%",
            st.label,
            st.served,
            st.served as f64 / st.wall_s,
            st.ttft_p50_ms,
            st.ttft_p95_ms,
            st.exec_mean_ms,
            st.budget_pct,
            st.em_pct
        );
    }
    if rows.len() == 2 {
        println!(
            "\nstem vs dense: exec {:.2}x faster, budget {:.1}% vs 100%, accuracy delta {:+.1}pp",
            rows[0].exec_mean_ms / rows[1].exec_mean_ms.max(1e-9),
            rows[1].budget_pct,
            rows[1].em_pct - rows[0].em_pct
        );
    }
    Ok(())
}
