//! Shared-prefix fan-out demo (no artifacts needed):
//!
//!   cargo run --release --example fanout_stream [-- --prompt-len 2048 --fanout 4 --max-new 32]
//!
//! Ingests one prompt into a root decode session, forks N branches off
//! the refcounted prefix (zero K/V copied at fork time), steers each
//! branch with a distinct divergence token, and streams all N
//! continuations. The branches diverge copy-on-write: only the shared
//! tail page is duplicated per branch, so page residency stays near
//! `prefix + N` instead of `N × (prefix + 1)`. The final report compares
//! both numbers and re-checks token-level isolation (a branch replayed
//! on a fresh pool must reproduce its stream exactly).

use std::sync::Arc;

use anyhow::Result;

use stem::coordinator::kv_cache::KvConfig;
use stem::decode::{DecodePolicy, DecodeSession, SharedKv, TinyLm};
use stem::model::vocab;
use stem::util::cli::Args;
use stem::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), false);
    args.init_thread_pool();
    let block = args.usize_or("block", 64);
    let prompt_len = args.usize_or("prompt-len", 2048);
    let max_new = args.usize_or("max-new", 32);
    let fanout = args.usize_or("fanout", 4).max(1);
    let (h, hk, dh) = (8usize, 4usize, 32usize);

    let kv = SharedKv::new(
        KvConfig { total_pages: args.usize_or("pages", 4096), page_tokens: block },
        hk,
        dh,
    );
    let model = Arc::new(TinyLm::new(0xD0C0DE, h, hk, dh, vocab::VOCAB_SIZE));
    let mut rng = Rng::new(args.u64_or("seed", 42));
    let mut prompt = vec![vocab::BOS];
    prompt.extend((1..prompt_len).map(|_| vocab::WORD0 + rng.below(64) as i32));

    let policy = DecodePolicy {
        dense_below: args.usize_or("dense-below", 1024),
        k_start: args.f64_or("k-start", 8.0),
        horizon: max_new.max(1),
        ..Default::default()
    };

    // 1. ingest the shared prefix once
    let t0 = std::time::Instant::now();
    let mut root = DecodeSession::new(Arc::clone(&kv), Arc::clone(&model), policy, 1)?;
    root.prefill(&prompt)?;
    let prefix_pages = kv.occupancy().0;
    println!(
        "[prefix] {} tokens ingested once in {:.1}ms -> {prefix_pages} shared pages",
        prompt.len(),
        t0.elapsed().as_secs_f64() * 1e3,
    );

    // 2. fork the branches (refcount bumps only — no K/V copied)
    let t_fork = std::time::Instant::now();
    let mut branches: Vec<DecodeSession> = Vec::with_capacity(fanout);
    for i in 0..fanout {
        let mut b = root.fork(2 + i as u64)?;
        b.prefill(&[vocab::WORD0 + (i % 40) as i32])?; // divergence token
        branches.push(b);
    }
    println!(
        "[fork  ] {fanout} branches in {:.0}µs, kv pages now {} (CoW tails only)",
        t_fork.elapsed().as_secs_f64() * 1e6,
        kv.occupancy().0,
    );

    // 3. decode every branch, streaming
    let mut streams = Vec::with_capacity(fanout);
    for (i, b) in branches.iter_mut().enumerate() {
        let stats = b.generate(max_new, Some(vocab::END), |_| true)?;
        println!(
            "[br {i:>2} ] {:<56} ({:.1}µs/token, budget {:.1}%)",
            vocab::detok(&stats.tokens),
            stats.decode_ns as f64 / 1e3 / stats.steps.max(1) as f64,
            100.0 * stats.mean_budget_fraction,
        );
        streams.push(stats.tokens);
    }

    // 4. isolation check: replay branch 0 on a fresh pool
    let replay = {
        let kv2 = SharedKv::new(
            KvConfig { total_pages: args.usize_or("pages", 4096), page_tokens: block },
            hk,
            dh,
        );
        let mut s = DecodeSession::new(kv2, Arc::clone(&model), policy, 1)?;
        s.prefill(&prompt)?;
        s.prefill(&[vocab::WORD0])?;
        s.generate(max_new, Some(vocab::END), |_| true)?.tokens
    };
    assert_eq!(streams[0], replay, "CoW isolation: fork must equal its independent replay");

    let (used, total, _) = kv.occupancy();
    let independent = fanout * (prefix_pages + 1);
    println!("---");
    println!(
        "kv {used}/{total} pages with {fanout} live branches vs ~{independent} independent \
         ({:.1}x page savings); branch 0 verified against an independent replay",
        independent as f64 / used.max(1) as f64,
    );
    Ok(())
}
