//! Figure-5 ablation sweep through the public API: μ (decay ratio) and
//! β (magnitude coefficient) accuracy/budget curves on the LongBench
//! proxy suite.
//!
//!   cargo run --release --example ablation_sweep [-- --limit 6 --bucket 1024]
//!
//! Unlike `stem figure5` this sweeps finer grids and prints machine-
//! readable CSV (for replotting) alongside the table.

use std::sync::Arc;

use anyhow::Result;

use stem::coordinator::{Coordinator, CoordinatorConfig, Method};
use stem::eval::tables::FAMILIES;
use stem::eval::Evaluator;
use stem::runtime::Engine;
use stem::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(false);
    let bucket = args.usize_or("bucket", 1024);
    let limit = args.usize_or("limit", 6);

    let engine = Arc::new(Engine::new(&stem::artifacts_dir())?);
    let coord = Arc::new(Coordinator::new(engine, CoordinatorConfig::default()));
    let ev = Evaluator { coordinator: Arc::clone(&coord), limit };
    let man = coord.manifest().clone();
    let d = man.defaults_for(bucket)?.clone();
    let fams: Vec<&str> = FAMILIES.to_vec();

    println!("# mu sweep at k_start={:.1}, beta={}", d.k_start, d.beta);
    println!("mu,acc,budget");
    for mu10 in 5..=10 {
        let mu = mu10 as f32 / 10.0;
        let m = Method::Stem { k_start: d.k_start as f32, mu, beta: d.beta as f32 };
        let out = ev.run("base", "stem", Some(m), "longbench", &fams, &[bucket])?;
        let a = out.overall();
        println!("{mu:.1},{:.2},{:.3}", a.token_acc(), a.budget());
    }

    println!("\n# beta sweep at k_start={:.1}, mu={}", d.k_start, d.mu);
    println!("beta,acc,budget");
    for b10 in 0..=5 {
        let beta = b10 as f32 / 10.0;
        let m = Method::Stem { k_start: d.k_start as f32, mu: d.mu as f32, beta };
        let out = ev.run("base", "stem", Some(m), "longbench", &fams, &[bucket])?;
        let a = out.overall();
        println!("{beta:.1},{:.2},{:.3}", a.token_acc(), a.budget());
    }

    // budget-matched sanity: uniform vs TPD at identical cost (§3.3)
    println!("\n# budget-matched uniform (k_uni = k_start(1+mu)/2) vs TPD");
    for (label, m) in [
        (
            "uniform",
            Method::Stem { k_start: d.k_uni_matched as f32, mu: 1.0, beta: 0.0 },
        ),
        ("tpd", Method::Stem { k_start: d.k_start as f32, mu: d.mu as f32, beta: 0.0 }),
    ] {
        let out = ev.run("base", label, Some(m), "longbench", &fams, &[bucket])?;
        let a = out.overall();
        println!("{label}: acc {:.2}%, budget {:.1}%", a.token_acc(), 100.0 * a.budget());
    }
    Ok(())
}
