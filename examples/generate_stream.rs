//! Streaming decode demo (no artifacts needed):
//!
//!   cargo run --release --example generate_stream [-- --prompt-len 2048 --max-new 48]
//!
//! Runs the same prompt through two decode sessions against the shared
//! paged KV pool — one with Stem's per-step sparsity policy (TPD budget
//! over generation steps + OAM block ranking, sinks/recent forced), one
//! dense — streaming tokens as they are emitted, then compares ns/token
//! and attended-budget fractions. The Lil-inspired dense fallback means
//! short prompts legitimately report "0 sparse steps": raise
//! --prompt-len past --dense-below to see the sparse path engage.

use std::io::Write;
use std::sync::Arc;

use anyhow::Result;

use stem::coordinator::kv_cache::KvConfig;
use stem::decode::{DecodePolicy, DecodeSession, SessionStats, SharedKv, TinyLm};
use stem::model::vocab;
use stem::util::cli::Args;
use stem::util::rng::Rng;

fn run(
    kv: &Arc<SharedKv>,
    model: &Arc<TinyLm>,
    policy: DecodePolicy,
    seq: u64,
    label: &str,
    prompt: &[i32],
    max_new: usize,
) -> Result<SessionStats> {
    let mut session = DecodeSession::new(Arc::clone(kv), Arc::clone(model), policy, seq)?;
    session.prefill(prompt)?;
    print!("[{label:>6}] ");
    let stats = session.generate(max_new, Some(vocab::END), |info| {
        print!("{} ", vocab::detok(&[info.token]));
        let _ = std::io::stdout().flush();
        true
    })?;
    println!();
    println!(
        "[{label:>6}] {} tokens, {:.1}µs/token, mean budget {:.1}%, dense steps {}, kv pages {}",
        stats.steps,
        stats.decode_ns as f64 / 1e3 / stats.steps.max(1) as f64,
        100.0 * stats.mean_budget_fraction,
        stats.dense_steps,
        kv.occupancy().0,
    );
    Ok(stats)
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), false);
    args.init_thread_pool();
    let block = args.usize_or("block", 64);
    let prompt_len = args.usize_or("prompt-len", 2048);
    let max_new = args.usize_or("max-new", 48);

    let kv = SharedKv::new(
        KvConfig { total_pages: args.usize_or("pages", 4096), page_tokens: block },
        4,
        32,
    );
    let model = Arc::new(TinyLm::new(0xD0C0DE, 8, 4, 32, vocab::VOCAB_SIZE));
    let mut rng = Rng::new(args.u64_or("seed", 42));
    let mut prompt = vec![vocab::BOS];
    prompt.extend((1..prompt_len).map(|_| vocab::WORD0 + rng.below(64) as i32));

    let sparse_policy = DecodePolicy {
        dense_below: args.usize_or("dense-below", 1024),
        k_start: args.f64_or("k-start", 8.0),
        horizon: max_new.max(1),
        ..Default::default()
    };
    let sparse = run(&kv, &model, sparse_policy, 1, "stem", &prompt, max_new)?;
    let dense = run(&kv, &model, DecodePolicy::dense(), 2, "dense", &prompt, max_new)?;

    let (su, du) = (
        sparse.decode_ns as f64 / sparse.steps.max(1) as f64,
        dense.decode_ns as f64 / dense.steps.max(1) as f64,
    );
    println!("---");
    println!(
        "stem decode is {:.2}x dense ns/token at ctx {} (attending {:.0}% of the cache)",
        du / su,
        prompt_len,
        100.0 * sparse.mean_budget_fraction
    );
    Ok(())
}
