//! Quickstart: load the AOT artifacts, run one prompt through dense and
//! Stem prefill, and compare outputs + budget.
//!
//!   make artifacts && cargo run --release --example quickstart
//!
//! This is the smallest end-to-end path through the public API: manifest →
//! engine → prefill. No coordinator — see `serve_longcontext.rs` for the
//! full serving stack.

use anyhow::Result;

use stem::runtime::{Engine, ScalarValue};

fn main() -> Result<()> {
    let artifacts = stem::artifacts_dir();
    println!("loading artifacts from {}", artifacts.display());
    let engine = Engine::new(&artifacts)?;
    let man = engine.manifest();
    println!(
        "model: {} layers, d_model {}, {} q-heads / {} kv-heads, block {}",
        man.model.n_layers, man.model.d_model, man.model.n_heads, man.model.n_kv_heads,
        man.model.block
    );

    // a needle-in-haystack style prompt from the exported eval sets
    let n_ctx = 1024usize;
    let set = man
        .eval_sets
        .iter()
        .find(|e| e.suite == "ruler" && e.family == "needle" && e.n_ctx == n_ctx)
        .expect("needle eval set (run `make artifacts`)");
    let samples = stem::workload::load_eval_set(&man.root.join(&set.file))?;
    let sample = &samples[0];
    let mut ids = sample.ids.clone();
    ids.resize(n_ctx, 0);

    // dense reference
    let dense = engine.prefill("base", "prefill_dense", n_ctx, &ids, &[])?;

    // Stem at the serving defaults for this bucket
    let d = man.defaults_for(n_ctx)?;
    let scalars = [
        ScalarValue::F32(d.k_start as f32),
        ScalarValue::F32(d.mu as f32),
        ScalarValue::F32(d.beta as f32),
    ];
    let sparse = engine.prefill("base", "prefill_stem", n_ctx, &ids, &scalars)?;

    // compare
    let max_abs_diff = dense
        .logits
        .iter()
        .zip(&sparse.logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    let answer = &sample.ids[sample.answer_start..sample.answer_start + sample.answer_len];
    let argmax = |o: &stem::runtime::PrefillOutput, p: usize| -> i32 {
        let row = &o.logits[p * o.vocab..(p + 1) * o.vocab];
        row.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0 as i32
    };
    let correct = |o: &stem::runtime::PrefillOutput| -> usize {
        answer
            .iter()
            .enumerate()
            .filter(|(i, &t)| argmax(o, sample.answer_start + i - 1) == t)
            .count()
    };

    println!("\nprompt: {} tokens, answer span {} tokens", sample.ids.len(), answer.len());
    println!("dense : budget 100%, answer tokens correct {}/{}", correct(&dense), answer.len());
    println!(
        "stem  : budget {:>5.1}%, answer tokens correct {}/{}  (k_start={:.1} blocks, mu={}, beta={})",
        100.0 * sparse.budget_fraction,
        correct(&sparse),
        answer.len(),
        d.k_start,
        d.mu,
        d.beta
    );
    println!("max |dense - stem| logit diff: {max_abs_diff:.4}");
    Ok(())
}
