//! Budget planner: paper-scale projections from the analytic cost model.
//!
//!   cargo run --release --example budget_planner [-- --n 131072 --mu 0.7]
//!
//! For a target context length and decay ratio, prints (a) the Eq. (2)/(4)
//! pair-count budgets, (b) the per-position schedule's head/tail budgets,
//! (c) the Figure-1 H20 latency projection, and (d) the k_start needed to
//! hit a requested budget fraction — the planning loop an operator would
//! run before deploying Stem on real traffic.

use anyhow::Result;

use stem::sim::{method_cost, project_figure1, MethodCost, LLAMA31_8B};
use stem::sparse::schedule::{self, TpdConfig};
use stem::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(false);
    let n = args.usize_or("n", 131072);
    let mu = args.f64_or("mu", 0.7);
    let block = args.usize_or("block", 128);
    let target_budget = args.f64_or("target-budget", 0.0);
    let g = LLAMA31_8B;
    let nblk = n / block;
    let frac = if n <= 16384 { 0.2 } else { 0.1 };
    let k_start = args.f64_or("k-start", frac * nblk as f64);

    println!("=== Stem budget plan: N={n} ({nblk} blocks of {block}), k_start={k_start:.1}, mu={mu} ===\n");

    // (a) pair counts
    let c_dense = schedule::cost_dense(n);
    let c_uni = schedule::cost_uniform(n, k_start * block as f64);
    let c_dec = schedule::cost_decay(n, k_start * block as f64, mu);
    println!("causal pairs     : dense {c_dense:.3e}");
    println!("uniform top-k    : {c_uni:.3e}  ({:.1}%)", 100.0 * c_uni / c_dense);
    println!("TPD decay        : {c_dec:.3e}  ({:.1}%)", 100.0 * c_dec / c_dense);
    println!("decay saves      : {:.1}% vs uniform (Eq. 4 savings term)\n", 100.0 * (1.0 - c_dec / c_uni));

    // (b) schedule endpoints
    let cfg = TpdConfig { k_start, mu, ..Default::default() };
    let sched = schedule::block_budget_schedule(nblk, &cfg);
    println!(
        "schedule         : k(first)={} blocks, k(mid)={}, k(last)={} (k_end = mu*k_start = {:.1})",
        sched[0],
        sched[nblk / 2],
        sched[nblk - 1],
        mu * k_start
    );
    println!("k_avg            : {:.1} blocks ({:.1}% of mean causal width)\n",
        schedule::k_avg_blocks(nblk, &cfg),
        100.0 * schedule::k_avg_blocks(nblk, &cfg) / ((nblk + 1) as f64 / 2.0));

    // (c) whole-model FLOPs + H20 kernel projection
    for (name, m) in [
        ("dense", MethodCost::Dense),
        ("stem", MethodCost::Stem { k_start_blocks: k_start, mu }),
    ] {
        let c = method_cost(&g, n, m);
        println!(
            "{name:>6} whole-model: attn {:.2e} FLOPs + metric {:.2e} + linear {:.2e} (budget {:.1}%)",
            c.attn_flops, c.metric_flops, c.linear_flops, 100.0 * c.budget_fraction
        );
    }
    println!();
    if [16384usize, 32768, 65536, 131072].contains(&n) {
        for p in project_figure1(&[n]) {
            println!(
                "H20 per-layer kernel projection: {:<12} {:>7.0} ms kernel / {:>7.0} ms total",
                p.method, p.kernel_ms, p.total_ms
            );
        }
        println!();
    }

    // (d) inverse planning: k_start for a requested budget fraction
    if target_budget > 0.0 {
        let mut lo = 1.0f64;
        let mut hi = nblk as f64;
        for _ in 0..60 {
            let mid = 0.5 * (lo + hi);
            let cfg = TpdConfig { k_start: mid, mu, ..Default::default() };
            let got = schedule::k_avg_blocks(nblk, &cfg) / ((nblk + 1) as f64 / 2.0);
            if got < target_budget {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        println!(
            "to hit budget {:.0}%: k_start = {:.1} blocks ({:.1}% of N_blk)",
            100.0 * target_budget,
            hi,
            100.0 * hi / nblk as f64
        );
    }
    Ok(())
}
