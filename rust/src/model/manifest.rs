//! Artifact manifest: the contract between the python compile path and the
//! rust request path. Parses `artifacts/manifest.json` (written by
//! `python/compile/aot.py`) into typed structs.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// The compiled model's geometry and Stem keep-set parameters.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    /// Vocabulary size.
    pub vocab_size: usize,
    /// Model width.
    pub d_model: usize,
    /// Transformer layers.
    pub n_layers: usize,
    /// Query heads per layer.
    pub n_heads: usize,
    /// K/V heads per layer (GQA).
    pub n_kv_heads: usize,
    /// Feed-forward inner width.
    pub d_ff: usize,
    /// Attention block size (= KV page tokens).
    pub block: usize,
    /// Leading blocks always kept by the schedule.
    pub init_keep: usize,
    /// Trailing blocks always kept by the schedule.
    pub local_keep: usize,
    /// Hard floor on kept blocks per row.
    pub min_total: usize,
    /// Head dimension.
    pub d_head: usize,
}

/// One runtime scalar a compiled module takes (name + dtype).
#[derive(Debug, Clone)]
pub struct ScalarSpec {
    /// Scalar name as declared by the compile path.
    pub name: String,
    /// `true` for f32 scalars, `false` for i32.
    pub is_f32: bool,
}

/// One compiled HLO module: a (kind, context-bucket) prefill graph.
#[derive(Debug, Clone)]
pub struct ModuleInfo {
    /// Unique module name.
    pub name: String,
    /// Module kind (e.g. `"prefill_stem"`, `"diag_dense"`).
    pub kind: String,
    /// Padded context length the graph was lowered at.
    pub n_ctx: usize,
    /// HLO text file, relative to the artifacts root.
    pub file: String,
    /// Runtime scalars, in call order.
    pub scalars: Vec<ScalarSpec>,
    /// Named outputs the module returns.
    pub outputs: Vec<String>,
}

impl ModuleInfo {
    /// The attention-method part of the kind (prefix stripped).
    pub fn method(&self) -> &str {
        self.kind
            .strip_prefix("prefill_")
            .or_else(|| self.kind.strip_prefix("diag_"))
            .or_else(|| self.kind.strip_prefix("decode_"))
            .unwrap_or(&self.kind)
    }

    /// Whether this is a diagnostic module (returns hidden states).
    pub fn is_diag(&self) -> bool {
        self.kind.starts_with("diag_")
    }

    /// Whether this is a per-step decode module (`decode_step` buckets
    /// executed by `decode::EngineBackend`, not the prefill lane).
    pub fn is_decode(&self) -> bool {
        self.kind.starts_with("decode_")
    }
}

/// Declared shape of one weight tensor.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    /// Parameter name.
    pub name: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
}

/// Per-bucket serving defaults the compile path recommends.
#[derive(Debug, Clone)]
pub struct ServingDefaults {
    /// Context bucket these defaults apply to.
    pub n_ctx: usize,
    /// Blocks in that bucket.
    pub n_blocks: usize,
    /// Stem starting block budget.
    pub k_start: f64,
    /// Stem decay floor multiplier.
    pub mu: f64,
    /// OAM value-magnitude weight.
    pub beta: f64,
    /// Budget-matched uniform k (Eq. 4 comparison).
    pub k_uni_matched: f64,
    /// Streaming baseline: sink blocks.
    pub sink_blocks: i64,
    /// Streaming baseline: local blocks.
    pub local_blocks: i64,
    /// XAttention threshold.
    pub xattn_tau: f64,
    /// MInference vertical stripes.
    pub minf_vertical: i64,
    /// MInference slash diagonals.
    pub minf_slash: i64,
    /// FlexPrefill coverage parameter.
    pub flex_gamma: f64,
    /// FlexPrefill entropy threshold.
    pub flex_entropy: f64,
}

/// One eval-set file listed in the manifest.
#[derive(Debug, Clone)]
pub struct EvalSetInfo {
    /// Task family (e.g. `"qa"`, `"ruler"`).
    pub family: String,
    /// Suite the family belongs to (e.g. `"longbench"`).
    pub suite: String,
    /// Context bucket the samples target.
    pub n_ctx: usize,
    /// JSON file, relative to the artifacts root.
    pub file: String,
    /// Samples in the file.
    pub count: usize,
}

/// The parsed artifacts manifest (see module docs).
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Artifacts directory the manifest was loaded from.
    pub root: PathBuf,
    /// Model geometry + Stem keep-set parameters.
    pub model: ModelConfig,
    /// Declared weight-tensor shapes.
    pub param_spec: Vec<ParamSpec>,
    /// Checkpoint name → weights file, as listed.
    pub weights: Vec<(String, String)>,
    /// Compiled modules (kind × bucket).
    pub modules: Vec<ModuleInfo>,
    /// Eval sets shipped with the artifacts.
    pub eval_sets: Vec<EvalSetInfo>,
    /// Per-bucket serving defaults, sorted by `n_ctx`.
    pub defaults: Vec<ServingDefaults>,
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    j.get(key).and_then(Json::as_usize).ok_or_else(|| anyhow!("manifest: missing usize `{key}`"))
}

fn req_f64(j: &Json, key: &str) -> Result<f64> {
    j.get(key).and_then(Json::as_f64).ok_or_else(|| anyhow!("manifest: missing f64 `{key}`"))
}

fn req_str(j: &Json, key: &str) -> Result<String> {
    Ok(j.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("manifest: missing str `{key}`"))?
        .to_string())
}

impl Manifest {
    /// Parse `artifacts/manifest.json` under `artifacts_dir`.
    pub fn load(artifacts_dir: &Path) -> Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing manifest: {e}"))?;

        let m = j.get("model").ok_or_else(|| anyhow!("manifest: missing model"))?;
        let model = ModelConfig {
            vocab_size: req_usize(m, "vocab_size")?,
            d_model: req_usize(m, "d_model")?,
            n_layers: req_usize(m, "n_layers")?,
            n_heads: req_usize(m, "n_heads")?,
            n_kv_heads: req_usize(m, "n_kv_heads")?,
            d_ff: req_usize(m, "d_ff")?,
            block: req_usize(m, "block")?,
            init_keep: req_usize(m, "init_keep")?,
            local_keep: req_usize(m, "local_keep")?,
            min_total: req_usize(m, "min_total")?,
            d_head: req_usize(&j, "d_head")?,
        };

        let param_spec = j
            .get("param_spec")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest: param_spec"))?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: req_str(p, "name")?,
                    shape: p
                        .get("shape")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("param shape"))?
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let weights = j
            .get("weights")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest: weights"))?
            .iter()
            .map(|(k, v)| (k.clone(), v.as_str().unwrap_or_default().to_string()))
            .collect();

        let modules = j
            .get("modules")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest: modules"))?
            .iter()
            .map(|mo| {
                Ok(ModuleInfo {
                    name: req_str(mo, "name")?,
                    kind: req_str(mo, "kind")?,
                    n_ctx: req_usize(mo, "n_ctx")?,
                    file: req_str(mo, "file")?,
                    scalars: mo
                        .get("scalars")
                        .and_then(Json::as_arr)
                        .unwrap_or(&[])
                        .iter()
                        .map(|s| ScalarSpec {
                            name: s.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
                            is_f32: s.get("dtype").and_then(Json::as_str) == Some("f32"),
                        })
                        .collect(),
                    outputs: mo
                        .get("outputs")
                        .and_then(Json::as_arr)
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|o| o.as_str().map(str::to_string))
                        .collect(),
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let eval_sets = j
            .get("eval_sets")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .map(|e| {
                Ok(EvalSetInfo {
                    family: req_str(e, "family")?,
                    suite: req_str(e, "suite")?,
                    n_ctx: req_usize(e, "n_ctx")?,
                    file: req_str(e, "file")?,
                    count: req_usize(e, "count")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let mut defaults = vec![];
        if let Some(obj) = j.get("serving_defaults").and_then(Json::as_obj) {
            for (_, d) in obj {
                defaults.push(ServingDefaults {
                    n_ctx: req_usize(d, "n_ctx")?,
                    n_blocks: req_usize(d, "n_blocks")?,
                    k_start: req_f64(d, "k_start")?,
                    mu: req_f64(d, "mu")?,
                    beta: req_f64(d, "beta")?,
                    k_uni_matched: req_f64(d, "k_uni_matched")?,
                    sink_blocks: d.path("streaming.sink_blocks").and_then(Json::as_i64).unwrap_or(1),
                    local_blocks: d.path("streaming.local_blocks").and_then(Json::as_i64).unwrap_or(3),
                    xattn_tau: d.path("xattn.tau").and_then(Json::as_f64).unwrap_or(0.9),
                    minf_vertical: d.path("minference.n_vertical").and_then(Json::as_i64).unwrap_or(2),
                    minf_slash: d.path("minference.n_slash").and_then(Json::as_i64).unwrap_or(2),
                    flex_gamma: d.path("flexprefill.gamma").and_then(Json::as_f64).unwrap_or(0.9),
                    flex_entropy: d
                        .path("flexprefill.entropy_thresh")
                        .and_then(Json::as_f64)
                        .unwrap_or(0.35),
                });
            }
        }
        defaults.sort_by_key(|d| d.n_ctx);

        Ok(Manifest {
            root: artifacts_dir.to_path_buf(),
            model,
            param_spec,
            weights,
            modules,
            eval_sets,
            defaults,
        })
    }

    /// The compiled module serving `(kind, n_ctx)` exactly.
    pub fn module(&self, kind: &str, n_ctx: usize) -> Result<&ModuleInfo> {
        self.modules
            .iter()
            .find(|m| m.kind == kind && m.n_ctx == n_ctx)
            .ok_or_else(|| anyhow!("no module {kind}@{n_ctx} in manifest"))
    }

    /// Smallest *prefill* bucket whose n_ctx >= the request length
    /// (diag and decode_step modules have their own selection paths).
    pub fn bucket_for(&self, n_tokens: usize) -> Option<usize> {
        let mut buckets: Vec<usize> = self
            .modules
            .iter()
            .filter(|m| !m.is_diag() && !m.is_decode())
            .map(|m| m.n_ctx)
            .collect();
        buckets.sort();
        buckets.dedup();
        buckets.into_iter().find(|&b| b >= n_tokens)
    }

    /// The serving defaults declared for bucket `n_ctx`.
    pub fn defaults_for(&self, n_ctx: usize) -> Result<&ServingDefaults> {
        self.defaults
            .iter()
            .find(|d| d.n_ctx == n_ctx)
            .ok_or_else(|| anyhow!("no serving defaults for n_ctx={n_ctx}"))
    }

    /// Absolute path of the named checkpoint's weights file.
    pub fn weights_path(&self, name: &str) -> Result<PathBuf> {
        let f = self
            .weights
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.clone())
            .ok_or_else(|| anyhow!("no weights `{name}`"))?;
        Ok(self.root.join(f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        // synthetic manifest check happens in integration tests with real
        // artifacts; here just the bucket logic on a hand-built manifest.
        let mk = |n| ModuleInfo {
            name: format!("prefill_stem_{n}"),
            kind: "prefill_stem".into(),
            n_ctx: n,
            file: String::new(),
            scalars: vec![],
            outputs: vec![],
        };
        let man = Manifest {
            root: PathBuf::new(),
            model: ModelConfig {
                vocab_size: 96,
                d_model: 256,
                n_layers: 8,
                n_heads: 8,
                n_kv_heads: 4,
                d_ff: 512,
                block: 64,
                init_keep: 1,
                local_keep: 2,
                min_total: 3,
                d_head: 32,
            },
            param_spec: vec![],
            weights: vec![],
            modules: vec![
                mk(512),
                mk(1024),
                mk(2048),
                // a decode bucket must never satisfy prefill selection
                ModuleInfo {
                    name: "decode_step_4096".into(),
                    kind: "decode_step".into(),
                    n_ctx: 4096,
                    file: String::new(),
                    scalars: vec![],
                    outputs: vec![],
                },
            ],
            eval_sets: vec![],
            defaults: vec![],
        };
        assert_eq!(man.bucket_for(100), Some(512));
        assert_eq!(man.bucket_for(512), Some(512));
        assert_eq!(man.bucket_for(513), Some(1024));
        assert_eq!(man.bucket_for(4096), None, "decode buckets are not prefill buckets");
        assert!(man.module("decode_step", 4096).unwrap().is_decode());
        assert_eq!(man.module("decode_step", 4096).unwrap().method(), "step");
        assert!(!man.module("prefill_stem", 512).unwrap().is_decode());
    }
}
