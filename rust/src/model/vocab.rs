//! Token vocabulary mirror of `python/compile/tasks.py` (display +
//! workload synthesis on the serving path).

/// Padding token.
pub const PAD: i32 = 0;
/// Beginning-of-sequence token.
pub const BOS: i32 = 1;
/// Separator token.
pub const SEP: i32 = 2;
/// Query-section marker.
pub const QUERY: i32 = 3;
/// Answer marker.
pub const AMARK: i32 = 4;
/// Document marker.
pub const DOC: i32 = 5;
/// Key marker (KV tasks).
pub const KEY: i32 = 6;
/// "is" connective (KV tasks).
pub const IS: i32 = 7;
/// Tag marker.
pub const TAG: i32 = 8;
/// Function marker (code-ish tasks).
pub const FN: i32 = 9;
/// Reference marker.
pub const REF: i32 = 10;
/// End-of-generation token.
pub const END: i32 = 11;
/// First content-word id; words are `WORD0 + n`.
pub const WORD0: i32 = 16;
/// Total vocabulary size.
pub const VOCAB_SIZE: usize = 96;

/// Render token ids as a human-readable string.
pub fn detok(ids: &[i32]) -> String {
    ids.iter()
        .map(|&t| match t {
            PAD => "<pad>".to_string(),
            BOS => "<bos>".to_string(),
            SEP => ";".to_string(),
            QUERY => "<q>".to_string(),
            AMARK => "=>".to_string(),
            DOC => "<doc>".to_string(),
            KEY => "<key>".to_string(),
            IS => "<is>".to_string(),
            TAG => "<tag>".to_string(),
            FN => "<fn>".to_string(),
            REF => "<ref>".to_string(),
            END => "<end>".to_string(),
            t if t >= WORD0 => format!("w{}", t - WORD0),
            t => format!("?{t}"),
        })
        .collect::<Vec<_>>()
        .join(" ")
}

#[cfg(test)]
mod tests {
    #[test]
    fn detok_words() {
        assert_eq!(super::detok(&[1, 16, 4, 17]), "<bos> w0 => w1");
    }
}
