//! Model metadata: manifest parsing and the token vocabulary mirror.

pub mod manifest;
pub mod vocab;

pub use manifest::{Manifest, ModelConfig, ModuleInfo, ServingDefaults};
