//! `stem` — the leader binary: serving coordinator + experiment drivers.
//!
//! Subcommands (each regenerates one paper artifact; DESIGN.md §6):
//!   serve      boot the coordinator and serve an open-loop trace
//!   generate   stream tokens from a decode session (no artifacts needed)
//!   table1     SAM vs OAM sparse loss at depths (Table 1)
//!   table2     LongBench proxy accuracy × method (Table 2)
//!   table3     Stem on the training-based sparse checkpoint (Table 3)
//!   table4     RULER proxy accuracy × length (Table 4)
//!   table5     Uniform / +TPD / +OAM ablation (Table 5)
//!   figure1    latency projection on H20 geometry (Figure 1, analytic)
//!   figure3    positional-sensitivity diagnostic (Figure 3)
//!   figure5    μ / β sweeps (Figure 5)
//!   cost       cost-model report for arbitrary (N, k_start, μ)
//!   selftest   load artifacts, compile one module, check goldens
//!
//! Common flags: --artifacts <dir>  --limit <n per eval set>  --workers <n>
//!               --buckets 512,1024,2048  --quiet

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use stem::coordinator::{Coordinator, CoordinatorConfig, Method};
use stem::eval::tables;
use stem::eval::Evaluator;
use stem::runtime::Engine;
use stem::sim::{method_cost, MethodCost};
use stem::sparse::schedule;
use stem::util::cli::Args;
use stem::util::rng::Rng;
use stem::workload::{load_eval_set, poisson_trace};

const USAGE: &str = "\
stem — Stem sparse-attention serving system (paper reproduction)

USAGE: stem <subcommand> [flags]

  serve     [--requests N] [--rps R] [--method stem|dense|...] [--mix]
            [--prefix-mode exact|radix] [--deadline-ms MS]
            [--metrics-out FILE] [--metrics-interval-ms N]
            [--decode-backend tiny|engine] [--chunk-tokens N] [--seed S]
  generate  [--prompt 1,16,17 | --prompt-len N] [--max-new N] [--dense]
            [--fanout N] [--spec N] [--k-start K] [--mu MU] [--sink S]
            [--recent R] [--dense-below TOKENS] [--block B] [--pages P]
            [--seed S] [--decode-backend tiny|engine]
  table1    [--limit N]
  table2    [--limit N] [--buckets 512,1024,2048]
  table3    [--limit N] [--buckets ...] [--native-k K]
  table4    [--limit N] [--buckets ...]
  table5    [--limit N] [--buckets ...]
  table6    [--max-new N]   (decode backends: µs/token + spec per backend)
  figure1
  figure3   [--limit N]
  figure5   [--limit N] [--buckets ...]
  cost      [--n N] [--k-start K] [--mu MU] [--block B]
  selftest

flags: --artifacts DIR  --workers N  --threads N  --limit N  --quiet
       --decode-backend tiny|engine  (which DecodeBackend serves decode
       steps: the in-process TinyLm projection core, or compiled
       per-step decode_step modules through the runtime; default tiny)
       --prefix-mode exact|radix  (how the coordinator matches cached
       prompt prefixes: byte-identical prompts only, or token-granular
       longest-common-prefix reuse with partial-page forks; default radix)
       --deadline-ms MS  (serve: per-request TTL — queued work past it is
       shed with a typed error instead of executed; default none)
       --chunk-tokens N  (serve: split prompt ingest into N-token chunks
       interleaved with decode rounds so long prompts stop head-of-line
       blocking decode; 0 = monolithic one-shot ingest; default 2048)
       --metrics-out FILE  (serve: write the structured metrics snapshot
       as JSON to FILE and Prometheus text to FILE.prom, every
       --metrics-interval-ms (default 1000) and once more at shutdown)
       --simd auto|scalar|wide  (pin the sparse-kernel SIMD dispatch arm;
       STEM_SIMD does the same for non-CLI entry points; default auto =
       widest supported lanes, with a guaranteed scalar fallback)
       (--threads / STEM_THREADS size the pure-rust sparse-core pool;
       STEM_FAULTS=seed=S,kv=R,exec=R,step=R,stall=R,stall_us=U,ingest=R
       arms deterministic fault injection in the coordinator for chaos
       runs; `ingest` fires at chunked-prefill chunk boundaries)
";

fn main() {
    let args = Args::from_env(true);
    if args.flag("quiet") {
        stem::util::set_log_level(1);
    }
    // size the sparse-core pool before any kernel runs (--threads /
    // STEM_THREADS / available cores)
    args.init_thread_pool();
    // pin the SIMD arm before any kernel runs (--simd / STEM_SIMD)
    if let Err(e) = args.init_simd() {
        eprintln!("error: {e}");
        std::process::exit(2);
    }
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn artifacts_from(args: &Args) -> PathBuf {
    args.get("artifacts").map(PathBuf::from).unwrap_or_else(stem::artifacts_dir)
}

fn boot(args: &Args) -> Result<(Arc<Coordinator>, Evaluator)> {
    let dir = artifacts_from(args);
    let engine = Arc::new(Engine::new(&dir)?);
    let mut cfg = CoordinatorConfig::default();
    if let Some(w) = args.get("workers") {
        cfg.workers = w.parse().map_err(|_| anyhow!("--workers must be an integer"))?;
    }
    if let Some(pm) = args.get("prefix-mode") {
        cfg.prefix_mode = pm.parse().map_err(|e: String| anyhow!(e))?;
    }
    if let Some(c) = args.get("chunk-tokens") {
        cfg.chunk_tokens = c.parse().map_err(|_| anyhow!("--chunk-tokens must be an integer"))?;
    }
    if let Some(b) = args.get("decode-backend") {
        cfg.decode_backend = stem::decode::DecodeBackendKind::parse(b)
            .ok_or_else(|| anyhow!("--decode-backend must be `tiny` or `engine`"))?;
    }
    let coordinator = Arc::new(Coordinator::new(engine, cfg));
    let limit = args.usize_or("limit", 12);
    Ok((Arc::clone(&coordinator), Evaluator { coordinator, limit }))
}

fn buckets_from(args: &Args, default: &[usize]) -> Vec<usize> {
    match args.get("buckets") {
        Some(v) => v.split(',').filter_map(|s| s.trim().parse().ok()).collect(),
        None => default.to_vec(),
    }
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("serve") => serve(args),
        Some("generate") => generate(args),
        Some("table1") => {
            let (coord, _) = boot(args)?;
            println!("{}", tables::table1(&coord, args.usize_or("limit", 8))?);
            Ok(())
        }
        Some("table2") => {
            let (_, ev) = boot(args)?;
            let b = buckets_from(args, &[512, 1024, 2048]);
            println!("{}", tables::table2(&ev, &b)?);
            Ok(())
        }
        Some("table3") => {
            let (_, ev) = boot(args)?;
            let b = buckets_from(args, &[512, 1024, 2048]);
            let native_k = args.f64_or("native-k", 6.0) as f32;
            println!("{}", tables::table3(&ev, &b, native_k)?);
            Ok(())
        }
        Some("table4") => {
            let (_, ev) = boot(args)?;
            let b = buckets_from(args, &[512, 1024, 2048]);
            println!("{}", tables::table4(&ev, &b)?);
            Ok(())
        }
        Some("table5") => {
            let (_, ev) = boot(args)?;
            let b = buckets_from(args, &[512, 1024, 2048]);
            println!("{}", tables::table5(&ev, &b)?);
            Ok(())
        }
        Some("table6") => {
            let (coord, _) = boot(args)?;
            println!("{}", tables::decode_table(&coord, args.usize_or("max-new", 32))?);
            Ok(())
        }
        Some("figure1") => {
            println!("{}", tables::figure1());
            Ok(())
        }
        Some("figure3") => {
            let (coord, _) = boot(args)?;
            println!("{}", tables::figure3(&coord, args.usize_or("limit", 6))?);
            Ok(())
        }
        Some("figure5") => {
            let (_, ev) = boot(args)?;
            let b = buckets_from(args, &[1024]);
            println!("{}", tables::figure5(&ev, &b)?);
            Ok(())
        }
        Some("cost") => cost_report(args),
        Some("selftest") => selftest(args),
        _ => {
            eprint!("{USAGE}");
            Ok(())
        }
    }
}

/// `stem serve`: boot the full stack and push an open-loop Poisson trace
/// through it, then print the serving report (the e2e driver behind
/// examples/serve_longcontext.rs).
fn serve(args: &Args) -> Result<()> {
    let (coord, _) = boot(args)?;
    let man = coord.manifest().clone();
    let n_requests = args.usize_or("requests", 64);
    let rps = args.f64_or("rps", 8.0);
    let method_name = args.str_or("method", "stem");
    let mix = args.flag("mix");
    // --deadline-ms: per-request TTL measured from submission
    let deadline_ms: Option<u64> = match args.get("deadline-ms") {
        Some(v) => Some(v.parse().map_err(|_| anyhow!("--deadline-ms must be an integer"))?),
        None => None,
    };
    // --metrics-out FILE: periodic structured metrics export (JSON at
    // FILE, Prometheus text at FILE.prom) plus a final snapshot once the
    // trace drains — the scrape-free monitoring path (obs::snapshot)
    let metrics_out: Option<PathBuf> = args.get("metrics-out").map(PathBuf::from);
    let metrics_interval = Duration::from_millis(args.u64_or("metrics-interval-ms", 1000));
    let stop_exporter = Arc::new(AtomicBool::new(false));
    let exporter = metrics_out.clone().map(|path| {
        let coord = Arc::clone(&coord);
        let stop = Arc::clone(&stop_exporter);
        std::thread::spawn(move || {
            // tick in small slices so shutdown joins promptly even with
            // a long export interval
            const TICK: Duration = Duration::from_millis(20);
            let mut since = Duration::ZERO;
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(TICK);
                since += TICK;
                if since >= metrics_interval {
                    since = Duration::ZERO;
                    if let Err(e) = write_metrics(&coord, &path) {
                        eprintln!("[stem:serve] metrics export failed: {e}");
                    }
                }
            }
        })
    });

    // sample pool: every longbench eval set, mixed families and lengths
    let mut pool = vec![];
    for set in &man.eval_sets {
        if set.suite == "longbench" {
            pool.extend(load_eval_set(&man.root.join(&set.file))?);
        }
    }
    if pool.is_empty() {
        return Err(anyhow!("no eval sets in manifest — rerun `make artifacts`"));
    }
    pre_warm(&coord, &method_name)?;

    let trace = poisson_trace(args.u64_or("seed", 42), n_requests, rps, pool.len());
    let start = Instant::now();
    let mut rxs = vec![];
    for item in &trace {
        // open-loop: wait until the arrival offset
        let now = start.elapsed();
        if item.at > now {
            std::thread::sleep(item.at - now);
        }
        let sample = &pool[item.sample];
        let bucket = man
            .bucket_for(sample.ids.len())
            .ok_or_else(|| anyhow!("sample longer than every bucket"))?;
        let defaults = man.defaults_for(bucket)?;
        let method = if method_name == "dense" || (mix && item.sample % 2 == 1) {
            Method::Dense
        } else {
            Evaluator::method_for(&method_name, defaults)
        };
        let deadline = deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms));
        match coord.submit_with_deadline("base", method, sample.ids.clone(), false, deadline) {
            Ok(rx) => rxs.push((rx, item.sample)),
            Err(e) => eprintln!("[stem:serve] rejected: {e}"),
        }
    }
    let mut ok = 0usize;
    let mut em = 0usize;
    let mut shed = 0usize;
    for (rx, si) in rxs {
        match rx.recv().map_err(|_| anyhow!("response channel closed"))? {
            Ok(resp) => {
                let score = stem::eval::score_sample(&resp, &pool[si]);
                ok += 1;
                em += score.exact_match as usize;
            }
            // deadline sheds are an expected outcome under --deadline-ms,
            // not a driver failure
            Err(e) => {
                shed += 1;
                if deadline_ms.is_none() {
                    eprintln!("[stem:serve] failed: {e}");
                }
            }
        }
    }
    let wall = start.elapsed();
    stop_exporter.store(true, Ordering::Relaxed);
    if let Some(h) = exporter {
        let _ = h.join();
    }
    println!("{}", coord.report());
    println!(
        "served {ok}/{n_requests} requests ({shed} shed) in {:.2}s ({:.1} req/s), exact-match {:.1}%",
        wall.as_secs_f64(),
        ok as f64 / wall.as_secs_f64(),
        100.0 * em as f64 / ok.max(1) as f64
    );
    // final artifact: one last snapshot after every response has landed
    if let Some(path) = &metrics_out {
        write_metrics(&coord, path)?;
        println!("metrics written to {} (+ .prom)", path.display());
    }
    Ok(())
}

/// Write the coordinator's current metrics snapshot to `path` (JSON) and
/// `path.prom` (Prometheus text exposition).
fn write_metrics(coord: &Coordinator, path: &Path) -> Result<()> {
    let snap = coord.snapshot();
    std::fs::write(path, format!("{}\n", snap.to_json()))?;
    let mut prom = path.as_os_str().to_owned();
    prom.push(".prom");
    std::fs::write(PathBuf::from(prom), snap.to_prometheus())?;
    Ok(())
}

fn pre_warm(coord: &Arc<Coordinator>, method: &str) -> Result<()> {
    let sparse_kind = match method {
        "stem" => "prefill_stem",
        "streaming" => "prefill_streaming",
        "xattn" => "prefill_xattn",
        "minference" => "prefill_minference",
        "flexprefill" => "prefill_flexprefill",
        _ => "prefill_stem",
    };
    let kinds: Vec<&str> =
        if method == "dense" { vec!["prefill_dense"] } else { vec!["prefill_dense", sparse_kind] };
    match coord.engine() {
        Some(engine) => engine.warmup(&kinds, &[512, 1024, 2048]),
        // synthetic backends have nothing to JIT
        None => Ok(()),
    }
}

/// `stem generate`: stream tokens from a decode session against the
/// shared paged KV store — the pure-rust decode stack end to end (policy
/// → selection → single-query kernel → paged append), no artifacts
/// needed. With `--fanout N` the prompt is ingested once and N forked
/// continuations (each steered by a distinct divergence token) decode
/// off the shared refcounted prefix.
fn generate(args: &Args) -> Result<()> {
    use std::sync::Arc;
    use stem::coordinator::kv_cache::KvConfig;
    use stem::decode::{
        DecodeBackend, DecodeBackendKind, DecodePolicy, DecodeSession, EngineBackend, SharedKv,
        TinyLm,
    };
    use stem::model::vocab;
    use stem::runtime::SyntheticEngine;

    let block = args.usize_or("block", 64);
    let pages = args.usize_or("pages", 4096);
    let max_new = args.usize_or("max-new", 64);
    let seed = args.u64_or("seed", 42);
    let fanout = args.usize_or("fanout", 1);
    let (h, hk, dh) = (
        args.usize_or("heads", 8),
        args.usize_or("kv-heads", 4),
        args.usize_or("dh", 32),
    );

    let prompt: Vec<i32> = match args.get("prompt") {
        Some(spec) => spec.split(',').filter_map(|t| t.trim().parse().ok()).collect(),
        None => {
            // synthetic prompt: BOS + seeded word salad
            let n = args.usize_or("prompt-len", 512);
            let mut r = Rng::new(seed);
            let mut p = vec![vocab::BOS];
            p.extend((1..n).map(|_| vocab::WORD0 + r.below(64) as i32));
            p
        }
    };

    let mut policy = if args.flag("dense") {
        DecodePolicy::dense()
    } else {
        DecodePolicy {
            dense_below: args.usize_or("dense-below", 1024),
            k_start: args.f64_or("k-start", 8.0),
            mu: args.f64_or("mu", 0.7),
            horizon: max_new.max(1),
            sink_blocks: args.usize_or("sink", 1),
            recent_blocks: args.usize_or("recent", 2),
            ..Default::default()
        }
    };
    // --spec N: draft N tokens per round with the cheap draft policy and
    // verify them batched under the policy above — same output stream,
    // fewer serving-attention passes per token
    policy.spec_gamma = args.usize_or("spec", 0);
    policy.validate().map_err(|e| anyhow!("invalid policy: {e}"))?;

    let backend_kind = match args.get("decode-backend") {
        Some(b) => DecodeBackendKind::parse(b)
            .ok_or_else(|| anyhow!("--decode-backend must be `tiny` or `engine`"))?,
        None => DecodeBackendKind::Tiny,
    };
    let kv = SharedKv::new(KvConfig { total_pages: pages, page_tokens: block }, hk, dh);
    let model: Arc<dyn DecodeBackend> = match backend_kind {
        DecodeBackendKind::Tiny => {
            Arc::new(TinyLm::new(0xD0C0DE, h, hk, dh, vocab::VOCAB_SIZE))
        }
        DecodeBackendKind::Engine => {
            // Compiled per-step decode. With real artifacts present the
            // coordinator path (`stem serve --decode-backend engine`)
            // exercises PJRT modules; here `generate` stays artifact-free
            // by serving the decode_step modules from the synthetic
            // engine at the CLI geometry, with context buckets sized to
            // cover the whole stream.
            let mut m = SyntheticEngine::tiny_model();
            m.n_heads = h;
            m.n_kv_heads = hk;
            m.d_head = dh;
            m.d_model = h * dh;
            m.block = block;
            let need = prompt.len() + max_new + 2;
            let mut buckets = vec![];
            let mut b = 512usize;
            loop {
                buckets.push(b);
                if b >= need {
                    break;
                }
                b *= 2;
            }
            let engine = Arc::new(SyntheticEngine::with_model(m, &buckets));
            Arc::new(EngineBackend::new(engine, "base")?)
        }
    };
    println!("decode backend: {}", model.name());
    let mut session = DecodeSession::new(Arc::clone(&kv), model, policy, 1)?;

    let t0 = Instant::now();
    session.prefill(&prompt)?;
    let ingest = t0.elapsed();
    let prefix_pages = kv.pool().map(|g| g.used_pages()).unwrap_or(0);
    println!(
        "ingested {} prompt tokens in {:.1}ms ({prefix_pages} pages)",
        prompt.len(),
        ingest.as_secs_f64() * 1e3,
    );

    if fanout > 1 {
        return generate_fanout(&kv, session, fanout, max_new, prefix_pages);
    }

    let quiet = args.flag("quiet");
    let stats = session.generate(max_new, Some(vocab::END), |info| {
        if !quiet {
            println!(
                "step {:>4}  tok {:>3} {:<8} ctx {:>6}  budget {:>5.1}%{}  {:>8.1}µs",
                info.step,
                info.token,
                vocab::detok(&[info.token]),
                info.n_ctx,
                100.0 * info.budget_fraction,
                if info.dense { " (dense)" } else { "        " },
                info.step_ns as f64 / 1e3,
            );
        }
        true
    })?;

    let (used, total, _) = kv.occupancy();
    println!("---");
    println!("stream: {}", vocab::detok(&stats.tokens));
    println!(
        "{} tokens in {:.1}ms ({:.1}µs/token) | dense steps {} | mean budget {:.1}% | kv {used}/{total} pages",
        stats.steps,
        stats.decode_ns as f64 / 1e6,
        stats.decode_ns as f64 / 1e3 / stats.steps.max(1) as f64,
        stats.dense_steps,
        100.0 * stats.mean_budget_fraction,
    );
    if stats.spec.rounds > 0 {
        println!(
            "spec: {} rounds, {} drafted, {} accepted ({:.0}% acceptance), {:.2} tokens/round",
            stats.spec.rounds,
            stats.spec.drafted,
            stats.spec.accepted,
            100.0 * stats.spec.acceptance_rate(),
            stats.spec.tokens_per_round(),
        );
    }
    Ok(())
}

/// `stem generate --fanout N`: serve N divergent continuations off the
/// one ingested prefix — fork the root session per branch, steer each
/// with a distinct divergence token, decode, and report the page savings
/// vs. N independent sessions.
fn generate_fanout(
    kv: &std::sync::Arc<stem::decode::SharedKv>,
    root: stem::decode::DecodeSession,
    fanout: usize,
    max_new: usize,
    prefix_pages: usize,
) -> Result<()> {
    use stem::model::vocab;

    let t0 = Instant::now();
    let mut total_tokens = 0usize;
    let mut total_ns = 0u64;
    let mut spec = stem::decode::SpecStats::default();
    // keep every branch alive so the page report shows true fan-out
    // residency (shared prefix counted once + per-branch CoW tails)
    let mut branches = Vec::with_capacity(fanout);
    for i in 0..fanout {
        let mut branch = root.fork(2 + i as u64)?;
        // distinct steering token per branch so the streams diverge
        branch.prefill(&[vocab::WORD0 + (i % 40) as i32])?;
        branches.push(branch);
    }
    for (i, branch) in branches.iter_mut().enumerate() {
        let stats = branch.generate(max_new, Some(vocab::END), |_| true)?;
        println!(
            "[branch {i}] {:<48} ({} tokens, {:.1}µs/token, budget {:.1}%)",
            vocab::detok(&stats.tokens),
            stats.steps,
            stats.decode_ns as f64 / 1e3 / stats.steps.max(1) as f64,
            100.0 * stats.mean_budget_fraction,
        );
        total_tokens += stats.steps;
        total_ns += stats.decode_ns;
        spec.merge(&stats.spec);
    }
    let wall = t0.elapsed();
    let (used, total, _) = kv.occupancy();
    let independent_pages = fanout * (prefix_pages + 1);
    println!("---");
    println!(
        "fanout {fanout}: {total_tokens} tokens in {:.1}ms ({:.1}µs/token decode) | kv {used}/{total} pages now",
        wall.as_secs_f64() * 1e3,
        total_ns as f64 / 1e3 / total_tokens.max(1) as f64,
    );
    println!(
        "shared prefix: {prefix_pages} pages ingested once vs ~{independent_pages} for {fanout} independent sessions",
    );
    if spec.rounds > 0 {
        println!(
            "spec: {} rounds across branches, {:.0}% acceptance, {:.2} tokens/round",
            spec.rounds,
            100.0 * spec.acceptance_rate(),
            spec.tokens_per_round(),
        );
    }
    Ok(())
}

/// `stem cost`: print the Eq. (2)/(4)/(8) budget/FLOP breakdown for an
/// arbitrary configuration (the planner behind examples/budget_planner.rs).
fn cost_report(args: &Args) -> Result<()> {
    let n = args.usize_or("n", 131072);
    let block = args.usize_or("block", 128);
    let nblk = (n / block).max(1);
    let k_start = args.f64_or("k-start", 0.1 * nblk as f64);
    let mu = args.f64_or("mu", 0.7);
    let g = stem::sim::LLAMA31_8B;

    let c_uni = schedule::cost_uniform(n, k_start * block as f64);
    let c_dec = schedule::cost_decay(n, k_start * block as f64, mu);
    let c_den = schedule::cost_dense(n);
    println!("pair-count model (Eq. 2/4), N={n}, k_start={k_start:.1} blocks, mu={mu}");
    println!("  dense pairs    {c_den:.3e}");
    println!("  uniform pairs  {c_uni:.3e}  ({:.1}% of dense)", 100.0 * c_uni / c_den);
    println!("  decay pairs    {c_dec:.3e}  ({:.1}% of dense)", 100.0 * c_dec / c_den);
    println!("  decay savings vs uniform: {:.1}%", 100.0 * (1.0 - c_dec / c_uni));

    for (name, m) in [
        ("dense", MethodCost::Dense),
        ("stem", MethodCost::Stem { k_start_blocks: k_start, mu }),
    ] {
        let c = method_cost(&g, n, m);
        println!(
            "  {name:>6}: attn {:.2e} FLOPs, metric {:.2e}, linear {:.2e}, budget {:.1}%",
            c.attn_flops,
            c.metric_flops,
            c.linear_flops,
            100.0 * c.budget_fraction
        );
    }
    Ok(())
}

/// `stem selftest`: artifact sanity — manifest parses, weights load, one
/// module compiles and reproduces the python golden logits.
fn selftest(args: &Args) -> Result<()> {
    use stem::util::json::Json;
    let dir = artifacts_from(args);
    let engine = Engine::new(&dir)?;
    let man = engine.manifest();
    println!("manifest: {} modules, {} eval sets", man.modules.len(), man.eval_sets.len());

    // golden logits check (model_dense_512.json from aot.py)
    let gpath = dir.join("golden/model_dense_512.json");
    let text = std::fs::read_to_string(&gpath)?;
    let j = Json::parse(&text).map_err(|e| anyhow!("golden: {e}"))?;
    let ids: Vec<i32> = j
        .get("ids")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("golden ids"))?
        .iter()
        .map(|v| v.as_i64().unwrap_or(0) as i32)
        .collect();
    let argmax: Vec<i32> = j
        .get("argmax")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("golden argmax"))?
        .iter()
        .map(|v| v.as_i64().unwrap_or(0) as i32)
        .collect();
    let out = engine.prefill("base", "prefill_dense", ids.len(), &ids, &[])?;
    let mut mismatches = 0usize;
    for (p, &want) in argmax.iter().enumerate() {
        let row = &out.logits[p * out.vocab..(p + 1) * out.vocab];
        let got = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap();
        if got != want {
            mismatches += 1;
        }
    }
    let frac = mismatches as f64 / argmax.len() as f64;
    println!(
        "golden argmax agreement: {:.2}% ({} / {} mismatched)",
        100.0 * (1.0 - frac),
        mismatches,
        argmax.len()
    );
    if frac > 0.02 {
        return Err(anyhow!("selftest failed: rust-executed HLO disagrees with python logits"));
    }
    println!("selftest OK");
    Ok(())
}
