//! Workload handling: eval-set loading (JSON emitted by aot.py — the
//! python generators are the single source of truth, so there is no
//! dual-implementation drift) and open/closed-loop traffic synthesis
//! for the serving example and the load harness in `bench_serve`.
//!
//! All generators take a `u64` seed (the shared `util::rng` convention:
//! the caller passes a seed, the generator owns its stream), so the same
//! seed always reproduces the same trace regardless of what the caller
//! did with its own RNG beforehand.

use std::path::Path;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;
use crate::util::rng::Rng;

/// One teacher-forced eval sample (ids + where its answer span lives).
#[derive(Debug, Clone)]
pub struct EvalSample {
    /// Full token sequence, answer included.
    pub ids: Vec<i32>,
    /// Index of the first answer token within `ids`.
    pub answer_start: usize,
    /// Answer length in tokens.
    pub answer_len: usize,
}

impl EvalSample {
    /// Teacher-forced exact match: argmax at positions answer_start-1 ..
    /// answer_start+len-2 must reproduce the answer tokens.
    pub fn answer_tokens(&self) -> &[i32] {
        &self.ids[self.answer_start..self.answer_start + self.answer_len]
    }
}

/// Load an eval set JSON emitted by `aot.py`.
pub fn load_eval_set(path: &Path) -> Result<Vec<EvalSample>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading eval set {}", path.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow!("eval json: {e}"))?;
    j.as_arr()
        .ok_or_else(|| anyhow!("eval set not an array"))?
        .iter()
        .map(|s| {
            Ok(EvalSample {
                ids: s
                    .get("ids")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("sample ids"))?
                    .iter()
                    .map(|t| t.as_i64().unwrap_or(0) as i32)
                    .collect(),
                answer_start: s
                    .get("answer_start")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("answer_start"))?,
                answer_len: s
                    .get("answer_len")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("answer_len"))?,
            })
        })
        .collect()
}

/// One request of an open-loop arrival trace.
#[derive(Debug, Clone)]
pub struct TraceItem {
    /// offset from trace start
    pub at: Duration,
    /// index into the sample pool
    pub sample: usize,
}

/// Poisson open-loop arrival trace over a sample pool. The seed fully
/// determines the trace (shared `util::rng` convention).
pub fn poisson_trace(seed: u64, n_requests: usize, rps: f64, pool: usize) -> Vec<TraceItem> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    (0..n_requests)
        .map(|_| {
            t += rng.exp(rps);
            TraceItem { at: Duration::from_secs_f64(t), sample: rng.below(pool as u64) as usize }
        })
        .collect()
}

/// Arrival-time process of a synthesized trace.
#[derive(Debug, Clone)]
pub enum ArrivalModel {
    /// Memoryless arrivals at a constant mean rate (requests/second).
    Poisson {
        /// Mean arrival rate in requests per second.
        rps: f64,
    },
    /// Two-state burst-modulated arrivals: the process alternates between
    /// a hot phase (rate `rps * burst`) and a cold phase (rate
    /// `rps / burst`), flipping state with probability 1/8 after each
    /// arrival, so bursts have geometric length (mean 8 requests). The
    /// long-run rate is near — not exactly — `rps`; the point is
    /// clustered arrivals that stress admission and the degrade ladder,
    /// not rate precision.
    Bursty {
        /// Baseline rate in requests per second; hot/cold phases run at
        /// `rps * burst` and `rps / burst`.
        rps: f64,
        /// Burstiness factor (> 1); 1.0 degenerates to Poisson.
        burst: f64,
    },
}

/// Heavy-tailed (lognormal) length distribution with hard caps, used for
/// both prompt and output lengths. `exp(log_mean + log_sigma · N(0,1))`
/// rounded and clamped into `[min, cap]`.
#[derive(Debug, Clone)]
pub struct LengthModel {
    /// Mean of the underlying normal (`ln` of the median length).
    pub log_mean: f64,
    /// Standard deviation of the underlying normal; bigger = heavier tail.
    pub log_sigma: f64,
    /// Smallest length ever emitted.
    pub min: usize,
    /// Largest length ever emitted — the tail is truncated here so a
    /// synthesized trace can never exceed the harness's KV budget.
    pub cap: usize,
}

impl LengthModel {
    /// Draw one length. Float-to-int casts saturate, so even an extreme
    /// tail draw lands on `cap` rather than wrapping.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let x = (self.log_mean + self.log_sigma * rng.normal()).exp();
        (x.round() as usize).clamp(self.min, self.cap)
    }
}

/// One tenant priority class of a synthesized workload.
#[derive(Debug, Clone)]
pub struct TenantClass {
    /// Relative share of traffic this class receives.
    pub weight: f64,
    /// Per-request TTL for this class (`None` = best-effort, never shed
    /// on deadline). Latency-sensitive classes get tight deadlines so
    /// goodput-under-overload measures what the SLO pick rule protects.
    pub deadline_ms: Option<u64>,
}

/// Full specification of a synthesized traffic trace: arrivals,
/// heavy-tailed lengths, fan-out families and tenant priorities. One
/// config + one seed = one exact trace (see [`synthesize`]).
#[derive(Debug, Clone)]
pub struct TrafficConfig {
    /// Seed for the whole trace (shared `util::rng` convention).
    pub seed: u64,
    /// Number of requests to synthesize.
    pub n_requests: usize,
    /// Arrival-time process.
    pub arrivals: ArrivalModel,
    /// Prompt-length distribution.
    pub prompt_len: LengthModel,
    /// Output-length (max-new-tokens) distribution.
    pub output_len: LengthModel,
    /// `(fanout, weight)` families: each request decodes `fanout`
    /// branches off one shared prompt ingest. Empty = every request has
    /// fan-out 1.
    pub fanout_weights: Vec<(usize, f64)>,
    /// Tenant classes sampled by weight. Empty = one best-effort tenant.
    pub tenants: Vec<TenantClass>,
}

impl Default for TrafficConfig {
    /// A small mixed workload: Poisson 8 rps, median 512-token prompts
    /// with a heavy tail capped at 4096, short outputs, mostly fan-out 1
    /// with occasional families, and a latency-sensitive minority tenant.
    fn default() -> Self {
        TrafficConfig {
            seed: 42,
            n_requests: 64,
            arrivals: ArrivalModel::Poisson { rps: 8.0 },
            prompt_len: LengthModel { log_mean: 6.24, log_sigma: 0.8, min: 16, cap: 4096 },
            output_len: LengthModel { log_mean: 3.46, log_sigma: 0.6, min: 4, cap: 256 },
            fanout_weights: vec![(1, 0.9), (2, 0.07), (4, 0.03)],
            tenants: vec![
                TenantClass { weight: 0.8, deadline_ms: None },
                TenantClass { weight: 0.2, deadline_ms: Some(250) },
            ],
        }
    }
}

/// One synthesized request of a load-harness trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SyntheticRequest {
    /// Arrival offset from trace start.
    pub at: Duration,
    /// Prompt length in tokens.
    pub prompt_tokens: usize,
    /// Decode budget (max new tokens per branch).
    pub max_new: usize,
    /// Number of decode branches sharing this request's prompt ingest.
    pub fanout: usize,
    /// Index into [`TrafficConfig::tenants`] (0 when that list is empty).
    pub tenant: usize,
    /// TTL inherited from the tenant class.
    pub deadline_ms: Option<u64>,
}

/// Weighted index pick; returns 0 on an empty or all-zero table.
fn weighted_pick(rng: &mut Rng, weights: &[f64]) -> usize {
    let total: f64 = weights.iter().filter(|w| w.is_finite() && **w > 0.0).sum();
    if total <= 0.0 {
        return 0;
    }
    let mut x = rng.f64() * total;
    for (i, &w) in weights.iter().enumerate() {
        if w.is_finite() && w > 0.0 {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
    }
    weights.len().saturating_sub(1)
}

/// Synthesize a full load-harness trace from a [`TrafficConfig`]. Purely
/// deterministic: the same config (including seed) always produces the
/// identical request list — the regression suite pins this, so traces in
/// bench artifacts are replayable by seed alone.
pub fn synthesize(cfg: &TrafficConfig) -> Vec<SyntheticRequest> {
    let mut rng = Rng::new(cfg.seed);
    let fan_w: Vec<f64> = cfg.fanout_weights.iter().map(|&(_, w)| w).collect();
    let ten_w: Vec<f64> = cfg.tenants.iter().map(|t| t.weight).collect();
    let mut t = 0.0f64;
    let mut hot = false;
    (0..cfg.n_requests)
        .map(|_| {
            let rate = match cfg.arrivals {
                ArrivalModel::Poisson { rps } => rps,
                ArrivalModel::Bursty { rps, burst } => {
                    if rng.bool(1.0 / 8.0) {
                        hot = !hot;
                    }
                    let b = burst.max(1.0);
                    if hot {
                        rps * b
                    } else {
                        rps / b
                    }
                }
            };
            t += rng.exp(rate.max(1e-9));
            let fanout = if cfg.fanout_weights.is_empty() {
                1
            } else {
                cfg.fanout_weights[weighted_pick(&mut rng, &fan_w)].0.max(1)
            };
            let (tenant, deadline_ms) = if cfg.tenants.is_empty() {
                (0, None)
            } else {
                let i = weighted_pick(&mut rng, &ten_w);
                (i, cfg.tenants[i].deadline_ms)
            };
            SyntheticRequest {
                at: Duration::from_secs_f64(t),
                prompt_tokens: cfg.prompt_len.sample(&mut rng),
                max_new: cfg.output_len.sample(&mut rng),
                fanout,
                tenant,
                deadline_ms,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_eval_set() {
        let dir = std::env::temp_dir().join("stem_eval_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("e.json");
        std::fs::write(&p, r#"[{"ids":[1,2,3,4],"answer_start":2,"answer_len":1}]"#).unwrap();
        let s = load_eval_set(&p).unwrap();
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].answer_tokens(), &[3]);
    }

    #[test]
    fn poisson_trace_monotone() {
        let tr = poisson_trace(5, 100, 50.0, 10);
        assert_eq!(tr.len(), 100);
        for w in tr.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        let mean_gap = tr.last().unwrap().at.as_secs_f64() / 100.0;
        assert!((mean_gap - 0.02).abs() < 0.01, "gap {mean_gap}");
    }

    #[test]
    fn poisson_trace_is_seed_deterministic() {
        let a = poisson_trace(9, 50, 20.0, 7);
        let b = poisson_trace(9, 50, 20.0, 7);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.sample, y.sample);
        }
        let c = poisson_trace(10, 50, 20.0, 7);
        assert!(a.iter().zip(&c).any(|(x, y)| x.at != y.at), "different seeds diverge");
    }

    #[test]
    fn synthesize_same_seed_identical_trace() {
        let cfg = TrafficConfig::default();
        assert_eq!(synthesize(&cfg), synthesize(&cfg), "same seed → byte-identical trace");
        let other = TrafficConfig { seed: 43, ..cfg };
        assert_ne!(synthesize(&other), synthesize(&TrafficConfig::default()));
    }

    #[test]
    fn lengths_stay_inside_configured_caps() {
        // huge sigma: the untruncated lognormal would routinely blow past
        // the cap, so every draw landing inside [min, cap] is the clamp
        let cfg = TrafficConfig {
            n_requests: 500,
            prompt_len: LengthModel { log_mean: 6.0, log_sigma: 3.0, min: 8, cap: 1024 },
            output_len: LengthModel { log_mean: 3.0, log_sigma: 3.0, min: 2, cap: 64 },
            ..TrafficConfig::default()
        };
        let tr = synthesize(&cfg);
        assert_eq!(tr.len(), 500);
        let mut hit_prompt_cap = false;
        for r in &tr {
            assert!((8..=1024).contains(&r.prompt_tokens), "prompt {}", r.prompt_tokens);
            assert!((2..=64).contains(&r.max_new), "output {}", r.max_new);
            hit_prompt_cap |= r.prompt_tokens == 1024;
        }
        assert!(hit_prompt_cap, "sigma=3 must actually exercise the cap");
    }

    #[test]
    fn synthesize_arrivals_monotone_for_both_models() {
        let models =
            [ArrivalModel::Poisson { rps: 40.0 }, ArrivalModel::Bursty { rps: 40.0, burst: 8.0 }];
        for arrivals in models {
            let cfg = TrafficConfig { n_requests: 200, arrivals, ..TrafficConfig::default() };
            let tr = synthesize(&cfg);
            for w in tr.windows(2) {
                assert!(w[0].at <= w[1].at);
            }
        }
    }

    #[test]
    fn fanout_and_tenants_come_from_the_config_tables() {
        let cfg = TrafficConfig {
            n_requests: 300,
            fanout_weights: vec![(2, 1.0), (8, 1.0)],
            tenants: vec![
                TenantClass { weight: 1.0, deadline_ms: None },
                TenantClass { weight: 1.0, deadline_ms: Some(50) },
            ],
            ..TrafficConfig::default()
        };
        let tr = synthesize(&cfg);
        let mut saw = [false; 2];
        for r in &tr {
            assert!(r.fanout == 2 || r.fanout == 8, "fanout {}", r.fanout);
            assert!(r.tenant < 2);
            saw[r.tenant] = true;
            // deadline rides with the tenant class
            assert_eq!(r.deadline_ms, cfg.tenants[r.tenant].deadline_ms);
        }
        assert!(saw[0] && saw[1], "equal weights must hit both classes");
    }

    #[test]
    fn empty_tables_degenerate_to_single_class() {
        let cfg = TrafficConfig {
            n_requests: 20,
            fanout_weights: vec![],
            tenants: vec![],
            ..TrafficConfig::default()
        };
        for r in synthesize(&cfg) {
            assert_eq!(r.fanout, 1);
            assert_eq!(r.tenant, 0);
            assert_eq!(r.deadline_ms, None);
        }
    }
}
