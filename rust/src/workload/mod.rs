//! Workload handling: eval-set loading (JSON emitted by aot.py — the
//! python generators are the single source of truth, so there is no
//! dual-implementation drift) and open-loop traffic synthesis for the
//! serving example.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;
use crate::util::rng::Rng;

/// One teacher-forced eval sample (ids + where its answer span lives).
#[derive(Debug, Clone)]
pub struct EvalSample {
    /// Full token sequence, answer included.
    pub ids: Vec<i32>,
    /// Index of the first answer token within `ids`.
    pub answer_start: usize,
    /// Answer length in tokens.
    pub answer_len: usize,
}

impl EvalSample {
    /// Teacher-forced exact match: argmax at positions answer_start-1 ..
    /// answer_start+len-2 must reproduce the answer tokens.
    pub fn answer_tokens(&self) -> &[i32] {
        &self.ids[self.answer_start..self.answer_start + self.answer_len]
    }
}

/// Load an eval set JSON emitted by `aot.py`.
pub fn load_eval_set(path: &Path) -> Result<Vec<EvalSample>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading eval set {}", path.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow!("eval json: {e}"))?;
    j.as_arr()
        .ok_or_else(|| anyhow!("eval set not an array"))?
        .iter()
        .map(|s| {
            Ok(EvalSample {
                ids: s
                    .get("ids")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("sample ids"))?
                    .iter()
                    .map(|t| t.as_i64().unwrap_or(0) as i32)
                    .collect(),
                answer_start: s
                    .get("answer_start")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("answer_start"))?,
                answer_len: s
                    .get("answer_len")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("answer_len"))?,
            })
        })
        .collect()
}

/// One request of an open-loop arrival trace.
#[derive(Debug, Clone)]
pub struct TraceItem {
    /// offset from trace start
    pub at: std::time::Duration,
    /// index into the sample pool
    pub sample: usize,
}

/// Poisson open-loop arrival trace over a sample pool.
pub fn poisson_trace(rng: &mut Rng, n_requests: usize, rps: f64, pool: usize) -> Vec<TraceItem> {
    let mut t = 0.0f64;
    (0..n_requests)
        .map(|_| {
            t += rng.exp(rps);
            TraceItem {
                at: std::time::Duration::from_secs_f64(t),
                sample: rng.below(pool as u64) as usize,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_eval_set() {
        let dir = std::env::temp_dir().join("stem_eval_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("e.json");
        std::fs::write(&p, r#"[{"ids":[1,2,3,4],"answer_start":2,"answer_len":1}]"#).unwrap();
        let s = load_eval_set(&p).unwrap();
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].answer_tokens(), &[3]);
    }

    #[test]
    fn poisson_trace_monotone() {
        let mut rng = Rng::new(5);
        let tr = poisson_trace(&mut rng, 100, 50.0, 10);
        assert_eq!(tr.len(), 100);
        for w in tr.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        let mean_gap = tr.last().unwrap().at.as_secs_f64() / 100.0;
        assert!((mean_gap - 0.02).abs() < 0.01, "gap {mean_gap}");
    }
}
