//! Admission control / backpressure: bounds outstanding prefill work so a
//! burst cannot blow memory or queue latency. Three limits:
//!   * outstanding tokens (the quantity the cost model says we pay for)
//!   * outstanding requests
//!   * outstanding estimated work (wall-clock ns from the calibrated core
//!     cost model, `sim::cost::estimate_core_prefill_ns` — constants
//!     re-fit to the PR-1 flat-CSR parallel kernel, so the same token
//!     count now admits more concurrent work than the seed scalar path)
//! Shed-on-overflow semantics (caller may retry); the serve example turns
//! rejections into client backoff.

use std::sync::{Condvar, Mutex, MutexGuard};

/// Backpressure ceilings (see module docs for the three dimensions).
#[derive(Debug, Clone, Copy)]
pub struct AdmissionConfig {
    /// Ceiling on summed tokens of admitted, uncompleted requests.
    pub max_tokens: usize,
    /// Ceiling on admitted, uncompleted requests.
    pub max_requests: usize,
    /// Ceiling on summed estimated work of admitted requests, in ns;
    /// `f64::INFINITY` (the default) disables the work dimension.
    pub max_work_ns: f64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig { max_tokens: 64 * 1024, max_requests: 256, max_work_ns: f64::INFINITY }
    }
}

#[derive(Debug, Default)]
struct State {
    tokens: usize,
    requests: usize,
    work_ns: f64,
}

/// Shared admission state: counts outstanding work against the
/// configured ceilings and sheds on overflow.
pub struct Admission {
    cfg: AdmissionConfig,
    state: Mutex<State>,
    freed: Condvar,
}

/// Outcome of an admission attempt.
#[derive(Debug, PartialEq)]
pub enum Admit {
    /// Admitted; the caller owes a matching release on completion.
    Accepted,
    /// Shed (backpressure); the caller may retry later.
    Rejected {
        /// Which ceiling rejected: `"max_tokens"`, `"max_requests"` or
        /// `"max_work_ns"`.
        reason: &'static str,
    },
}

impl Admission {
    /// Build an admission gate with the given ceilings.
    pub fn new(cfg: AdmissionConfig) -> Self {
        Admission { cfg, state: Mutex::new(State::default()), freed: Condvar::new() }
    }

    /// Lock the counter state, recovering from poisoning: the state is
    /// three plain counters that are never left mid-update (no panic can
    /// occur between the reads and writes of one critical section), so a
    /// poisoned lock is safe to adopt — and refusing would wedge every
    /// Condvar waiter behind one panicked worker forever.
    fn lock_state(&self) -> MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Non-blocking admission attempt.
    pub fn try_admit(&self, n_tokens: usize) -> Admit {
        self.try_admit_work(n_tokens, 0.0)
    }

    /// Non-blocking admission with a work estimate (ns) from the cost
    /// model attached; the estimate must be passed back to
    /// [`Admission::release_work`].
    pub fn try_admit_work(&self, n_tokens: usize, est_ns: f64) -> Admit {
        self.try_admit_work_n(1, n_tokens, est_ns)
    }

    /// Admit a group of `n_requests` at once (a shared-prefix fan-out:
    /// one admission decision, but every branch later calls
    /// [`Admission::release_work`] individually, so the request count
    /// must be charged per branch up front to stay balanced).
    pub fn try_admit_work_n(&self, n_requests: usize, n_tokens: usize, est_ns: f64) -> Admit {
        let mut s = self.lock_state();
        // checked adds: caller-supplied group sizes must reject, never
        // wrap past the ceilings in release builds
        match s.requests.checked_add(n_requests) {
            Some(r) if r <= self.cfg.max_requests => {}
            _ => return Admit::Rejected { reason: "max_requests" },
        }
        match s.tokens.checked_add(n_tokens) {
            Some(t) if t <= self.cfg.max_tokens => {}
            _ => return Admit::Rejected { reason: "max_tokens" },
        }
        // never starve: an empty system admits any SINGLE request however
        // large its estimate — but a multi-branch group gets no such
        // exemption, or one burst could blow past the work ceiling
        // wholesale on an idle system
        if (s.requests > 0 || n_requests > 1) && s.work_ns + est_ns > self.cfg.max_work_ns {
            return Admit::Rejected { reason: "max_work_ns" };
        }
        s.tokens += n_tokens;
        s.requests += n_requests;
        s.work_ns += est_ns;
        Admit::Accepted
    }

    /// Blocking admission (used by the synchronous eval harness).
    pub fn admit_blocking(&self, n_tokens: usize) {
        let mut s = self.lock_state();
        while s.requests + 1 > self.cfg.max_requests || s.tokens + n_tokens > self.cfg.max_tokens {
            s = self.freed.wait(s).unwrap_or_else(|p| p.into_inner());
        }
        s.tokens += n_tokens;
        s.requests += 1;
    }

    /// Release a completed request's token share (no work estimate).
    pub fn release(&self, n_tokens: usize) {
        self.release_work(n_tokens, 0.0);
    }

    /// Release a completed request's token share and the work estimate
    /// it was admitted with.
    pub fn release_work(&self, n_tokens: usize, est_ns: f64) {
        let mut s = self.lock_state();
        s.tokens = s.tokens.saturating_sub(n_tokens);
        s.requests = s.requests.saturating_sub(1);
        s.work_ns = (s.work_ns - est_ns).max(0.0);
        drop(s);
        self.freed.notify_all();
    }

    /// Currently admitted `(tokens, requests)`.
    pub fn outstanding(&self) -> (usize, usize) {
        let s = self.lock_state();
        (s.tokens, s.requests)
    }

    /// Summed work estimate (ns) of currently admitted requests.
    pub fn outstanding_work_ns(&self) -> f64 {
        self.lock_state().work_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_over_token_budget() {
        let a = Admission::new(AdmissionConfig {
            max_tokens: 1000,
            max_requests: 10,
            ..Default::default()
        });
        assert_eq!(a.try_admit(600), Admit::Accepted);
        assert!(matches!(a.try_admit(600), Admit::Rejected { reason: "max_tokens" }));
        a.release(600);
        assert_eq!(a.try_admit(600), Admit::Accepted);
    }

    #[test]
    fn rejects_over_request_budget() {
        let a = Admission::new(AdmissionConfig {
            max_tokens: 1_000_000,
            max_requests: 2,
            ..Default::default()
        });
        assert_eq!(a.try_admit(1), Admit::Accepted);
        assert_eq!(a.try_admit(1), Admit::Accepted);
        assert!(matches!(a.try_admit(1), Admit::Rejected { reason: "max_requests" }));
    }

    #[test]
    fn rejects_over_work_budget_but_never_starves() {
        let a = Admission::new(AdmissionConfig { max_work_ns: 1e6, ..Default::default() });
        // a single oversized request is always admitted on an empty system
        assert_eq!(a.try_admit_work(64, 5e6), Admit::Accepted);
        assert!(matches!(a.try_admit_work(64, 1.0), Admit::Rejected { reason: "max_work_ns" }));
        a.release_work(64, 5e6);
        assert_eq!(a.outstanding_work_ns(), 0.0);
        assert_eq!(a.try_admit_work(64, 4e5), Admit::Accepted);
        assert_eq!(a.try_admit_work(64, 4e5), Admit::Accepted);
        assert!(matches!(a.try_admit_work(64, 4e5), Admit::Rejected { reason: "max_work_ns" }));
    }

    #[test]
    fn work_budget_from_calibrated_cost_model() {
        use crate::sim::cost::{estimate_core_prefill_ns, Geometry, MethodCost};
        let g = Geometry {
            n_layers: 1,
            n_heads: 8,
            d_head: 32,
            d_model: 256,
            d_ff: 1024,
            block: 64,
        };
        let est =
            |n: usize| estimate_core_prefill_ns(&g, n, MethodCost::Stem { k_start_blocks: 6.4, mu: 0.7 }, 4);
        // budget two mid-size prefills' worth of work
        let a = Admission::new(AdmissionConfig {
            max_work_ns: 2.1 * est(2048),
            ..Default::default()
        });
        assert_eq!(a.try_admit_work(2048, est(2048)), Admit::Accepted);
        assert_eq!(a.try_admit_work(2048, est(2048)), Admit::Accepted);
        assert!(matches!(
            a.try_admit_work(2048, est(2048)),
            Admit::Rejected { reason: "max_work_ns" }
        ));
    }

    #[test]
    fn group_admission_balances_per_branch_release() {
        let a = Admission::new(AdmissionConfig {
            max_tokens: 10_000,
            max_requests: 4,
            ..Default::default()
        });
        // a fanout-3 group takes 3 request slots atomically
        assert_eq!(a.try_admit_work_n(3, 300, 3e5), Admit::Accepted);
        assert_eq!(a.outstanding(), (300, 3));
        assert!(matches!(a.try_admit_work_n(2, 10, 1.0), Admit::Rejected { reason: "max_requests" }));
        // branches release individually (100 tokens + 1e5 ns each)
        a.release_work(100, 1e5);
        a.release_work(100, 1e5);
        a.release_work(100, 1e5);
        assert_eq!(a.outstanding(), (0, 0), "per-branch releases must zero the group");
        assert_eq!(a.outstanding_work_ns(), 0.0);
    }

    #[test]
    fn blocking_admission_wakes_on_release() {
        use std::sync::Arc;
        let a = Arc::new(Admission::new(AdmissionConfig {
            max_tokens: 100,
            max_requests: 10,
            ..Default::default()
        }));
        a.admit_blocking(100);
        let a2 = Arc::clone(&a);
        let h = std::thread::spawn(move || {
            a2.admit_blocking(50);
            a2.release(50);
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        a.release(100);
        h.join().unwrap();
        assert_eq!(a.outstanding(), (0, 0));
    }
}
