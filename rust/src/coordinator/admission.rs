//! Admission control / backpressure: bounds outstanding prefill work so a
//! burst cannot blow memory or queue latency. Two limits:
//!   * outstanding tokens (the quantity the cost model says we pay for)
//!   * outstanding requests
//! Shed-on-overflow semantics (caller may retry); the serve example turns
//! rejections into client backoff.

use std::sync::{Condvar, Mutex};

#[derive(Debug, Clone, Copy)]
pub struct AdmissionConfig {
    pub max_tokens: usize,
    pub max_requests: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig { max_tokens: 64 * 1024, max_requests: 256 }
    }
}

#[derive(Debug, Default)]
struct State {
    tokens: usize,
    requests: usize,
}

pub struct Admission {
    cfg: AdmissionConfig,
    state: Mutex<State>,
    freed: Condvar,
}

#[derive(Debug, PartialEq, Eq)]
pub enum Admit {
    Accepted,
    Rejected { reason: &'static str },
}

impl Admission {
    pub fn new(cfg: AdmissionConfig) -> Self {
        Admission { cfg, state: Mutex::new(State::default()), freed: Condvar::new() }
    }

    /// Non-blocking admission attempt.
    pub fn try_admit(&self, n_tokens: usize) -> Admit {
        let mut s = self.state.lock().unwrap();
        if s.requests + 1 > self.cfg.max_requests {
            return Admit::Rejected { reason: "max_requests" };
        }
        if s.tokens + n_tokens > self.cfg.max_tokens {
            return Admit::Rejected { reason: "max_tokens" };
        }
        s.tokens += n_tokens;
        s.requests += 1;
        Admit::Accepted
    }

    /// Blocking admission (used by the synchronous eval harness).
    pub fn admit_blocking(&self, n_tokens: usize) {
        let mut s = self.state.lock().unwrap();
        while s.requests + 1 > self.cfg.max_requests || s.tokens + n_tokens > self.cfg.max_tokens {
            s = self.freed.wait(s).unwrap();
        }
        s.tokens += n_tokens;
        s.requests += 1;
    }

    pub fn release(&self, n_tokens: usize) {
        let mut s = self.state.lock().unwrap();
        s.tokens = s.tokens.saturating_sub(n_tokens);
        s.requests = s.requests.saturating_sub(1);
        drop(s);
        self.freed.notify_all();
    }

    pub fn outstanding(&self) -> (usize, usize) {
        let s = self.state.lock().unwrap();
        (s.tokens, s.requests)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_over_token_budget() {
        let a = Admission::new(AdmissionConfig { max_tokens: 1000, max_requests: 10 });
        assert_eq!(a.try_admit(600), Admit::Accepted);
        assert!(matches!(a.try_admit(600), Admit::Rejected { reason: "max_tokens" }));
        a.release(600);
        assert_eq!(a.try_admit(600), Admit::Accepted);
    }

    #[test]
    fn rejects_over_request_budget() {
        let a = Admission::new(AdmissionConfig { max_tokens: 1_000_000, max_requests: 2 });
        assert_eq!(a.try_admit(1), Admit::Accepted);
        assert_eq!(a.try_admit(1), Admit::Accepted);
        assert!(matches!(a.try_admit(1), Admit::Rejected { reason: "max_requests" }));
    }

    #[test]
    fn blocking_admission_wakes_on_release() {
        use std::sync::Arc;
        let a = Arc::new(Admission::new(AdmissionConfig { max_tokens: 100, max_requests: 10 }));
        a.admit_blocking(100);
        let a2 = Arc::clone(&a);
        let h = std::thread::spawn(move || {
            a2.admit_blocking(50);
            a2.release(50);
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        a.release(100);
        h.join().unwrap();
        assert_eq!(a.outstanding(), (0, 0));
    }
}
