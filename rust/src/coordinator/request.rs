//! Request/response types flowing through the coordinator.

use std::time::Instant;

use crate::decode::DecodePolicy;
use crate::runtime::ScalarValue;

/// Attention method requested for a prefill. `Stem` carries its runtime
/// hyper-parameters so one compiled module serves every configuration
/// (uniform SAM and the +TPD ablation are Stem with mu=1 / beta=0).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Method {
    /// Full causal attention (the quality/latency baseline).
    Dense,
    /// Stem: TPD budget decay + OAM block selection.
    Stem {
        /// Starting block budget of the TPD schedule.
        k_start: f32,
        /// Decay floor multiplier (budget → `mu·k_start`).
        mu: f32,
        /// OAM value-magnitude weight (Eq. 7).
        beta: f32,
    },
    /// StreamingLLM-style sinks + local window.
    Streaming {
        /// Leading sink blocks always kept.
        sink: i32,
        /// Trailing local blocks always kept.
        local: i32,
    },
    /// XAttention baseline (threshold on antidiagonal scores).
    XAttn {
        /// Score-mass threshold.
        tau: f32,
    },
    /// MInference vertical-slash baseline.
    MInference {
        /// Vertical stripes kept.
        vertical: i32,
        /// Slash diagonals kept.
        slash: i32,
    },
    /// FlexPrefill baseline (entropy-adaptive budget).
    FlexPrefill {
        /// Coverage parameter.
        gamma: f32,
        /// Entropy threshold.
        entropy: f32,
    },
    /// Figure-3 diagnostic (diag module only).
    Segment {
        /// First block of the probed segment.
        lo: i32,
        /// One past the last block of the probed segment.
        hi: i32,
        /// Blocks kept inside the segment.
        k_seg: i32,
        /// Keep ratio outside the segment.
        ratio: f32,
    },
}

impl Method {
    /// The compiled-module kind serving this method (`diag` selects the
    /// diagnostic variant that also returns hidden states).
    pub fn kind(&self, diag: bool) -> &'static str {
        let base = match self {
            Method::Dense => "dense",
            Method::Stem { .. } => "stem",
            Method::Streaming { .. } => "streaming",
            Method::XAttn { .. } => "xattn",
            Method::MInference { .. } => "minference",
            Method::FlexPrefill { .. } => "flexprefill",
            Method::Segment { .. } => "segment",
        };
        // static strings for HashMap keys
        match (diag, base) {
            (false, "dense") => "prefill_dense",
            (false, "stem") => "prefill_stem",
            (false, "streaming") => "prefill_streaming",
            (false, "xattn") => "prefill_xattn",
            (false, "minference") => "prefill_minference",
            (false, "flexprefill") => "prefill_flexprefill",
            (true, "dense") => "diag_dense",
            (true, "stem") => "diag_stem",
            (true, "segment") => "diag_segment",
            _ => panic!("no module for method {base} diag={diag}"),
        }
    }

    /// Runtime scalar arguments in the order the compiled module's
    /// manifest declares them.
    pub fn scalars(&self) -> Vec<ScalarValue> {
        use ScalarValue::*;
        match *self {
            Method::Dense => vec![],
            Method::Stem { k_start, mu, beta } => vec![F32(k_start), F32(mu), F32(beta)],
            Method::Streaming { sink, local } => vec![I32(sink), I32(local)],
            Method::XAttn { tau } => vec![F32(tau)],
            Method::MInference { vertical, slash } => vec![I32(vertical), I32(slash)],
            Method::FlexPrefill { gamma, entropy } => vec![F32(gamma), F32(entropy)],
            Method::Segment { lo, hi, k_seg, ratio } => {
                vec![I32(lo), I32(hi), I32(k_seg), F32(ratio)]
            }
        }
    }

    /// Short display name (table rows).
    pub fn label(&self) -> &'static str {
        match self {
            Method::Dense => "dense",
            Method::Stem { .. } => "stem",
            Method::Streaming { .. } => "streaming",
            Method::XAttn { .. } => "xattn",
            Method::MInference { .. } => "minference",
            Method::FlexPrefill { .. } => "flexprefill",
            Method::Segment { .. } => "segment",
        }
    }
}

/// One prefill request as queued in the coordinator.
#[derive(Debug, Clone)]
pub struct PrefillRequest {
    /// Coordinator-assigned request id.
    pub id: u64,
    /// Weight checkpoint to execute against.
    pub checkpoint: String,
    /// Attention method + its runtime scalars.
    pub method: Method,
    /// Input token ids (padded to the bucket at execution).
    pub ids: Vec<i32>,
    /// Route to the diagnostic module (also returns hidden states).
    pub diag: bool,
    /// Submission time (queue-latency accounting).
    pub enqueued: Instant,
    /// Absolute deadline: the dispatcher sheds the request (typed
    /// [`ServeError::DeadlineExceeded`], admission unwound) instead of
    /// executing it once this instant passes. `None` = no deadline.
    pub deadline: Option<Instant>,
}

/// Result of one prefill execution.
#[derive(Debug)]
pub struct PrefillResponse {
    /// The request id this answers.
    pub id: u64,
    /// Row-major `[n_ctx, vocab]` logits.
    pub logits: Vec<f32>,
    /// Vocabulary size (row stride of `logits`).
    pub vocab: usize,
    /// Padded context length executed.
    pub n_ctx: usize,
    /// Unpadded input length.
    pub n_input: usize,
    /// Fraction of causal pairs computed (the paper's BUD column).
    pub budget_fraction: f32,
    /// Per-layer hidden states (diagnostic modules only).
    pub hidden: Option<Vec<f32>>,
    /// Microseconds spent queued before execution.
    pub queue_us: u64,
    /// Microseconds spent executing on a worker.
    pub exec_us: u64,
}

/// An autoregressive generation request ([`crate::coordinator::Coordinator::submit_generate`]
/// / `submit_generate_many`): prompt ingest followed by up to
/// `max_new_tokens` policy-directed decode steps per branch over the
/// paged KV cache.
#[derive(Debug, Clone)]
pub struct GenerateRequest {
    /// Base id of the request: the prefix-holder sequence is `id`, the
    /// branch sequences `id+1 ..= id+fanout`.
    pub id: u64,
    /// Prompt token ids shared by every branch.
    pub prompt: Vec<i32>,
    /// Per-branch generation-length cap.
    pub max_new_tokens: usize,
    /// Per-step sparsity policy every branch decodes under.
    pub policy: DecodePolicy,
    /// Continuations to serve off one shared prompt prefix (>= 1). The
    /// prompt is prefilled once; every branch forks the refcounted
    /// prefix and diverges copy-on-write.
    pub fanout: usize,
    /// `prompt_hash(&prompt)`, computed once at submit so the dispatcher
    /// hot path does not re-hash long prompts (exact prefix mode).
    pub prefix_hash: u64,
    /// Submission time (queue-latency accounting).
    pub enqueued: Instant,
    /// Absolute deadline. A queued generation past it is shed whole
    /// ([`ServeError::DeadlineExceeded`]); a branch already decoding
    /// stops at its next step and returns the tokens generated so far
    /// with [`Finish::DeadlineExceeded`]. `None` = no deadline.
    pub deadline: Option<Instant>,
}

/// How a generation branch terminated.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Finish {
    /// Ran to the length cap or the END token.
    #[default]
    Complete,
    /// The deadline fired mid-decode; `tokens` holds the partial output.
    DeadlineExceeded,
    /// A cancel handle fired (or the client abandoned the ticket);
    /// `tokens` holds the partial output.
    Cancelled,
}

/// Typed serving failures the coordinator returns for requests that
/// never produce a (possibly partial) response. Carried through
/// `anyhow::Error`; match with `err.downcast_ref::<ServeError>()`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, thiserror::Error)]
pub enum ServeError {
    /// The deadline passed while the request was still queued — it was
    /// shed without executing.
    #[error("deadline exceeded before execution")]
    DeadlineExceeded,
    /// The worker executing this request panicked; the panic was
    /// isolated, the request's resources were reclaimed, and sibling
    /// requests kept serving.
    #[error("worker panicked while executing this request")]
    WorkerPanic,
}

/// Final result of a generation (per-token streaming happens inside the
/// decode session; the coordinator returns the aggregate).
#[derive(Debug, Clone)]
pub struct GenerateResponse {
    /// The branch's sequence id.
    pub id: u64,
    /// Generated tokens, in order (may stop early on the END token).
    pub tokens: Vec<i32>,
    /// Prompt length the branch conditioned on.
    pub n_prompt: usize,
    /// Decode steps executed (equals `tokens.len()`).
    pub steps: usize,
    /// Mean fraction of the cached context attended per step.
    pub mean_budget_fraction: f64,
    /// Steps that ran the dense fallback path.
    pub dense_steps: usize,
    /// Time from submit to the first decode step starting.
    pub queue_us: u64,
    /// Summed per-step execution time (the session's own step clocks);
    /// inter-step scheduling gaps are excluded.
    pub exec_us: u64,
    /// Mean decode latency per generated token.
    pub ns_per_token: f64,
    /// How the branch terminated (complete / deadline / cancelled).
    pub finish: Finish,
}

impl PrefillResponse {
    /// argmax token at position `pos` (predicting token pos+1).
    pub fn argmax_at(&self, pos: usize) -> i32 {
        let row = &self.logits[pos * self.vocab..(pos + 1) * self.vocab];
        let mut best = 0usize;
        for (i, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = i;
            }
        }
        best as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_mapping() {
        assert_eq!(Method::Dense.kind(false), "prefill_dense");
        assert_eq!(
            Method::Stem { k_start: 4.0, mu: 0.7, beta: 0.2 }.kind(true),
            "diag_stem"
        );
    }

    #[test]
    fn scalar_order_matches_manifest_contract() {
        let s = Method::Stem { k_start: 4.0, mu: 0.7, beta: 0.2 }.scalars();
        assert_eq!(s, vec![ScalarValue::F32(4.0), ScalarValue::F32(0.7), ScalarValue::F32(0.2)]);
        let s = Method::Segment { lo: 1, hi: 2, k_seg: 3, ratio: 0.5 }.scalars();
        assert_eq!(s.len(), 4);
    }

    #[test]
    fn argmax() {
        let r = PrefillResponse {
            id: 0,
            logits: vec![0.0, 1.0, 0.5, /* row1 */ 2.0, -1.0, 0.0],
            vocab: 3,
            n_ctx: 2,
            n_input: 2,
            budget_fraction: 1.0,
            hidden: None,
            queue_us: 0,
            exec_us: 0,
        };
        assert_eq!(r.argmax_at(0), 1);
        assert_eq!(r.argmax_at(1), 0);
    }
}
