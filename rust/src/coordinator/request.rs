//! Request/response types flowing through the coordinator.

use std::time::Instant;

use crate::decode::DecodePolicy;
use crate::runtime::ScalarValue;

/// Attention method requested for a prefill. `Stem` carries its runtime
/// hyper-parameters so one compiled module serves every configuration
/// (uniform SAM and the +TPD ablation are Stem with mu=1 / beta=0).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Method {
    Dense,
    Stem { k_start: f32, mu: f32, beta: f32 },
    Streaming { sink: i32, local: i32 },
    XAttn { tau: f32 },
    MInference { vertical: i32, slash: i32 },
    FlexPrefill { gamma: f32, entropy: f32 },
    /// Figure-3 diagnostic (diag module only).
    Segment { lo: i32, hi: i32, k_seg: i32, ratio: f32 },
}

impl Method {
    pub fn kind(&self, diag: bool) -> &'static str {
        let base = match self {
            Method::Dense => "dense",
            Method::Stem { .. } => "stem",
            Method::Streaming { .. } => "streaming",
            Method::XAttn { .. } => "xattn",
            Method::MInference { .. } => "minference",
            Method::FlexPrefill { .. } => "flexprefill",
            Method::Segment { .. } => "segment",
        };
        // static strings for HashMap keys
        match (diag, base) {
            (false, "dense") => "prefill_dense",
            (false, "stem") => "prefill_stem",
            (false, "streaming") => "prefill_streaming",
            (false, "xattn") => "prefill_xattn",
            (false, "minference") => "prefill_minference",
            (false, "flexprefill") => "prefill_flexprefill",
            (true, "dense") => "diag_dense",
            (true, "stem") => "diag_stem",
            (true, "segment") => "diag_segment",
            _ => panic!("no module for method {base} diag={diag}"),
        }
    }

    pub fn scalars(&self) -> Vec<ScalarValue> {
        use ScalarValue::*;
        match *self {
            Method::Dense => vec![],
            Method::Stem { k_start, mu, beta } => vec![F32(k_start), F32(mu), F32(beta)],
            Method::Streaming { sink, local } => vec![I32(sink), I32(local)],
            Method::XAttn { tau } => vec![F32(tau)],
            Method::MInference { vertical, slash } => vec![I32(vertical), I32(slash)],
            Method::FlexPrefill { gamma, entropy } => vec![F32(gamma), F32(entropy)],
            Method::Segment { lo, hi, k_seg, ratio } => {
                vec![I32(lo), I32(hi), I32(k_seg), F32(ratio)]
            }
        }
    }

    /// Short display name (table rows).
    pub fn label(&self) -> &'static str {
        match self {
            Method::Dense => "dense",
            Method::Stem { .. } => "stem",
            Method::Streaming { .. } => "streaming",
            Method::XAttn { .. } => "xattn",
            Method::MInference { .. } => "minference",
            Method::FlexPrefill { .. } => "flexprefill",
            Method::Segment { .. } => "segment",
        }
    }
}

#[derive(Debug, Clone)]
pub struct PrefillRequest {
    pub id: u64,
    pub checkpoint: String,
    pub method: Method,
    pub ids: Vec<i32>,
    pub diag: bool,
    pub enqueued: Instant,
}

#[derive(Debug)]
pub struct PrefillResponse {
    pub id: u64,
    pub logits: Vec<f32>,
    pub vocab: usize,
    pub n_ctx: usize,
    pub n_input: usize,
    pub budget_fraction: f32,
    pub hidden: Option<Vec<f32>>,
    pub queue_us: u64,
    pub exec_us: u64,
}

/// An autoregressive generation request ([`crate::coordinator::Coordinator::submit_generate`]
/// / `submit_generate_many`): prompt ingest followed by up to
/// `max_new_tokens` policy-directed decode steps per branch over the
/// paged KV cache.
#[derive(Debug, Clone)]
pub struct GenerateRequest {
    /// Base id of the request: the prefix-holder sequence is `id`, the
    /// branch sequences `id+1 ..= id+fanout`.
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub policy: DecodePolicy,
    /// Continuations to serve off one shared prompt prefix (>= 1). The
    /// prompt is prefilled once; every branch forks the refcounted
    /// prefix and diverges copy-on-write.
    pub fanout: usize,
    /// `prompt_hash(&prompt)`, computed once at submit so the dispatcher
    /// hot path does not re-hash long prompts.
    pub prefix_hash: u64,
    pub enqueued: Instant,
}

/// Final result of a generation (per-token streaming happens inside the
/// decode session; the coordinator returns the aggregate).
#[derive(Debug, Clone)]
pub struct GenerateResponse {
    pub id: u64,
    /// Generated tokens, in order (may stop early on the END token).
    pub tokens: Vec<i32>,
    pub n_prompt: usize,
    pub steps: usize,
    /// Mean fraction of the cached context attended per step.
    pub mean_budget_fraction: f64,
    /// Steps that ran the dense fallback path.
    pub dense_steps: usize,
    /// Time from submit to the first decode step starting.
    pub queue_us: u64,
    /// Summed per-step execution time (the session's own step clocks);
    /// inter-step scheduling gaps are excluded.
    pub exec_us: u64,
    /// Mean decode latency per generated token.
    pub ns_per_token: f64,
}

impl PrefillResponse {
    /// argmax token at position `pos` (predicting token pos+1).
    pub fn argmax_at(&self, pos: usize) -> i32 {
        let row = &self.logits[pos * self.vocab..(pos + 1) * self.vocab];
        let mut best = 0usize;
        for (i, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = i;
            }
        }
        best as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_mapping() {
        assert_eq!(Method::Dense.kind(false), "prefill_dense");
        assert_eq!(
            Method::Stem { k_start: 4.0, mu: 0.7, beta: 0.2 }.kind(true),
            "diag_stem"
        );
    }

    #[test]
    fn scalar_order_matches_manifest_contract() {
        let s = Method::Stem { k_start: 4.0, mu: 0.7, beta: 0.2 }.scalars();
        assert_eq!(s, vec![ScalarValue::F32(4.0), ScalarValue::F32(0.7), ScalarValue::F32(0.2)]);
        let s = Method::Segment { lo: 1, hi: 2, k_seg: 3, ratio: 0.5 }.scalars();
        assert_eq!(s.len(), 4);
    }

    #[test]
    fn argmax() {
        let r = PrefillResponse {
            id: 0,
            logits: vec![0.0, 1.0, 0.5, /* row1 */ 2.0, -1.0, 0.0],
            vocab: 3,
            n_ctx: 2,
            n_input: 2,
            budget_fraction: 1.0,
            hidden: None,
            queue_us: 0,
            exec_us: 0,
        };
        assert_eq!(r.argmax_at(0), 1);
        assert_eq!(r.argmax_at(1), 0);
    }
}
