//! L3 coordinator: the serving-system half of the reproduction.
//!
//! request → router/admission → dynamic batcher → dispatcher → worker
//! pool → PJRT engine; plus the paged KV pool and metrics. See
//! `server.rs` for the threading model.

pub mod admission;
pub mod batcher;
pub mod kv_cache;
pub mod metrics;
pub mod request;
pub mod server;

pub use request::{Method, PrefillRequest, PrefillResponse};
pub use server::{Coordinator, CoordinatorConfig};
