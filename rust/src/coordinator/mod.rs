//! L3 coordinator: the serving-system half of the reproduction.
//!
//! request → router/admission → dynamic batcher → dispatcher → worker
//! pool → PJRT engine; plus the shared paged KV store and metrics.
//! Prefill requests and decode generations share the store and the
//! batcher, with decode steps continuously batched between prefill
//! batches. Generations route through refcounted prefix holders
//! (shared-prefix fan-out: one ingest per unique prompt, N forked
//! continuations diverging copy-on-write — `submit_generate_many`). See
//! `server.rs` for the threading model and the prefix cache.

pub mod admission;
pub mod batcher;
pub mod kv_cache;
pub mod metrics;
pub mod request;
pub mod server;

pub use request::{GenerateRequest, GenerateResponse, Method, PrefillRequest, PrefillResponse};
pub use server::{prompt_hash, Coordinator, CoordinatorConfig, PrefixIndex};
