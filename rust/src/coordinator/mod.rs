//! L3 coordinator: the serving-system half of the reproduction.
//!
//! request → router/admission → dynamic batcher → dispatcher → worker
//! pool → PJRT engine; plus the paged KV pool and metrics. Prefill
//! requests and decode generations share the pool and the batcher, with
//! decode steps continuously batched between prefill batches. See
//! `server.rs` for the threading model.

pub mod admission;
pub mod batcher;
pub mod kv_cache;
pub mod metrics;
pub mod request;
pub mod server;

pub use request::{GenerateRequest, GenerateResponse, Method, PrefillRequest, PrefillResponse};
pub use server::{Coordinator, CoordinatorConfig};
