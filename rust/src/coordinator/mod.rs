//! L3 coordinator: the serving-system half of the reproduction.
//!
//! request → router/admission → dynamic batcher → dispatcher → worker
//! pool → PJRT engine; plus the shared paged KV store and metrics.
//! Prefill requests and decode generations share the store and the
//! batcher, with decode steps continuously batched between prefill
//! batches. Generations route through refcounted prefix holders
//! (shared-prefix fan-out: one ingest per unique prompt, N forked
//! continuations diverging copy-on-write — `submit_generate_many`),
//! matched either by exact prompt hash or token-granularly through the
//! [`prefix::RadixIndex`] (`--prefix-mode`), where a partial hit forks
//! the covered pages and ingests only the prompt suffix. See `server.rs`
//! for the threading model and the prefix cache, and
//! `docs/ARCHITECTURE.md` for the end-to-end dataflow.

pub mod admission;
pub mod batcher;
pub mod degrade;
pub mod kv_cache;
pub mod metrics;
pub mod prefix;
pub mod request;
pub mod server;

pub use degrade::{DegradeConfig, Degrader};
pub use prefix::{PrefixIndex, PrefixMode, RadixIndex, RadixMatch};
pub use request::{
    Finish, GenerateRequest, GenerateResponse, Method, PrefillRequest, PrefillResponse, ServeError,
};
pub use server::{prompt_hash, CancelHandle, Coordinator, CoordinatorConfig, GenerateTicket};
