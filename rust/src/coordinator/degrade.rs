//! Graceful-degradation ladder: a hysteresis state machine the
//! dispatcher consults to step service quality down (and back up)
//! under sustained overload, instead of collapsing ad hoc.
//!
//! Pressure signals are KV-pool occupancy and the shed/reject rate
//! since the last evaluation. The ladder has four levels, applied to
//! *newly launched* branches only (in-flight work is never mutated, so
//! every step is reversible):
//!
//! * **0** — full service.
//! * **1** — speculative drafting halved (γ → γ/2): drafts burn decode
//!   throughput that overload needs for committed tokens.
//! * **2** — drafting off (γ = 0), the prefix-holder cap shrunk
//!   (parked holders pin KV pages that queued work is waiting for) and
//!   the ingest chunk size halved, so long prompts yield to decode
//!   lanes more often.
//! * **3** — decode top-k budgets tightened toward the schedule floor
//!   (Lil-style: decode-stage sparsity degrades more gracefully than
//!   prefill, so the budget is the last thing cut and the first
//!   restored) and the ingest chunk size quartered.
//!
//! Transitions need `up_patience` consecutive pressured evaluations to
//! step down and `down_patience` calm ones to step up, so a single
//! burst cannot flap the ladder.

use std::time::{Duration, Instant};

/// Tuning knobs of the [`Degrader`] (see module docs).
#[derive(Debug, Clone)]
pub struct DegradeConfig {
    /// Occupancy fraction at or above which an evaluation counts as
    /// pressured.
    pub hi_occupancy: f64,
    /// Occupancy fraction below which an evaluation counts as calm
    /// (between the two thresholds neither streak advances).
    pub lo_occupancy: f64,
    /// Requests shed/rejected since the previous evaluation at or above
    /// which an evaluation counts as pressured regardless of occupancy.
    pub shed_per_eval: u64,
    /// Consecutive pressured evaluations before stepping down a level.
    pub up_patience: u32,
    /// Consecutive calm evaluations before stepping back up a level.
    pub down_patience: u32,
    /// Minimum spacing between evaluations; [`Degrader::observe`] calls
    /// inside the window return the current level unchanged.
    pub eval_every: Duration,
}

impl Default for DegradeConfig {
    fn default() -> Self {
        DegradeConfig {
            hi_occupancy: 0.85,
            lo_occupancy: 0.60,
            shed_per_eval: 4,
            up_patience: 3,
            down_patience: 6,
            eval_every: Duration::from_millis(5),
        }
    }
}

/// Deepest ladder level (see module docs for what each level disables).
pub const MAX_LEVEL: u8 = 3;

/// The ladder's state: current level plus the pressured/calm streaks
/// driving hysteresis. Purely computational — the dispatcher owns one
/// and applies the level to new branches.
#[derive(Debug)]
pub struct Degrader {
    cfg: DegradeConfig,
    level: u8,
    pressured_streak: u32,
    calm_streak: u32,
    last_eval: Option<Instant>,
}

impl Degrader {
    /// A ladder at level 0 with the given tuning.
    pub fn new(cfg: DegradeConfig) -> Degrader {
        Degrader { cfg, level: 0, pressured_streak: 0, calm_streak: 0, last_eval: None }
    }

    /// Current level (0 = full service ..= [`MAX_LEVEL`]).
    pub fn level(&self) -> u8 {
        self.level
    }

    /// Feed one observation (`now` is passed in so tests drive time):
    /// KV occupancy as a fraction and requests shed/rejected since the
    /// previous evaluation. Returns the possibly-updated level.
    /// Evaluations are rate-limited by `eval_every`; calls inside the
    /// window are no-ops.
    pub fn observe(&mut self, now: Instant, occupancy: f64, shed_delta: u64) -> u8 {
        if let Some(last) = self.last_eval {
            if now.duration_since(last) < self.cfg.eval_every {
                return self.level;
            }
        }
        self.last_eval = Some(now);
        let pressured = occupancy >= self.cfg.hi_occupancy || shed_delta >= self.cfg.shed_per_eval;
        let calm = occupancy < self.cfg.lo_occupancy && shed_delta == 0;
        if pressured {
            self.calm_streak = 0;
            self.pressured_streak += 1;
            if self.pressured_streak >= self.cfg.up_patience && self.level < MAX_LEVEL {
                self.level += 1;
                self.pressured_streak = 0;
            }
        } else if calm {
            self.pressured_streak = 0;
            self.calm_streak += 1;
            if self.calm_streak >= self.cfg.down_patience && self.level > 0 {
                self.level -= 1;
                self.calm_streak = 0;
            }
        } else {
            // between the thresholds: hold both the level and the streaks
            self.pressured_streak = 0;
            self.calm_streak = 0;
        }
        self.level
    }

    /// Speculative draft length to launch new branches with: the
    /// requested γ at level 0, halved at level 1, zero from level 2.
    pub fn effective_gamma(&self, requested: usize) -> usize {
        match self.level {
            0 => requested,
            1 => requested / 2,
            _ => 0,
        }
    }

    /// Prefix-holder cap under the current level: the full cap until
    /// level 2, then a quarter of it (≥ 1) so parked holders stop
    /// pinning pages queued work needs.
    pub fn holder_cap(&self, full: usize) -> usize {
        if self.level >= 2 {
            (full / 4).max(1)
        } else {
            full
        }
    }

    /// Decode top-k starting budget under the current level: unchanged
    /// until level 3, then halved but never below `floor_blocks` (the
    /// schedule's min-blocks floor).
    pub fn effective_k_start(&self, requested: f64, floor_blocks: usize) -> f64 {
        if self.level >= MAX_LEVEL {
            (requested / 2.0).max(floor_blocks as f64)
        } else {
            requested
        }
    }

    /// Ingest chunk size under the current level: the configured size
    /// until level 2, halved there and quartered at level 3 (floor 256
    /// tokens), so a pressured scheduler yields to decode lanes more
    /// often. `base == 0` (chunking disabled, monolithic ingest) is
    /// passed through untouched.
    pub fn effective_chunk_tokens(&self, base: usize) -> usize {
        if base == 0 {
            return 0;
        }
        let scaled = match self.level {
            0 | 1 => base,
            2 => base / 2,
            _ => base / 4,
        };
        scaled.max(256.min(base))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn degrader() -> Degrader {
        // 1ms eval window so tests can step time explicitly
        Degrader::new(DegradeConfig {
            up_patience: 2,
            down_patience: 3,
            eval_every: Duration::from_millis(1),
            ..DegradeConfig::default()
        })
    }

    /// Advance a degrader through `n` evaluations of the same signal.
    fn feed(d: &mut Degrader, t0: Instant, start: u32, n: u32, occ: f64, shed: u64) -> u8 {
        let mut lvl = d.level();
        for i in start..start + n {
            lvl = d.observe(t0 + Duration::from_millis(2 * (i as u64 + 1)), occ, shed);
        }
        lvl
    }

    #[test]
    fn steps_down_only_after_sustained_pressure() {
        let mut d = degrader();
        let t0 = Instant::now();
        assert_eq!(feed(&mut d, t0, 0, 1, 0.95, 0), 0, "one pressured eval is not enough");
        assert_eq!(feed(&mut d, t0, 1, 1, 0.95, 0), 1, "second consecutive steps down");
        assert_eq!(feed(&mut d, t0, 2, 2, 0.95, 0), 2, "pressure keeps stepping");
        assert_eq!(feed(&mut d, t0, 4, 10, 0.95, 0), 3, "clamped at MAX_LEVEL");
    }

    #[test]
    fn shed_rate_alone_is_pressure() {
        let mut d = degrader();
        let t0 = Instant::now();
        assert_eq!(feed(&mut d, t0, 0, 2, 0.1, 10), 1, "shedding counts even at low occupancy");
    }

    #[test]
    fn recovers_with_hysteresis() {
        let mut d = degrader();
        let t0 = Instant::now();
        feed(&mut d, t0, 0, 4, 0.95, 0); // down to level 2
        assert_eq!(d.level(), 2);
        // calm evals: down_patience=3 per step up
        assert_eq!(feed(&mut d, t0, 4, 2, 0.1, 0), 2, "two calm evals hold the level");
        assert_eq!(feed(&mut d, t0, 6, 1, 0.1, 0), 1, "third steps back up");
        assert_eq!(feed(&mut d, t0, 7, 3, 0.1, 0), 0, "and eventually recovers fully");
        // mid-band neither advances: streaks reset, level holds
        feed(&mut d, t0, 10, 1, 0.95, 0); // pressured streak = 1
        assert_eq!(feed(&mut d, t0, 11, 8, 0.7, 0), 0, "between thresholds holds steady");
        assert_eq!(feed(&mut d, t0, 19, 1, 0.95, 0), 0, "mid-band reset the pressured streak");
    }

    #[test]
    fn rate_limited_evaluations() {
        let mut d = degrader();
        let t0 = Instant::now();
        d.observe(t0, 0.95, 0);
        // same instant: inside the window, ignored no matter how often
        for _ in 0..10 {
            d.observe(t0, 0.95, 0);
        }
        assert_eq!(d.level(), 0, "rapid re-observations must not fast-forward the ladder");
    }

    #[test]
    fn level_maps_to_knobs() {
        let mut d = degrader();
        assert_eq!(d.effective_gamma(4), 4);
        assert_eq!(d.holder_cap(32), 32);
        assert_eq!(d.effective_k_start(8.0, 4), 8.0);
        assert_eq!(d.effective_chunk_tokens(2048), 2048);
        let t0 = Instant::now();
        feed(&mut d, t0, 0, 20, 0.95, 0); // ride to MAX_LEVEL
        assert_eq!(d.level(), MAX_LEVEL);
        assert_eq!(d.effective_gamma(4), 0);
        assert_eq!(d.holder_cap(32), 8);
        assert_eq!(d.effective_k_start(8.0, 4), 4.0, "halved");
        assert_eq!(d.effective_k_start(6.0, 4), 4.0, "never below the floor");
        assert_eq!(d.effective_chunk_tokens(2048), 512, "quartered at MAX_LEVEL");
        assert_eq!(d.effective_chunk_tokens(512), 256, "floored at 256 tokens");
        assert_eq!(d.effective_chunk_tokens(128), 128, "small bases pass through");
        assert_eq!(d.effective_chunk_tokens(0), 0, "monolithic stays monolithic");
    }
}
