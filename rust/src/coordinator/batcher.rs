//! Dynamic batcher: groups compatible prefill requests so a worker picks
//! up a whole batch at once, and continuously batches decode steps
//! between them (vLLM-style continuous batching across both phases).
//!
//! Prefill compatibility key = (module kind, seqlen bucket, checkpoint):
//! the compiled artifacts are per-(kind, bucket), and mixing checkpoints
//! would mix weight sets. Policy: emit a batch when (a) a queue reaches
//! `max_batch`, or (b) its head request has waited `max_wait` — classic
//! size-or-timeout.
//!
//! Decode steps live in their own lane: every active generation
//! re-enqueues one [`DecodeStep`] after each token, and
//! [`Batcher::pop_ready_any`] alternates between the lanes so a stream of
//! prefill bursts cannot starve inter-token latency (nor vice versa).
//! Decode uses a much shorter timeout — a step is one token of someone's
//! stream. Pure logic, no threads: the server drives it, the tests poke
//! it directly.
//!
//! Chunked prompt ingest adds a third lane: a long prompt is split into
//! fixed-token chunks and each chunk becomes one [`IngestStep`] the
//! dispatcher re-enqueues after the previous chunk lands, so a 128K-token
//! ingest no longer occupies a worker for a whole prefill turn while
//! decode stalls. Ingest competes with decode under an SLO-aware pick
//! rule: oldest-deadline-first (a step without a deadline sorts after
//! every step with one), with a never-starve bound on consecutive
//! same-lane pops — and the hard invariant that the batcher never emits
//! two consecutive ingest rounds while a ready decode head has waited
//! past the decode lane's `max_wait`.

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

use super::request::PrefillRequest;

/// Prefill batch compatibility key: requests in one batch must share
/// the compiled module kind, the sequence-length bucket and the weight
/// checkpoint.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct BatchKey {
    /// Compiled module kind (e.g. `"prefill_stem"`).
    pub kind: &'static str,
    /// Padded sequence-length bucket.
    pub bucket: usize,
    /// Weight checkpoint name.
    pub checkpoint: String,
}

/// A formed prefill batch handed to a worker.
#[derive(Debug)]
pub struct Batch {
    /// Compatibility key every request in the batch shares.
    pub key: BatchKey,
    /// The batched requests, FIFO within the key.
    pub requests: Vec<PrefillRequest>,
    /// When the batcher emitted this batch.
    pub formed_at: Instant,
}

/// Size-or-timeout policy of the prefill lane.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Emit a batch as soon as a queue reaches this many requests.
    pub max_batch: usize,
    /// Emit a partial batch once its head request has waited this long.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(2) }
    }
}

/// One pending decode step of an active generation (the sequence id is
/// enough — the dispatcher owns the session state).
#[derive(Debug, Clone, Copy)]
pub struct DecodeStep {
    /// Sequence id of the generation this step advances.
    pub seq: u64,
    /// Tokens this step may commit: 1 for a plain decode step, γ+1 for a
    /// speculative draft/verify round — so one lane round can carry
    /// multi-token steps and the dispatcher can reason about queued
    /// *tokens*, not just queued steps.
    pub tokens: usize,
    /// When the step entered the decode lane.
    pub enqueued: Instant,
}

/// A group of decode steps emitted together (steps of *different*
/// sequences — one sequence has at most one step in flight).
#[derive(Debug)]
pub struct DecodeBatch {
    /// The batched steps (distinct sequences), FIFO.
    pub steps: Vec<DecodeStep>,
    /// When the batcher emitted this batch.
    pub formed_at: Instant,
}

/// Size-or-timeout policy of the decode lane. The timeout is an order of
/// magnitude tighter than prefill's: a decode step is one token of a
/// live stream, so holding it for batch-fill hurts inter-token latency.
#[derive(Debug, Clone)]
pub struct DecodeLaneConfig {
    /// Emit a decode batch as soon as this many steps are queued.
    pub max_batch: usize,
    /// Emit a partial batch once its head step has waited this long.
    pub max_wait: Duration,
}

impl Default for DecodeLaneConfig {
    fn default() -> Self {
        DecodeLaneConfig { max_batch: 8, max_wait: Duration::from_micros(200) }
    }
}

/// One pending prompt-ingest chunk of a resumable chunked prefill. The
/// holder key is enough — the dispatcher owns the session and the
/// remaining-suffix cursor; the batcher only schedules *when* the next
/// chunk runs relative to decode traffic.
#[derive(Debug, Clone, Copy)]
pub struct IngestStep {
    /// Prefix-holder key whose ingest this chunk advances.
    pub key: u64,
    /// Tokens the chunk will ingest (for queued-token accounting).
    pub tokens: usize,
    /// Earliest deadline among the branches waiting on this ingest —
    /// the SLO the pick rule orders by. `None` sorts after every
    /// deadline-carrying step.
    pub deadline: Option<Instant>,
    /// When the chunk entered the ingest lane.
    pub enqueued: Instant,
}

/// Policy of the ingest lane. Chunks are coarse units of work (whole
/// `extend_prompt` calls), so there is no size-or-timeout batching —
/// a queued chunk is always ready; the knob is the fairness bound.
#[derive(Debug, Clone)]
pub struct IngestLaneConfig {
    /// Never-starve bound: maximum consecutive pops from one lane of the
    /// decode/ingest pair while the other lane has ready work. Tightened
    /// to 1 for ingest whenever a ready decode head has already waited
    /// past the decode lane's `max_wait`.
    pub starve_bound: usize,
}

impl Default for IngestLaneConfig {
    fn default() -> Self {
        IngestLaneConfig { starve_bound: 2 }
    }
}

/// Either kind of ready work ([`Batcher::pop_ready_any`]).
#[derive(Debug)]
pub enum AnyBatch {
    /// A prefill batch from the request lane.
    Prefill(Batch),
    /// A decode-step batch from the continuous-batching lane.
    Decode(DecodeBatch),
    /// One prompt-ingest chunk from the chunked-prefill lane.
    Ingest(IngestStep),
}

/// The two-lane dynamic batcher (see module docs). Pure logic, no
/// threads: the dispatcher drives it.
pub struct Batcher {
    cfg: BatcherConfig,
    decode_cfg: DecodeLaneConfig,
    ingest_cfg: IngestLaneConfig,
    queues: BTreeMap<BatchKey, VecDeque<PrefillRequest>>,
    decode_q: VecDeque<DecodeStep>,
    ingest_q: Vec<IngestStep>,
    pending: usize,
    /// Lane-fairness toggle: flips after every emitted batch.
    prefer_decode: bool,
    /// Consecutive ingest pops while decode had ready work (never-starve).
    consecutive_ingest: usize,
    /// Consecutive decode pops while ingest had queued work (never-starve).
    consecutive_decode: usize,
}

impl Batcher {
    /// Build a batcher with the default decode-lane policy.
    pub fn new(cfg: BatcherConfig) -> Self {
        Self::with_decode(cfg, DecodeLaneConfig::default())
    }

    /// Build a batcher with explicit policies for both lanes.
    pub fn with_decode(cfg: BatcherConfig, decode_cfg: DecodeLaneConfig) -> Self {
        Batcher {
            cfg,
            decode_cfg,
            ingest_cfg: IngestLaneConfig::default(),
            queues: BTreeMap::new(),
            decode_q: VecDeque::new(),
            ingest_q: Vec::new(),
            pending: 0,
            prefer_decode: true,
            consecutive_ingest: 0,
            consecutive_decode: 0,
        }
    }

    /// Override the ingest-lane fairness policy (builder style).
    pub fn with_ingest_cfg(mut self, ingest_cfg: IngestLaneConfig) -> Self {
        self.ingest_cfg = ingest_cfg;
        self
    }

    /// Pending work across both lanes.
    pub fn pending(&self) -> usize {
        self.pending
    }

    /// Queued decode steps (the dispatcher uses this to pick its sleep
    /// quantum — a waiting step must be re-checked at the decode lane's
    /// timeout, not prefill's).
    pub fn decode_pending(&self) -> usize {
        self.decode_q.len()
    }

    /// Upper bound on tokens the queued decode steps may commit —
    /// speculative rounds carry up to γ+1 tokens per step, so this can
    /// exceed [`Batcher::decode_pending`].
    pub fn decode_pending_tokens(&self) -> usize {
        self.decode_q.iter().map(|s| s.tokens.max(1)).sum()
    }

    /// Enqueue one prefill request under its compatibility key.
    pub fn push(&mut self, key: BatchKey, req: PrefillRequest) {
        self.queues.entry(key).or_default().push_back(req);
        self.pending += 1;
    }

    /// Enqueue one decode step (a generation's next token).
    pub fn push_decode(&mut self, step: DecodeStep) {
        self.decode_q.push_back(step);
        self.pending += 1;
    }

    /// Enqueue a sibling group of decode steps (the branches of one
    /// shared-prefix fan-out) back to back, so one `pop_ready_any` round
    /// emits them in the same decode batch whenever the group fits
    /// `max_batch` — sibling steps then share a dispatch round instead of
    /// trickling through separate timeout flushes.
    pub fn push_decode_many(&mut self, steps: Vec<DecodeStep>) {
        self.pending += steps.len();
        self.decode_q.extend(steps);
    }

    /// Enqueue one prompt-ingest chunk. A holder has at most one chunk
    /// queued at a time: the dispatcher pushes the next chunk only after
    /// the previous one lands.
    pub fn push_ingest(&mut self, step: IngestStep) {
        self.ingest_q.push(step);
        self.pending += 1;
    }

    /// Queued ingest chunks.
    pub fn ingest_pending(&self) -> usize {
        self.ingest_q.len()
    }

    /// Drop the queued ingest chunk for `key`, if any (holder abandoned
    /// mid-ingest: every waiting branch cancelled or past deadline).
    /// Returns whether a chunk was removed.
    pub fn remove_ingest(&mut self, key: u64) -> bool {
        let before = self.ingest_q.len();
        self.ingest_q.retain(|s| s.key != key);
        let removed = before - self.ingest_q.len();
        self.pending -= removed;
        removed > 0
    }

    /// Next ready batch under the size-or-timeout policy; `now` is passed
    /// in for testability.
    pub fn pop_ready(&mut self, now: Instant) -> Option<Batch> {
        // full batches first (throughput), then expired heads (latency)
        let full = self
            .queues
            .iter()
            .find(|(_, q)| q.len() >= self.cfg.max_batch)
            .map(|(k, _)| k.clone());
        let key = full.or_else(|| {
            self.queues
                .iter()
                .filter(|(_, q)| {
                    q.front().is_some_and(|r| now.duration_since(r.enqueued) >= self.cfg.max_wait)
                })
                // None sorts first but cannot occur (the filter requires a
                // head); using Option as the key keeps this panic-free
                .min_by_key(|(_, q)| q.front().map(|r| r.enqueued))
                .map(|(k, _)| k.clone())
        })?;
        // key selected above so the lookup cannot miss; `?` keeps it
        // panic-free regardless
        let q = self.queues.get_mut(&key)?;
        let n = q.len().min(self.cfg.max_batch);
        let requests: Vec<_> = q.drain(..n).collect();
        if q.is_empty() {
            self.queues.remove(&key);
        }
        self.pending -= requests.len();
        Some(Batch { key, requests, formed_at: now })
    }

    /// Next ready decode batch (size-or-timeout over the decode lane).
    pub fn pop_decode_ready(&mut self, now: Instant) -> Option<DecodeBatch> {
        let ready = self.decode_q.len() >= self.decode_cfg.max_batch
            || self
                .decode_q
                .front()
                .is_some_and(|s| now.duration_since(s.enqueued) >= self.decode_cfg.max_wait);
        if !ready {
            return None;
        }
        let n = self.decode_q.len().min(self.decode_cfg.max_batch);
        let steps: Vec<_> = self.decode_q.drain(..n).collect();
        self.pending -= steps.len();
        Some(DecodeBatch { steps, formed_at: now })
    }

    /// Whether the decode lane would emit a batch right now (size or
    /// timeout), without popping.
    fn decode_ready(&self, now: Instant) -> bool {
        self.decode_q.len() >= self.decode_cfg.max_batch
            || self
                .decode_q
                .front()
                .is_some_and(|s| now.duration_since(s.enqueued) >= self.decode_cfg.max_wait)
    }

    /// Index of the ingest chunk the SLO rule picks next:
    /// oldest-deadline-first, deadline-free steps after every
    /// deadline-carrying one, earliest-enqueued as the tie break.
    fn ingest_pick(&self) -> Option<usize> {
        self.ingest_q
            .iter()
            .enumerate()
            .min_by_key(|(_, s)| (s.deadline.is_none(), s.deadline, s.enqueued))
            .map(|(i, _)| i)
    }

    /// Emit the ingest chunk at `i`, updating the never-starve counters.
    fn pop_ingest_at(&mut self, i: usize, decode_has_ready: bool) -> AnyBatch {
        let step = self.ingest_q.swap_remove(i);
        self.pending -= 1;
        self.consecutive_ingest =
            if decode_has_ready { self.consecutive_ingest + 1 } else { 0 };
        self.consecutive_decode = 0;
        AnyBatch::Ingest(step)
    }

    /// Pick between the decode and ingest lanes — the generation-side
    /// pair — under the SLO rule. A ready decode head's implicit deadline
    /// is `enqueued + max_wait` (the latest the lane policy would have
    /// flushed it); ingest chunks carry the earliest waiter deadline.
    /// Oldest deadline wins, bounded by `IngestLaneConfig::starve_bound`
    /// consecutive same-lane pops — tightened so two ingest chunks never
    /// go back to back while a ready decode head is already past
    /// `max_wait`.
    fn pop_generation_side(&mut self, now: Instant) -> Option<AnyBatch> {
        let decode_ready = self.decode_ready(now);
        let ingest = self.ingest_pick();
        match (decode_ready, ingest) {
            (false, None) => None,
            (false, Some(i)) => Some(self.pop_ingest_at(i, false)),
            (true, None) => {
                let b = self.pop_decode_ready(now)?;
                self.consecutive_ingest = 0;
                self.consecutive_decode = 0; // no ingest waiting: not starving it
                Some(AnyBatch::Decode(b))
            }
            (true, Some(i)) => {
                // hard invariant: a ready decode head past its own
                // max_wait bound allows at most one consecutive ingest pop
                let decode_expired = self
                    .decode_q
                    .front()
                    .is_some_and(|s| now.duration_since(s.enqueued) >= self.decode_cfg.max_wait);
                let ingest_bound =
                    if decode_expired { 1 } else { self.ingest_cfg.starve_bound.max(1) };
                let pick_ingest = if self.consecutive_ingest >= ingest_bound {
                    false // ingest has had its run: decode's turn
                } else if self.consecutive_decode >= self.ingest_cfg.starve_bound.max(1) {
                    true // decode has had its run: ingest's turn
                } else {
                    // oldest-deadline-first; a deadline-free chunk defers
                    // to any ready decode head (whose deadline is finite)
                    let decode_deadline =
                        self.decode_q.front().map(|s| s.enqueued + self.decode_cfg.max_wait);
                    match (self.ingest_q[i].deadline, decode_deadline) {
                        (Some(id), Some(dd)) => id < dd,
                        (Some(_), None) => true,
                        (None, _) => false,
                    }
                };
                if pick_ingest {
                    Some(self.pop_ingest_at(i, true))
                } else {
                    let b = self.pop_decode_ready(now)?;
                    self.consecutive_ingest = 0;
                    self.consecutive_decode += 1;
                    Some(AnyBatch::Decode(b))
                }
            }
        }
    }

    /// Next ready batch from any lane. The outer rule alternates the
    /// generation side (decode + ingest) with the prefill side after
    /// every emission so neither phase starves the other under sustained
    /// load; within the generation side, decode and ingest are picked by
    /// the SLO rule of [`Batcher::pop_generation_side`].
    pub fn pop_ready_any(&mut self, now: Instant) -> Option<AnyBatch> {
        let decode_first = self.prefer_decode;
        for lane in [decode_first, !decode_first] {
            if lane {
                if let Some(any) = self.pop_generation_side(now) {
                    self.prefer_decode = false;
                    return Some(any);
                }
            } else if let Some(b) = self.pop_ready(now) {
                self.prefer_decode = true;
                return Some(AnyBatch::Prefill(b));
            }
        }
        None
    }

    /// Drain everything regardless of timers (shutdown path).
    pub fn drain_all(&mut self, now: Instant) -> Vec<Batch> {
        let mut out = vec![];
        let keys: Vec<_> = self.queues.keys().cloned().collect();
        for key in keys {
            let Some(mut q) = self.queues.remove(&key) else {
                continue; // keys snapshotted above; unreachable, panic-free
            };
            while !q.is_empty() {
                let n = q.len().min(self.cfg.max_batch);
                let requests: Vec<_> = q.drain(..n).collect();
                self.pending -= requests.len();
                out.push(Batch { key: key.clone(), requests, formed_at: now });
            }
        }
        out
    }

    /// Flush the decode lane regardless of timers (shutdown path).
    pub fn drain_decode(&mut self, now: Instant) -> Option<DecodeBatch> {
        if self.decode_q.is_empty() {
            return None;
        }
        let steps: Vec<_> = self.decode_q.drain(..).collect();
        self.pending -= steps.len();
        Some(DecodeBatch { steps, formed_at: now })
    }

    /// Flush the ingest lane regardless of fairness state (shutdown
    /// path), in SLO order.
    pub fn drain_ingest(&mut self) -> Vec<IngestStep> {
        let mut steps = std::mem::take(&mut self.ingest_q);
        steps.sort_by_key(|s| (s.deadline.is_none(), s.deadline, s.enqueued));
        self.pending -= steps.len();
        steps
    }

    /// Earliest enqueue time among all queued work (for sleep timing).
    pub fn oldest_enqueue(&self) -> Option<Instant> {
        let prefill = self.queues.values().filter_map(|q| q.front()).map(|r| r.enqueued).min();
        let decode = self.decode_q.front().map(|s| s.enqueued);
        let ingest = self.ingest_q.iter().map(|s| s.enqueued).min();
        [prefill, decode, ingest].into_iter().flatten().min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Method;

    fn req(id: u64, t: Instant) -> PrefillRequest {
        PrefillRequest {
            id,
            checkpoint: "base".into(),
            method: Method::Dense,
            ids: vec![1, 2, 3],
            diag: false,
            enqueued: t,
            deadline: None,
        }
    }

    fn key(bucket: usize) -> BatchKey {
        BatchKey { kind: "prefill_dense", bucket, checkpoint: "base".into() }
    }

    #[test]
    fn emits_full_batch_immediately() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 2, max_wait: Duration::from_secs(10) });
        let t = Instant::now();
        b.push(key(512), req(1, t));
        assert!(b.pop_ready(t).is_none(), "not full, not expired");
        b.push(key(512), req(2, t));
        let batch = b.pop_ready(t).unwrap();
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn timeout_flushes_partial_batch() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5) });
        let t = Instant::now();
        b.push(key(512), req(1, t));
        assert!(b.pop_ready(t).is_none());
        let later = t + Duration::from_millis(6);
        let batch = b.pop_ready(later).unwrap();
        assert_eq!(batch.requests.len(), 1);
    }

    #[test]
    fn never_mixes_buckets() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 4, max_wait: Duration::ZERO });
        let t = Instant::now();
        b.push(key(512), req(1, t));
        b.push(key(1024), req(2, t));
        let b1 = b.pop_ready(t).unwrap();
        let b2 = b.pop_ready(t).unwrap();
        assert_ne!(b1.key.bucket, b2.key.bucket);
        assert_eq!(b1.requests.len() + b2.requests.len(), 2);
    }

    #[test]
    fn fifo_within_queue() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 3, max_wait: Duration::ZERO });
        let t = Instant::now();
        for i in 0..3 {
            b.push(key(512), req(i, t + Duration::from_micros(i)));
        }
        let batch = b.pop_ready(t + Duration::from_secs(1)).unwrap();
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    fn step(seq: u64, t: Instant) -> DecodeStep {
        DecodeStep { seq, tokens: 1, enqueued: t }
    }

    #[test]
    fn decode_lane_size_or_timeout() {
        let mut b = Batcher::with_decode(
            BatcherConfig::default(),
            DecodeLaneConfig { max_batch: 3, max_wait: Duration::from_millis(5) },
        );
        let t = Instant::now();
        b.push_decode(step(1, t));
        b.push_decode(step(2, t));
        assert!(b.pop_decode_ready(t).is_none(), "not full, not expired");
        b.push_decode(step(3, t));
        let batch = b.pop_decode_ready(t).expect("full batch");
        assert_eq!(batch.steps.len(), 3);
        assert_eq!(b.pending(), 0);
        // timeout path
        b.push_decode(step(4, t));
        assert!(b.pop_decode_ready(t).is_none());
        let batch = b.pop_decode_ready(t + Duration::from_millis(6)).expect("timeout flush");
        assert_eq!(batch.steps.len(), 1);
        assert_eq!(batch.steps[0].seq, 4);
    }

    #[test]
    fn lanes_alternate_so_neither_starves() {
        let mut b = Batcher::with_decode(
            BatcherConfig { max_batch: 1, max_wait: Duration::ZERO },
            DecodeLaneConfig { max_batch: 1, max_wait: Duration::ZERO },
        );
        let t = Instant::now();
        for i in 0..3 {
            b.push(key(512), req(i, t));
            b.push_decode(step(100 + i, t));
        }
        let mut kinds = vec![];
        while let Some(any) = b.pop_ready_any(t + Duration::from_secs(1)) {
            kinds.push(match any {
                AnyBatch::Decode(_) => 'd',
                AnyBatch::Prefill(_) => 'p',
            });
        }
        assert_eq!(kinds, vec!['d', 'p', 'd', 'p', 'd', 'p'], "lanes must alternate");
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn pop_ready_any_falls_through_to_nonempty_lane() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 1, max_wait: Duration::ZERO });
        let t = Instant::now();
        b.push(key(512), req(1, t));
        // decode lane empty: prefill must still come out even on a
        // decode-preferring turn
        assert!(matches!(b.pop_ready_any(t), Some(AnyBatch::Prefill(_))));
        b.push_decode(step(7, t));
        assert!(matches!(b.pop_ready_any(t), Some(AnyBatch::Decode(_))));
        assert!(b.pop_ready_any(t).is_none());
    }

    #[test]
    fn sibling_group_lands_in_one_decode_batch() {
        let mut b = Batcher::with_decode(
            BatcherConfig::default(),
            DecodeLaneConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
        );
        let t = Instant::now();
        b.push_decode_many((0..4).map(|i| step(100 + i, t)).collect());
        assert_eq!(b.pending(), 4);
        let batch = b.pop_decode_ready(t + Duration::from_millis(2)).expect("timeout flush");
        let seqs: Vec<u64> = batch.steps.iter().map(|s| s.seq).collect();
        assert_eq!(seqs, vec![100, 101, 102, 103], "siblings share one batch, in order");
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn multi_token_sibling_rounds_batch_together_and_count_tokens() {
        // speculative fan-out siblings: each step carries γ+1 tokens but
        // the lane still batches the whole group into one round
        let mut b = Batcher::with_decode(
            BatcherConfig::default(),
            DecodeLaneConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
        );
        let t = Instant::now();
        b.push_decode_many(
            (0..3).map(|i| DecodeStep { seq: 200 + i, tokens: 5, enqueued: t }).collect(),
        );
        b.push_decode(step(300, t)); // a plain single-token generation
        assert_eq!(b.decode_pending(), 4);
        assert_eq!(b.decode_pending_tokens(), 3 * 5 + 1);
        let batch = b.pop_decode_ready(t + Duration::from_millis(2)).expect("timeout flush");
        assert_eq!(batch.steps.len(), 4, "spec rounds and plain steps share one batch");
        assert_eq!(batch.steps.iter().map(|s| s.tokens).sum::<usize>(), 16);
        assert_eq!(b.decode_pending_tokens(), 0);
    }

    #[test]
    fn drain_decode_flushes_everything() {
        let mut b = Batcher::new(BatcherConfig::default());
        let t = Instant::now();
        for i in 0..5 {
            b.push_decode(step(i, t));
        }
        let batch = b.drain_decode(t).unwrap();
        assert_eq!(batch.steps.len(), 5);
        assert_eq!(b.pending(), 0);
        assert!(b.drain_decode(t).is_none());
    }

    #[test]
    fn oldest_enqueue_spans_both_lanes() {
        let mut b = Batcher::new(BatcherConfig::default());
        let t = Instant::now();
        b.push(key(512), req(1, t + Duration::from_millis(10)));
        b.push_decode(step(2, t));
        assert_eq!(b.oldest_enqueue(), Some(t));
    }

    fn ingest(key: u64, deadline: Option<Instant>, t: Instant) -> IngestStep {
        IngestStep { key, tokens: 2048, deadline, enqueued: t }
    }

    #[test]
    fn ingest_lane_emits_when_nothing_else_is_ready() {
        let mut b = Batcher::new(BatcherConfig::default());
        let t = Instant::now();
        b.push_ingest(ingest(7, None, t));
        assert_eq!(b.ingest_pending(), 1);
        assert_eq!(b.pending(), 1);
        match b.pop_ready_any(t) {
            Some(AnyBatch::Ingest(s)) => assert_eq!(s.key, 7),
            other => panic!("expected ingest chunk, got {other:?}"),
        }
        assert_eq!(b.pending(), 0);
        assert!(b.pop_ready_any(t).is_none());
    }

    #[test]
    fn ingest_pops_oldest_deadline_first() {
        let mut b = Batcher::new(BatcherConfig::default());
        let t = Instant::now();
        b.push_ingest(ingest(1, None, t));
        b.push_ingest(ingest(2, Some(t + Duration::from_millis(50)), t));
        b.push_ingest(ingest(3, Some(t + Duration::from_millis(10)), t));
        let mut order = vec![];
        while let Some(AnyBatch::Ingest(s)) = b.pop_ready_any(t) {
            order.push(s.key);
        }
        assert_eq!(order, vec![3, 2, 1], "earliest deadline first, None last");
    }

    #[test]
    fn urgent_ingest_preempts_decode_once_but_never_twice() {
        // decode head is already past max_wait (expired => ready), and a
        // stream of urgent ingest chunks tries to hog the lane: the
        // never-starve invariant caps consecutive ingest pops at one
        let mut b = Batcher::with_decode(
            BatcherConfig::default(),
            DecodeLaneConfig { max_batch: 1, max_wait: Duration::from_millis(10) },
        );
        let t = Instant::now();
        for i in 0..4 {
            b.push_decode(step(100 + i, t));
            // deadline earlier than the decode head's implicit
            // enqueued+max_wait deadline, so the SLO rule prefers ingest
            b.push_ingest(ingest(i, Some(t + Duration::from_millis(1)), t));
        }
        let now = t + Duration::from_millis(20); // decode head long expired
        let mut kinds = vec![];
        while let Some(any) = b.pop_ready_any(now) {
            kinds.push(match any {
                AnyBatch::Ingest(_) => 'i',
                AnyBatch::Decode(_) => 'd',
                AnyBatch::Prefill(_) => 'p',
            });
        }
        assert_eq!(kinds, vec!['i', 'd', 'i', 'd', 'i', 'd', 'i', 'd']);
    }

    #[test]
    fn decode_cannot_starve_a_deadline_free_ingest() {
        // sustained expired decode traffic vs one chunk without any
        // deadline: the symmetric starve bound forces the chunk through
        // after `starve_bound` consecutive decode pops
        let mut b = Batcher::with_decode(
            BatcherConfig::default(),
            DecodeLaneConfig { max_batch: 1, max_wait: Duration::ZERO },
        )
        .with_ingest_cfg(IngestLaneConfig { starve_bound: 2 });
        let t = Instant::now();
        for i in 0..6 {
            b.push_decode(step(100 + i, t));
        }
        b.push_ingest(ingest(42, None, t));
        let now = t + Duration::from_millis(1);
        let mut kinds = vec![];
        while let Some(any) = b.pop_ready_any(now) {
            kinds.push(match any {
                AnyBatch::Ingest(_) => 'i',
                AnyBatch::Decode(_) => 'd',
                AnyBatch::Prefill(_) => 'p',
            });
        }
        assert_eq!(kinds, vec!['d', 'd', 'i', 'd', 'd', 'd', 'd']);
    }

    #[test]
    fn remove_ingest_conserves_pending() {
        let mut b = Batcher::new(BatcherConfig::default());
        let t = Instant::now();
        b.push_ingest(ingest(1, None, t));
        b.push_ingest(ingest(2, None, t));
        assert!(b.remove_ingest(1));
        assert!(!b.remove_ingest(1), "already removed");
        assert_eq!(b.pending(), 1);
        assert_eq!(b.ingest_pending(), 1);
        let steps = b.drain_ingest();
        assert_eq!(steps.len(), 1);
        assert_eq!(steps[0].key, 2);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn prefill_alternation_survives_ingest_traffic() {
        // the outer decode<->prefill alternation is pinned by
        // `lanes_alternate_so_neither_starves`; with ingest chunks in the
        // mix the prefill lane must still get every other emission
        let mut b = Batcher::with_decode(
            BatcherConfig { max_batch: 1, max_wait: Duration::ZERO },
            DecodeLaneConfig { max_batch: 1, max_wait: Duration::ZERO },
        );
        let t = Instant::now();
        for i in 0..2 {
            b.push(key(512), req(i, t));
            b.push_ingest(ingest(i, Some(t), t));
        }
        let now = t + Duration::from_secs(1);
        let mut kinds = vec![];
        while let Some(any) = b.pop_ready_any(now) {
            kinds.push(match any {
                AnyBatch::Ingest(_) => 'i',
                AnyBatch::Decode(_) => 'd',
                AnyBatch::Prefill(_) => 'p',
            });
        }
        assert_eq!(kinds, vec!['i', 'p', 'i', 'p'], "prefill gets every other turn");
    }

    #[test]
    fn fairness_under_random_interleavings() {
        // satellite property: under randomized interleavings of long
        // ingests and decode lanes, (a) work is conserved, (b) the lane
        // never emits two consecutive ingest chunks while a ready decode
        // head is past the decode max_wait bound
        use crate::util::prop::forall;
        use crate::util::rng::Rng;
        forall(
            11,
            60,
            |r: &mut Rng| {
                (0..40)
                    .map(|_| {
                        // 0 => decode step, 1 => urgent ingest, 2 => lazy ingest
                        (r.below(3) as u32, r.below(4))
                    })
                    .collect::<Vec<(u32, u64)>>()
            },
            |ops| {
                let mut b = Batcher::with_decode(
                    BatcherConfig::default(),
                    DecodeLaneConfig { max_batch: 2, max_wait: Duration::ZERO },
                );
                let t = Instant::now();
                let mut n_decode = 0usize;
                let mut n_ingest = 0usize;
                for (i, &(op, jitter)) in ops.iter().enumerate() {
                    let at = t + Duration::from_micros(jitter);
                    match op {
                        0 => {
                            b.push_decode(step(i as u64, at));
                            n_decode += 1;
                        }
                        1 => {
                            b.push_ingest(ingest(i as u64, Some(at), at));
                            n_ingest += 1;
                        }
                        _ => {
                            b.push_ingest(ingest(i as u64, None, at));
                            n_ingest += 1;
                        }
                    }
                }
                let now = t + Duration::from_millis(5);
                let mut got_decode = 0usize;
                let mut got_ingest = 0usize;
                let mut prev_was_ingest = false;
                while let Some(any) = {
                    let decode_head_expired = b.decode_ready(now);
                    let popped = b.pop_ready_any(now);
                    if let Some(AnyBatch::Ingest(_)) = popped {
                        if prev_was_ingest && decode_head_expired {
                            return Err("two ingest rounds past a ready decode lane".into());
                        }
                        prev_was_ingest = true;
                    } else if popped.is_some() {
                        prev_was_ingest = false;
                    }
                    popped
                } {
                    match any {
                        AnyBatch::Decode(batch) => got_decode += batch.steps.len(),
                        AnyBatch::Ingest(_) => got_ingest += 1,
                        AnyBatch::Prefill(_) => return Err("no prefill was pushed".into()),
                    }
                }
                if b.pending() != 0 {
                    return Err(format!("pending stuck at {}", b.pending()));
                }
                if got_decode != n_decode || got_ingest != n_ingest {
                    return Err(format!(
                        "lost work: decode {got_decode}/{n_decode}, ingest {got_ingest}/{n_ingest}"
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn conservation_under_random_traffic() {
        use crate::util::prop::forall;
        use crate::util::rng::Rng;
        forall(
            7,
            50,
            |r: &mut Rng| (0..30).map(|_| r.below(3) as usize).collect::<Vec<usize>>(),
            |buckets| {
                let mut b =
                    Batcher::new(BatcherConfig { max_batch: 3, max_wait: Duration::ZERO });
                let t = Instant::now();
                let mut pushed = vec![];
                for (i, &bk) in buckets.iter().enumerate() {
                    b.push(key(512 << bk), req(i as u64, t));
                    pushed.push(i as u64);
                }
                let mut popped = vec![];
                while let Some(batch) = b.pop_ready(t + Duration::from_secs(1)) {
                    for r in batch.requests {
                        popped.push(r.id);
                    }
                }
                popped.sort();
                if popped == pushed {
                    Ok(())
                } else {
                    Err(format!("lost/dup requests: {} vs {}", popped.len(), pushed.len()))
                }
            },
        );
    }
}
