//! Dynamic batcher: groups compatible prefill requests so a worker picks
//! up a whole batch at once (vLLM-style continuous batching, restricted to
//! the prefill phase this paper optimizes).
//!
//! Compatibility key = (module kind, seqlen bucket, checkpoint): the
//! compiled artifacts are per-(kind, bucket), and mixing checkpoints would
//! mix weight sets. Policy: emit a batch when (a) a queue reaches
//! `max_batch`, or (b) its head request has waited `max_wait` — classic
//! size-or-timeout. Pure logic, no threads: the server drives it, the
//! tests poke it directly.

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

use super::request::PrefillRequest;

#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct BatchKey {
    pub kind: &'static str,
    pub bucket: usize,
    pub checkpoint: String,
}

#[derive(Debug)]
pub struct Batch {
    pub key: BatchKey,
    pub requests: Vec<PrefillRequest>,
    pub formed_at: Instant,
}

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(2) }
    }
}

pub struct Batcher {
    cfg: BatcherConfig,
    queues: BTreeMap<BatchKey, VecDeque<PrefillRequest>>,
    pending: usize,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        Batcher { cfg, queues: BTreeMap::new(), pending: 0 }
    }

    pub fn pending(&self) -> usize {
        self.pending
    }

    pub fn push(&mut self, key: BatchKey, req: PrefillRequest) {
        self.queues.entry(key).or_default().push_back(req);
        self.pending += 1;
    }

    /// Next ready batch under the size-or-timeout policy; `now` is passed
    /// in for testability.
    pub fn pop_ready(&mut self, now: Instant) -> Option<Batch> {
        // full batches first (throughput), then expired heads (latency)
        let full = self
            .queues
            .iter()
            .find(|(_, q)| q.len() >= self.cfg.max_batch)
            .map(|(k, _)| k.clone());
        let key = full.or_else(|| {
            self.queues
                .iter()
                .filter(|(_, q)| {
                    q.front().is_some_and(|r| now.duration_since(r.enqueued) >= self.cfg.max_wait)
                })
                .min_by_key(|(_, q)| q.front().map(|r| r.enqueued).unwrap())
                .map(|(k, _)| k.clone())
        })?;
        let q = self.queues.get_mut(&key).unwrap();
        let n = q.len().min(self.cfg.max_batch);
        let requests: Vec<_> = q.drain(..n).collect();
        if q.is_empty() {
            self.queues.remove(&key);
        }
        self.pending -= requests.len();
        Some(Batch { key, requests, formed_at: now })
    }

    /// Drain everything regardless of timers (shutdown path).
    pub fn drain_all(&mut self, now: Instant) -> Vec<Batch> {
        let mut out = vec![];
        let keys: Vec<_> = self.queues.keys().cloned().collect();
        for key in keys {
            let mut q = self.queues.remove(&key).unwrap();
            while !q.is_empty() {
                let n = q.len().min(self.cfg.max_batch);
                let requests: Vec<_> = q.drain(..n).collect();
                self.pending -= requests.len();
                out.push(Batch { key: key.clone(), requests, formed_at: now });
            }
        }
        out
    }

    /// Earliest enqueue time among all queued requests (for sleep timing).
    pub fn oldest_enqueue(&self) -> Option<Instant> {
        self.queues.values().filter_map(|q| q.front()).map(|r| r.enqueued).min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Method;

    fn req(id: u64, t: Instant) -> PrefillRequest {
        PrefillRequest {
            id,
            checkpoint: "base".into(),
            method: Method::Dense,
            ids: vec![1, 2, 3],
            diag: false,
            enqueued: t,
        }
    }

    fn key(bucket: usize) -> BatchKey {
        BatchKey { kind: "prefill_dense", bucket, checkpoint: "base".into() }
    }

    #[test]
    fn emits_full_batch_immediately() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 2, max_wait: Duration::from_secs(10) });
        let t = Instant::now();
        b.push(key(512), req(1, t));
        assert!(b.pop_ready(t).is_none(), "not full, not expired");
        b.push(key(512), req(2, t));
        let batch = b.pop_ready(t).unwrap();
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn timeout_flushes_partial_batch() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5) });
        let t = Instant::now();
        b.push(key(512), req(1, t));
        assert!(b.pop_ready(t).is_none());
        let later = t + Duration::from_millis(6);
        let batch = b.pop_ready(later).unwrap();
        assert_eq!(batch.requests.len(), 1);
    }

    #[test]
    fn never_mixes_buckets() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 4, max_wait: Duration::ZERO });
        let t = Instant::now();
        b.push(key(512), req(1, t));
        b.push(key(1024), req(2, t));
        let b1 = b.pop_ready(t).unwrap();
        let b2 = b.pop_ready(t).unwrap();
        assert_ne!(b1.key.bucket, b2.key.bucket);
        assert_eq!(b1.requests.len() + b2.requests.len(), 2);
    }

    #[test]
    fn fifo_within_queue() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 3, max_wait: Duration::ZERO });
        let t = Instant::now();
        for i in 0..3 {
            b.push(key(512), req(i, t + Duration::from_micros(i)));
        }
        let batch = b.pop_ready(t + Duration::from_secs(1)).unwrap();
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn conservation_under_random_traffic() {
        use crate::util::prop::forall;
        use crate::util::rng::Rng;
        forall(
            7,
            50,
            |r: &mut Rng| (0..30).map(|_| r.below(3) as usize).collect::<Vec<usize>>(),
            |buckets| {
                let mut b =
                    Batcher::new(BatcherConfig { max_batch: 3, max_wait: Duration::ZERO });
                let t = Instant::now();
                let mut pushed = vec![];
                for (i, &bk) in buckets.iter().enumerate() {
                    b.push(key(512 << bk), req(i as u64, t));
                    pushed.push(i as u64);
                }
                let mut popped = vec![];
                while let Some(batch) = b.pop_ready(t + Duration::from_secs(1)) {
                    for r in batch.requests {
                        popped.push(r.id);
                    }
                }
                popped.sort();
                if popped == pushed {
                    Ok(())
                } else {
                    Err(format!("lost/dup requests: {} vs {}", popped.len(), pushed.len()))
                }
            },
        );
    }
}
