//! Prefix-cache indexes: how the coordinator decides which cached
//! prompt prefix (if any) a new generation can reuse.
//!
//! Two lookup modes back `--prefix-mode {exact,radix}`:
//!
//! * [`PrefixIndex`] — the exact mode: a live set of prompt *hashes*. A
//!   generation reuses a cached prefix only when its prompt is
//!   byte-identical to a parked holder's prompt. Cheap, but a prompt
//!   that shares 99% of its tokens with a cached one still re-ingests
//!   everything.
//! * [`RadixIndex`] — the token-granular mode (the Stem argument taken
//!   to serving: early tokens feed *every* later aggregation, so a
//!   cached prefix is reusable by any request sharing a token prefix,
//!   not just an identical prompt). A compressed radix tree over prompt
//!   token sequences maps a new prompt to the parked holder with the
//!   longest common prefix; the reusable amount is floored to a page
//!   boundary ([`RadixMatch::covered`]) because forked page tables
//!   share whole pages — a partially-matching tail page would leak the
//!   holder's diverging tokens into the fork.
//!
//! Both indexes are advisory on the submit side (admission charges the
//! ingest estimate against the uncovered suffix only) and authoritative
//! on the dispatcher side, which owns the holder sessions and keeps the
//! index in sync as holders are created and retired. Locks degrade
//! gracefully: a poisoned index reports "no match" rather than
//! panicking the serving path.

use std::collections::HashMap;
use std::collections::HashSet;
use std::sync::Mutex;

/// How the coordinator matches new prompts against cached prefix
/// holders (`--prefix-mode`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PrefixMode {
    /// Prompt-hash matching: reuse only byte-identical prompts.
    Exact,
    /// Token-granular radix matching: reuse the longest page-aligned
    /// common token prefix of any cached prompt (the default).
    #[default]
    Radix,
}

impl std::str::FromStr for PrefixMode {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "exact" => Ok(PrefixMode::Exact),
            "radix" => Ok(PrefixMode::Radix),
            other => Err(format!("unknown prefix mode {other:?} (want exact|radix)")),
        }
    }
}

/// Prompt-hash → live-prefix set shared between the submit side (charge
/// prefill once per unique prefix) and the dispatcher (which owns the
/// entries: inserted when a holder starts ingesting, removed when it
/// retires). Admission reads are advisory — a stale hit merely
/// undercharges one request's estimate.
#[derive(Default)]
pub struct PrefixIndex {
    live: Mutex<HashSet<u64>>,
}

impl PrefixIndex {
    /// Whether `hash` names a resident or mid-ingest cached prefix.
    pub fn is_live(&self, hash: u64) -> bool {
        self.live.lock().map(|s| s.contains(&hash)).unwrap_or(false)
    }

    pub(crate) fn insert(&self, hash: u64) {
        if let Ok(mut s) = self.live.lock() {
            s.insert(hash);
        }
    }

    pub(crate) fn remove(&self, hash: u64) {
        if let Ok(mut s) = self.live.lock() {
            s.remove(&hash);
        }
    }

    /// Live (resident or mid-ingest) cached prefixes.
    pub fn len(&self) -> usize {
        self.live.lock().map(|s| s.len()).unwrap_or(0)
    }

    /// Whether no cached prefix is live.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Result of a [`RadixIndex::lookup`]: the best cached holder for a
/// prompt and how much of it is reusable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RadixMatch {
    /// Holder key the coordinator registered the matching prompt under.
    pub key: u64,
    /// Raw longest-common-prefix length, in tokens.
    pub lcp: usize,
    /// Reusable token count: `lcp` floored to a page boundary, or the
    /// whole prompt on an exact match (a full fork shares even the
    /// partially-filled tail page).
    pub covered: usize,
    /// Whether the prompt is byte-identical to the matched holder's.
    pub exact: bool,
}

/// One node of the compressed radix tree: a token run (`edge`) plus
/// children keyed by their edge's first token. `holders` lists every
/// key whose prompt passes through (or ends in) this node's subtree —
/// holder counts are capped by the coordinator's holder cache, so the
/// per-node lists stay tiny. `terminal` lists keys whose prompt ends
/// exactly at the end of this node's edge.
#[derive(Debug, Default)]
struct Node {
    edge: Vec<i32>,
    children: HashMap<i32, usize>,
    holders: Vec<u64>,
    terminal: Vec<u64>,
}

/// The tree proper (kept behind [`RadixIndex`]'s lock). Nodes live in a
/// slab `Vec` with a free list so holder churn does not grow memory
/// without bound.
#[derive(Debug)]
struct RadixTree {
    nodes: Vec<Node>,
    free: Vec<usize>,
    count: usize,
}

fn common_prefix_len(a: &[i32], b: &[i32]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

fn vec_remove(v: &mut Vec<u64>, key: u64) {
    if let Some(i) = v.iter().position(|&k| k == key) {
        v.swap_remove(i);
    }
}

impl RadixTree {
    fn new() -> Self {
        // node 0 is the root (empty edge)
        RadixTree { nodes: vec![Node::default()], free: vec![], count: 0 }
    }

    fn alloc(&mut self, node: Node) -> usize {
        match self.free.pop() {
            Some(i) => {
                self.nodes[i] = node;
                i
            }
            None => {
                self.nodes.push(node);
                self.nodes.len() - 1
            }
        }
    }

    /// Free `idx` and its whole subtree (only called when the subtree
    /// holds no keys — descendants of an empty node are empty too,
    /// because every descendant key also appears in the ancestor's
    /// `holders`).
    fn free_subtree(&mut self, idx: usize) {
        let mut stack = vec![idx];
        while let Some(i) = stack.pop() {
            stack.extend(self.nodes[i].children.values().copied());
            self.nodes[i] = Node::default();
            self.free.push(i);
        }
    }

    fn insert(&mut self, key: u64, prompt: &[i32]) {
        self.count += 1;
        let mut cur = 0usize;
        let mut i = 0usize;
        loop {
            if i == prompt.len() {
                self.nodes[cur].terminal.push(key);
                return;
            }
            let t = prompt[i];
            let Some(&child) = self.nodes[cur].children.get(&t) else {
                let leaf = self.alloc(Node {
                    edge: prompt[i..].to_vec(),
                    children: HashMap::new(),
                    holders: vec![key],
                    terminal: vec![key],
                });
                self.nodes[cur].children.insert(t, leaf);
                return;
            };
            let j = common_prefix_len(&self.nodes[child].edge, &prompt[i..]);
            if j == self.nodes[child].edge.len() {
                self.nodes[child].holders.push(key);
                cur = child;
                i += j;
                continue;
            }
            // split the child's edge at the divergence point
            let rest_first = self.nodes[child].edge[j];
            let mid_edge = self.nodes[child].edge[..j].to_vec();
            self.nodes[child].edge.drain(..j);
            let mut mid_holders = self.nodes[child].holders.clone();
            mid_holders.push(key);
            let mid = self.alloc(Node {
                edge: mid_edge,
                children: HashMap::from([(rest_first, child)]),
                holders: mid_holders,
                terminal: vec![],
            });
            self.nodes[cur].children.insert(t, mid);
            if i + j == prompt.len() {
                self.nodes[mid].terminal.push(key);
            } else {
                let leaf = self.alloc(Node {
                    edge: prompt[i + j..].to_vec(),
                    children: HashMap::new(),
                    holders: vec![key],
                    terminal: vec![key],
                });
                self.nodes[mid].children.insert(prompt[i + j], leaf);
            }
            return;
        }
    }

    fn remove(&mut self, key: u64, prompt: &[i32]) {
        let mut cur = 0usize;
        let mut i = 0usize;
        // (parent, first edge token, child) hops taken, for pruning
        let mut path: Vec<(usize, i32, usize)> = vec![];
        loop {
            if i == prompt.len() {
                // decrement the live count only for a real registration —
                // removing an absent (key, prompt) must stay a full no-op
                // so the len() gauge cannot drift
                if let Some(pos) = self.nodes[cur].terminal.iter().position(|&k| k == key) {
                    self.nodes[cur].terminal.swap_remove(pos);
                    self.count = self.count.saturating_sub(1);
                }
                break;
            }
            let t = prompt[i];
            let Some(&child) = self.nodes[cur].children.get(&t) else {
                break; // key was never inserted with this prompt: tolerate
            };
            let elen = self.nodes[child].edge.len();
            if prompt[i..].len() < elen || prompt[i..i + elen] != self.nodes[child].edge[..] {
                break;
            }
            vec_remove(&mut self.nodes[child].holders, key);
            path.push((cur, t, child));
            cur = child;
            i += elen;
        }
        // prune now-empty subtrees bottom-up (stop at the first survivor)
        for &(parent, t, child) in path.iter().rev() {
            if self.nodes[child].holders.is_empty() {
                self.nodes[parent].children.remove(&t);
                self.free_subtree(child);
            } else {
                break;
            }
        }
    }

    fn lookup(&self, prompt: &[i32], page_tokens: usize) -> Option<RadixMatch> {
        let mut cur = 0usize;
        let mut i = 0usize;
        loop {
            if i == prompt.len() {
                if let Some(&key) = self.nodes[cur].terminal.last() {
                    return Some(RadixMatch { key, lcp: i, covered: i, exact: true });
                }
                return self.best_partial(cur, i, page_tokens);
            }
            let Some(&child) = self.nodes[cur].children.get(&prompt[i]) else {
                return self.best_partial(cur, i, page_tokens);
            };
            let j = common_prefix_len(&self.nodes[child].edge, &prompt[i..]);
            if j == self.nodes[child].edge.len() {
                cur = child;
                i += j;
                continue;
            }
            // stopped mid-edge: every holder under `child` shares i+j tokens
            return self.best_partial(child, i + j, page_tokens);
        }
    }

    /// Best non-exact candidate at a stop point: any holder in `node`'s
    /// subtree shares exactly `lcp` leading tokens with the query.
    fn best_partial(&self, node: usize, lcp: usize, page_tokens: usize) -> Option<RadixMatch> {
        let covered = lcp - lcp % page_tokens.max(1);
        if covered == 0 {
            return None;
        }
        let key = *self.nodes[node].holders.last()?;
        Some(RadixMatch { key, lcp, covered, exact: false })
    }
}

/// Token-granular prefix index: a compressed radix tree over the
/// prompts of live prefix holders, shared (like [`PrefixIndex`])
/// between the submit side and the dispatcher. See module docs for the
/// matching semantics and [`RadixMatch`] for what a lookup returns.
pub struct RadixIndex {
    page_tokens: usize,
    tree: Mutex<RadixTree>,
}

impl RadixIndex {
    /// Build an empty index; `page_tokens` is the KV page size used to
    /// floor partial matches to page-aligned split points.
    pub fn new(page_tokens: usize) -> Self {
        RadixIndex { page_tokens, tree: Mutex::new(RadixTree::new()) }
    }

    /// Register `prompt` under a holder `key` (keys are unique per
    /// holder; the dispatcher allocates them from the request id space).
    pub fn insert(&self, key: u64, prompt: &[i32]) {
        if let Ok(mut t) = self.tree.lock() {
            t.insert(key, prompt);
        }
    }

    /// Remove the `(key, prompt)` registration (no-op if absent).
    pub fn remove(&self, key: u64, prompt: &[i32]) {
        if let Ok(mut t) = self.tree.lock() {
            t.remove(key, prompt);
        }
    }

    /// The holder sharing the longest token prefix with `prompt`, if any
    /// of it is reusable (exact match, or at least one whole page).
    pub fn lookup(&self, prompt: &[i32]) -> Option<RadixMatch> {
        self.tree.lock().ok().and_then(|t| t.lookup(prompt, self.page_tokens))
    }

    /// Live registered holders.
    pub fn len(&self) -> usize {
        self.tree.lock().map(|t| t.count).unwrap_or(0)
    }

    /// Whether no holder is registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    const PT: usize = 4; // page_tokens for the unit tests

    #[test]
    fn prefix_index_tracks_live_hashes() {
        let ix = PrefixIndex::default();
        assert!(ix.is_empty());
        assert!(!ix.is_live(7));
        ix.insert(7);
        assert!(ix.is_live(7));
        assert_eq!(ix.len(), 1);
        ix.remove(7);
        assert!(!ix.is_live(7));
    }

    #[test]
    fn prefix_mode_parses() {
        assert_eq!("exact".parse::<PrefixMode>().unwrap(), PrefixMode::Exact);
        assert_eq!("radix".parse::<PrefixMode>().unwrap(), PrefixMode::Radix);
        assert!("fuzzy".parse::<PrefixMode>().is_err());
        assert_eq!(PrefixMode::default(), PrefixMode::Radix);
    }

    #[test]
    fn exact_match_beats_page_flooring() {
        let ix = RadixIndex::new(PT);
        let p: Vec<i32> = vec![1, 2, 3, 4, 5, 6]; // 6 tokens: not page-aligned
        ix.insert(9, &p);
        let m = ix.lookup(&p).expect("exact hit");
        assert_eq!(m, RadixMatch { key: 9, lcp: 6, covered: 6, exact: true });
    }

    #[test]
    fn partial_match_floors_to_page_boundary() {
        let ix = RadixIndex::new(PT);
        ix.insert(1, &[1, 2, 3, 4, 5, 6, 7, 8, 9]);
        // shares 6 tokens -> 1 whole page of 4
        let m = ix.lookup(&[1, 2, 3, 4, 5, 6, 99, 98]).expect("partial hit");
        assert_eq!((m.key, m.lcp, m.covered, m.exact), (1, 6, 4, false));
        // shares only 3 tokens -> below a page: no usable match
        assert!(ix.lookup(&[1, 2, 3, 99]).is_none());
        // query that is a strict prefix of the holder still matches
        let m = ix.lookup(&[1, 2, 3, 4, 5]).expect("prefix-of-holder hit");
        assert_eq!((m.lcp, m.covered, m.exact), (5, 4, false));
    }

    #[test]
    fn longest_of_several_holders_wins() {
        let ix = RadixIndex::new(PT);
        ix.insert(1, &[1, 2, 3, 4, 9, 9, 9, 9]);
        ix.insert(2, &[1, 2, 3, 4, 5, 6, 7, 8, 50]);
        let m = ix.lookup(&[1, 2, 3, 4, 5, 6, 7, 8, 60, 61]).expect("hit");
        assert_eq!((m.key, m.lcp, m.covered), (2, 8, 8));
        // diverging right after the shared run still finds the short one
        let m = ix.lookup(&[1, 2, 3, 4, 9, 9, 70, 71]).expect("hit");
        assert_eq!((m.key, m.lcp, m.covered), (1, 6, 4));
    }

    #[test]
    fn remove_retires_holders_and_prunes() {
        let ix = RadixIndex::new(PT);
        let a: Vec<i32> = (0..12).collect();
        let b: Vec<i32> = (0..8).chain([90, 91, 92, 93]).collect();
        ix.insert(1, &a);
        ix.insert(2, &b);
        assert_eq!(ix.len(), 2);
        ix.remove(1, &a);
        assert_eq!(ix.len(), 1);
        // the shared prefix must now resolve to holder 2 only
        let m = ix.lookup(&a).expect("shared prefix still cached via b");
        assert_eq!((m.key, m.covered, m.exact), (2, 8, false));
        ix.remove(2, &b);
        assert!(ix.is_empty());
        assert!(ix.lookup(&a).is_none());
        // removing an unknown key is a no-op, not a panic
        ix.remove(3, &a);
    }

    #[test]
    fn empty_prompt_only_matches_an_empty_holder_exactly() {
        let ix = RadixIndex::new(PT);
        ix.insert(5, &[1, 2, 3, 4]);
        assert!(ix.lookup(&[]).is_none());
        ix.insert(6, &[]);
        let m = ix.lookup(&[]).expect("empty exact hit");
        assert_eq!((m.key, m.lcp, m.covered, m.exact), (6, 0, 0, true));
    }

    /// Satellite property test: against a random prompt set, every
    /// lookup must return the true longest page-aligned common prefix —
    /// checked against a brute-force LCP oracle over all live prompts —
    /// and removals must keep the index consistent.
    #[test]
    fn prop_lookup_finds_true_longest_page_aligned_prefix() {
        forall(
            42,
            60,
            |r: &mut Rng| {
                // small alphabet + shared stems force deep prefix overlap
                let n_prompts = 2 + r.below(6) as usize;
                let prompts: Vec<Vec<i32>> = (0..n_prompts)
                    .map(|_| {
                        let len = 1 + r.below(24) as usize;
                        (0..len).map(|_| r.below(3) as i32).collect()
                    })
                    .collect();
                let queries: Vec<Vec<i32>> = (0..6)
                    .map(|_| {
                        let len = 1 + r.below(24) as usize;
                        (0..len).map(|_| r.below(3) as i32).collect()
                    })
                    .collect();
                let drop_mask: Vec<bool> = (0..n_prompts).map(|_| r.below(3) == 0).collect();
                (prompts, queries, drop_mask)
            },
            |(prompts, queries, drop_mask)| {
                let ix = RadixIndex::new(PT);
                for (k, p) in prompts.iter().enumerate() {
                    ix.insert(k as u64, p);
                }
                // retire a random subset, as holder churn would
                let mut live: Vec<(u64, &Vec<i32>)> = vec![];
                for (k, p) in prompts.iter().enumerate() {
                    if drop_mask.get(k).copied().unwrap_or(false) {
                        ix.remove(k as u64, p);
                    } else {
                        live.push((k as u64, p));
                    }
                }
                if ix.len() != live.len() {
                    return Err(format!("len {} != live {}", ix.len(), live.len()));
                }
                for q in prompts.iter().chain(queries) {
                    let lcp = |p: &[i32]| common_prefix_len(q, p);
                    let oracle_lcp = live.iter().map(|(_, p)| lcp(p)).max().unwrap_or(0);
                    let oracle_exact = live.iter().any(|(_, p)| p.as_slice() == q.as_slice());
                    let oracle_covered = if oracle_exact {
                        q.len()
                    } else {
                        oracle_lcp - oracle_lcp % PT
                    };
                    match ix.lookup(q) {
                        None => {
                            if oracle_exact || oracle_covered > 0 {
                                return Err(format!(
                                    "missed match for {q:?}: oracle covered {oracle_covered}"
                                ));
                            }
                        }
                        Some(m) => {
                            let (_, held) = live
                                .iter()
                                .find(|(k, _)| *k == m.key)
                                .ok_or_else(|| format!("lookup returned dead key {}", m.key))?;
                            if lcp(held) != m.lcp {
                                return Err(format!(
                                    "reported lcp {} but true lcp with key {} is {}",
                                    m.lcp,
                                    m.key,
                                    lcp(held)
                                ));
                            }
                            if m.exact != (held.as_slice() == q.as_slice()) {
                                return Err(format!("exactness misreported for {q:?}"));
                            }
                            if m.covered != oracle_covered {
                                return Err(format!(
                                    "covered {} != oracle {oracle_covered} for {q:?}",
                                    m.covered
                                ));
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
