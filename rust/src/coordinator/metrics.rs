//! Serving metrics: counters + log-bucketed latency histograms with
//! percentile estimation (the TTFT / throughput numbers in EXPERIMENTS.md
//! come from here). The machine-readable view of this block — JSON and
//! Prometheus exposition with exact bucket export — lives in
//! [`crate::obs::snapshot`]; the flight recorder and per-band sparsity
//! telemetry ride along inside [`Metrics`] so every code path holding the
//! shared metrics handle can trace and observe without extra plumbing.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::obs::sparsity::{SparsityStats, StepTelemetry};
use crate::obs::trace::Trace;

/// Log-scale histogram: bucket i covers [2^i, 2^(i+1)) microseconds.
pub struct LatencyHisto {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl LatencyHisto {
    /// Build an empty histogram (40 power-of-two µs buckets).
    pub fn new() -> Self {
        LatencyHisto {
            buckets: (0..40).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    /// Record one latency sample.
    pub fn record(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let b = (64 - us.max(1).leading_zeros() as usize - 1).min(self.buckets.len() - 1);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency in microseconds (0 when empty).
    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Largest recorded sample in microseconds.
    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Total microseconds across all recorded samples.
    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// Percentile estimate, p in [0, 1]: the upper bound of the bucket the
    /// target sample falls in, clamped to the largest observed sample (a
    /// power-of-two bucket bound can otherwise overstate the tail ~2x).
    pub fn percentile_us(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * p).ceil() as u64;
        let mut acc = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return (1u64 << (i + 1)).min(self.max_us());
            }
        }
        self.max_us()
    }

    /// Raw per-bucket counts; bucket `i` covers `[2^i, 2^(i+1))` µs (the
    /// last bucket absorbs everything larger). Exact export for the
    /// metrics snapshot — no percentile estimation in between.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }
}

impl Default for LatencyHisto {
    fn default() -> Self {
        Self::new()
    }
}

/// Default capacity of the serving-path error ring.
pub const ERROR_LOG_CAP: usize = 64;

/// Capped ring of serving-path error strings: keeps the newest
/// [`ERROR_LOG_CAP`] entries and counts the rest as dropped, so a flapping
/// backend logging one error per request can never grow memory without
/// bound (the log used to be an unbounded `Vec`).
pub struct ErrorRing {
    cap: usize,
    logged: u64,
    dropped: u64,
    entries: VecDeque<String>,
}

impl Default for ErrorRing {
    fn default() -> Self {
        Self::with_capacity(ERROR_LOG_CAP)
    }
}

impl ErrorRing {
    /// A ring keeping the newest `cap` entries (min 1).
    pub fn with_capacity(cap: usize) -> Self {
        let cap = cap.max(1);
        ErrorRing { cap, logged: 0, dropped: 0, entries: VecDeque::with_capacity(cap) }
    }

    /// Append an error, evicting the oldest entry once full.
    pub fn push(&mut self, e: String) {
        self.logged += 1;
        if self.entries.len() == self.cap {
            self.entries.pop_front();
            self.dropped += 1;
        }
        self.entries.push_back(e);
    }

    /// The newest retained entry.
    pub fn last(&self) -> Option<&String> {
        self.entries.back()
    }

    /// Retained entries, oldest first.
    pub fn iter(&self) -> impl Iterator<Item = &String> {
        self.entries.iter()
    }

    /// Clone the retained entries, oldest first.
    pub fn to_vec(&self) -> Vec<String> {
        self.entries.iter().cloned().collect()
    }

    /// Entries currently retained.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing has been retained.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total errors ever logged (retained + dropped).
    pub fn logged(&self) -> u64 {
        self.logged
    }

    /// Errors evicted by the cap.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }
}

/// Aggregate serving metrics shared across coordinator threads.
#[derive(Default)]
pub struct Metrics {
    /// Prefill requests accepted by admission.
    pub submitted: AtomicU64,
    /// Prefill requests completed successfully.
    pub completed: AtomicU64,
    /// Requests shed by admission (all kinds).
    pub rejected: AtomicU64,
    /// Prefill batches emitted.
    pub batches: AtomicU64,
    /// Tokens ingested (prefill inputs + prompt ingests).
    pub tokens_in: AtomicU64,
    /// Queue-wait latency (submit → batch emission).
    pub queue: LatencyHisto,
    /// Execution latency on a worker.
    pub exec: LatencyHisto,
    /// Time to first token (queue + exec).
    pub ttft: LatencyHisto,
    /// sum of budget fractions * 1e6 (atomic fixed-point), for mean budget
    pub budget_sum_micro: AtomicU64,
    // --- decode phase ---------------------------------------------------
    /// Generation branches accepted by admission.
    pub generates_submitted: AtomicU64,
    /// Generation branches completed successfully.
    pub generates_completed: AtomicU64,
    /// Decode-step batches emitted by the continuous-batching lane.
    pub decode_batches: AtomicU64,
    /// Individual decode steps executed (one generated token each, so
    /// this is also the tokens-out counter).
    pub decode_steps: AtomicU64,
    /// Steps that ran the dense fallback path.
    pub decode_dense_steps: AtomicU64,
    /// Per-step decode latency.
    pub decode_step: LatencyHisto,
    /// Generation time-to-first-token: submit → first committed decode
    /// token of the branch (includes routing, queued chunked ingest and
    /// the first decode dispatch — the latency chunked ingest exists to
    /// protect).
    pub gen_ttft: LatencyHisto,
    /// Time-per-output-token: inter-commit gap per generated token
    /// (speculative rounds committing k tokens record the gap / k once
    /// per token).
    pub tpot: LatencyHisto,
    /// sum of per-step decode budget fractions * 1e6, for the mean
    pub decode_budget_sum_micro: AtomicU64,
    // --- speculative decode ---------------------------------------------
    /// Speculative draft/verify rounds executed in the decode lane.
    pub spec_rounds: AtomicU64,
    /// Draft tokens proposed across all rounds (γ per round).
    pub spec_drafted: AtomicU64,
    /// Draft tokens the batched verify accepted.
    pub spec_accepted: AtomicU64,
    /// Tokens committed by speculative rounds (accepted drafts + one
    /// verify correction/bonus per round, after stop/budget trims).
    pub spec_committed: AtomicU64,
    // --- shared-prefix fan-out ------------------------------------------
    /// Branch sessions forked off a refcounted prefix (every admitted
    /// generation branch forks exactly once).
    pub forks: AtomicU64,
    /// Branches whose prompt prefix was already resident (or mid-ingest):
    /// the prefill cost was paid by an earlier request.
    pub prefix_hits: AtomicU64,
    /// Unique prefixes that had to be ingested from scratch.
    pub prefix_misses: AtomicU64,
    /// Fan-out groups served as a *partial* prefix hit (radix mode):
    /// a page-aligned prefix was forked from a cached holder and only
    /// the uncovered prompt suffix was ingested.
    pub prefix_partial_hits: AtomicU64,
    /// Prompt tokens across all routed generate groups — the
    /// denominator of the covered-token ratio gauge.
    pub prefix_tokens_total: AtomicU64,
    /// Prompt tokens served from cached prefixes (full or partial hits)
    /// instead of being re-ingested. Advisory: a holder evicted between
    /// routing and fork can make this overcount slightly.
    pub prefix_tokens_covered: AtomicU64,
    /// Ingest chunk steps completed by the chunked-prefill lane (a
    /// monolithic ingest counts as zero; see `coordinator::batcher`).
    pub ingest_chunks: AtomicU64,
    // --- failure domains --------------------------------------------------
    /// Requests shed because their deadline passed while still queued
    /// (typed [`crate::coordinator::request::ServeError::DeadlineExceeded`]).
    pub shed_deadline: AtomicU64,
    /// Generation branches cut off mid-decode by their deadline (partial
    /// result returned with `Finish::DeadlineExceeded`).
    pub deadline_exceeded: AtomicU64,
    /// Generation branches cancelled by a cancel handle or an abandoned
    /// ticket (partial result with `Finish::Cancelled`, or response
    /// discarded because the receiver was dropped).
    pub cancelled: AtomicU64,
    /// Worker panics caught and isolated (each became a per-request
    /// error + full cleanup; the worker kept serving).
    pub worker_panics: AtomicU64,
    // --- degradation ladder -----------------------------------------------
    /// Current degradation level (0 = full service; see
    /// [`crate::coordinator::degrade`]). A gauge, not a counter.
    pub degradation_level: AtomicU64,
    /// Degradation-level transitions (either direction) since start.
    pub degradation_transitions: AtomicU64,
    /// Serving-path error strings, newest last — a capped ring (see
    /// [`ErrorRing`]): the newest [`ERROR_LOG_CAP`] survive, older
    /// entries are counted as dropped.
    pub errors: Mutex<ErrorRing>,
    // --- observability ----------------------------------------------------
    /// Flight-recorder handle. Off (`Trace::off()`) by default; the
    /// coordinator arms it from `CoordinatorConfig::trace_events` so every
    /// code path holding the shared metrics can record span events.
    pub trace: Trace,
    /// Per-context-band sparsity telemetry fed by the decode kernels (see
    /// [`crate::obs::sparsity`]).
    pub sparsity: SparsityStats,
}

impl Metrics {
    /// Build a zeroed metrics block.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a serving-path error string. A poisoned error log is
    /// recovered, not propagated — losing one diagnostic string must
    /// never fail a request.
    pub fn record_error(&self, e: String) {
        self.errors.lock().unwrap_or_else(|p| p.into_inner()).push(e);
    }

    /// Mean prefill budget fraction over completed requests.
    pub fn mean_budget(&self) -> f64 {
        let c = self.completed.load(Ordering::Relaxed);
        if c == 0 {
            0.0
        } else {
            self.budget_sum_micro.load(Ordering::Relaxed) as f64 / 1e6 / c as f64
        }
    }

    /// Mean per-step decode budget fraction over executed steps.
    pub fn mean_decode_budget(&self) -> f64 {
        let c = self.decode_steps.load(Ordering::Relaxed);
        if c == 0 {
            0.0
        } else {
            self.decode_budget_sum_micro.load(Ordering::Relaxed) as f64 / 1e6 / c as f64
        }
    }

    /// Record one executed decode step (latency, budget, dense flag).
    pub fn record_decode_step(&self, d: Duration, budget_fraction: f64, dense: bool) {
        self.decode_steps.fetch_add(1, Ordering::Relaxed);
        self.decode_step.record(d);
        self.decode_budget_sum_micro
            .fetch_add((budget_fraction * 1e6) as u64, Ordering::Relaxed);
        if dense {
            self.decode_dense_steps.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Fold one step's kernel-level sparsity observation into the
    /// per-band telemetry (blocks visited vs kept, realized vs planned k,
    /// dense-fallback cause, captured OAM score mass).
    pub fn record_step_telemetry(&self, n_ctx: usize, t: &StepTelemetry) {
        self.sparsity.observe(n_ctx, t);
    }

    /// Record one speculative draft/verify round (its committed tokens
    /// are recorded per token via [`Metrics::record_decode_step`]).
    pub fn record_spec_round(&self, drafted: u64, accepted: u64, committed: u64) {
        self.spec_rounds.fetch_add(1, Ordering::Relaxed);
        self.spec_drafted.fetch_add(drafted, Ordering::Relaxed);
        self.spec_accepted.fetch_add(accepted, Ordering::Relaxed);
        self.spec_committed.fetch_add(committed, Ordering::Relaxed);
    }

    /// Fraction of drafted tokens the verify accepted (0 before any
    /// speculative round runs).
    pub fn spec_acceptance_rate(&self) -> f64 {
        let drafted = self.spec_drafted.load(Ordering::Relaxed);
        if drafted == 0 {
            0.0
        } else {
            self.spec_accepted.load(Ordering::Relaxed) as f64 / drafted as f64
        }
    }

    /// Mean tokens committed per speculative round (0 before any runs).
    pub fn spec_tokens_per_round(&self) -> f64 {
        let rounds = self.spec_rounds.load(Ordering::Relaxed);
        if rounds == 0 {
            0.0
        } else {
            self.spec_committed.load(Ordering::Relaxed) as f64 / rounds as f64
        }
    }

    /// Render the multi-line serving report (rates computed over
    /// `wall`, the coordinator's uptime).
    pub fn report(&self, wall: Duration) -> String {
        let completed = self.completed.load(Ordering::Relaxed);
        let toks = self.tokens_in.load(Ordering::Relaxed);
        let mut out = format!(
            "requests: submitted={} completed={} rejected={} batches={}\n\
             tokens prefilled: {} ({:.0} tok/s)\n\
             TTFT  mean={:.1}ms p50={:.1}ms p90={:.1}ms p99={:.1}ms max={:.1}ms\n\
             queue mean={:.1}ms p90={:.1}ms | exec mean={:.1}ms p90={:.1}ms\n\
             mean budget fraction: {:.3}",
            self.submitted.load(Ordering::Relaxed),
            completed,
            self.rejected.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            toks,
            toks as f64 / wall.as_secs_f64().max(1e-9),
            self.ttft.mean_us() / 1e3,
            self.ttft.percentile_us(0.5) as f64 / 1e3,
            self.ttft.percentile_us(0.9) as f64 / 1e3,
            self.ttft.percentile_us(0.99) as f64 / 1e3,
            self.ttft.max_us() as f64 / 1e3,
            self.queue.mean_us() / 1e3,
            self.queue.percentile_us(0.9) as f64 / 1e3,
            self.exec.mean_us() / 1e3,
            self.exec.percentile_us(0.9) as f64 / 1e3,
            self.mean_budget(),
        );
        let steps = self.decode_steps.load(Ordering::Relaxed);
        if steps > 0 || self.generates_submitted.load(Ordering::Relaxed) > 0 {
            out.push_str(&format!(
                "\ndecode: generations submitted={} completed={} | steps={} batches={}\n\
                 tokens generated: {} ({:.0} tok/s) | step mean={:.1}µs p90={:.1}µs\n\
                 dense-fallback steps: {} | mean decode budget fraction: {:.3}",
                self.generates_submitted.load(Ordering::Relaxed),
                self.generates_completed.load(Ordering::Relaxed),
                steps,
                self.decode_batches.load(Ordering::Relaxed),
                steps,
                steps as f64 / wall.as_secs_f64().max(1e-9),
                self.decode_step.mean_us(),
                self.decode_step.percentile_us(0.9) as f64,
                self.decode_dense_steps.load(Ordering::Relaxed),
                self.mean_decode_budget(),
            ));
        }
        if self.gen_ttft.count() > 0 {
            out.push_str(&format!(
                "\ngen TTFT p50={:.1}ms p99={:.1}ms | TPOT p50={:.1}µs p99={:.1}µs | \
                 ingest chunks={}",
                self.gen_ttft.percentile_us(0.5) as f64 / 1e3,
                self.gen_ttft.percentile_us(0.99) as f64 / 1e3,
                self.tpot.percentile_us(0.5) as f64,
                self.tpot.percentile_us(0.99) as f64,
                self.ingest_chunks.load(Ordering::Relaxed),
            ));
        }
        let rounds = self.spec_rounds.load(Ordering::Relaxed);
        if rounds > 0 {
            out.push_str(&format!(
                "\nspec: rounds={rounds} drafted={} accepted={} ({:.0}% acceptance) | \
                 tokens/round={:.2}",
                self.spec_drafted.load(Ordering::Relaxed),
                self.spec_accepted.load(Ordering::Relaxed),
                100.0 * self.spec_acceptance_rate(),
                self.spec_tokens_per_round(),
            ));
        }
        let forks = self.forks.load(Ordering::Relaxed);
        let hits = self.prefix_hits.load(Ordering::Relaxed);
        let misses = self.prefix_misses.load(Ordering::Relaxed);
        let partial = self.prefix_partial_hits.load(Ordering::Relaxed);
        if forks > 0 || hits > 0 || misses > 0 || partial > 0 {
            let ptot = self.prefix_tokens_total.load(Ordering::Relaxed);
            let pcov = self.prefix_tokens_covered.load(Ordering::Relaxed);
            out.push_str(&format!(
                "\nfanout: forks={forks} | prefix hits={hits} partial={partial} misses={misses} \
                 ({:.0}% reuse) | prompt tokens covered: {pcov}/{ptot} ({:.0}%)",
                100.0 * hits as f64 / (hits + misses).max(1) as f64,
                100.0 * pcov as f64 / ptot.max(1) as f64,
            ));
        }
        if self.sparsity.total_steps() > 0 {
            out.push_str("\nsparsity (context bands):");
            for b in self.sparsity.bands().iter().filter(|b| b.steps > 0) {
                out.push_str(&format!(
                    "\n  {:>7}: steps={} dense={}(short)/{}(budget) | kept {:.1}% of blocks \
                     (planned {:.1}%) | score mass {:.1}%",
                    b.label,
                    b.steps,
                    b.dense_short_context,
                    b.dense_budget_covers,
                    100.0 * b.kept_fraction(),
                    100.0 * b.planned_fraction(),
                    100.0 * b.mean_score_mass(),
                ));
            }
        }
        let shed = self.shed_deadline.load(Ordering::Relaxed);
        let expired = self.deadline_exceeded.load(Ordering::Relaxed);
        let cancelled = self.cancelled.load(Ordering::Relaxed);
        let panics = self.worker_panics.load(Ordering::Relaxed);
        let level = self.degradation_level.load(Ordering::Relaxed);
        let trans = self.degradation_transitions.load(Ordering::Relaxed);
        let (errs, errs_dropped) = {
            let e = self.errors.lock().unwrap_or_else(|p| p.into_inner());
            (e.logged(), e.dropped())
        };
        if shed + expired + cancelled + panics + level + trans + errs > 0 {
            out.push_str(&format!(
                "\nfailures: shed_deadline={shed} deadline_exceeded={expired} \
                 cancelled={cancelled} worker_panics={panics} | \
                 degradation level={level} transitions={trans} | \
                 errors logged={errs} dropped={errs_dropped}"
            ));
        }
        out
    }

    /// Covered-token ratio gauge: the fraction of routed prompt tokens
    /// that were served from a cached prefix (full or partial hit)
    /// instead of being re-ingested. `0.0` before any generation routes.
    pub fn covered_token_ratio(&self) -> f64 {
        let total = self.prefix_tokens_total.load(Ordering::Relaxed);
        if total == 0 {
            0.0
        } else {
            self.prefix_tokens_covered.load(Ordering::Relaxed) as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histo_percentiles_ordered() {
        let h = LatencyHisto::new();
        for us in [10u64, 100, 1000, 10_000, 100_000] {
            for _ in 0..20 {
                h.record(Duration::from_micros(us));
            }
        }
        assert_eq!(h.count(), 100);
        let p50 = h.percentile_us(0.5);
        let p90 = h.percentile_us(0.9);
        let p99 = h.percentile_us(0.99);
        assert!(p50 <= p90 && p90 <= p99, "{p50} {p90} {p99}");
        assert!(h.mean_us() > 0.0);
    }

    #[test]
    fn empty_histo_safe() {
        let h = LatencyHisto::new();
        assert_eq!(h.percentile_us(0.9), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn percentile_never_exceeds_max_sample() {
        // regression: the raw upper bucket bound 1<<(i+1) overstates the
        // tail — 1000µs lands in [512, 1024) and used to report p99=1024
        let h = LatencyHisto::new();
        h.record(Duration::from_micros(1000));
        assert_eq!(h.percentile_us(0.99), 1000);
        assert_eq!(h.percentile_us(0.5), 1000);

        let h = LatencyHisto::new();
        h.record(Duration::from_micros(5));
        assert_eq!(h.percentile_us(1.0), 5, "single 5µs sample must not report 8µs");

        // mixed: every percentile stays within the observed range
        let h = LatencyHisto::new();
        for us in [3u64, 700, 999] {
            h.record(Duration::from_micros(us));
        }
        for p in [0.5, 0.9, 0.99, 1.0] {
            assert!(h.percentile_us(p) <= h.max_us(), "p{p} exceeds max_us");
        }
    }

    #[test]
    fn bucket_counts_export_is_exact() {
        let h = LatencyHisto::new();
        h.record(Duration::from_micros(1)); // bucket 0
        h.record(Duration::from_micros(3)); // bucket 1
        h.record(Duration::from_micros(3)); // bucket 1
        h.record(Duration::from_micros(1000)); // bucket 9
        let counts = h.bucket_counts();
        assert_eq!(counts.len(), 40);
        assert_eq!(counts[0], 1);
        assert_eq!(counts[1], 2);
        assert_eq!(counts[9], 1);
        assert_eq!(counts.iter().sum::<u64>(), h.count());
        assert_eq!(h.sum_us(), 1 + 3 + 3 + 1000);
    }

    #[test]
    fn error_ring_caps_and_counts_drops() {
        let mut r = ErrorRing::with_capacity(3);
        assert!(r.is_empty());
        for i in 0..10 {
            r.push(format!("err {i}"));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.logged(), 10);
        assert_eq!(r.dropped(), 7);
        assert_eq!(r.to_vec(), vec!["err 7", "err 8", "err 9"]);
        assert_eq!(r.last().map(String::as_str), Some("err 9"));
        assert_eq!(r.iter().count(), 3);
    }

    #[test]
    fn metrics_error_log_is_bounded() {
        let m = Metrics::new();
        for i in 0..(ERROR_LOG_CAP + 50) {
            m.record_error(format!("backend flap {i}"));
        }
        let e = m.errors.lock().unwrap();
        assert_eq!(e.len(), ERROR_LOG_CAP);
        assert_eq!(e.logged(), (ERROR_LOG_CAP + 50) as u64);
        assert_eq!(e.dropped(), 50);
        // newest survive
        assert_eq!(e.last().map(String::as_str), Some(format!("backend flap {}", ERROR_LOG_CAP + 49).as_str()));
    }

    #[test]
    fn sparsity_section_appears_once_observed() {
        use crate::obs::sparsity::StepTelemetry;
        let m = Metrics::new();
        assert!(!m.report(Duration::from_secs(1)).contains("sparsity"));
        m.record_step_telemetry(5000, &StepTelemetry::sparse(100, 25, 30, 0.95));
        let r = m.report(Duration::from_secs(1));
        assert!(r.contains("sparsity (context bands):"), "{r}");
        assert!(r.contains("4k-16k"), "{r}");
        assert!(r.contains("kept 25.0% of blocks"), "{r}");
    }

    #[test]
    fn decode_section_appears_once_steps_recorded() {
        let m = Metrics::new();
        let quiet = m.report(Duration::from_secs(1));
        assert!(!quiet.contains("decode:"), "no decode section before any decode work");
        m.record_decode_step(Duration::from_micros(120), 0.25, false);
        m.record_decode_step(Duration::from_micros(80), 1.0, true);
        let loud = m.report(Duration::from_secs(1));
        assert!(loud.contains("decode:"));
        assert!(loud.contains("tokens generated: 2"));
        assert_eq!(m.decode_dense_steps.load(Ordering::Relaxed), 1);
        assert!((m.mean_decode_budget() - 0.625).abs() < 1e-6);
    }

    #[test]
    fn spec_section_appears_once_rounds_recorded() {
        let m = Metrics::new();
        assert!(!m.report(Duration::from_secs(1)).contains("spec:"));
        assert_eq!(m.spec_acceptance_rate(), 0.0);
        assert_eq!(m.spec_tokens_per_round(), 0.0);
        m.record_spec_round(4, 3, 4);
        m.record_spec_round(4, 1, 2);
        let r = m.report(Duration::from_secs(1));
        assert!(r.contains("spec: rounds=2 drafted=8 accepted=4 (50% acceptance)"), "{r}");
        assert!(r.contains("tokens/round=3.00"), "{r}");
        assert!((m.spec_acceptance_rate() - 0.5).abs() < 1e-12);
        assert!((m.spec_tokens_per_round() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn gen_latency_section_appears_once_ttft_recorded() {
        let m = Metrics::new();
        assert!(!m.report(Duration::from_secs(1)).contains("gen TTFT"));
        m.gen_ttft.record(Duration::from_millis(5));
        m.tpot.record(Duration::from_micros(200));
        m.ingest_chunks.fetch_add(3, Ordering::Relaxed);
        let r = m.report(Duration::from_secs(1));
        assert!(r.contains("gen TTFT"), "{r}");
        assert!(r.contains("ingest chunks=3"), "{r}");
    }

    #[test]
    fn fanout_section_appears_once_forks_recorded() {
        let m = Metrics::new();
        assert!(!m.report(Duration::from_secs(1)).contains("fanout:"));
        assert_eq!(m.covered_token_ratio(), 0.0);
        m.forks.fetch_add(4, Ordering::Relaxed);
        m.prefix_misses.fetch_add(1, Ordering::Relaxed);
        m.prefix_hits.fetch_add(3, Ordering::Relaxed);
        m.prefix_partial_hits.fetch_add(2, Ordering::Relaxed);
        m.prefix_tokens_total.fetch_add(1000, Ordering::Relaxed);
        m.prefix_tokens_covered.fetch_add(750, Ordering::Relaxed);
        let r = m.report(Duration::from_secs(1));
        assert!(r.contains("fanout: forks=4"), "{r}");
        assert!(r.contains("hits=3 partial=2 misses=1 (75% reuse)"), "{r}");
        assert!(r.contains("prompt tokens covered: 750/1000 (75%)"), "{r}");
        assert!((m.covered_token_ratio() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn failure_section_appears_once_anything_fails() {
        let m = Metrics::new();
        assert!(!m.report(Duration::from_secs(1)).contains("failures:"));
        m.shed_deadline.fetch_add(3, Ordering::Relaxed);
        m.worker_panics.fetch_add(1, Ordering::Relaxed);
        m.degradation_level.store(2, Ordering::Relaxed);
        m.degradation_transitions.fetch_add(2, Ordering::Relaxed);
        let r = m.report(Duration::from_secs(1));
        assert!(r.contains("failures: shed_deadline=3"), "{r}");
        assert!(r.contains("worker_panics=1"), "{r}");
        assert!(r.contains("degradation level=2 transitions=2"), "{r}");
    }

    #[test]
    fn poisoned_error_log_recovers() {
        let m = std::sync::Arc::new(Metrics::new());
        let m2 = std::sync::Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.errors.lock().unwrap();
            panic!("poison the error log");
        })
        .join();
        m.record_error("after poison".into());
        let errs = m.errors.lock().unwrap_or_else(|p| p.into_inner());
        assert_eq!(errs.last().map(String::as_str), Some("after poison"));
    }
}
