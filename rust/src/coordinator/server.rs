//! The coordinator itself: router → admission → dynamic batcher →
//! dispatcher → worker pool → PJRT engine, with a paged KV pool and
//! serving metrics. This is the paper-as-a-system: the Stem budget enters
//! through `Method::Stem` scalars and shows up as lower exec latency and
//! budget fraction per request.
//!
//! Threading model (std threads; see DESIGN.md §2 on tokio):
//!   * callers enqueue via `submit` (mpsc into the dispatcher)
//!   * one dispatcher thread forms batches (size-or-timeout)
//!   * `workers` threads execute batch items on the shared PJRT engine
//!   * completions flow back through per-request channels

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::admission::{Admission, AdmissionConfig, Admit};
use super::batcher::{Batch, BatchKey, Batcher, BatcherConfig};
use super::kv_cache::{KvCache, KvConfig};
use super::metrics::Metrics;
use super::request::{Method, PrefillRequest, PrefillResponse};
use crate::model::vocab;
use crate::runtime::Engine;
use crate::util::threadpool::ThreadPool;

pub struct CoordinatorConfig {
    pub workers: usize,
    pub batcher: BatcherConfig,
    pub admission: AdmissionConfig,
    pub kv_pages: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8),
            batcher: BatcherConfig::default(),
            admission: AdmissionConfig::default(),
            kv_pages: 4096,
        }
    }
}

enum Msg {
    Request(PrefillRequest, mpsc::Sender<Result<PrefillResponse>>),
    Shutdown,
}

pub struct Coordinator {
    engine: Arc<Engine>,
    tx: mpsc::Sender<Msg>,
    dispatcher: Option<thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    admission: Arc<Admission>,
    next_id: AtomicU64,
    started: Instant,
}

impl Coordinator {
    pub fn new(engine: Arc<Engine>, cfg: CoordinatorConfig) -> Coordinator {
        let metrics = Arc::new(Metrics::new());
        let admission = Arc::new(Admission::new(cfg.admission));
        let block = engine.manifest().model.block;
        let kv = Arc::new(Mutex::new(KvCache::new(KvConfig {
            total_pages: cfg.kv_pages,
            page_tokens: block,
        })));
        let (tx, rx) = mpsc::channel::<Msg>();

        let dispatcher = {
            let engine = Arc::clone(&engine);
            let metrics = Arc::clone(&metrics);
            let admission = Arc::clone(&admission);
            let batcher_cfg = cfg.batcher.clone();
            let workers = cfg.workers;
            thread::spawn(move || {
                dispatcher_loop(rx, engine, metrics, admission, kv, batcher_cfg, workers)
            })
        };

        Coordinator {
            engine,
            tx,
            dispatcher: Some(dispatcher),
            metrics,
            admission,
            next_id: AtomicU64::new(1),
            started: Instant::now(),
        }
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// Route + admit + enqueue. Returns the response channel, or an
    /// immediate rejection (backpressure).
    pub fn submit(
        &self,
        checkpoint: &str,
        method: Method,
        ids: Vec<i32>,
        diag: bool,
    ) -> Result<mpsc::Receiver<Result<PrefillResponse>>> {
        let bucket = self
            .engine
            .manifest()
            .bucket_for(ids.len())
            .ok_or_else(|| anyhow!("request of {} tokens exceeds every bucket", ids.len()))?;
        match self.admission.try_admit(bucket) {
            Admit::Accepted => {}
            Admit::Rejected { reason } => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(anyhow!("rejected: {reason}"));
            }
        }
        let req = PrefillRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            checkpoint: checkpoint.to_string(),
            method,
            ids,
            diag,
            enqueued: Instant::now(),
        };
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        let (rtx, rrx) = mpsc::channel();
        self.tx.send(Msg::Request(req, rtx)).map_err(|_| anyhow!("coordinator stopped"))?;
        Ok(rrx)
    }

    /// Synchronous convenience wrapper (eval harness path).
    pub fn prefill_blocking(
        &self,
        checkpoint: &str,
        method: Method,
        ids: Vec<i32>,
        diag: bool,
    ) -> Result<PrefillResponse> {
        let rx = self.submit(checkpoint, method, ids, diag)?;
        rx.recv().map_err(|_| anyhow!("response channel closed"))?
    }

    pub fn uptime(&self) -> Duration {
        self.started.elapsed()
    }

    pub fn report(&self) -> String {
        self.metrics.report(self.uptime())
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn dispatcher_loop(
    rx: mpsc::Receiver<Msg>,
    engine: Arc<Engine>,
    metrics: Arc<Metrics>,
    admission: Arc<Admission>,
    kv: Arc<Mutex<KvCache>>,
    batcher_cfg: BatcherConfig,
    workers: usize,
) {
    let pool = ThreadPool::new(workers);
    let mut batcher = Batcher::new(batcher_cfg.clone());
    let mut channels: std::collections::HashMap<u64, mpsc::Sender<Result<PrefillResponse>>> =
        std::collections::HashMap::new();
    let shutdown = AtomicBool::new(false);

    loop {
        // 1. pull what's available (block briefly if nothing pending)
        let msg = if batcher.pending() == 0 {
            match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => break,
            }
        } else {
            match rx.recv_timeout(batcher_cfg.max_wait / 2) {
                Ok(m) => Some(m),
                Err(mpsc::RecvTimeoutError::Timeout) => None,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        };
        if let Some(msg) = msg {
            match msg {
                Msg::Shutdown => {
                    shutdown.store(true, Ordering::SeqCst);
                }
                Msg::Request(req, ch) => {
                    let bucket = engine.manifest().bucket_for(req.ids.len()).unwrap();
                    let key = BatchKey {
                        kind: req.method.kind(req.diag),
                        bucket,
                        checkpoint: req.checkpoint.clone(),
                    };
                    channels.insert(req.id, ch);
                    batcher.push(key, req);
                }
            }
        }

        // 2. emit ready batches to the pool
        let now = Instant::now();
        let batches: Vec<Batch> = if shutdown.load(Ordering::SeqCst) {
            batcher.drain_all(now)
        } else {
            let mut v = vec![];
            while let Some(b) = batcher.pop_ready(now) {
                v.push(b);
            }
            v
        };
        for batch in batches {
            metrics.batches.fetch_add(1, Ordering::Relaxed);
            for req in batch.requests {
                let ch = channels.remove(&req.id).unwrap();
                let engine = Arc::clone(&engine);
                let metrics = Arc::clone(&metrics);
                let admission = Arc::clone(&admission);
                let kv = Arc::clone(&kv);
                let bucket = batch.key.bucket;
                let kind = batch.key.kind;
                pool.submit(move || {
                    let out = execute_one(&engine, &kv, kind, bucket, &req);
                    match &out {
                        Ok(resp) => {
                            metrics.completed.fetch_add(1, Ordering::Relaxed);
                            metrics.tokens_in.fetch_add(req.ids.len() as u64, Ordering::Relaxed);
                            metrics.queue.record(Duration::from_micros(resp.queue_us));
                            metrics.exec.record(Duration::from_micros(resp.exec_us));
                            metrics
                                .ttft
                                .record(Duration::from_micros(resp.queue_us + resp.exec_us));
                            metrics.budget_sum_micro.fetch_add(
                                (resp.budget_fraction as f64 * 1e6) as u64,
                                Ordering::Relaxed,
                            );
                        }
                        Err(e) => metrics.record_error(e.to_string()),
                    }
                    admission.release(bucket);
                    let _ = ch.send(out);
                });
            }
        }

        if shutdown.load(Ordering::SeqCst) && batcher.pending() == 0 {
            break;
        }
    }
    pool.wait_idle();
}

fn execute_one(
    engine: &Engine,
    kv: &Mutex<KvCache>,
    kind: &'static str,
    bucket: usize,
    req: &PrefillRequest,
) -> Result<PrefillResponse> {
    let queue_us = req.enqueued.elapsed().as_micros() as u64;
    // KV pages for the prefilled sequence (released right after readback —
    // this system serves prefill; decode would hold them).
    {
        let mut kv = kv.lock().unwrap();
        kv.allocate(req.id, bucket)?;
    }
    let mut ids = req.ids.clone();
    ids.resize(bucket, vocab::PAD);
    let t0 = Instant::now();
    let result = engine.prefill(&req.checkpoint, kind, bucket, &ids, &req.method.scalars());
    let exec_us = t0.elapsed().as_micros() as u64;
    {
        let mut kv = kv.lock().unwrap();
        let _ = kv.release(req.id);
        let _ = kv.drop_seq(req.id);
    }
    let out = result?;
    Ok(PrefillResponse {
        id: req.id,
        logits: out.logits,
        vocab: out.vocab,
        n_ctx: out.n_ctx,
        n_input: req.ids.len(),
        budget_fraction: out.budget_fraction,
        hidden: out.hidden,
        queue_us,
        exec_us,
    })
}
