//! The coordinator itself: router → admission → dynamic batcher →
//! dispatcher → worker pool → PJRT engine, with a paged KV pool and
//! serving metrics. This is the paper-as-a-system: the Stem budget enters
//! through `Method::Stem` scalars on the prefill side and through the
//! decode [`DecodePolicy`] on the generation side, and shows up as lower
//! exec latency and budget fraction per request.
//!
//! Threading model (std threads; see DESIGN.md §2 on tokio):
//!   * callers enqueue via `submit` / `submit_generate` (mpsc into the
//!     dispatcher)
//!   * one dispatcher thread forms batches (size-or-timeout, prefill and
//!     decode lanes alternating — see `batcher`)
//!   * `workers` threads execute batch items on the shared PJRT engine;
//!     decode steps advance their `DecodeSession` one token and then
//!     re-enqueue themselves through the dispatcher (continuous
//!     batching), so a long generation never monopolizes a worker
//!   * completions flow back through per-request channels

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::admission::{Admission, AdmissionConfig, Admit};
use super::batcher::{
    AnyBatch, Batch, BatchKey, Batcher, BatcherConfig, DecodeLaneConfig, DecodeStep,
};
use super::kv_cache::{KvCache, KvConfig};
use super::metrics::Metrics;
use super::request::{GenerateRequest, GenerateResponse, Method, PrefillRequest, PrefillResponse};
use crate::decode::{DecodePolicy, DecodeSession, StepPlan, TinyLm};
use crate::model::vocab;
use crate::runtime::Engine;
use crate::sim::cost::{estimate_generate_ns, Geometry};
use crate::util::threadpool::ThreadPool;

pub struct CoordinatorConfig {
    pub workers: usize,
    pub batcher: BatcherConfig,
    /// Size-or-timeout policy of the decode-step lane.
    pub decode_lane: DecodeLaneConfig,
    pub admission: AdmissionConfig,
    pub kv_pages: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8),
            batcher: BatcherConfig::default(),
            decode_lane: DecodeLaneConfig::default(),
            admission: AdmissionConfig::default(),
            kv_pages: 4096,
        }
    }
}

enum Msg {
    Request(PrefillRequest, mpsc::Sender<Result<PrefillResponse>>),
    /// The f64 is the admitted work estimate (ns) to release on completion.
    Generate(GenerateRequest, mpsc::Sender<Result<GenerateResponse>>, f64),
    /// A generation finished a step and wants its next one scheduled.
    DecodeReady(u64),
    Shutdown,
}

/// One active generation owned by the dispatcher/worker handoff: the
/// session leaves the map while its step runs and returns afterwards, so
/// a sequence can never run two steps concurrently.
struct DecodeTask {
    session: DecodeSession,
    ch: mpsc::Sender<Result<GenerateResponse>>,
    prompt: Vec<i32>,
    max_new: usize,
    tokens: Vec<i32>,
    prefilled: bool,
    enqueued: Instant,
    first_step_at: Option<Instant>,
    /// Admission bookkeeping to release on completion.
    admit_tokens: usize,
    admit_ns: f64,
}

type DecodeTasks = Arc<Mutex<std::collections::HashMap<u64, DecodeTask>>>;

pub struct Coordinator {
    engine: Arc<Engine>,
    tx: mpsc::Sender<Msg>,
    dispatcher: Option<thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    admission: Arc<Admission>,
    kv: Arc<Mutex<KvCache>>,
    decode_model: Arc<TinyLm>,
    geometry: Geometry,
    workers: usize,
    next_id: AtomicU64,
    started: Instant,
}

impl Coordinator {
    pub fn new(engine: Arc<Engine>, cfg: CoordinatorConfig) -> Coordinator {
        let metrics = Arc::new(Metrics::new());
        let admission = Arc::new(Admission::new(cfg.admission));
        let m = &engine.manifest().model;
        let kv = Arc::new(Mutex::new(KvCache::new(KvConfig {
            total_pages: cfg.kv_pages,
            page_tokens: m.block,
        })));
        // decode stand-in LM shares the manifest geometry (see
        // decode::session docs); one attention layer today.
        let decode_model =
            Arc::new(TinyLm::new(0xD0C0DE, m.n_heads, m.n_kv_heads.max(1), m.d_head, m.vocab_size));
        let geometry = Geometry {
            n_layers: 1,
            n_heads: m.n_heads,
            d_head: m.d_head,
            d_model: m.n_heads * m.d_head,
            d_ff: m.d_ff,
            block: m.block,
        };
        let (tx, rx) = mpsc::channel::<Msg>();

        let dispatcher = {
            let engine = Arc::clone(&engine);
            let metrics = Arc::clone(&metrics);
            let admission = Arc::clone(&admission);
            let kv = Arc::clone(&kv);
            let decode_model = Arc::clone(&decode_model);
            let batcher_cfg = cfg.batcher.clone();
            let decode_cfg = cfg.decode_lane.clone();
            let workers = cfg.workers;
            let tx2 = tx.clone();
            thread::spawn(move || {
                dispatcher_loop(DispatcherCtx {
                    rx,
                    tx: tx2,
                    engine,
                    metrics,
                    admission,
                    kv,
                    decode_model,
                    batcher_cfg,
                    decode_cfg,
                    workers,
                })
            })
        };

        Coordinator {
            engine,
            tx,
            dispatcher: Some(dispatcher),
            metrics,
            admission,
            kv,
            decode_model,
            geometry,
            workers: cfg.workers,
            next_id: AtomicU64::new(1),
            started: Instant::now(),
        }
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// The deterministic decode LM (exposed so tests/benches can share
    /// the exact serving geometry).
    pub fn decode_model(&self) -> &Arc<TinyLm> {
        &self.decode_model
    }

    /// Route + admit + enqueue. Returns the response channel, or an
    /// immediate rejection (backpressure).
    pub fn submit(
        &self,
        checkpoint: &str,
        method: Method,
        ids: Vec<i32>,
        diag: bool,
    ) -> Result<mpsc::Receiver<Result<PrefillResponse>>> {
        let bucket = self
            .engine
            .manifest()
            .bucket_for(ids.len())
            .ok_or_else(|| anyhow!("request of {} tokens exceeds every bucket", ids.len()))?;
        match self.admission.try_admit(bucket) {
            Admit::Accepted => {}
            Admit::Rejected { reason } => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(anyhow!("rejected: {reason}"));
            }
        }
        let req = PrefillRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            checkpoint: checkpoint.to_string(),
            method,
            ids,
            diag,
            enqueued: Instant::now(),
        };
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        let (rtx, rrx) = mpsc::channel();
        self.tx.send(Msg::Request(req, rtx)).map_err(|_| anyhow!("coordinator stopped"))?;
        Ok(rrx)
    }

    /// Synchronous convenience wrapper (eval harness path).
    pub fn prefill_blocking(
        &self,
        checkpoint: &str,
        method: Method,
        ids: Vec<i32>,
        diag: bool,
    ) -> Result<PrefillResponse> {
        let rx = self.submit(checkpoint, method, ids, diag)?;
        rx.recv().map_err(|_| anyhow!("response channel closed"))?
    }

    /// Submit an autoregressive generation: admit against the decode cost
    /// model ([`estimate_generate_ns`]), then hand the prompt to the
    /// dispatcher, which interleaves its decode steps with prefill
    /// batches. The response arrives once on the returned channel.
    pub fn submit_generate(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        policy: DecodePolicy,
    ) -> Result<mpsc::Receiver<Result<GenerateResponse>>> {
        policy.validate().map_err(|e| anyhow!("invalid decode policy: {e}"))?;
        if max_new_tokens == 0 {
            return Err(anyhow!("max_new_tokens must be >= 1"));
        }
        let n_tokens = prompt.len() + max_new_tokens;
        // budget the whole generation's estimated work up front — a
        // decode stream holds pages and a worker slice for its lifetime
        let budget = match policy.plan(n_tokens, 0, self.geometry.block) {
            StepPlan::Dense => None,
            StepPlan::Sparse { budget_blocks } => Some(budget_blocks as f64),
        };
        let est_ns = estimate_generate_ns(
            &self.geometry,
            prompt.len(),
            max_new_tokens,
            budget,
            policy.stride,
            self.workers,
        );
        match self.admission.try_admit_work(n_tokens, est_ns) {
            Admit::Accepted => {}
            Admit::Rejected { reason } => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(anyhow!("rejected: {reason}"));
            }
        }
        let req = GenerateRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            prompt,
            max_new_tokens,
            policy,
            enqueued: Instant::now(),
        };
        self.metrics.generates_submitted.fetch_add(1, Ordering::Relaxed);
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Generate(req, rtx, est_ns))
            .map_err(|_| anyhow!("coordinator stopped"))?;
        Ok(rrx)
    }

    /// Synchronous convenience wrapper around [`Coordinator::submit_generate`].
    pub fn generate_blocking(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        policy: DecodePolicy,
    ) -> Result<GenerateResponse> {
        let rx = self.submit_generate(prompt, max_new_tokens, policy)?;
        rx.recv().map_err(|_| anyhow!("response channel closed"))?
    }

    pub fn uptime(&self) -> Duration {
        self.started.elapsed()
    }

    /// Current KV page occupancy (used, total, fraction).
    pub fn kv_occupancy(&self) -> (usize, usize, f64) {
        let kv = self.kv.lock().unwrap();
        (kv.used_pages(), kv.total_pages(), kv.occupancy())
    }

    pub fn report(&self) -> String {
        let (used, total, frac) = self.kv_occupancy();
        format!(
            "{}\nkv pages: {used}/{total} in use ({:.1}%)",
            self.metrics.report(self.uptime()),
            100.0 * frac
        )
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

struct DispatcherCtx {
    rx: mpsc::Receiver<Msg>,
    tx: mpsc::Sender<Msg>,
    engine: Arc<Engine>,
    metrics: Arc<Metrics>,
    admission: Arc<Admission>,
    kv: Arc<Mutex<KvCache>>,
    decode_model: Arc<TinyLm>,
    batcher_cfg: BatcherConfig,
    decode_cfg: DecodeLaneConfig,
    workers: usize,
}

fn dispatcher_loop(ctx: DispatcherCtx) {
    let DispatcherCtx {
        rx,
        tx,
        engine,
        metrics,
        admission,
        kv,
        decode_model,
        batcher_cfg,
        decode_cfg,
        workers,
    } = ctx;
    let pool = ThreadPool::new(workers);
    let mut batcher = Batcher::with_decode(batcher_cfg.clone(), decode_cfg.clone());
    let mut channels: std::collections::HashMap<u64, mpsc::Sender<Result<PrefillResponse>>> =
        std::collections::HashMap::new();
    let tasks: DecodeTasks = Arc::new(Mutex::new(std::collections::HashMap::new()));
    // generations admitted but not yet completed (steps may be in flight
    // outside both the batcher and the task map)
    let active_decodes = Arc::new(AtomicUsize::new(0));
    let shutdown = AtomicBool::new(false);

    loop {
        // 1. pull what's available (block briefly if nothing pending);
        //    while decode steps are in flight we must keep serving
        //    DecodeReady messages even with an empty batcher
        let draining = shutdown.load(Ordering::SeqCst);
        let idle = batcher.pending() == 0;
        let msg = if idle && !draining && active_decodes.load(Ordering::SeqCst) == 0 {
            match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => break,
            }
        } else {
            // sleep no longer than the tightest lane deadline: a queued
            // decode step must not wait out the (much longer) prefill
            // quantum before its age-based flush is re-checked
            let quantum = if batcher.decode_pending() > 0 {
                (batcher_cfg.max_wait / 2).min(decode_cfg.max_wait)
            } else {
                batcher_cfg.max_wait / 2
            };
            match rx.recv_timeout(quantum) {
                Ok(m) => Some(m),
                Err(mpsc::RecvTimeoutError::Timeout) => None,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        };
        if let Some(msg) = msg {
            match msg {
                Msg::Shutdown => {
                    shutdown.store(true, Ordering::SeqCst);
                }
                Msg::Request(req, ch) => {
                    let bucket = engine.manifest().bucket_for(req.ids.len()).unwrap();
                    let key = BatchKey {
                        kind: req.method.kind(req.diag),
                        bucket,
                        checkpoint: req.checkpoint.clone(),
                    };
                    channels.insert(req.id, ch);
                    batcher.push(key, req);
                }
                Msg::Generate(req, ch, est_ns) => {
                    if shutdown.load(Ordering::SeqCst) {
                        let _ = ch.send(Err(anyhow!("coordinator shutting down")));
                        admission
                            .release_work(req.prompt.len() + req.max_new_tokens, est_ns);
                        continue;
                    }
                    // on None the rejection already went out on the channel
                    if let Some((seq, task)) =
                        start_decode_task(&kv, &decode_model, &admission, req, ch, est_ns)
                    {
                        active_decodes.fetch_add(1, Ordering::SeqCst);
                        let enqueued = task.enqueued;
                        tasks.lock().unwrap().insert(seq, task);
                        batcher.push_decode(DecodeStep { seq, enqueued });
                    }
                }
                Msg::DecodeReady(seq) => {
                    batcher.push_decode(DecodeStep { seq, enqueued: Instant::now() });
                }
            }
        }

        // 2. emit ready batches to the pool
        let now = Instant::now();
        let mut any: Vec<AnyBatch> = vec![];
        if shutdown.load(Ordering::SeqCst) {
            any.extend(batcher.drain_all(now).into_iter().map(AnyBatch::Prefill));
            if let Some(d) = batcher.drain_decode(now) {
                any.push(AnyBatch::Decode(d));
            }
        } else {
            while let Some(b) = batcher.pop_ready_any(now) {
                any.push(b);
            }
        }
        for batch in any {
            match batch {
                AnyBatch::Prefill(batch) => {
                    metrics.batches.fetch_add(1, Ordering::Relaxed);
                    for req in batch.requests {
                        let ch = channels.remove(&req.id).unwrap();
                        let engine = Arc::clone(&engine);
                        let metrics = Arc::clone(&metrics);
                        let admission = Arc::clone(&admission);
                        let kv = Arc::clone(&kv);
                        let bucket = batch.key.bucket;
                        let kind = batch.key.kind;
                        pool.submit(move || {
                            let out = execute_one(&engine, &kv, kind, bucket, &req);
                            match &out {
                                Ok(resp) => {
                                    metrics.completed.fetch_add(1, Ordering::Relaxed);
                                    metrics
                                        .tokens_in
                                        .fetch_add(req.ids.len() as u64, Ordering::Relaxed);
                                    metrics.queue.record(Duration::from_micros(resp.queue_us));
                                    metrics.exec.record(Duration::from_micros(resp.exec_us));
                                    metrics
                                        .ttft
                                        .record(Duration::from_micros(resp.queue_us + resp.exec_us));
                                    metrics.budget_sum_micro.fetch_add(
                                        (resp.budget_fraction as f64 * 1e6) as u64,
                                        Ordering::Relaxed,
                                    );
                                }
                                Err(e) => metrics.record_error(e.to_string()),
                            }
                            admission.release(bucket);
                            let _ = ch.send(out);
                        });
                    }
                }
                AnyBatch::Decode(batch) => {
                    metrics.decode_batches.fetch_add(1, Ordering::Relaxed);
                    for step in batch.steps {
                        let metrics = Arc::clone(&metrics);
                        let admission = Arc::clone(&admission);
                        let tasks = Arc::clone(&tasks);
                        let active = Arc::clone(&active_decodes);
                        let tx = tx.clone();
                        pool.submit(move || {
                            run_decode_step(step.seq, &tasks, &metrics, &admission, &active, &tx);
                        });
                    }
                }
            }
        }

        if shutdown.load(Ordering::SeqCst)
            && batcher.pending() == 0
            && active_decodes.load(Ordering::SeqCst) == 0
        {
            break;
        }
    }
    pool.wait_idle();
}

/// Build the decode session for an admitted generation; on failure the
/// error goes straight back on the response channel (admission released).
fn start_decode_task(
    kv: &Arc<Mutex<KvCache>>,
    model: &Arc<TinyLm>,
    admission: &Arc<Admission>,
    req: GenerateRequest,
    ch: mpsc::Sender<Result<GenerateResponse>>,
    est_ns: f64,
) -> Option<(u64, DecodeTask)> {
    let admit_tokens = req.prompt.len() + req.max_new_tokens;
    let session =
        DecodeSession::new(Arc::clone(kv), Arc::clone(model), req.policy, req.id);
    match session {
        Ok(session) => Some((
            req.id,
            DecodeTask {
                session,
                ch,
                prompt: req.prompt,
                max_new: req.max_new_tokens,
                tokens: Vec::new(),
                prefilled: false,
                enqueued: req.enqueued,
                first_step_at: None,
                admit_tokens,
                admit_ns: est_ns,
            },
        )),
        Err(e) => {
            admission.release_work(admit_tokens, est_ns);
            let _ = ch.send(Err(anyhow!("kv allocation failed: {e}")));
            None
        }
    }
}

/// Advance one generation by one token on a worker thread, then either
/// complete it or hand it back to the dispatcher for its next step.
fn run_decode_step(
    seq: u64,
    tasks: &DecodeTasks,
    metrics: &Arc<Metrics>,
    admission: &Arc<Admission>,
    active: &Arc<AtomicUsize>,
    tx: &mpsc::Sender<Msg>,
) {
    let Some(mut task) = tasks.lock().unwrap().remove(&seq) else {
        return; // task vanished (completed with an error elsewhere)
    };
    let finish = |task: DecodeTask, out: Result<GenerateResponse>| {
        if let Err(e) = &out {
            metrics.record_error(e.to_string());
        } else {
            metrics.generates_completed.fetch_add(1, Ordering::Relaxed);
        }
        admission.release_work(task.admit_tokens, task.admit_ns);
        let _ = task.ch.send(out);
        active.fetch_sub(1, Ordering::SeqCst);
    };
    if task.first_step_at.is_none() {
        task.first_step_at = Some(Instant::now());
    }
    if !task.prefilled {
        let prompt = std::mem::take(&mut task.prompt);
        if let Err(e) = task.session.prefill(&prompt) {
            finish(task, Err(anyhow!("prompt ingest failed: {e}")));
            return;
        }
        metrics.tokens_in.fetch_add(prompt.len() as u64, Ordering::Relaxed);
        task.prompt = prompt;
        task.prefilled = true;
    }
    match task.session.step_once() {
        Ok(info) => {
            metrics.record_decode_step(
                Duration::from_nanos(info.step_ns),
                info.budget_fraction,
                info.dense,
            );
            task.tokens.push(info.token);
            let done = task.tokens.len() >= task.max_new || info.token == vocab::END;
            if done {
                let resp = generate_response(seq, &mut task);
                finish(task, Ok(resp));
            } else {
                tasks.lock().unwrap().insert(seq, task);
                if tx.send(Msg::DecodeReady(seq)).is_err() {
                    // dispatcher gone: complete what we have so the
                    // caller is not left hanging
                    if let Some(mut task) = tasks.lock().unwrap().remove(&seq) {
                        let resp = generate_response(seq, &mut task);
                        finish(task, Ok(resp));
                    }
                }
            }
        }
        Err(e) => finish(task, Err(anyhow!("decode step failed: {e}"))),
    }
}

/// Assemble the final [`GenerateResponse`] from a task's accumulated
/// state (single construction point for the done and dispatcher-gone
/// paths). `exec_us` is the *summed step execution time* from the
/// session's own clocks; scheduling gaps between steps show up in
/// end-to-end wall time, not here.
fn generate_response(seq: u64, task: &mut DecodeTask) -> GenerateResponse {
    let queue_us = task
        .first_step_at
        .map(|t| (t - task.enqueued).as_micros() as u64)
        .unwrap_or(0);
    let steps = task.tokens.len();
    GenerateResponse {
        id: seq,
        tokens: std::mem::take(&mut task.tokens),
        n_prompt: task.prompt.len(),
        steps,
        mean_budget_fraction: task.session.mean_budget_fraction(),
        dense_steps: task.session.dense_steps(),
        queue_us,
        exec_us: task.session.decode_ns() / 1_000,
        ns_per_token: task.session.decode_ns() as f64 / steps.max(1) as f64,
    }
}

fn execute_one(
    engine: &Engine,
    kv: &Mutex<KvCache>,
    kind: &'static str,
    bucket: usize,
    req: &PrefillRequest,
) -> Result<PrefillResponse> {
    let queue_us = req.enqueued.elapsed().as_micros() as u64;
    // KV pages for the prefilled sequence. Pure-prefill requests read the
    // logits back and release immediately; generations hold their pages
    // through a `DecodeSession` for the whole token stream instead.
    {
        let mut kv = kv.lock().unwrap();
        kv.allocate(req.id, bucket)?;
    }
    let mut ids = req.ids.clone();
    ids.resize(bucket, vocab::PAD);
    let t0 = Instant::now();
    let result = engine.prefill(&req.checkpoint, kind, bucket, &ids, &req.method.scalars());
    let exec_us = t0.elapsed().as_micros() as u64;
    {
        let mut kv = kv.lock().unwrap();
        let _ = kv.release(req.id);
        let _ = kv.drop_seq(req.id);
    }
    let out = result?;
    Ok(PrefillResponse {
        id: req.id,
        logits: out.logits,
        vocab: out.vocab,
        n_ctx: out.n_ctx,
        n_input: req.ids.len(),
        budget_fraction: out.budget_fraction,
        hidden: out.hidden,
        queue_us,
        exec_us,
    })
}
