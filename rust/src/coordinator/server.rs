//! The coordinator itself: router → admission → dynamic batcher →
//! dispatcher → worker pool → PJRT engine, with a shared paged KV store
//! and serving metrics. This is the paper-as-a-system: the Stem budget
//! enters through `Method::Stem` scalars on the prefill side and through
//! the decode [`DecodePolicy`] on the generation side, and shows up as
//! lower exec latency and budget fraction per request.
//!
//! Shared-prefix fan-out: Stem's core observation — initial tokens feed
//! every later token's aggregation — makes the prompt prefix the most
//! reused KV in the system, so generations route through *prefix
//! holder* sessions: the first request ingests a prompt once, every
//! branch (`submit_generate_many` / `fanout`) forks the refcounted
//! prefix and diverges copy-on-write. Parked holders form a prefix
//! cache (unpinned, LRU-evictable under page pressure, capped at
//! [`MAX_PREFIX_HOLDERS`] with LCP-aware retirement — the lightest
//! covered-tokens × refcount holder goes first).
//!
//! Holder lookup is governed by [`PrefixMode`] (`--prefix-mode`):
//!
//! * **exact** — prompt-hash keyed; only byte-identical prompts reuse a
//!   holder ([`PrefixIndex`]).
//! * **radix** (default) — token-granular: a [`RadixIndex`] maps the new
//!   prompt to the holder with the longest page-aligned common token
//!   prefix. A *partial* hit forks just the covered pages off the
//!   matched holder ([`DecodeSession::fork_prefix`]) into a fresh
//!   holder, ingests only the uncovered prompt suffix
//!   ([`DecodeSession::extend_prompt`]), and parks it under the full
//!   prompt — so overlapping prompt families converge onto shared page
//!   prefixes instead of re-ingesting from scratch.
//!
//! Either index lets admission charge the ingest estimate against the
//! uncovered suffix only ([`estimate_ingest_ns`] on the suffix length);
//! every branch still pays its own decode estimate.
//!
//! Threading model (std threads; see DESIGN.md §2 on tokio):
//!   * callers enqueue via `submit` / `submit_generate` /
//!     `submit_generate_many` (mpsc into the dispatcher)
//!   * one dispatcher thread forms batches (size-or-timeout, prefill and
//!     decode lanes alternating — see `batcher`) and owns the prefix
//!     holders; prompt ingest runs on a worker and reports back via
//!     `Msg::PrefixFilled`
//!   * `workers` threads execute batch items on the shared PJRT engine;
//!     decode steps advance their `DecodeSession` one token and then
//!     re-enqueue themselves through the dispatcher (continuous
//!     batching), so a long generation never monopolizes a worker —
//!     sibling branches of one fan-out enter the decode lane together
//!     and share a dispatch round
//!   * completions flow back through per-request channels

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::admission::{Admission, AdmissionConfig, Admit};
use super::batcher::{
    AnyBatch, BatchKey, Batcher, BatcherConfig, DecodeLaneConfig, DecodeStep,
};
use super::kv_cache::{KvConfig, KvError};
use super::metrics::Metrics;
use super::prefix::{PrefixIndex, PrefixMode, RadixIndex};
use super::request::{GenerateRequest, GenerateResponse, Method, PrefillRequest, PrefillResponse};
use crate::decode::{
    DecodeError, DecodePolicy, DecodeSession, SharedKv, StepInfo, StepPlan, TinyLm,
};
use crate::model::vocab;
use crate::runtime::Engine;
use crate::sim::cost::{
    estimate_generate_ns, estimate_ingest_ns, estimate_spec_step_ns, Geometry,
    SPEC_ASSUMED_ACCEPTANCE,
};
use crate::util::threadpool::ThreadPool;

/// Parked prefix holders kept as a cache before the lightest are
/// retired (their pages also yield to LRU eviction under pool pressure).
pub const MAX_PREFIX_HOLDERS: usize = 32;

/// Construction-time knobs of a [`Coordinator`].
pub struct CoordinatorConfig {
    /// Worker threads executing prefill batches and decode steps.
    pub workers: usize,
    /// Size-or-timeout policy of the prefill batcher.
    pub batcher: BatcherConfig,
    /// Size-or-timeout policy of the decode-step lane.
    pub decode_lane: DecodeLaneConfig,
    /// Backpressure limits (tokens, requests, estimated work).
    pub admission: AdmissionConfig,
    /// Total pages in the shared KV pool.
    pub kv_pages: usize,
    /// How generations match cached prompt prefixes (`--prefix-mode`):
    /// exact prompt-hash equality, or token-granular radix matching with
    /// partial (page-aligned) reuse. Defaults to radix.
    pub prefix_mode: PrefixMode,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8),
            batcher: BatcherConfig::default(),
            decode_lane: DecodeLaneConfig::default(),
            admission: AdmissionConfig::default(),
            kv_pages: 4096,
            prefix_mode: PrefixMode::default(),
        }
    }
}

/// FNV-1a over the token stream: the prefix identity used by the prefix
/// cache and the admission-side [`PrefixIndex`].
pub fn prompt_hash(prompt: &[i32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in prompt {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Mode-dispatched view over the two prefix indexes, so holder
/// bookkeeping (insert on fill start, remove on retirement) is written
/// once. Copyable borrow bundle — the dispatcher threads it through the
/// routing helpers.
#[derive(Clone, Copy)]
struct PrefixTables<'a> {
    mode: PrefixMode,
    exact: &'a PrefixIndex,
    radix: &'a RadixIndex,
}

impl PrefixTables<'_> {
    fn insert(&self, key: u64, prompt: &[i32]) {
        match self.mode {
            PrefixMode::Exact => self.exact.insert(key),
            PrefixMode::Radix => self.radix.insert(key, prompt),
        }
    }

    fn remove(&self, key: u64, prompt: &[i32]) {
        match self.mode {
            PrefixMode::Exact => self.exact.remove(key),
            PrefixMode::Radix => self.radix.remove(key, prompt),
        }
    }
}

/// Admission share of one fan-out branch, released when it completes.
#[derive(Debug, Clone, Copy)]
struct BranchAdmit {
    tokens: usize,
    ns: f64,
}

enum Msg {
    Request(PrefillRequest, mpsc::Sender<Result<PrefillResponse>>),
    /// One fan-out group: `req.fanout` branches over one shared prompt,
    /// one response channel + admission share per branch.
    Generate(GenerateRequest, Vec<mpsc::Sender<Result<GenerateResponse>>>, Vec<BranchAdmit>),
    /// A prefix holder finished (or failed) its one-time prompt ingest
    /// on a worker; the session comes back to be parked in the cache.
    PrefixFilled { key: u64, session: Result<Box<DecodeSession>, String> },
    /// A generation finished a step and wants its next one scheduled;
    /// the second field is the step's token width (γ+1 for speculative
    /// rounds, 1 otherwise) so the decode lane carries it.
    DecodeReady(u64, usize),
    Shutdown,
}

/// One active generation branch owned by the dispatcher/worker handoff:
/// the session leaves the map while its step runs and returns
/// afterwards, so a sequence can never run two steps concurrently.
struct DecodeTask {
    session: DecodeSession,
    ch: mpsc::Sender<Result<GenerateResponse>>,
    n_prompt: usize,
    max_new: usize,
    tokens: Vec<i32>,
    enqueued: Instant,
    first_step_at: Option<Instant>,
    /// Admission bookkeeping to release on completion.
    admit_tokens: usize,
    admit_ns: f64,
}

type DecodeTasks = Arc<Mutex<HashMap<u64, DecodeTask>>>;

/// One branch of a fan-out group waiting to fork its prefix.
struct BranchSpec {
    seq: u64,
    ch: mpsc::Sender<Result<GenerateResponse>>,
    max_new: usize,
    policy: DecodePolicy,
    n_prompt: usize,
    enqueued: Instant,
    admit: BranchAdmit,
}

/// A prefix-holder entry: the session that ingested (or is ingesting)
/// one unique prompt, plus branches queued while the ingest runs.
struct Holder {
    seq: u64,
    prompt: Vec<i32>,
    /// Parked after ingest; `None` while the prefill job runs on a worker.
    session: Option<DecodeSession>,
    waiting: Vec<BranchSpec>,
    /// LRU clock for cap-retirement: bumped on creation and every hit.
    last_used: u64,
}

/// The serving runtime (see module docs for the threading model).
pub struct Coordinator {
    engine: Arc<Engine>,
    tx: mpsc::Sender<Msg>,
    dispatcher: Option<thread::JoinHandle<()>>,
    /// Serving counters/histograms behind [`Coordinator::report`].
    pub metrics: Arc<Metrics>,
    admission: Arc<Admission>,
    kv: Arc<SharedKv>,
    prefix_index: Arc<PrefixIndex>,
    radix_index: Arc<RadixIndex>,
    prefix_mode: PrefixMode,
    decode_model: Arc<TinyLm>,
    geometry: Geometry,
    workers: usize,
    next_id: AtomicU64,
    started: Instant,
}

impl Coordinator {
    /// Boot the serving stack over a compiled [`Engine`]: spawn the
    /// dispatcher thread, size the shared KV pool from the manifest
    /// geometry, and wire up admission + both prefix indexes.
    pub fn new(engine: Arc<Engine>, cfg: CoordinatorConfig) -> Coordinator {
        let metrics = Arc::new(Metrics::new());
        let admission = Arc::new(Admission::new(cfg.admission));
        let m = &engine.manifest().model;
        // decode stand-in LM shares the manifest geometry (see
        // decode::session docs); one attention layer today.
        let decode_model =
            Arc::new(TinyLm::new(0xD0C0DE, m.n_heads, m.n_kv_heads.max(1), m.d_head, m.vocab_size));
        let kv = SharedKv::new(
            KvConfig { total_pages: cfg.kv_pages, page_tokens: m.block },
            decode_model.hk,
            decode_model.dh,
        );
        let prefix_index = Arc::new(PrefixIndex::default());
        let radix_index = Arc::new(RadixIndex::new(m.block));
        let geometry = Geometry {
            n_layers: 1,
            n_heads: m.n_heads,
            d_head: m.d_head,
            d_model: m.n_heads * m.d_head,
            d_ff: m.d_ff,
            block: m.block,
        };
        let (tx, rx) = mpsc::channel::<Msg>();

        let dispatcher = {
            let engine = Arc::clone(&engine);
            let metrics = Arc::clone(&metrics);
            let admission = Arc::clone(&admission);
            let kv = Arc::clone(&kv);
            let prefix_index = Arc::clone(&prefix_index);
            let radix_index = Arc::clone(&radix_index);
            let prefix_mode = cfg.prefix_mode;
            let decode_model = Arc::clone(&decode_model);
            let batcher_cfg = cfg.batcher.clone();
            let decode_cfg = cfg.decode_lane.clone();
            let workers = cfg.workers;
            let tx2 = tx.clone();
            thread::spawn(move || {
                dispatcher_loop(DispatcherCtx {
                    rx,
                    tx: tx2,
                    engine,
                    metrics,
                    admission,
                    kv,
                    prefix_index,
                    radix_index,
                    prefix_mode,
                    decode_model,
                    batcher_cfg,
                    decode_cfg,
                    workers,
                })
            })
        };

        Coordinator {
            engine,
            tx,
            dispatcher: Some(dispatcher),
            metrics,
            admission,
            kv,
            prefix_index,
            radix_index,
            prefix_mode: cfg.prefix_mode,
            decode_model,
            geometry,
            workers: cfg.workers,
            next_id: AtomicU64::new(1),
            started: Instant::now(),
        }
    }

    /// The PJRT engine executing prefill graphs.
    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// The deterministic decode LM (exposed so tests/benches can share
    /// the exact serving geometry).
    pub fn decode_model(&self) -> &Arc<TinyLm> {
        &self.decode_model
    }

    /// The shared paged KV store (pool + slabs) behind every decode
    /// session and prefill reservation.
    pub fn shared_kv(&self) -> &Arc<SharedKv> {
        &self.kv
    }

    /// The exact-mode live-prefix index (admission-side view of the
    /// prefix cache when `prefix_mode` is [`PrefixMode::Exact`]).
    pub fn prefix_index(&self) -> &Arc<PrefixIndex> {
        &self.prefix_index
    }

    /// The token-granular radix index (admission-side view of the
    /// prefix cache when `prefix_mode` is [`PrefixMode::Radix`]).
    pub fn radix_index(&self) -> &Arc<RadixIndex> {
        &self.radix_index
    }

    /// The active prefix-matching mode.
    pub fn prefix_mode(&self) -> PrefixMode {
        self.prefix_mode
    }

    /// Live cached prefixes under the active mode.
    pub fn cached_prefixes(&self) -> usize {
        match self.prefix_mode {
            PrefixMode::Exact => self.prefix_index.len(),
            PrefixMode::Radix => self.radix_index.len(),
        }
    }

    /// Route + admit + enqueue. Returns the response channel, or an
    /// immediate rejection (backpressure).
    pub fn submit(
        &self,
        checkpoint: &str,
        method: Method,
        ids: Vec<i32>,
        diag: bool,
    ) -> Result<mpsc::Receiver<Result<PrefillResponse>>> {
        let bucket = self
            .engine
            .manifest()
            .bucket_for(ids.len())
            .ok_or_else(|| anyhow!("request of {} tokens exceeds every bucket", ids.len()))?;
        match self.admission.try_admit(bucket) {
            Admit::Accepted => {}
            Admit::Rejected { reason } => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(anyhow!("rejected: {reason}"));
            }
        }
        let req = PrefillRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            checkpoint: checkpoint.to_string(),
            method,
            ids,
            diag,
            enqueued: Instant::now(),
        };
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        let (rtx, rrx) = mpsc::channel();
        self.tx.send(Msg::Request(req, rtx)).map_err(|_| anyhow!("coordinator stopped"))?;
        Ok(rrx)
    }

    /// Synchronous convenience wrapper (eval harness path).
    pub fn prefill_blocking(
        &self,
        checkpoint: &str,
        method: Method,
        ids: Vec<i32>,
        diag: bool,
    ) -> Result<PrefillResponse> {
        let rx = self.submit(checkpoint, method, ids, diag)?;
        rx.recv().map_err(|_| anyhow!("response channel closed"))?
    }

    /// Submit `fanout` continuations of one prompt: the prompt is
    /// ingested once into a prefix-holder session (reused across
    /// requests, exactly or — in radix mode — by longest page-aligned
    /// common prefix), each branch forks the refcounted prefix and
    /// decodes independently with copy-on-write divergence. Admission
    /// charges the decode work per branch but the ingest work only for
    /// the prompt suffix not covered by a cached prefix
    /// ([`estimate_ingest_ns`] on the suffix length — zero on a full
    /// hit). Returns one response channel per branch, in branch order.
    pub fn submit_generate_many(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        policy: DecodePolicy,
        fanout: usize,
    ) -> Result<Vec<mpsc::Receiver<Result<GenerateResponse>>>> {
        policy.validate().map_err(|e| anyhow!("invalid decode policy: {e}"))?;
        if max_new_tokens == 0 {
            return Err(anyhow!("max_new_tokens must be >= 1"));
        }
        if fanout == 0 {
            return Err(anyhow!("fanout must be >= 1"));
        }
        let n_tokens = prompt.len() + max_new_tokens;
        // budget each branch's estimated work up front — a decode stream
        // holds pages and a worker slice for its lifetime
        let budget = match policy.plan(n_tokens, 0, self.geometry.block) {
            StepPlan::Dense => None,
            StepPlan::Sparse { budget_blocks } => Some(budget_blocks as f64),
        };
        let full_ns = if policy.spec_gamma >= 1 {
            // speculative branch: charge draft/verify rounds at the
            // conservative assumed acceptance instead of per-token steps
            let mean_ctx = prompt.len() + max_new_tokens / 2;
            let draft = policy.draft();
            let draft_budget = match draft.plan(mean_ctx, 0, self.geometry.block) {
                StepPlan::Dense => None,
                StepPlan::Sparse { budget_blocks } => Some(budget_blocks as f64),
            };
            let round_ns = estimate_spec_step_ns(
                &self.geometry,
                mean_ctx,
                policy.spec_gamma,
                draft_budget,
                budget,
                policy.stride,
                self.workers,
            );
            let commits = 1.0 + policy.spec_gamma as f64 * SPEC_ASSUMED_ACCEPTANCE;
            estimate_ingest_ns(&self.geometry, prompt.len())
                + (max_new_tokens as f64 / commits).ceil() * round_ns
        } else {
            estimate_generate_ns(
                &self.geometry,
                prompt.len(),
                max_new_tokens,
                budget,
                policy.stride,
                self.workers,
            )
        };
        let full_ingest_ns = estimate_ingest_ns(&self.geometry, prompt.len());
        let decode_ns = (full_ns - full_ingest_ns).max(0.0);
        let prefix_hash = prompt_hash(&prompt);
        // token-granular admission: only the *uncovered* prompt suffix
        // is charged, once, to the first branch — an exact live prefix
        // covers everything (the charge-once-per-unique-prefix rule), a
        // radix match covers its page-aligned LCP. Index reads are
        // advisory; a stale hit merely undercharges one estimate. Totals
        // are closed-form so the admission decision runs BEFORE any
        // per-branch allocation (a huge fanout must reject cleanly, not
        // OOM building vectors — `max_requests` bounds the group size).
        let covered = match self.prefix_mode {
            PrefixMode::Exact => {
                if self.prefix_index.is_live(prefix_hash) {
                    prompt.len()
                } else {
                    0
                }
            }
            PrefixMode::Radix => self
                .radix_index
                .lookup(&prompt)
                .map(|m| m.covered.min(prompt.len()))
                .unwrap_or(0),
        };
        let suffix_len = prompt.len() - covered;
        let ingest_ns = estimate_ingest_ns(&self.geometry, suffix_len);
        let Some(total_tokens) =
            fanout.checked_mul(max_new_tokens).and_then(|t| t.checked_add(suffix_len))
        else {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow!("rejected: fanout x max_new_tokens overflows"));
        };
        let total_ns = fanout as f64 * decode_ns + ingest_ns;
        match self.admission.try_admit_work_n(fanout, total_tokens, total_ns) {
            Admit::Accepted => {}
            Admit::Rejected { reason } => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(anyhow!("rejected: {reason}"));
            }
        }
        let mut admits = Vec::with_capacity(fanout);
        for i in 0..fanout {
            let first = i == 0 && suffix_len > 0;
            admits.push(BranchAdmit {
                tokens: max_new_tokens + if first { suffix_len } else { 0 },
                ns: decode_ns + if first { ingest_ns } else { 0.0 },
            });
        }
        // id block: holder seq = id, branch seqs = id+1 ..= id+fanout
        let id = self.next_id.fetch_add(1 + fanout as u64, Ordering::Relaxed);
        let req = GenerateRequest {
            id,
            prompt,
            max_new_tokens,
            policy,
            fanout,
            prefix_hash,
            enqueued: Instant::now(),
        };
        self.metrics.generates_submitted.fetch_add(fanout as u64, Ordering::Relaxed);
        let mut txs = Vec::with_capacity(fanout);
        let mut rxs = Vec::with_capacity(fanout);
        for _ in 0..fanout {
            let (rtx, rrx) = mpsc::channel();
            txs.push(rtx);
            rxs.push(rrx);
        }
        self.tx
            .send(Msg::Generate(req, txs, admits))
            .map_err(|_| anyhow!("coordinator stopped"))?;
        Ok(rxs)
    }

    /// Submit a single autoregressive generation (fan-out of one); the
    /// response arrives once on the returned channel.
    pub fn submit_generate(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        policy: DecodePolicy,
    ) -> Result<mpsc::Receiver<Result<GenerateResponse>>> {
        Ok(self
            .submit_generate_many(prompt, max_new_tokens, policy, 1)?
            .pop()
            .expect("fanout=1 yields exactly one channel"))
    }

    /// Synchronous convenience wrapper around [`Coordinator::submit_generate`].
    pub fn generate_blocking(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        policy: DecodePolicy,
    ) -> Result<GenerateResponse> {
        let rx = self.submit_generate(prompt, max_new_tokens, policy)?;
        rx.recv().map_err(|_| anyhow!("response channel closed"))?
    }

    /// Wall-clock time since the coordinator booted.
    pub fn uptime(&self) -> Duration {
        self.started.elapsed()
    }

    /// Current KV page occupancy (used, total, fraction).
    pub fn kv_occupancy(&self) -> (usize, usize, f64) {
        self.kv.occupancy()
    }

    /// Human-readable serving report: request/decode/fan-out counters,
    /// latency percentiles, KV occupancy and prefix-cache gauges.
    pub fn report(&self) -> String {
        let (used, total, frac) = self.kv_occupancy();
        format!(
            "{}\nkv pages: {used}/{total} in use ({:.1}%) | slab pages resident: {} | cached prefixes: {}",
            self.metrics.report(self.uptime()),
            100.0 * frac,
            self.kv.pages_resident(),
            self.cached_prefixes(),
        )
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

struct DispatcherCtx {
    rx: mpsc::Receiver<Msg>,
    tx: mpsc::Sender<Msg>,
    engine: Arc<Engine>,
    metrics: Arc<Metrics>,
    admission: Arc<Admission>,
    kv: Arc<SharedKv>,
    prefix_index: Arc<PrefixIndex>,
    radix_index: Arc<RadixIndex>,
    prefix_mode: PrefixMode,
    decode_model: Arc<TinyLm>,
    batcher_cfg: BatcherConfig,
    decode_cfg: DecodeLaneConfig,
    workers: usize,
}

fn dispatcher_loop(ctx: DispatcherCtx) {
    let DispatcherCtx {
        rx,
        tx,
        engine,
        metrics,
        admission,
        kv,
        prefix_index,
        radix_index,
        prefix_mode,
        decode_model,
        batcher_cfg,
        decode_cfg,
        workers,
    } = ctx;
    let tables = PrefixTables { mode: prefix_mode, exact: &prefix_index, radix: &radix_index };
    let pool = ThreadPool::new(workers);
    let mut batcher = Batcher::with_decode(batcher_cfg.clone(), decode_cfg.clone());
    let mut channels: HashMap<u64, mpsc::Sender<Result<PrefillResponse>>> = HashMap::new();
    let tasks: DecodeTasks = Arc::new(Mutex::new(HashMap::new()));
    // prefix cache: holder sessions keyed by prompt hash (exact mode)
    // or by their own holder id with prompts indexed in the radix tree
    // (see module docs)
    let mut holders: HashMap<u64, Holder> = HashMap::new();
    let mut holder_clock: u64 = 0;
    // generations admitted but not yet completed (branches may be queued
    // on a filling holder, in the batcher, or running a step)
    let active_decodes = Arc::new(AtomicUsize::new(0));
    let shutdown = AtomicBool::new(false);

    loop {
        // 1. pull what's available (block briefly if nothing pending);
        //    while decode steps are in flight we must keep serving
        //    DecodeReady/PrefixFilled messages even with an empty batcher
        let draining = shutdown.load(Ordering::SeqCst);
        let idle = batcher.pending() == 0;
        let msg = if idle && !draining && active_decodes.load(Ordering::SeqCst) == 0 {
            match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => break,
            }
        } else {
            // sleep no longer than the tightest lane deadline: a queued
            // decode step must not wait out the (much longer) prefill
            // quantum before its age-based flush is re-checked
            let quantum = if batcher.decode_pending() > 0 {
                (batcher_cfg.max_wait / 2).min(decode_cfg.max_wait)
            } else {
                batcher_cfg.max_wait / 2
            };
            match rx.recv_timeout(quantum) {
                Ok(m) => Some(m),
                Err(mpsc::RecvTimeoutError::Timeout) => None,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        };
        if let Some(msg) = msg {
            match msg {
                Msg::Shutdown => {
                    shutdown.store(true, Ordering::SeqCst);
                }
                Msg::Request(req, ch) => {
                    let bucket = engine.manifest().bucket_for(req.ids.len()).unwrap();
                    let key = BatchKey {
                        kind: req.method.kind(req.diag),
                        bucket,
                        checkpoint: req.checkpoint.clone(),
                    };
                    channels.insert(req.id, ch);
                    batcher.push(key, req);
                }
                Msg::Generate(req, chs, admits) => {
                    let n_prompt = req.prompt.len();
                    let specs: Vec<BranchSpec> = chs
                        .into_iter()
                        .zip(admits)
                        .enumerate()
                        .map(|(i, (ch, admit))| BranchSpec {
                            seq: req.id + 1 + i as u64,
                            ch,
                            max_new: req.max_new_tokens,
                            policy: req.policy,
                            n_prompt,
                            enqueued: req.enqueued,
                            admit,
                        })
                        .collect();
                    if shutdown.load(Ordering::SeqCst) {
                        for spec in specs {
                            admission.release_work(spec.admit.tokens, spec.admit.ns);
                            let _ = spec.ch.send(Err(anyhow!("coordinator shutting down")));
                        }
                        continue;
                    }
                    active_decodes.fetch_add(specs.len(), Ordering::SeqCst);
                    // covered-token gauge: every routed group contributes
                    // its prompt length; hits add back what the cache
                    // actually covered
                    metrics.prefix_tokens_total.fetch_add(n_prompt as u64, Ordering::Relaxed);
                    enum Route {
                        // parked holder with this exact prompt: fork it
                        Hit(u64),
                        // same prompt mid-ingest: queue on the holder
                        Filling(u64),
                        // holder exists but its pages were evicted:
                        // retire `stale`, re-ingest under `fresh`
                        Refill { stale: u64, fresh: u64 },
                        // radix-only: a holder covers a page-aligned
                        // prefix; fork it and ingest just the suffix
                        Partial { src: u64, covered: usize },
                        // nothing reusable: ingest under a new holder
                        Miss(u64),
                    }
                    let route = match prefix_mode {
                        PrefixMode::Exact => {
                            let hash = req.prefix_hash;
                            // hash collision with a cached *different*
                            // prompt: bypass the cache under a synthetic
                            // single-use key
                            let key = match holders.get(&hash) {
                                Some(h) if h.prompt != req.prompt => {
                                    hash ^ req.id.rotate_left(17) ^ 0x9e37_79b9_7f4a_7c15
                                }
                                _ => hash,
                            };
                            match holders.get(&key) {
                                None => Route::Miss(key),
                                Some(h) => match &h.session {
                                    None => Route::Filling(key),
                                    // verify the parked prefix survived
                                    // LRU pressure
                                    Some(_)
                                        if kv.seq_tokens(h.seq).ok().flatten()
                                            == Some(n_prompt) =>
                                    {
                                        Route::Hit(key)
                                    }
                                    Some(_) => Route::Refill { stale: key, fresh: key },
                                },
                            }
                        }
                        PrefixMode::Radix => match radix_index.lookup(&req.prompt) {
                            None => Route::Miss(req.id),
                            Some(m) => match holders.get(&m.key) {
                                // index/holder desync (holder retired
                                // between lookup and here): re-ingest
                                None => Route::Miss(req.id),
                                Some(h) if m.exact => match &h.session {
                                    None => Route::Filling(m.key),
                                    Some(_)
                                        if kv.seq_tokens(h.seq).ok().flatten()
                                            == Some(n_prompt) =>
                                    {
                                        Route::Hit(m.key)
                                    }
                                    Some(_) => {
                                        Route::Refill { stale: m.key, fresh: req.id }
                                    }
                                },
                                // partial overlap is only usable against a
                                // parked holder whose pages are still fresh
                                Some(h)
                                    if m.covered > 0
                                        && h.session.is_some()
                                        && kv.seq_tokens(h.seq).ok().flatten()
                                            == Some(h.prompt.len()) =>
                                {
                                    Route::Partial { src: m.key, covered: m.covered }
                                }
                                Some(_) => Route::Miss(req.id),
                            },
                        },
                    };
                    match route {
                        Route::Hit(key) => {
                            metrics.prefix_hits.fetch_add(specs.len() as u64, Ordering::Relaxed);
                            metrics
                                .prefix_tokens_covered
                                .fetch_add(n_prompt as u64, Ordering::Relaxed);
                            // touch the holder so cap-retirement favors
                            // hot prefixes
                            holder_clock += 1;
                            let holder = holders.get_mut(&key).unwrap();
                            holder.last_used = holder_clock;
                            let bounced = launch_branches(
                                holder.session.as_ref().unwrap(),
                                specs,
                                &tasks,
                                &mut batcher,
                                &metrics,
                                &admission,
                                &active_decodes,
                            );
                            if !bounced.is_empty() {
                                // the parked holder was evicted between the
                                // freshness check and the fork: retire it
                                // and re-ingest for the bounced branches
                                metrics
                                    .prefix_hits
                                    .fetch_sub(bounced.len() as u64, Ordering::Relaxed);
                                let stale = holders.remove(&key).unwrap();
                                tables.remove(key, &stale.prompt);
                                let fresh = match prefix_mode {
                                    PrefixMode::Exact => key,
                                    PrefixMode::Radix => req.id,
                                };
                                start_prefix_fill(
                                    fresh,
                                    req,
                                    bounced,
                                    None,
                                    &mut holders,
                                    &mut holder_clock,
                                    tables,
                                    &kv,
                                    &decode_model,
                                    &metrics,
                                    &admission,
                                    &active_decodes,
                                    &pool,
                                    &tx,
                                );
                            }
                        }
                        Route::Filling(key) => {
                            // ingest already in flight: ride it for free
                            metrics.prefix_hits.fetch_add(specs.len() as u64, Ordering::Relaxed);
                            metrics
                                .prefix_tokens_covered
                                .fetch_add(n_prompt as u64, Ordering::Relaxed);
                            holders.get_mut(&key).unwrap().waiting.extend(specs);
                        }
                        Route::Refill { stale, fresh } => {
                            // the parked prefix was evicted under pressure:
                            // retire the stale holder and ingest afresh
                            let old = holders.remove(&stale).unwrap();
                            tables.remove(stale, &old.prompt);
                            start_prefix_fill(
                                fresh,
                                req,
                                specs,
                                None,
                                &mut holders,
                                &mut holder_clock,
                                tables,
                                &kv,
                                &decode_model,
                                &metrics,
                                &admission,
                                &active_decodes,
                                &pool,
                                &tx,
                            );
                        }
                        Route::Partial { src, covered } => {
                            // token-granular reuse: fork the covered pages
                            // off the matched holder into a NEW holder for
                            // this full prompt, then ingest only the
                            // suffix on a worker; branches queue on the
                            // new holder exactly like a fresh ingest
                            holder_clock += 1;
                            let src_holder = holders.get_mut(&src).unwrap();
                            src_holder.last_used = holder_clock;
                            let last_tok = req.prompt[covered - 1];
                            let forked = src_holder
                                .session
                                .as_ref()
                                .unwrap()
                                .fork_prefix(req.id, covered, last_tok);
                            match forked {
                                Ok(session) => {
                                    metrics
                                        .prefix_partial_hits
                                        .fetch_add(1, Ordering::Relaxed);
                                    metrics
                                        .prefix_tokens_covered
                                        .fetch_add(covered as u64, Ordering::Relaxed);
                                    start_prefix_fill(
                                        req.id,
                                        req,
                                        specs,
                                        Some((session, covered)),
                                        &mut holders,
                                        &mut holder_clock,
                                        tables,
                                        &kv,
                                        &decode_model,
                                        &metrics,
                                        &admission,
                                        &active_decodes,
                                        &pool,
                                        &tx,
                                    );
                                }
                                Err(DecodeError::Kv(KvError::UnknownSeq(_))) => {
                                    // holder pages vanished between the
                                    // freshness check and the fork: retire
                                    // it and fall back to a full ingest
                                    let stale = holders.remove(&src).unwrap();
                                    tables.remove(src, &stale.prompt);
                                    start_prefix_fill(
                                        req.id,
                                        req,
                                        specs,
                                        None,
                                        &mut holders,
                                        &mut holder_clock,
                                        tables,
                                        &kv,
                                        &decode_model,
                                        &metrics,
                                        &admission,
                                        &active_decodes,
                                        &pool,
                                        &tx,
                                    );
                                }
                                Err(e) => {
                                    let msg = format!("prefix fork failed: {e}");
                                    for spec in specs {
                                        fail_branch(
                                            spec,
                                            msg.clone(),
                                            &metrics,
                                            &admission,
                                            &active_decodes,
                                        );
                                    }
                                }
                            }
                        }
                        Route::Miss(key) => start_prefix_fill(
                            key,
                            req,
                            specs,
                            None,
                            &mut holders,
                            &mut holder_clock,
                            tables,
                            &kv,
                            &decode_model,
                            &metrics,
                            &admission,
                            &active_decodes,
                            &pool,
                            &tx,
                        ),
                    }
                }
                Msg::PrefixFilled { key, session } => {
                    if !holders.contains_key(&key) {
                        // holder retired while filling; dropping `session`
                        // (if Ok) closes the seq and frees its pages
                        continue;
                    }
                    match session {
                        Ok(sess) => {
                            let holder = holders.get_mut(&key).unwrap();
                            let specs = std::mem::take(&mut holder.waiting);
                            let bounced = launch_branches(
                                &sess,
                                specs,
                                &tasks,
                                &mut batcher,
                                &metrics,
                                &admission,
                                &active_decodes,
                            );
                            // the holder is still pinned here, so its seq
                            // cannot have been evicted mid-fork
                            for spec in bounced {
                                fail_branch(
                                    spec,
                                    "prefix vanished during ingest".into(),
                                    &metrics,
                                    &admission,
                                    &active_decodes,
                                );
                            }
                            // park unpinned: the cached prefix yields to
                            // live traffic under page pressure (forks
                            // re-pin themselves)
                            let _ = sess.unpin();
                            holder.session = Some(*sess);
                        }
                        Err(msg) => {
                            let holder = holders.remove(&key).unwrap();
                            tables.remove(key, &holder.prompt);
                            for spec in holder.waiting {
                                fail_branch(spec, msg.clone(), &metrics, &admission, &active_decodes);
                            }
                        }
                    }
                    retire_excess_holders(&mut holders, tables, &kv);
                }
                Msg::DecodeReady(seq, tokens) => {
                    batcher.push_decode(DecodeStep { seq, tokens, enqueued: Instant::now() });
                }
            }
        }

        // 2. emit ready batches to the pool
        let now = Instant::now();
        let mut any: Vec<AnyBatch> = vec![];
        if shutdown.load(Ordering::SeqCst) {
            any.extend(batcher.drain_all(now).into_iter().map(AnyBatch::Prefill));
            if let Some(d) = batcher.drain_decode(now) {
                any.push(AnyBatch::Decode(d));
            }
        } else {
            while let Some(b) = batcher.pop_ready_any(now) {
                any.push(b);
            }
        }
        for batch in any {
            match batch {
                AnyBatch::Prefill(batch) => {
                    metrics.batches.fetch_add(1, Ordering::Relaxed);
                    for req in batch.requests {
                        let ch = channels.remove(&req.id).unwrap();
                        let engine = Arc::clone(&engine);
                        let metrics = Arc::clone(&metrics);
                        let admission = Arc::clone(&admission);
                        let kv = Arc::clone(&kv);
                        let bucket = batch.key.bucket;
                        let kind = batch.key.kind;
                        pool.submit(move || {
                            let out = execute_one(&engine, &kv, kind, bucket, &req);
                            match &out {
                                Ok(resp) => {
                                    metrics.completed.fetch_add(1, Ordering::Relaxed);
                                    metrics
                                        .tokens_in
                                        .fetch_add(req.ids.len() as u64, Ordering::Relaxed);
                                    metrics.queue.record(Duration::from_micros(resp.queue_us));
                                    metrics.exec.record(Duration::from_micros(resp.exec_us));
                                    metrics
                                        .ttft
                                        .record(Duration::from_micros(resp.queue_us + resp.exec_us));
                                    metrics.budget_sum_micro.fetch_add(
                                        (resp.budget_fraction as f64 * 1e6) as u64,
                                        Ordering::Relaxed,
                                    );
                                }
                                Err(e) => metrics.record_error(e.to_string()),
                            }
                            admission.release(bucket);
                            let _ = ch.send(out);
                        });
                    }
                }
                AnyBatch::Decode(batch) => {
                    metrics.decode_batches.fetch_add(1, Ordering::Relaxed);
                    for step in batch.steps {
                        let metrics = Arc::clone(&metrics);
                        let admission = Arc::clone(&admission);
                        let tasks = Arc::clone(&tasks);
                        let active = Arc::clone(&active_decodes);
                        let tx = tx.clone();
                        pool.submit(move || {
                            run_decode_step(step.seq, &tasks, &metrics, &admission, &active, &tx);
                        });
                    }
                }
            }
        }

        if shutdown.load(Ordering::SeqCst)
            && batcher.pending() == 0
            && active_decodes.load(Ordering::SeqCst) == 0
        {
            break;
        }
    }
    pool.wait_idle();
    // parked prefix holders drop here, freeing their cached pages
}

/// Fail one branch: record, release its admission share, answer its
/// channel, and retire it from the active count.
fn fail_branch(
    spec: BranchSpec,
    msg: String,
    metrics: &Arc<Metrics>,
    admission: &Arc<Admission>,
    active: &Arc<AtomicUsize>,
) {
    metrics.record_error(msg.clone());
    admission.release_work(spec.admit.tokens, spec.admit.ns);
    let _ = spec.ch.send(Err(anyhow!(msg)));
    active.fetch_sub(1, Ordering::SeqCst);
}

/// Fork every branch off the (prefilled) holder session and push their
/// first decode steps into the lane as one sibling group. Returns the
/// specs whose fork found the holder's sequence *gone* — a parked,
/// unpinned holder can be LRU-evicted by a concurrent worker between
/// the dispatcher's freshness check and the fork — so the caller can
/// fall back to a fresh ingest instead of failing the request.
fn launch_branches(
    holder: &DecodeSession,
    specs: Vec<BranchSpec>,
    tasks: &DecodeTasks,
    batcher: &mut Batcher,
    metrics: &Arc<Metrics>,
    admission: &Arc<Admission>,
    active: &Arc<AtomicUsize>,
) -> Vec<BranchSpec> {
    let mut steps = Vec::with_capacity(specs.len());
    let mut bounced = Vec::new();
    for spec in specs {
        match holder.fork(spec.seq) {
            Ok(mut session) => {
                session.set_policy(spec.policy);
                metrics.forks.fetch_add(1, Ordering::Relaxed);
                let task = DecodeTask {
                    session,
                    ch: spec.ch,
                    n_prompt: spec.n_prompt,
                    max_new: spec.max_new,
                    tokens: Vec::new(),
                    enqueued: spec.enqueued,
                    first_step_at: None,
                    admit_tokens: spec.admit.tokens,
                    admit_ns: spec.admit.ns,
                };
                tasks.lock().unwrap().insert(spec.seq, task);
                steps.push(DecodeStep {
                    seq: spec.seq,
                    tokens: spec.policy.spec_gamma + 1,
                    enqueued: spec.enqueued,
                });
            }
            Err(DecodeError::Kv(KvError::UnknownSeq(_))) => bounced.push(spec),
            Err(e) => fail_branch(
                spec,
                format!("prefix fork failed: {e}"),
                metrics,
                admission,
                active,
            ),
        }
    }
    batcher.push_decode_many(steps);
    bounced
}

/// Start a prefix holder for `req.prompt` under `key`: allocate (or
/// adopt, for a radix partial hit) its session now — cheap — then run
/// the prompt-suffix ingest on a worker and report back via
/// [`Msg::PrefixFilled`]. Branches queue on the holder meanwhile.
/// `base` is `None` for a full ingest (counted as a prefix miss) or
/// `Some((forked_session, covered))` when the leading `covered` tokens
/// were already forked off a matched holder and only the remaining
/// suffix needs projecting.
#[allow(clippy::too_many_arguments)]
fn start_prefix_fill(
    key: u64,
    req: GenerateRequest,
    specs: Vec<BranchSpec>,
    base: Option<(DecodeSession, usize)>,
    holders: &mut HashMap<u64, Holder>,
    holder_clock: &mut u64,
    tables: PrefixTables<'_>,
    kv: &Arc<SharedKv>,
    model: &Arc<TinyLm>,
    metrics: &Arc<Metrics>,
    admission: &Arc<Admission>,
    active: &Arc<AtomicUsize>,
    pool: &ThreadPool,
    tx: &mpsc::Sender<Msg>,
) {
    // `mut`: the move closure below ingests through `&mut self`
    let (mut session, covered) = match base {
        Some((session, covered)) => (session, covered),
        None => {
            metrics.prefix_misses.fetch_add(1, Ordering::Relaxed);
            match DecodeSession::new(Arc::clone(kv), Arc::clone(model), req.policy, req.id) {
                Ok(s) => (s, 0),
                Err(e) => {
                    let msg = format!("kv allocation failed: {e}");
                    for spec in specs {
                        fail_branch(spec, msg.clone(), metrics, admission, active);
                    }
                    return;
                }
            }
        }
    };
    *holder_clock += 1;
    holders.insert(
        key,
        Holder {
            seq: session.seq_id(),
            prompt: req.prompt.clone(),
            session: None,
            waiting: specs,
            last_used: *holder_clock,
        },
    );
    tables.insert(key, &req.prompt);
    let suffix: Vec<i32> = req.prompt[covered..].to_vec();
    let metrics = Arc::clone(metrics);
    let tx = tx.clone();
    pool.submit(move || {
        let res = match session.extend_prompt(&suffix) {
            Ok(()) => {
                metrics.tokens_in.fetch_add(suffix.len() as u64, Ordering::Relaxed);
                Ok(Box::new(session))
            }
            Err(e) => Err(format!("prompt ingest failed: {e}")),
        };
        let _ = tx.send(Msg::PrefixFilled { key, session: res });
    });
}

/// Retire parked holders beyond [`MAX_PREFIX_HOLDERS`] (never one
/// mid-ingest or with branches still waiting). Victim selection is
/// LCP-aware, not blind LRU: the holder with the lowest covered-tokens ×
/// refcount weight ([`SharedKv::seq_weight`]) goes first — an evicted or
/// short, unshared prefix before a long, heavily-forked one — with the
/// LRU clock as the tie-break. Dropping the session frees the prefix
/// pages not shared with live forks.
fn retire_excess_holders(
    holders: &mut HashMap<u64, Holder>,
    tables: PrefixTables<'_>,
    kv: &SharedKv,
) {
    while holders.len() > MAX_PREFIX_HOLDERS {
        let victim = holders
            .iter()
            .filter(|(_, h)| h.session.is_some() && h.waiting.is_empty())
            .min_by_key(|(_, h)| (kv.seq_weight(h.seq).ok().flatten().unwrap_or(0), h.last_used))
            .map(|(&k, _)| k);
        match victim {
            Some(k) => {
                let h = holders.remove(&k).unwrap();
                tables.remove(k, &h.prompt);
            }
            None => break,
        }
    }
}

/// Advance one generation on a worker thread — one token for plain
/// decode, up to γ+1 tokens for a speculative draft/verify round — then
/// either complete it or hand it back to the dispatcher for its next
/// step. Either way the generation occupies exactly one decode-lane slot
/// per round, so fork fan-out siblings keep batching together whether or
/// not they speculate.
fn run_decode_step(
    seq: u64,
    tasks: &DecodeTasks,
    metrics: &Arc<Metrics>,
    admission: &Arc<Admission>,
    active: &Arc<AtomicUsize>,
    tx: &mpsc::Sender<Msg>,
) {
    let Some(mut task) = tasks.lock().unwrap().remove(&seq) else {
        return; // task vanished (completed with an error elsewhere)
    };
    let finish = |task: DecodeTask, out: Result<GenerateResponse>| {
        if let Err(e) = &out {
            metrics.record_error(e.to_string());
        } else {
            metrics.generates_completed.fetch_add(1, Ordering::Relaxed);
        }
        admission.release_work(task.admit_tokens, task.admit_ns);
        let _ = task.ch.send(out);
        active.fetch_sub(1, Ordering::SeqCst);
    };
    if task.first_step_at.is_none() {
        task.first_step_at = Some(Instant::now());
    }
    let gamma = task.session.policy().spec_gamma;
    let stepped: Result<(Vec<StepInfo>, bool), DecodeError> = if gamma >= 1 {
        let remaining = task.max_new.saturating_sub(task.tokens.len()).max(1);
        task.session.spec_round(gamma.min(remaining), remaining, Some(vocab::END), |_| true).map(
            |round| {
                metrics.record_spec_round(
                    round.drafted as u64,
                    round.accepted as u64,
                    round.infos.len() as u64,
                );
                (round.infos, round.halt)
            },
        )
    } else {
        task.session.step_once().map(|info| {
            let halt = info.token == vocab::END;
            (vec![info], halt)
        })
    };
    match stepped {
        Ok((infos, halt)) => {
            for info in &infos {
                metrics.record_decode_step(
                    Duration::from_nanos(info.step_ns),
                    info.budget_fraction,
                    info.dense,
                );
                task.tokens.push(info.token);
            }
            let done = task.tokens.len() >= task.max_new || halt;
            if done {
                let resp = generate_response(seq, &mut task);
                finish(task, Ok(resp));
            } else {
                tasks.lock().unwrap().insert(seq, task);
                if tx.send(Msg::DecodeReady(seq, gamma + 1)).is_err() {
                    // dispatcher gone: complete what we have so the
                    // caller is not left hanging
                    if let Some(mut task) = tasks.lock().unwrap().remove(&seq) {
                        let resp = generate_response(seq, &mut task);
                        finish(task, Ok(resp));
                    }
                }
            }
        }
        Err(e) => finish(task, Err(anyhow!("decode step failed: {e}"))),
    }
}

/// Assemble the final [`GenerateResponse`] from a task's accumulated
/// state (single construction point for the done and dispatcher-gone
/// paths). `exec_us` is the *summed step execution time* from the
/// session's own clocks; scheduling gaps between steps show up in
/// end-to-end wall time, not here.
fn generate_response(seq: u64, task: &mut DecodeTask) -> GenerateResponse {
    let queue_us = task
        .first_step_at
        .map(|t| (t - task.enqueued).as_micros() as u64)
        .unwrap_or(0);
    let steps = task.tokens.len();
    GenerateResponse {
        id: seq,
        tokens: std::mem::take(&mut task.tokens),
        n_prompt: task.n_prompt,
        steps,
        mean_budget_fraction: task.session.mean_budget_fraction(),
        dense_steps: task.session.dense_steps(),
        queue_us,
        exec_us: task.session.decode_ns() / 1_000,
        ns_per_token: task.session.decode_ns() as f64 / steps.max(1) as f64,
    }
}

fn execute_one(
    engine: &Engine,
    kv: &SharedKv,
    kind: &'static str,
    bucket: usize,
    req: &PrefillRequest,
) -> Result<PrefillResponse> {
    let queue_us = req.enqueued.elapsed().as_micros() as u64;
    // KV pages for the prefilled sequence. Pure-prefill requests read the
    // logits back and release immediately; generations hold their pages
    // through a `DecodeSession` for the whole token stream instead.
    kv.allocate(req.id, bucket)?;
    let mut ids = req.ids.clone();
    ids.resize(bucket, vocab::PAD);
    let t0 = Instant::now();
    let result = engine.prefill(&req.checkpoint, kind, bucket, &ids, &req.method.scalars());
    let exec_us = t0.elapsed().as_micros() as u64;
    let _ = kv.release(req.id);
    let _ = kv.drop_seq(req.id);
    let out = result?;
    Ok(PrefillResponse {
        id: req.id,
        logits: out.logits,
        vocab: out.vocab,
        n_ctx: out.n_ctx,
        n_input: req.ids.len(),
        budget_fraction: out.budget_fraction,
        hidden: out.hidden,
        queue_us,
        exec_us,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prompt_hash_distinguishes_prompts_not_order_of_calls() {
        let a = prompt_hash(&[1, 2, 3]);
        assert_eq!(a, prompt_hash(&[1, 2, 3]), "hash must be deterministic");
        assert_ne!(a, prompt_hash(&[1, 2, 4]));
        assert_ne!(a, prompt_hash(&[3, 2, 1]));
        assert_ne!(prompt_hash(&[]), prompt_hash(&[0]));
    }

}
