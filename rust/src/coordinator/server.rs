//! The coordinator itself: router → admission → dynamic batcher →
//! dispatcher → worker pool → PJRT engine, with a shared paged KV store
//! and serving metrics. This is the paper-as-a-system: the Stem budget
//! enters through `Method::Stem` scalars on the prefill side and through
//! the decode [`DecodePolicy`] on the generation side, and shows up as
//! lower exec latency and budget fraction per request.
//!
//! Shared-prefix fan-out: Stem's core observation — initial tokens feed
//! every later token's aggregation — makes the prompt prefix the most
//! reused KV in the system, so generations route through *prefix
//! holder* sessions: the first request ingests a prompt once, every
//! branch (`submit_generate_many` / `fanout`) forks the refcounted
//! prefix and diverges copy-on-write. Parked holders form a prefix
//! cache (unpinned, LRU-evictable under page pressure, capped at
//! [`MAX_PREFIX_HOLDERS`] with LCP-aware retirement — the lightest
//! covered-tokens × refcount holder goes first).
//!
//! Holder lookup is governed by [`PrefixMode`] (`--prefix-mode`):
//!
//! * **exact** — prompt-hash keyed; only byte-identical prompts reuse a
//!   holder ([`PrefixIndex`]).
//! * **radix** (default) — token-granular: a [`RadixIndex`] maps the new
//!   prompt to the holder with the longest page-aligned common token
//!   prefix. A *partial* hit forks just the covered pages off the
//!   matched holder ([`DecodeSession::fork_prefix`]) into a fresh
//!   holder, ingests only the uncovered prompt suffix
//!   ([`DecodeSession::extend_prompt`]), and parks it under the full
//!   prompt — so overlapping prompt families converge onto shared page
//!   prefixes instead of re-ingesting from scratch.
//!
//! Either index lets admission charge the ingest estimate against the
//! uncovered suffix only ([`estimate_ingest_ns`] on the suffix length);
//! every branch still pays its own decode estimate.
//!
//! Threading model (std threads; see DESIGN.md §2 on tokio):
//!   * callers enqueue via `submit` / `submit_generate` /
//!     `submit_generate_many` (mpsc into the dispatcher)
//!   * one dispatcher thread forms batches (size-or-timeout, prefill and
//!     decode lanes alternating — see `batcher`) and owns the prefix
//!     holders; prompt ingest runs on a worker and reports back via
//!     `Msg::PrefixFilled`
//!   * `workers` threads execute batch items on the shared PJRT engine;
//!     decode steps advance their `DecodeSession` one token and then
//!     re-enqueue themselves through the dispatcher (continuous
//!     batching), so a long generation never monopolizes a worker —
//!     sibling branches of one fan-out enter the decode lane together
//!     and share a dispatch round
//!   * completions flow back through per-request channels
//!
//! Failure domains (see `docs/ARCHITECTURE.md` §Failure domains):
//!
//! * **Deadlines** — requests may carry an absolute deadline. Queued
//!   work past it is shed with a typed [`ServeError::DeadlineExceeded`]
//!   (never executed, admission unwound, `shed_deadline` counted); a
//!   generation already decoding stops at its next step and returns its
//!   partial tokens with [`Finish::DeadlineExceeded`].
//! * **Cancellation** — every generation branch carries a cancel flag
//!   ([`CancelHandle`]); dropping an unconsumed [`GenerateTicket`]
//!   raises it, so an abandoned client reaps its own session: the next
//!   step unwinds admission, frees the branch's KV pages and answers
//!   the (possibly dead) channel with [`Finish::Cancelled`].
//! * **Panic isolation** — worker closures wrap execution in
//!   `catch_unwind`: a panicking batch item becomes a per-request
//!   [`ServeError::WorkerPanic`] with full session/admission cleanup
//!   while the worker thread keeps serving the next item.
//! * **Fault injection** — a [`FaultPlan`] (env `STEM_FAULTS`) drives
//!   deterministic failures at KV allocation, engine execution,
//!   decode-step dispatch and worker stalls for the chaos suite.
//! * **Graceful degradation** — a [`Degrader`] ladder steps service
//!   quality down reversibly under sustained shedding or KV pressure
//!   (spec drafting off, holder cap shrunk, decode budgets tightened).

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, MutexGuard};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::admission::{Admission, AdmissionConfig, Admit};
use super::batcher::{
    AnyBatch, BatchKey, Batcher, BatcherConfig, DecodeLaneConfig, DecodeStep, IngestStep,
};
use super::degrade::{DegradeConfig, Degrader};
use super::kv_cache::{KvConfig, KvError};
use super::metrics::Metrics;
use super::prefix::{PrefixIndex, PrefixMode, RadixIndex};
use super::request::{
    Finish, GenerateRequest, GenerateResponse, Method, PrefillRequest, PrefillResponse, ServeError,
};
use crate::decode::{
    DecodeBackend, DecodeBackendKind, DecodeError, DecodePolicy, DecodeSession, SharedKv,
    StepInfo, StepPlan,
};
use crate::model::vocab;
use crate::model::Manifest;
use crate::obs::snapshot::{KvGauges, MetricsSnapshot};
use crate::obs::trace::{EventKind, FlightRecorder, Outcome, PanicSite, RouteKind, Trace};
use crate::runtime::{Engine, PrefillBackend};
use crate::sim::cost::{
    estimate_generate_ns_for, estimate_ingest_ns, estimate_spec_step_ns_for, DecodeCostModel,
    Geometry, SPEC_ASSUMED_ACCEPTANCE,
};
use crate::util::fault::{FaultPlan, FaultPoint};
use crate::util::threadpool::ThreadPool;

/// Parked prefix holders kept as a cache before the lightest are
/// retired (their pages also yield to LRU eviction under pool pressure).
/// The degradation ladder shrinks the effective cap under pressure
/// ([`Degrader::holder_cap`]).
pub const MAX_PREFIX_HOLDERS: usize = 32;

/// Construction-time knobs of a [`Coordinator`].
pub struct CoordinatorConfig {
    /// Worker threads executing prefill batches and decode steps.
    pub workers: usize,
    /// Size-or-timeout policy of the prefill batcher.
    pub batcher: BatcherConfig,
    /// Size-or-timeout policy of the decode-step lane.
    pub decode_lane: DecodeLaneConfig,
    /// Backpressure limits (tokens, requests, estimated work).
    pub admission: AdmissionConfig,
    /// Total pages in the shared KV pool.
    pub kv_pages: usize,
    /// How generations match cached prompt prefixes (`--prefix-mode`):
    /// exact prompt-hash equality, or token-granular radix matching with
    /// partial (page-aligned) reuse. Defaults to radix.
    pub prefix_mode: PrefixMode,
    /// Chunked prompt ingest (`--chunk-tokens`): a holder's prompt
    /// suffix is projected in fixed-token chunks scheduled through the
    /// batcher's ingest lane against decode traffic, so one long prompt
    /// can no longer head-of-line-block every decode stream for a full
    /// prefill turn (see `coordinator::batcher`). `0` disables chunking
    /// and ingests monolithically on a worker. The degradation ladder
    /// shrinks the effective size under pressure
    /// ([`Degrader::effective_chunk_tokens`]). Defaults to 2048.
    pub chunk_tokens: usize,
    /// Deterministic fault-injection plan for chaos testing. Defaults to
    /// whatever the `STEM_FAULTS` env var specifies — `None` when unset,
    /// which keeps every injection point zero-cost.
    pub faults: Option<Arc<FaultPlan>>,
    /// Hysteresis tuning of the graceful-degradation ladder.
    pub degrade: DegradeConfig,
    /// Flight-recorder ring capacity in events; `0` disables tracing
    /// entirely (every record call collapses to one branch — the
    /// `telemetry_overhead` bench gate measures exactly this toggle).
    pub trace_events: usize,
    /// Which LM the decode stack projects/unembeds through
    /// (`--decode-backend {tiny,engine}`): the in-process [`TinyLm`]
    /// default, or compiled per-step `decode_step` modules executed
    /// through the prefill backend. When the manifest lacks decode
    /// modules the coordinator logs and falls back to `tiny`.
    ///
    /// [`TinyLm`]: crate::decode::TinyLm
    pub decode_backend: DecodeBackendKind,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8),
            batcher: BatcherConfig::default(),
            decode_lane: DecodeLaneConfig::default(),
            admission: AdmissionConfig::default(),
            kv_pages: 4096,
            prefix_mode: PrefixMode::default(),
            chunk_tokens: 2048,
            faults: FaultPlan::from_env().map(Arc::new),
            degrade: DegradeConfig::default(),
            trace_events: 4096,
            decode_backend: DecodeBackendKind::default(),
        }
    }
}

/// FNV-1a over the token stream: the prefix identity used by the prefix
/// cache and the admission-side [`PrefixIndex`].
pub fn prompt_hash(prompt: &[i32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in prompt {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// A clonable handle that cancels one generation branch: the branch
/// stops at its next decode step, returns the tokens generated so far
/// with [`Finish::Cancelled`], and releases its KV pages and admission
/// share. Cancelling an already-finished branch is a no-op.
#[derive(Clone)]
pub struct CancelHandle(Arc<AtomicBool>);

impl CancelHandle {
    /// Raise the cancel flag (idempotent).
    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    /// Whether the flag has been raised.
    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// One generation branch's response slot plus its cancel flag.
/// Dropping a ticket before receiving its response counts as client
/// abandonment and cancels the branch — the serving side reaps the
/// session instead of decoding for a caller that went away.
pub struct GenerateTicket {
    rx: mpsc::Receiver<Result<GenerateResponse>>,
    cancel: Arc<AtomicBool>,
    received: bool,
    seq: u64,
}

impl GenerateTicket {
    /// Block until the branch's terminal outcome arrives.
    pub fn recv(&mut self) -> Result<GenerateResponse> {
        let out = self.rx.recv().map_err(|_| anyhow!("response channel closed"))?;
        self.received = true;
        out
    }

    /// Like [`GenerateTicket::recv`] with a timeout; timing out does
    /// *not* consume or cancel the ticket.
    pub fn recv_timeout(&mut self, timeout: Duration) -> Result<GenerateResponse> {
        let out = match self.rx.recv_timeout(timeout) {
            Ok(out) => out,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                return Err(anyhow!("timed out waiting for generation"))
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                return Err(anyhow!("response channel closed"))
            }
        };
        self.received = true;
        out
    }

    /// A handle that cancels this branch from another thread.
    pub fn cancel_handle(&self) -> CancelHandle {
        CancelHandle(Arc::clone(&self.cancel))
    }

    /// The branch's sequence id — its *span* in the flight recorder
    /// ([`FlightRecorder::span_events`] replays this branch's timeline).
    pub fn seq(&self) -> u64 {
        self.seq
    }
}

impl Drop for GenerateTicket {
    fn drop(&mut self) {
        if !self.received {
            self.cancel.store(true, Ordering::SeqCst);
        }
    }
}

/// Mode-dispatched view over the two prefix indexes, so holder
/// bookkeeping (insert on fill start, remove on retirement) is written
/// once. Copyable borrow bundle — the dispatcher threads it through the
/// routing helpers.
#[derive(Clone, Copy)]
struct PrefixTables<'a> {
    mode: PrefixMode,
    exact: &'a PrefixIndex,
    radix: &'a RadixIndex,
}

impl PrefixTables<'_> {
    fn insert(&self, key: u64, prompt: &[i32]) {
        match self.mode {
            PrefixMode::Exact => self.exact.insert(key),
            PrefixMode::Radix => self.radix.insert(key, prompt),
        }
    }

    fn remove(&self, key: u64, prompt: &[i32]) {
        match self.mode {
            PrefixMode::Exact => self.exact.remove(key),
            PrefixMode::Radix => self.radix.remove(key, prompt),
        }
    }
}

/// Admission share of one fan-out branch, released when it completes.
#[derive(Debug, Clone, Copy)]
struct BranchAdmit {
    tokens: usize,
    ns: f64,
}

impl BranchAdmit {
    /// A share that releases nothing (the drained/placeholder state).
    const ZERO: BranchAdmit = BranchAdmit { tokens: 0, ns: 0.0 };
}

enum Msg {
    Request(PrefillRequest, mpsc::Sender<Result<PrefillResponse>>),
    /// One fan-out group: `req.fanout` branches over one shared prompt,
    /// one (response channel, cancel flag) pair + admission share per
    /// branch, plus the group's shared ingest share (the uncovered
    /// prompt suffix — zero on a full prefix hit), released
    /// progressively as chunks land.
    Generate(
        GenerateRequest,
        Vec<(mpsc::Sender<Result<GenerateResponse>>, Arc<AtomicBool>)>,
        Vec<BranchAdmit>,
        BranchAdmit,
    ),
    /// A prefix holder finished (or failed) its one-time prompt ingest
    /// on a worker; the session comes back to be parked in the cache.
    PrefixFilled { key: u64, session: Result<Box<DecodeSession>, String> },
    /// One ingest chunk of a chunked prefill landed (or failed) on a
    /// worker; `tokens` is the chunk length just projected.
    ChunkDone { key: u64, tokens: usize, session: Result<Box<DecodeSession>, String> },
    /// A generation finished a step and wants its next one scheduled;
    /// the second field is the step's token width (γ+1 for speculative
    /// rounds, 1 otherwise) so the decode lane carries it.
    DecodeReady(u64, usize),
    Shutdown,
}

/// One active generation branch owned by the dispatcher/worker handoff:
/// the session leaves the map while its step runs and returns
/// afterwards, so a sequence can never run two steps concurrently.
struct DecodeTask {
    session: DecodeSession,
    ch: mpsc::Sender<Result<GenerateResponse>>,
    n_prompt: usize,
    max_new: usize,
    tokens: Vec<i32>,
    enqueued: Instant,
    first_step_at: Option<Instant>,
    /// When this branch last committed tokens (TPOT inter-commit gap).
    last_commit: Option<Instant>,
    /// Admission bookkeeping to release on completion.
    admit_tokens: usize,
    admit_ns: f64,
    /// Client-side cancel flag; checked before every step.
    cancel: Arc<AtomicBool>,
    /// Absolute deadline; checked before every step.
    deadline: Option<Instant>,
}

type DecodeTasks = Arc<Mutex<HashMap<u64, DecodeTask>>>;

/// Lock the decode-task map, recovering from poisoning: tasks are
/// inserted/removed whole (no critical section mutates one in place
/// across a panic point), so a poisoned map is safe to adopt — and
/// refusing would turn one isolated worker panic into a cascade.
fn lock_tasks(tasks: &DecodeTasks) -> MutexGuard<'_, HashMap<u64, DecodeTask>> {
    tasks.lock().unwrap_or_else(|p| p.into_inner())
}

/// One branch of a fan-out group waiting to fork its prefix.
struct BranchSpec {
    seq: u64,
    ch: mpsc::Sender<Result<GenerateResponse>>,
    max_new: usize,
    policy: DecodePolicy,
    n_prompt: usize,
    enqueued: Instant,
    admit: BranchAdmit,
    cancel: Arc<AtomicBool>,
    deadline: Option<Instant>,
}

/// A prefix-holder entry: the session that ingested (or is ingesting)
/// one unique prompt, plus branches queued while the ingest runs.
struct Holder {
    seq: u64,
    prompt: Vec<i32>,
    /// Parked after ingest; `None` while the prefill job runs on a worker.
    session: Option<DecodeSession>,
    waiting: Vec<BranchSpec>,
    /// Resumable chunked-ingest state; `None` once ingest completes (or
    /// for monolithic fills, which never enter the ingest lane).
    ingest: Option<IngestJob>,
    /// The group's unreleased ingest admission share, drained
    /// chunk-by-chunk as work lands and flushed on completion/failure.
    ingest_admit: BranchAdmit,
    /// LRU clock for cap-retirement: bumped on creation and every hit.
    last_used: u64,
}

/// Chunked-prefill progress of one holder: the suffix still being
/// projected, how much of it has landed, and the chunk size frozen at
/// fill start (so one ingest never changes granularity mid-flight even
/// if the degradation ladder moves).
struct IngestJob {
    /// Present while the next chunk waits in the batcher's ingest lane;
    /// taken (moved onto a worker) while a chunk runs.
    session: Option<DecodeSession>,
    suffix: Vec<i32>,
    done: usize,
    chunk: usize,
}

/// The serving runtime (see module docs for the threading model).
pub struct Coordinator {
    backend: Arc<dyn PrefillBackend>,
    /// The PJRT engine when serving compiled artifacts; `None` under a
    /// synthetic backend (chaos tests, benches).
    pjrt: Option<Arc<Engine>>,
    tx: mpsc::Sender<Msg>,
    dispatcher: Option<thread::JoinHandle<()>>,
    /// Serving counters/histograms behind [`Coordinator::report`].
    pub metrics: Arc<Metrics>,
    admission: Arc<Admission>,
    kv: Arc<SharedKv>,
    prefix_index: Arc<PrefixIndex>,
    radix_index: Arc<RadixIndex>,
    prefix_mode: PrefixMode,
    decode_model: Arc<dyn DecodeBackend>,
    /// Which decode cost constants admission budgets with — matched to
    /// the *resolved* backend (post-fallback), not the configured one.
    cost_model: DecodeCostModel,
    geometry: Geometry,
    workers: usize,
    next_id: AtomicU64,
    started: Instant,
}

impl Coordinator {
    /// Boot the serving stack over a compiled [`Engine`]: spawn the
    /// dispatcher thread, size the shared KV pool from the manifest
    /// geometry, and wire up admission + both prefix indexes.
    pub fn new(engine: Arc<Engine>, cfg: CoordinatorConfig) -> Coordinator {
        let backend: Arc<dyn PrefillBackend> = Arc::clone(&engine) as Arc<dyn PrefillBackend>;
        Coordinator::boot(backend, Some(engine), cfg)
    }

    /// Boot the serving stack over any [`PrefillBackend`] — the
    /// artifact-free [`crate::runtime::SyntheticEngine`] lets chaos
    /// tests and benches exercise the full coordinator without PJRT.
    pub fn with_backend(backend: Arc<dyn PrefillBackend>, cfg: CoordinatorConfig) -> Coordinator {
        Coordinator::boot(backend, None, cfg)
    }

    fn boot(
        backend: Arc<dyn PrefillBackend>,
        pjrt: Option<Arc<Engine>>,
        cfg: CoordinatorConfig,
    ) -> Coordinator {
        let mut metrics = Metrics::new();
        metrics.trace = Trace::new(cfg.trace_events);
        let metrics = Arc::new(metrics);
        let admission = Arc::new(Admission::new(cfg.admission));
        let m = &backend.manifest().model;
        // decode backend over the manifest geometry (see decode::backend
        // docs). Boot stays infallible: if the configured backend cannot
        // be built (e.g. `engine` against artifacts without decode
        // modules), serve on `tiny` and say so instead of panicking the
        // whole stack.
        let decode_model: Arc<dyn DecodeBackend> = match cfg.decode_backend.build(&backend) {
            Ok(b) => b,
            Err(e) => {
                crate::info!(
                    "decode backend `{}` unavailable ({e:#}) — falling back to `tiny`",
                    cfg.decode_backend.label()
                );
                DecodeBackendKind::Tiny
                    .build(&backend)
                    .expect("tiny decode backend construction is infallible")
            }
        };
        let cost_model = match decode_model.name() {
            "engine" => DecodeCostModel::Engine,
            _ => DecodeCostModel::Tiny,
        };
        let kv = SharedKv::new(
            KvConfig { total_pages: cfg.kv_pages, page_tokens: m.block },
            decode_model.kv_heads(),
            decode_model.head_dim(),
        );
        if let Some(plan) = &cfg.faults {
            kv.set_fault_plan(Arc::clone(plan));
        }
        let prefix_index = Arc::new(PrefixIndex::default());
        let radix_index = Arc::new(RadixIndex::new(m.block));
        let geometry = Geometry {
            n_layers: 1,
            n_heads: m.n_heads,
            d_head: m.d_head,
            d_model: m.n_heads * m.d_head,
            d_ff: m.d_ff,
            block: m.block,
        };
        let (tx, rx) = mpsc::channel::<Msg>();

        let dispatcher = {
            let backend = Arc::clone(&backend);
            let metrics = Arc::clone(&metrics);
            let admission = Arc::clone(&admission);
            let kv = Arc::clone(&kv);
            let prefix_index = Arc::clone(&prefix_index);
            let radix_index = Arc::clone(&radix_index);
            let prefix_mode = cfg.prefix_mode;
            let decode_model = Arc::clone(&decode_model);
            let batcher_cfg = cfg.batcher.clone();
            let decode_cfg = cfg.decode_lane.clone();
            let workers = cfg.workers;
            let faults = cfg.faults.clone();
            let degrade_cfg = cfg.degrade.clone();
            let chunk_tokens = cfg.chunk_tokens;
            let tx2 = tx.clone();
            thread::spawn(move || {
                dispatcher_loop(DispatcherCtx {
                    rx,
                    tx: tx2,
                    backend,
                    metrics,
                    admission,
                    kv,
                    prefix_index,
                    radix_index,
                    prefix_mode,
                    decode_model,
                    batcher_cfg,
                    decode_cfg,
                    workers,
                    faults,
                    degrade_cfg,
                    geometry,
                    chunk_tokens,
                })
            })
        };

        Coordinator {
            backend,
            pjrt,
            tx,
            dispatcher: Some(dispatcher),
            metrics,
            admission,
            kv,
            prefix_index,
            radix_index,
            prefix_mode: cfg.prefix_mode,
            decode_model,
            cost_model,
            geometry,
            workers: cfg.workers,
            next_id: AtomicU64::new(1),
            started: Instant::now(),
        }
    }

    /// The artifacts manifest the serving backend executes against.
    pub fn manifest(&self) -> &Manifest {
        self.backend.manifest()
    }

    /// The PJRT engine executing prefill graphs, when this coordinator
    /// serves compiled artifacts (`None` under a synthetic backend).
    pub fn engine(&self) -> Option<&Arc<Engine>> {
        self.pjrt.as_ref()
    }

    /// The serving backend (PJRT or synthetic) executing prefill
    /// modules — the same handle decode backends are built over, so
    /// eval drivers can construct alternative [`DecodeBackend`]s
    /// against the manifest this coordinator serves.
    pub fn prefill_backend(&self) -> &Arc<dyn PrefillBackend> {
        &self.backend
    }

    /// The admission gate (exposed so tests can assert the outstanding
    /// counters return to zero after a drain).
    pub fn admission(&self) -> &Arc<Admission> {
        &self.admission
    }

    /// The decode backend serving generations (exposed so tests/benches
    /// can share the exact serving geometry and assert the resolved
    /// backend via [`DecodeBackend::name`]).
    pub fn decode_model(&self) -> &Arc<dyn DecodeBackend> {
        &self.decode_model
    }

    /// The shared paged KV store (pool + slabs) behind every decode
    /// session and prefill reservation.
    pub fn shared_kv(&self) -> &Arc<SharedKv> {
        &self.kv
    }

    /// The exact-mode live-prefix index (admission-side view of the
    /// prefix cache when `prefix_mode` is [`PrefixMode::Exact`]).
    pub fn prefix_index(&self) -> &Arc<PrefixIndex> {
        &self.prefix_index
    }

    /// The token-granular radix index (admission-side view of the
    /// prefix cache when `prefix_mode` is [`PrefixMode::Radix`]).
    pub fn radix_index(&self) -> &Arc<RadixIndex> {
        &self.radix_index
    }

    /// The active prefix-matching mode.
    pub fn prefix_mode(&self) -> PrefixMode {
        self.prefix_mode
    }

    /// Live cached prefixes under the active mode.
    pub fn cached_prefixes(&self) -> usize {
        match self.prefix_mode {
            PrefixMode::Exact => self.prefix_index.len(),
            PrefixMode::Radix => self.radix_index.len(),
        }
    }

    /// Route + admit + enqueue. Returns the response channel, or an
    /// immediate rejection (backpressure).
    pub fn submit(
        &self,
        checkpoint: &str,
        method: Method,
        ids: Vec<i32>,
        diag: bool,
    ) -> Result<mpsc::Receiver<Result<PrefillResponse>>> {
        self.submit_with_deadline(checkpoint, method, ids, diag, None)
    }

    /// [`Coordinator::submit`] with an absolute deadline: if it passes
    /// while the request is still queued, the dispatcher sheds it with a
    /// typed [`ServeError::DeadlineExceeded`] instead of executing it.
    pub fn submit_with_deadline(
        &self,
        checkpoint: &str,
        method: Method,
        ids: Vec<i32>,
        diag: bool,
        deadline: Option<Instant>,
    ) -> Result<mpsc::Receiver<Result<PrefillResponse>>> {
        let bucket = self
            .backend
            .manifest()
            .bucket_for(ids.len())
            .ok_or_else(|| anyhow!("request of {} tokens exceeds every bucket", ids.len()))?;
        match self.admission.try_admit(bucket) {
            Admit::Accepted => {}
            Admit::Rejected { reason } => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                self.metrics.trace.record(0, EventKind::Reject);
                return Err(anyhow!("rejected: {reason}"));
            }
        }
        let req = PrefillRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            checkpoint: checkpoint.to_string(),
            method,
            ids,
            diag,
            enqueued: Instant::now(),
            deadline,
        };
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        self.metrics.trace.record(req.id, EventKind::Submit { tokens: req.ids.len() as u32 });
        let (rtx, rrx) = mpsc::channel();
        self.tx.send(Msg::Request(req, rtx)).map_err(|_| anyhow!("coordinator stopped"))?;
        Ok(rrx)
    }

    /// Synchronous convenience wrapper (eval harness path).
    pub fn prefill_blocking(
        &self,
        checkpoint: &str,
        method: Method,
        ids: Vec<i32>,
        diag: bool,
    ) -> Result<PrefillResponse> {
        let rx = self.submit(checkpoint, method, ids, diag)?;
        rx.recv().map_err(|_| anyhow!("response channel closed"))?
    }

    /// Submit `fanout` continuations of one prompt: the prompt is
    /// ingested once into a prefix-holder session (reused across
    /// requests, exactly or — in radix mode — by longest page-aligned
    /// common prefix), each branch forks the refcounted prefix and
    /// decodes independently with copy-on-write divergence. Admission
    /// charges the decode work per branch but the ingest work only for
    /// the prompt suffix not covered by a cached prefix
    /// ([`estimate_ingest_ns`] on the suffix length — zero on a full
    /// hit). Returns one response channel per branch, in branch order.
    pub fn submit_generate_many(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        policy: DecodePolicy,
        fanout: usize,
    ) -> Result<Vec<mpsc::Receiver<Result<GenerateResponse>>>> {
        let (rxs, _cancels, _first_seq) =
            self.submit_generate_inner(prompt, max_new_tokens, policy, fanout, None)?;
        Ok(rxs)
    }

    /// Like [`Coordinator::submit_generate_many`] but returns one
    /// [`GenerateTicket`] per branch — cancel handle plus abandonment
    /// semantics — and takes an optional absolute deadline shared by
    /// every branch.
    pub fn submit_generate_tickets(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        policy: DecodePolicy,
        fanout: usize,
        deadline: Option<Instant>,
    ) -> Result<Vec<GenerateTicket>> {
        let (rxs, cancels, first_seq) =
            self.submit_generate_inner(prompt, max_new_tokens, policy, fanout, deadline)?;
        Ok(rxs
            .into_iter()
            .zip(cancels)
            .enumerate()
            .map(|(i, (rx, cancel))| GenerateTicket {
                rx,
                cancel,
                received: false,
                seq: first_seq + i as u64,
            })
            .collect())
    }

    fn submit_generate_inner(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        policy: DecodePolicy,
        fanout: usize,
        deadline: Option<Instant>,
    ) -> Result<(Vec<mpsc::Receiver<Result<GenerateResponse>>>, Vec<Arc<AtomicBool>>, u64)> {
        policy.validate().map_err(|e| anyhow!("invalid decode policy: {e}"))?;
        if max_new_tokens == 0 {
            return Err(anyhow!("max_new_tokens must be >= 1"));
        }
        if fanout == 0 {
            return Err(anyhow!("fanout must be >= 1"));
        }
        let n_tokens = prompt.len() + max_new_tokens;
        // budget each branch's estimated work up front — a decode stream
        // holds pages and a worker slice for its lifetime
        let budget = match policy.plan(n_tokens, 0, self.geometry.block) {
            StepPlan::Dense => None,
            StepPlan::Sparse { budget_blocks } => Some(budget_blocks as f64),
        };
        let full_ns = if policy.spec_gamma >= 1 {
            // speculative branch: charge draft/verify rounds at the
            // conservative assumed acceptance instead of per-token steps
            let mean_ctx = prompt.len() + max_new_tokens / 2;
            let draft = policy.draft();
            let draft_budget = match draft.plan(mean_ctx, 0, self.geometry.block) {
                StepPlan::Dense => None,
                StepPlan::Sparse { budget_blocks } => Some(budget_blocks as f64),
            };
            let round_ns = estimate_spec_step_ns_for(
                self.cost_model,
                &self.geometry,
                mean_ctx,
                policy.spec_gamma,
                draft_budget,
                budget,
                policy.stride,
                self.workers,
            );
            let commits = 1.0 + policy.spec_gamma as f64 * SPEC_ASSUMED_ACCEPTANCE;
            estimate_ingest_ns(&self.geometry, prompt.len())
                + (max_new_tokens as f64 / commits).ceil() * round_ns
        } else {
            estimate_generate_ns_for(
                self.cost_model,
                &self.geometry,
                prompt.len(),
                max_new_tokens,
                budget,
                policy.stride,
                self.workers,
            )
        };
        let full_ingest_ns = estimate_ingest_ns(&self.geometry, prompt.len());
        let decode_ns = (full_ns - full_ingest_ns).max(0.0);
        let prefix_hash = prompt_hash(&prompt);
        // token-granular admission: only the *uncovered* prompt suffix
        // is charged, once, to the first branch — an exact live prefix
        // covers everything (the charge-once-per-unique-prefix rule), a
        // radix match covers its page-aligned LCP. Index reads are
        // advisory; a stale hit merely undercharges one estimate. Totals
        // are closed-form so the admission decision runs BEFORE any
        // per-branch allocation (a huge fanout must reject cleanly, not
        // OOM building vectors — `max_requests` bounds the group size).
        let covered = match self.prefix_mode {
            PrefixMode::Exact => {
                if self.prefix_index.is_live(prefix_hash) {
                    prompt.len()
                } else {
                    0
                }
            }
            PrefixMode::Radix => self
                .radix_index
                .lookup(&prompt)
                .map(|m| m.covered.min(prompt.len()))
                .unwrap_or(0),
        };
        let suffix_len = prompt.len() - covered;
        let ingest_ns = estimate_ingest_ns(&self.geometry, suffix_len);
        let Some(total_tokens) =
            fanout.checked_mul(max_new_tokens).and_then(|t| t.checked_add(suffix_len))
        else {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            self.metrics.trace.record(0, EventKind::Reject);
            return Err(anyhow!("rejected: fanout x max_new_tokens overflows"));
        };
        let total_ns = fanout as f64 * decode_ns + ingest_ns;
        match self.admission.try_admit_work_n(fanout, total_tokens, total_ns) {
            Admit::Accepted => {}
            Admit::Rejected { reason } => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                self.metrics.trace.record(0, EventKind::Reject);
                return Err(anyhow!("rejected: {reason}"));
            }
        }
        // each branch carries its decode estimate; the uncovered-suffix
        // ingest estimate rides separately with the group so the
        // dispatcher can release it chunk-by-chunk as ingest lands
        // (fanout * decode + ingest == the totals admitted above)
        let mut admits = Vec::with_capacity(fanout);
        for _ in 0..fanout {
            admits.push(BranchAdmit { tokens: max_new_tokens, ns: decode_ns });
        }
        let ingest_admit = BranchAdmit { tokens: suffix_len, ns: ingest_ns };
        // id block: holder seq = id, branch seqs = id+1 ..= id+fanout
        let id = self.next_id.fetch_add(1 + fanout as u64, Ordering::Relaxed);
        let req = GenerateRequest {
            id,
            prompt,
            max_new_tokens,
            policy,
            fanout,
            prefix_hash,
            enqueued: Instant::now(),
            deadline,
        };
        self.metrics.generates_submitted.fetch_add(fanout as u64, Ordering::Relaxed);
        if self.metrics.trace.enabled() {
            // one span per branch: every branch timeline starts at submit
            let tokens = req.prompt.len() as u32;
            for i in 0..fanout as u64 {
                self.metrics.trace.record(id + 1 + i, EventKind::Submit { tokens });
            }
        }
        let mut lines = Vec::with_capacity(fanout);
        let mut rxs = Vec::with_capacity(fanout);
        let mut cancels = Vec::with_capacity(fanout);
        for _ in 0..fanout {
            let (rtx, rrx) = mpsc::channel();
            let cancel = Arc::new(AtomicBool::new(false));
            cancels.push(Arc::clone(&cancel));
            lines.push((rtx, cancel));
            rxs.push(rrx);
        }
        self.tx
            .send(Msg::Generate(req, lines, admits, ingest_admit))
            .map_err(|_| anyhow!("coordinator stopped"))?;
        Ok((rxs, cancels, id + 1))
    }

    /// Submit a single autoregressive generation (fan-out of one); the
    /// response arrives once on the returned channel.
    pub fn submit_generate(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        policy: DecodePolicy,
    ) -> Result<mpsc::Receiver<Result<GenerateResponse>>> {
        self.submit_generate_many(prompt, max_new_tokens, policy, 1)?
            .pop()
            .ok_or_else(|| anyhow!("fanout=1 yielded no channel"))
    }

    /// Synchronous convenience wrapper around [`Coordinator::submit_generate`].
    pub fn generate_blocking(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        policy: DecodePolicy,
    ) -> Result<GenerateResponse> {
        let rx = self.submit_generate(prompt, max_new_tokens, policy)?;
        rx.recv().map_err(|_| anyhow!("response channel closed"))?
    }

    /// Wall-clock time since the coordinator booted.
    pub fn uptime(&self) -> Duration {
        self.started.elapsed()
    }

    /// Current KV page occupancy (used, total, fraction).
    pub fn kv_occupancy(&self) -> (usize, usize, f64) {
        self.kv.occupancy()
    }

    /// Human-readable serving report: request/decode/fan-out counters,
    /// latency percentiles, KV occupancy and prefix-cache gauges.
    pub fn report(&self) -> String {
        let (used, total, frac) = self.kv_occupancy();
        format!(
            "{}\nkv pages: {used}/{total} in use ({:.1}%) | slab pages resident: {} | cached prefixes: {} | decode backend: {}",
            self.metrics.report(self.uptime()),
            100.0 * frac,
            self.kv.pages_resident(),
            self.cached_prefixes(),
            self.decode_model.name(),
        )
    }

    /// Structured metrics snapshot: every counter, exact histogram
    /// buckets, KV-pool gauges, per-band sparsity telemetry and
    /// flight-recorder stats — the machine-readable sibling of
    /// [`Coordinator::report`]. Serialize with
    /// [`MetricsSnapshot::to_json`] or [`MetricsSnapshot::to_prometheus`].
    pub fn snapshot(&self) -> MetricsSnapshot {
        let (used, total, _) = self.kv_occupancy();
        let gauges = KvGauges {
            pages_used: used as u64,
            pages_total: total as u64,
            slab_pages: self.kv.pages_resident() as u64,
        };
        let mut snap = MetricsSnapshot::collect(&self.metrics, Some(gauges), self.uptime());
        snap.decode_backend = Some(self.decode_model.name());
        snap
    }

    /// The flight recorder, when tracing is armed
    /// (`CoordinatorConfig::trace_events > 0`).
    pub fn flight_recorder(&self) -> Option<&FlightRecorder> {
        self.metrics.trace.recorder()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

struct DispatcherCtx {
    rx: mpsc::Receiver<Msg>,
    tx: mpsc::Sender<Msg>,
    backend: Arc<dyn PrefillBackend>,
    metrics: Arc<Metrics>,
    admission: Arc<Admission>,
    kv: Arc<SharedKv>,
    prefix_index: Arc<PrefixIndex>,
    radix_index: Arc<RadixIndex>,
    prefix_mode: PrefixMode,
    decode_model: Arc<dyn DecodeBackend>,
    batcher_cfg: BatcherConfig,
    decode_cfg: DecodeLaneConfig,
    workers: usize,
    faults: Option<Arc<FaultPlan>>,
    degrade_cfg: DegradeConfig,
    /// Model geometry for per-chunk ingest cost estimates
    /// (`estimate_ingest_ns` is linear, so chunk estimates sum to the
    /// admitted total).
    geometry: Geometry,
    /// Configured ingest chunk size (0 = monolithic).
    chunk_tokens: usize,
}

fn dispatcher_loop(ctx: DispatcherCtx) {
    let DispatcherCtx {
        rx,
        tx,
        backend,
        metrics,
        admission,
        kv,
        prefix_index,
        radix_index,
        prefix_mode,
        decode_model,
        batcher_cfg,
        decode_cfg,
        workers,
        faults,
        degrade_cfg,
        geometry,
        chunk_tokens,
    } = ctx;
    let tables = PrefixTables { mode: prefix_mode, exact: &prefix_index, radix: &radix_index };
    let pool = ThreadPool::new(workers);
    let mut batcher = Batcher::with_decode(batcher_cfg.clone(), decode_cfg.clone());
    let mut channels: HashMap<u64, mpsc::Sender<Result<PrefillResponse>>> = HashMap::new();
    let tasks: DecodeTasks = Arc::new(Mutex::new(HashMap::new()));
    // prefix cache: holder sessions keyed by prompt hash (exact mode)
    // or by their own holder id with prompts indexed in the radix tree
    // (see module docs)
    let mut holders: HashMap<u64, Holder> = HashMap::new();
    let mut holder_clock: u64 = 0;
    // generations admitted but not yet completed (branches may be queued
    // on a filling holder, in the batcher, or running a step)
    let active_decodes = Arc::new(AtomicUsize::new(0));
    let shutdown = AtomicBool::new(false);
    // graceful-degradation ladder, evaluated on the dispatcher's own
    // cadence from KV occupancy + the shed/reject delta
    let degrade_every = degrade_cfg.eval_every;
    let mut degrader = Degrader::new(degrade_cfg);
    let mut degrade_last_eval = Instant::now();
    let mut degrade_last_shed: u64 = 0;

    loop {
        // 1. pull what's available (block briefly if nothing pending);
        //    while decode steps are in flight we must keep serving
        //    DecodeReady/PrefixFilled messages even with an empty batcher
        let draining = shutdown.load(Ordering::SeqCst);
        let idle = batcher.pending() == 0;
        let msg = if idle && !draining && active_decodes.load(Ordering::SeqCst) == 0 {
            match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => break,
            }
        } else {
            // sleep no longer than the tightest lane deadline: a queued
            // decode step must not wait out the (much longer) prefill
            // quantum before its age-based flush is re-checked
            let quantum = if batcher.decode_pending() > 0 {
                (batcher_cfg.max_wait / 2).min(decode_cfg.max_wait)
            } else {
                batcher_cfg.max_wait / 2
            };
            match rx.recv_timeout(quantum) {
                Ok(m) => Some(m),
                Err(mpsc::RecvTimeoutError::Timeout) => None,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        };
        if let Some(msg) = msg {
            match msg {
                Msg::Shutdown => {
                    shutdown.store(true, Ordering::SeqCst);
                }
                Msg::Request(req, ch) => {
                    // submit() validated the length against this same
                    // immutable manifest, so a miss here is a logic bug;
                    // answer it as an error instead of panicking the
                    // dispatcher (admission charged a bucket's tokens —
                    // the request length is the closest approximation).
                    let Some(bucket) = backend.manifest().bucket_for(req.ids.len()) else {
                        metrics.record_error(format!(
                            "no bucket for {}-token request at dispatch",
                            req.ids.len()
                        ));
                        metrics.trace.record(req.id, EventKind::Finish { outcome: Outcome::Error });
                        admission.release(req.ids.len());
                        let _ = ch.send(Err(anyhow!("no bucket for request length")));
                        continue;
                    };
                    let key = BatchKey {
                        kind: req.method.kind(req.diag),
                        bucket,
                        checkpoint: req.checkpoint.clone(),
                    };
                    channels.insert(req.id, ch);
                    batcher.push(key, req);
                }
                Msg::Generate(req, lines, admits, ingest_admit) => {
                    let n_prompt = req.prompt.len();
                    // chunk granularity for any fill this group starts,
                    // frozen here (the ladder may move mid-ingest)
                    let chunk_now = degrader.effective_chunk_tokens(chunk_tokens);
                    // degradation ladder: newly launched branches take the
                    // stepped-down policy (reversible — in-flight work is
                    // never mutated)
                    let mut policy = req.policy;
                    policy.spec_gamma = degrader.effective_gamma(policy.spec_gamma);
                    policy.k_start = degrader.effective_k_start(policy.k_start, policy.min_blocks);
                    let specs: Vec<BranchSpec> = lines
                        .into_iter()
                        .zip(admits)
                        .enumerate()
                        .map(|(i, ((ch, cancel), admit))| BranchSpec {
                            seq: req.id + 1 + i as u64,
                            ch,
                            max_new: req.max_new_tokens,
                            policy,
                            n_prompt,
                            enqueued: req.enqueued,
                            admit,
                            cancel,
                            deadline: req.deadline,
                        })
                        .collect();
                    if shutdown.load(Ordering::SeqCst) {
                        release_ingest_share(&admission, ingest_admit);
                        for spec in specs {
                            metrics
                                .trace
                                .record(spec.seq, EventKind::Finish { outcome: Outcome::Error });
                            admission.release_work(spec.admit.tokens, spec.admit.ns);
                            let _ = spec.ch.send(Err(anyhow!("coordinator shutting down")));
                        }
                        continue;
                    }
                    if req.deadline.is_some_and(|d| Instant::now() >= d) {
                        // queued past its deadline: shed the whole group
                        // before it touches the KV store or a worker
                        release_ingest_share(&admission, ingest_admit);
                        for spec in specs {
                            metrics.shed_deadline.fetch_add(1, Ordering::Relaxed);
                            metrics.trace.record(spec.seq, EventKind::Shed);
                            metrics.trace.record(
                                spec.seq,
                                EventKind::Finish { outcome: Outcome::DeadlineExceeded },
                            );
                            admission.release_work(spec.admit.tokens, spec.admit.ns);
                            let _ = spec
                                .ch
                                .send(Err(anyhow::Error::new(ServeError::DeadlineExceeded)));
                        }
                        continue;
                    }
                    active_decodes.fetch_add(specs.len(), Ordering::SeqCst);
                    // covered-token gauge: every routed group contributes
                    // its prompt length; hits add back what the cache
                    // actually covered
                    metrics.prefix_tokens_total.fetch_add(n_prompt as u64, Ordering::Relaxed);
                    enum Route {
                        // parked holder with this exact prompt: fork it
                        Hit(u64),
                        // same prompt mid-ingest: queue on the holder
                        Filling(u64),
                        // holder exists but its pages were evicted:
                        // retire `stale`, re-ingest under `fresh`
                        Refill { stale: u64, fresh: u64 },
                        // radix-only: a holder covers a page-aligned
                        // prefix; fork it and ingest just the suffix
                        Partial { src: u64, covered: usize },
                        // nothing reusable: ingest under a new holder
                        Miss(u64),
                    }
                    let route = match prefix_mode {
                        PrefixMode::Exact => {
                            let hash = req.prefix_hash;
                            // hash collision with a cached *different*
                            // prompt: bypass the cache under a synthetic
                            // single-use key
                            let key = match holders.get(&hash) {
                                Some(h) if h.prompt != req.prompt => {
                                    hash ^ req.id.rotate_left(17) ^ 0x9e37_79b9_7f4a_7c15
                                }
                                _ => hash,
                            };
                            match holders.get(&key) {
                                None => Route::Miss(key),
                                Some(h) => match &h.session {
                                    None => Route::Filling(key),
                                    // verify the parked prefix survived
                                    // LRU pressure
                                    Some(_)
                                        if kv.seq_tokens(h.seq).ok().flatten()
                                            == Some(n_prompt) =>
                                    {
                                        Route::Hit(key)
                                    }
                                    Some(_) => Route::Refill { stale: key, fresh: key },
                                },
                            }
                        }
                        PrefixMode::Radix => match radix_index.lookup(&req.prompt) {
                            None => Route::Miss(req.id),
                            Some(m) => match holders.get(&m.key) {
                                // index/holder desync (holder retired
                                // between lookup and here): re-ingest
                                None => Route::Miss(req.id),
                                Some(h) if m.exact => match &h.session {
                                    None => Route::Filling(m.key),
                                    Some(_)
                                        if kv.seq_tokens(h.seq).ok().flatten()
                                            == Some(n_prompt) =>
                                    {
                                        Route::Hit(m.key)
                                    }
                                    Some(_) => {
                                        Route::Refill { stale: m.key, fresh: req.id }
                                    }
                                },
                                // partial overlap is only usable against a
                                // parked holder whose pages are still fresh
                                Some(h)
                                    if m.covered > 0
                                        && h.session.is_some()
                                        && kv.seq_tokens(h.seq).ok().flatten()
                                            == Some(h.prompt.len()) =>
                                {
                                    Route::Partial { src: m.key, covered: m.covered }
                                }
                                Some(_) => Route::Miss(req.id),
                            },
                        },
                    };
                    if metrics.trace.enabled() {
                        let (outcome, covered) = match &route {
                            Route::Hit(_) => (RouteKind::Hit, n_prompt),
                            Route::Filling(_) => (RouteKind::Filling, n_prompt),
                            Route::Refill { .. } => (RouteKind::Refill, 0),
                            Route::Partial { covered, .. } => (RouteKind::Partial, *covered),
                            Route::Miss(_) => (RouteKind::Miss, 0),
                        };
                        let kind = EventKind::PrefixRoute { outcome, covered: covered as u32 };
                        for spec in &specs {
                            metrics.trace.record(spec.seq, kind);
                        }
                    }
                    match route {
                        Route::Hit(key) => {
                            // touch the holder so cap-retirement favors
                            // hot prefixes; take it out for the launch and
                            // put it back after — ownership instead of
                            // unwraps on the double lookup
                            holder_clock += 1;
                            match holders.remove(&key) {
                                Some(mut holder) => match holder.session.take() {
                                    Some(session) => {
                                        metrics
                                            .prefix_hits
                                            .fetch_add(specs.len() as u64, Ordering::Relaxed);
                                        metrics
                                            .prefix_tokens_covered
                                            .fetch_add(n_prompt as u64, Ordering::Relaxed);
                                        holder.last_used = holder_clock;
                                        let bounced = launch_branches(
                                            &session,
                                            specs,
                                            &tasks,
                                            &mut batcher,
                                            &metrics,
                                            &admission,
                                            &active_decodes,
                                        );
                                        if bounced.is_empty() {
                                            // nothing left to ingest: the
                                            // suffix estimate (if any) was
                                            // for a prefix this hit covers
                                            release_ingest_share(&admission, ingest_admit);
                                            holder.session = Some(session);
                                            holders.insert(key, holder);
                                        } else {
                                            // the parked holder was evicted
                                            // between the freshness check
                                            // and the fork: retire it and
                                            // re-ingest for the bounced
                                            // branches
                                            metrics.prefix_hits.fetch_sub(
                                                bounced.len() as u64,
                                                Ordering::Relaxed,
                                            );
                                            tables.remove(key, &holder.prompt);
                                            drop(session);
                                            let fresh = match prefix_mode {
                                                PrefixMode::Exact => key,
                                                PrefixMode::Radix => req.id,
                                            };
                                            start_prefix_fill(
                                                fresh,
                                                req,
                                                bounced,
                                                None,
                                                ingest_admit,
                                                chunk_now,
                                                &mut holders,
                                                &mut holder_clock,
                                                tables,
                                                &kv,
                                                &decode_model,
                                                &mut batcher,
                                                &metrics,
                                                &admission,
                                                &active_decodes,
                                                &pool,
                                                &tx,
                                                &faults,
                                            );
                                        }
                                    }
                                    None => {
                                        // routed as Hit but mid-ingest
                                        // after all (defensive): queue the
                                        // branches like Filling would
                                        release_ingest_share(&admission, ingest_admit);
                                        holder.waiting.extend(specs);
                                        holders.insert(key, holder);
                                    }
                                },
                                None => {
                                    // routing desync (unreachable on the
                                    // single-threaded dispatcher): recover
                                    // with a fresh ingest instead of panic
                                    let fresh = match prefix_mode {
                                        PrefixMode::Exact => key,
                                        PrefixMode::Radix => req.id,
                                    };
                                    start_prefix_fill(
                                        fresh,
                                        req,
                                        specs,
                                        None,
                                        ingest_admit,
                                        chunk_now,
                                        &mut holders,
                                        &mut holder_clock,
                                        tables,
                                        &kv,
                                        &decode_model,
                                        &mut batcher,
                                        &metrics,
                                        &admission,
                                        &active_decodes,
                                        &pool,
                                        &tx,
                                        &faults,
                                    );
                                }
                            }
                        }
                        Route::Filling(key) => {
                            // ingest already in flight: ride it for free
                            // (this group's own suffix estimate is surplus)
                            release_ingest_share(&admission, ingest_admit);
                            metrics.prefix_hits.fetch_add(specs.len() as u64, Ordering::Relaxed);
                            metrics
                                .prefix_tokens_covered
                                .fetch_add(n_prompt as u64, Ordering::Relaxed);
                            if let Some(h) = holders.get_mut(&key) {
                                h.waiting.extend(specs);
                            } else {
                                for spec in specs {
                                    fail_branch(
                                        spec,
                                        anyhow!("prefix holder vanished mid-ingest"),
                                        &metrics,
                                        &admission,
                                        &active_decodes,
                                    );
                                }
                            }
                        }
                        Route::Refill { stale, fresh } => {
                            // the parked prefix was evicted under pressure:
                            // retire the stale holder and ingest afresh
                            if let Some(old) = holders.remove(&stale) {
                                tables.remove(stale, &old.prompt);
                            }
                            start_prefix_fill(
                                fresh,
                                req,
                                specs,
                                None,
                                ingest_admit,
                                chunk_now,
                                &mut holders,
                                &mut holder_clock,
                                tables,
                                &kv,
                                &decode_model,
                                &mut batcher,
                                &metrics,
                                &admission,
                                &active_decodes,
                                &pool,
                                &tx,
                                &faults,
                            );
                        }
                        Route::Partial { src, covered } => {
                            // token-granular reuse: fork the covered pages
                            // off the matched holder into a NEW holder for
                            // this full prompt, then ingest only the
                            // suffix on a worker; branches queue on the
                            // new holder exactly like a fresh ingest
                            holder_clock += 1;
                            let last_tok = req.prompt[covered - 1];
                            let forked = match holders.get_mut(&src) {
                                Some(h) => match h.session.as_ref() {
                                    Some(s) => {
                                        h.last_used = holder_clock;
                                        s.fork_prefix(req.id, covered, last_tok)
                                    }
                                    // routed as Partial but no parked
                                    // session (defensive): same fallback as
                                    // a vanished sequence
                                    None => Err(DecodeError::Kv(KvError::UnknownSeq(req.id))),
                                },
                                None => Err(DecodeError::Kv(KvError::UnknownSeq(req.id))),
                            };
                            match forked {
                                Ok(session) => {
                                    metrics
                                        .prefix_partial_hits
                                        .fetch_add(1, Ordering::Relaxed);
                                    metrics
                                        .prefix_tokens_covered
                                        .fetch_add(covered as u64, Ordering::Relaxed);
                                    start_prefix_fill(
                                        req.id,
                                        req,
                                        specs,
                                        Some((session, covered)),
                                        ingest_admit,
                                        chunk_now,
                                        &mut holders,
                                        &mut holder_clock,
                                        tables,
                                        &kv,
                                        &decode_model,
                                        &mut batcher,
                                        &metrics,
                                        &admission,
                                        &active_decodes,
                                        &pool,
                                        &tx,
                                        &faults,
                                    );
                                }
                                Err(DecodeError::Kv(KvError::UnknownSeq(_))) => {
                                    // holder pages vanished between the
                                    // freshness check and the fork: retire
                                    // it and fall back to a full ingest
                                    if let Some(stale) = holders.remove(&src) {
                                        tables.remove(src, &stale.prompt);
                                    }
                                    start_prefix_fill(
                                        req.id,
                                        req,
                                        specs,
                                        None,
                                        ingest_admit,
                                        chunk_now,
                                        &mut holders,
                                        &mut holder_clock,
                                        tables,
                                        &kv,
                                        &decode_model,
                                        &mut batcher,
                                        &metrics,
                                        &admission,
                                        &active_decodes,
                                        &pool,
                                        &tx,
                                        &faults,
                                    );
                                }
                                Err(e) => {
                                    let msg = format!("prefix fork failed: {e}");
                                    release_ingest_share(&admission, ingest_admit);
                                    for spec in specs {
                                        fail_branch(
                                            spec,
                                            anyhow!(msg.clone()),
                                            &metrics,
                                            &admission,
                                            &active_decodes,
                                        );
                                    }
                                }
                            }
                        }
                        Route::Miss(key) => start_prefix_fill(
                            key,
                            req,
                            specs,
                            None,
                            ingest_admit,
                            chunk_now,
                            &mut holders,
                            &mut holder_clock,
                            tables,
                            &kv,
                            &decode_model,
                            &mut batcher,
                            &metrics,
                            &admission,
                            &active_decodes,
                            &pool,
                            &tx,
                            &faults,
                        ),
                    }
                }
                Msg::PrefixFilled { key, session } => {
                    match session {
                        Ok(sess) => {
                            if let Some(holder) = holders.get_mut(&key) {
                                release_holder_ingest(&admission, holder);
                                park_filled_holder(
                                    sess,
                                    holder,
                                    &tasks,
                                    &mut batcher,
                                    &metrics,
                                    &admission,
                                    &active_decodes,
                                );
                            }
                            // else: holder retired while filling; dropping
                            // `sess` closes the seq and frees its pages
                        }
                        Err(msg) => {
                            if let Some(mut holder) = holders.remove(&key) {
                                release_holder_ingest(&admission, &mut holder);
                                tables.remove(key, &holder.prompt);
                                for spec in holder.waiting {
                                    fail_branch(
                                        spec,
                                        anyhow!(msg.clone()),
                                        &metrics,
                                        &admission,
                                        &active_decodes,
                                    );
                                }
                            }
                        }
                    }
                    retire_excess_holders(
                        &mut holders,
                        tables,
                        &kv,
                        degrader.holder_cap(MAX_PREFIX_HOLDERS),
                    );
                }
                Msg::ChunkDone { key, tokens, session } => {
                    match session {
                        Ok(sess) => {
                            let mut finished_fill = false;
                            if let Some(holder) = holders.get_mut(&key) {
                                // progressive release: the landed chunk's
                                // share of the admitted ingest estimate
                                // (linear cost model, so chunk estimates
                                // sum to the admitted total)
                                let chunk_ns = estimate_ingest_ns(&geometry, tokens);
                                let rel_tokens = tokens.min(holder.ingest_admit.tokens);
                                let rel_ns = chunk_ns.min(holder.ingest_admit.ns);
                                if rel_tokens > 0 || rel_ns > 0.0 {
                                    admission.release_work(rel_tokens, rel_ns);
                                    holder.ingest_admit.tokens -= rel_tokens;
                                    holder.ingest_admit.ns -= rel_ns;
                                }
                                let next = match holder.ingest.as_mut() {
                                    Some(job) => {
                                        job.done += tokens;
                                        if job.done >= job.suffix.len() {
                                            None
                                        } else {
                                            Some((job.suffix.len() - job.done).min(job.chunk))
                                        }
                                    }
                                    // no job state (defensive): park as done
                                    None => None,
                                };
                                match next {
                                    None => {
                                        // last chunk landed: flush any
                                        // rounding remainder of the share
                                        // and launch the queued branches
                                        holder.ingest = None;
                                        release_holder_ingest(&admission, holder);
                                        park_filled_holder(
                                            sess,
                                            holder,
                                            &tasks,
                                            &mut batcher,
                                            &metrics,
                                            &admission,
                                            &active_decodes,
                                        );
                                        finished_fill = true;
                                    }
                                    Some(n_next) => {
                                        // hand the session back to the job
                                        // and queue the next chunk into the
                                        // ingest lane, against the earliest
                                        // waiting-branch deadline
                                        let deadline = holder
                                            .waiting
                                            .iter()
                                            .filter_map(|s| s.deadline)
                                            .min();
                                        if let Some(job) = holder.ingest.as_mut() {
                                            job.session = Some(*sess);
                                        }
                                        batcher.push_ingest(IngestStep {
                                            key,
                                            tokens: n_next,
                                            deadline,
                                            enqueued: Instant::now(),
                                        });
                                    }
                                }
                            }
                            // else: holder retired/abandoned while the
                            // chunk ran; dropping `sess` closes the seq
                            // and frees its pages
                            if finished_fill {
                                retire_excess_holders(
                                    &mut holders,
                                    tables,
                                    &kv,
                                    degrader.holder_cap(MAX_PREFIX_HOLDERS),
                                );
                            }
                        }
                        Err(msg) => {
                            // a failed chunk fails the whole fill exactly
                            // like a failed monolithic ingest would
                            if let Some(mut holder) = holders.remove(&key) {
                                release_holder_ingest(&admission, &mut holder);
                                tables.remove(key, &holder.prompt);
                                for spec in holder.waiting {
                                    fail_branch(
                                        spec,
                                        anyhow!(msg.clone()),
                                        &metrics,
                                        &admission,
                                        &active_decodes,
                                    );
                                }
                            }
                        }
                    }
                }
                Msg::DecodeReady(seq, tokens) => {
                    batcher.push_decode(DecodeStep { seq, tokens, enqueued: Instant::now() });
                }
            }
        }

        // 1.5 evaluate the degradation ladder on its cadence (the
        // Degrader rate-limits itself too, but tracking the shed delta
        // needs a dispatcher-side window so deltas are only consumed by
        // evaluations that actually run)
        if degrade_last_eval.elapsed() >= degrade_every {
            let now = Instant::now();
            let shed_total = metrics.rejected.load(Ordering::Relaxed)
                + metrics.shed_deadline.load(Ordering::Relaxed);
            let before = degrader.level();
            let level = degrader.observe(
                now,
                kv.occupancy().2,
                shed_total.saturating_sub(degrade_last_shed),
            );
            degrade_last_eval = now;
            degrade_last_shed = shed_total;
            metrics.degradation_level.store(level as u64, Ordering::Relaxed);
            if level != before {
                metrics.degradation_transitions.fetch_add(1, Ordering::Relaxed);
                metrics.trace.record(0, EventKind::Degrade { from: before, to: level });
                // stepping past level 2 shrinks the holder cap: retire
                // parked prefixes early so their pages free up
                retire_excess_holders(
                    &mut holders,
                    tables,
                    &kv,
                    degrader.holder_cap(MAX_PREFIX_HOLDERS),
                );
            }
        }

        // 2. emit ready batches to the pool
        let now = Instant::now();
        let mut any: Vec<AnyBatch> = vec![];
        if shutdown.load(Ordering::SeqCst) {
            any.extend(batcher.drain_all(now).into_iter().map(AnyBatch::Prefill));
            if let Some(d) = batcher.drain_decode(now) {
                any.push(AnyBatch::Decode(d));
            }
            // chunked fills keep stepping during the drain: each landed
            // chunk re-queues the next until the fill completes or its
            // waiting branches are all answered
            any.extend(batcher.drain_ingest().into_iter().map(AnyBatch::Ingest));
        } else {
            while let Some(b) = batcher.pop_ready_any(now) {
                any.push(b);
            }
        }
        for batch in any {
            match batch {
                AnyBatch::Prefill(batch) => {
                    metrics.batches.fetch_add(1, Ordering::Relaxed);
                    let batch_size = batch.requests.len() as u32;
                    for req in batch.requests {
                        let bucket = batch.key.bucket;
                        let Some(ch) = channels.remove(&req.id) else {
                            // channel lost (logic bug): keep the admission
                            // counters balanced and move on
                            metrics.record_error(format!(
                                "no response channel for request {}",
                                req.id
                            ));
                            metrics
                                .trace
                                .record(req.id, EventKind::Finish { outcome: Outcome::Error });
                            admission.release(bucket);
                            continue;
                        };
                        if req.deadline.is_some_and(|d| now >= d) {
                            // queued past its deadline: shed instead of
                            // burning a worker on an answer nobody wants
                            metrics.shed_deadline.fetch_add(1, Ordering::Relaxed);
                            metrics.trace.record(req.id, EventKind::Shed);
                            metrics.trace.record(
                                req.id,
                                EventKind::Finish { outcome: Outcome::DeadlineExceeded },
                            );
                            admission.release(bucket);
                            let _ =
                                ch.send(Err(anyhow::Error::new(ServeError::DeadlineExceeded)));
                            continue;
                        }
                        metrics.trace.record(req.id, EventKind::Batch { size: batch_size });
                        let backend = Arc::clone(&backend);
                        let metrics = Arc::clone(&metrics);
                        let admission = Arc::clone(&admission);
                        let kv = Arc::clone(&kv);
                        let faults = faults.clone();
                        let kind = batch.key.kind;
                        pool.submit(move || {
                            if let Some(f) = &faults {
                                f.maybe_stall();
                            }
                            // panic isolation: a panicking execution (real
                            // or injected downstream) becomes a typed
                            // per-request error; the pages are reclaimed
                            // and the worker serves the next item
                            let out = catch_unwind(AssertUnwindSafe(|| {
                                execute_one(
                                    backend.as_ref(),
                                    &kv,
                                    kind,
                                    bucket,
                                    &req,
                                    faults.as_deref(),
                                )
                            }))
                            .unwrap_or_else(|_| {
                                metrics.worker_panics.fetch_add(1, Ordering::Relaxed);
                                metrics.trace.record(
                                    req.id,
                                    EventKind::Panic { site: PanicSite::Prefill },
                                );
                                if let Some(r) = metrics.trace.recorder() {
                                    let replay = faults.as_deref().map(|f| f.spec_string());
                                    eprintln!(
                                        "{}",
                                        r.render_failure_dump(Some(req.id), replay.as_deref())
                                    );
                                }
                                let _ = kv.release(req.id);
                                let _ = kv.drop_seq(req.id);
                                Err(anyhow::Error::new(ServeError::WorkerPanic))
                            });
                            match &out {
                                Ok(resp) => {
                                    metrics.completed.fetch_add(1, Ordering::Relaxed);
                                    metrics
                                        .tokens_in
                                        .fetch_add(req.ids.len() as u64, Ordering::Relaxed);
                                    metrics.queue.record(Duration::from_micros(resp.queue_us));
                                    metrics.exec.record(Duration::from_micros(resp.exec_us));
                                    metrics
                                        .ttft
                                        .record(Duration::from_micros(resp.queue_us + resp.exec_us));
                                    metrics.budget_sum_micro.fetch_add(
                                        (resp.budget_fraction as f64 * 1e6) as u64,
                                        Ordering::Relaxed,
                                    );
                                    metrics.trace.record(
                                        req.id,
                                        EventKind::Exec { us: resp.exec_us.min(u32::MAX as u64) as u32 },
                                    );
                                    metrics.trace.record(
                                        req.id,
                                        EventKind::Finish { outcome: Outcome::Complete },
                                    );
                                }
                                Err(e) => {
                                    metrics.record_error(e.to_string());
                                    metrics.trace.record(
                                        req.id,
                                        EventKind::Finish { outcome: Outcome::Error },
                                    );
                                }
                            }
                            admission.release(bucket);
                            let _ = ch.send(out);
                        });
                    }
                }
                AnyBatch::Decode(batch) => {
                    metrics.decode_batches.fetch_add(1, Ordering::Relaxed);
                    for step in batch.steps {
                        let metrics = Arc::clone(&metrics);
                        let admission = Arc::clone(&admission);
                        let tasks = Arc::clone(&tasks);
                        let active = Arc::clone(&active_decodes);
                        let faults = faults.clone();
                        let tx = tx.clone();
                        pool.submit(move || {
                            if let Some(f) = &faults {
                                f.maybe_stall();
                            }
                            run_decode_step(
                                step.seq,
                                &tasks,
                                &metrics,
                                &admission,
                                &active,
                                &tx,
                                faults.as_deref(),
                            );
                        });
                    }
                }
                AnyBatch::Ingest(step) => {
                    let key = step.key;
                    let Some(holder) = holders.get_mut(&key) else {
                        continue; // holder failed/retired since queueing
                    };
                    // prune at the chunk boundary: branches cancelled or
                    // past their deadline while the fill was queued are
                    // answered now, and a fill nobody waits for anymore
                    // is abandoned before burning a worker on it
                    let waiting = std::mem::take(&mut holder.waiting);
                    let mut still = Vec::with_capacity(waiting.len());
                    for spec in waiting {
                        if spec.cancel.load(Ordering::SeqCst) {
                            metrics.cancelled.fetch_add(1, Ordering::Relaxed);
                            metrics.trace.record(spec.seq, EventKind::Cancel);
                            answer_unstarted(
                                spec,
                                Finish::Cancelled,
                                &metrics,
                                &admission,
                                &active_decodes,
                            );
                        } else if spec.deadline.is_some_and(|d| now >= d) {
                            metrics.shed_deadline.fetch_add(1, Ordering::Relaxed);
                            metrics.trace.record(spec.seq, EventKind::Shed);
                            fail_branch(
                                spec,
                                anyhow::Error::new(ServeError::DeadlineExceeded),
                                &metrics,
                                &admission,
                                &active_decodes,
                            );
                        } else {
                            still.push(spec);
                        }
                    }
                    holder.waiting = still;
                    let abandoned = holder.waiting.is_empty();
                    if abandoned {
                        // dropping the half-ingested session frees its
                        // pages; the unreleased share unwinds with it
                        if let Some(mut holder) = holders.remove(&key) {
                            release_holder_ingest(&admission, &mut holder);
                            tables.remove(key, &holder.prompt);
                        }
                        continue;
                    }
                    let Some(job) = holder.ingest.as_mut() else {
                        continue; // monolithic fill raced in (defensive)
                    };
                    let Some(mut session) = job.session.take() else {
                        continue; // a chunk is already in flight (defensive)
                    };
                    let end = (job.done + job.chunk).min(job.suffix.len());
                    let chunk: Vec<i32> = job.suffix[job.done..end].to_vec();
                    let n_chunk = chunk.len();
                    let holder_seq = holder.seq;
                    let metrics2 = Arc::clone(&metrics);
                    let faults2 = faults.clone();
                    let tx2 = tx.clone();
                    pool.submit(move || {
                        if let Some(f) = &faults2 {
                            f.maybe_stall();
                        }
                        // panic isolation: the ChunkDone message MUST
                        // reach the dispatcher either way, or the holder
                        // would sit mid-ingest forever (same contract as
                        // the monolithic fill closure)
                        let faults3 = faults2.clone();
                        let res = match catch_unwind(AssertUnwindSafe(move || {
                            if let Some(f) = &faults3 {
                                if f.should_fire(FaultPoint::IngestChunk) {
                                    panic!("injected ingest-chunk fault (chaos)");
                                }
                            }
                            session.extend_prompt(&chunk).map(|()| session)
                        })) {
                            Ok(Ok(session)) => {
                                metrics2.tokens_in.fetch_add(n_chunk as u64, Ordering::Relaxed);
                                metrics2.ingest_chunks.fetch_add(1, Ordering::Relaxed);
                                metrics2.trace.record(
                                    holder_seq,
                                    EventKind::IngestDone { tokens: n_chunk as u32 },
                                );
                                Ok(Box::new(session))
                            }
                            Ok(Err(e)) => Err(format!("prompt ingest failed: {e}")),
                            Err(_) => {
                                metrics2.worker_panics.fetch_add(1, Ordering::Relaxed);
                                metrics2.trace.record(
                                    holder_seq,
                                    EventKind::Panic { site: PanicSite::Ingest },
                                );
                                if let Some(r) = metrics2.trace.recorder() {
                                    let replay = faults2.as_deref().map(|f| f.spec_string());
                                    eprintln!(
                                        "{}",
                                        r.render_failure_dump(Some(holder_seq), replay.as_deref())
                                    );
                                }
                                Err("worker panicked during prompt ingest".to_string())
                            }
                        };
                        let _ = tx2.send(Msg::ChunkDone { key, tokens: n_chunk, session: res });
                    });
                }
            }
        }

        if shutdown.load(Ordering::SeqCst)
            && batcher.pending() == 0
            && active_decodes.load(Ordering::SeqCst) == 0
        {
            break;
        }
    }
    pool.wait_idle();
    // parked prefix holders drop here, freeing their cached pages
}

/// Release a group's (remaining) ingest admission share, if any.
fn release_ingest_share(admission: &Arc<Admission>, share: BranchAdmit) {
    if share.tokens > 0 || share.ns > 0.0 {
        admission.release_work(share.tokens, share.ns);
    }
}

/// Flush whatever is left of a holder's ingest share (progressive
/// chunk releases may have drained part of it) and zero it, so every
/// terminal path releases the share exactly once.
fn release_holder_ingest(admission: &Arc<Admission>, holder: &mut Holder) {
    release_ingest_share(admission, std::mem::replace(&mut holder.ingest_admit, BranchAdmit::ZERO));
}

/// A holder's ingest just completed (monolithic or final chunk): launch
/// every queued branch off the filled session and park it unpinned in
/// the cache. The holder is still pinned here, so its seq cannot have
/// been evicted mid-fork — a bounce is a logic error answered typed.
fn park_filled_holder(
    sess: Box<DecodeSession>,
    holder: &mut Holder,
    tasks: &DecodeTasks,
    batcher: &mut Batcher,
    metrics: &Arc<Metrics>,
    admission: &Arc<Admission>,
    active: &Arc<AtomicUsize>,
) {
    let specs = std::mem::take(&mut holder.waiting);
    let bounced = launch_branches(&sess, specs, tasks, batcher, metrics, admission, active);
    for spec in bounced {
        fail_branch(spec, anyhow!("prefix vanished during ingest"), metrics, admission, active);
    }
    // park unpinned: the cached prefix yields to live traffic under
    // page pressure (forks re-pin themselves)
    let _ = sess.unpin();
    holder.session = Some(*sess);
}

/// Fail one branch: record, release its admission share, answer its
/// channel, and retire it from the active count.
fn fail_branch(
    spec: BranchSpec,
    err: anyhow::Error,
    metrics: &Arc<Metrics>,
    admission: &Arc<Admission>,
    active: &Arc<AtomicUsize>,
) {
    metrics.record_error(err.to_string());
    metrics.trace.record(spec.seq, EventKind::Finish { outcome: Outcome::Error });
    admission.release_work(spec.admit.tokens, spec.admit.ns);
    let _ = spec.ch.send(Err(err));
    active.fetch_sub(1, Ordering::SeqCst);
}

/// Answer a branch that terminated before its first decode step (cancel
/// or deadline caught at launch) with an empty, typed partial result.
fn answer_unstarted(
    spec: BranchSpec,
    finish: Finish,
    metrics: &Arc<Metrics>,
    admission: &Arc<Admission>,
    active: &Arc<AtomicUsize>,
) {
    metrics.trace.record(spec.seq, EventKind::Finish { outcome: outcome_of(finish) });
    let resp = GenerateResponse {
        id: spec.seq,
        tokens: Vec::new(),
        n_prompt: spec.n_prompt,
        steps: 0,
        mean_budget_fraction: 0.0,
        dense_steps: 0,
        queue_us: spec.enqueued.elapsed().as_micros() as u64,
        exec_us: 0,
        ns_per_token: 0.0,
        finish,
    };
    admission.release_work(spec.admit.tokens, spec.admit.ns);
    let _ = spec.ch.send(Ok(resp));
    active.fetch_sub(1, Ordering::SeqCst);
}

/// The flight-recorder terminal outcome matching a [`Finish`] variant.
fn outcome_of(finish: Finish) -> Outcome {
    match finish {
        Finish::Complete => Outcome::Complete,
        Finish::Cancelled => Outcome::Cancelled,
        Finish::DeadlineExceeded => Outcome::DeadlineExceeded,
    }
}

/// Fork every branch off the (prefilled) holder session and push their
/// first decode steps into the lane as one sibling group. Branches
/// whose cancel flag or deadline already fired are answered here
/// without forking. Returns the specs whose fork found the holder's
/// sequence *gone* — a parked, unpinned holder can be LRU-evicted by a
/// concurrent worker between the dispatcher's freshness check and the
/// fork — so the caller can fall back to a fresh ingest instead of
/// failing the request.
fn launch_branches(
    holder: &DecodeSession,
    specs: Vec<BranchSpec>,
    tasks: &DecodeTasks,
    batcher: &mut Batcher,
    metrics: &Arc<Metrics>,
    admission: &Arc<Admission>,
    active: &Arc<AtomicUsize>,
) -> Vec<BranchSpec> {
    let mut steps = Vec::with_capacity(specs.len());
    let mut bounced = Vec::new();
    for spec in specs {
        if spec.cancel.load(Ordering::SeqCst) {
            // abandoned before its first step: reap without forking
            metrics.cancelled.fetch_add(1, Ordering::Relaxed);
            metrics.trace.record(spec.seq, EventKind::Cancel);
            answer_unstarted(spec, Finish::Cancelled, metrics, admission, active);
            continue;
        }
        if spec.deadline.is_some_and(|d| Instant::now() >= d) {
            // deadline passed while queued on the holder: typed shed
            metrics.shed_deadline.fetch_add(1, Ordering::Relaxed);
            metrics.trace.record(spec.seq, EventKind::Shed);
            fail_branch(
                spec,
                anyhow::Error::new(ServeError::DeadlineExceeded),
                metrics,
                admission,
                active,
            );
            continue;
        }
        match holder.fork(spec.seq) {
            Ok(mut session) => {
                session.set_policy(spec.policy);
                metrics.forks.fetch_add(1, Ordering::Relaxed);
                metrics.trace.record(spec.seq, EventKind::Fork);
                let task = DecodeTask {
                    session,
                    ch: spec.ch,
                    n_prompt: spec.n_prompt,
                    max_new: spec.max_new,
                    tokens: Vec::new(),
                    enqueued: spec.enqueued,
                    first_step_at: None,
                    last_commit: None,
                    admit_tokens: spec.admit.tokens,
                    admit_ns: spec.admit.ns,
                    cancel: spec.cancel,
                    deadline: spec.deadline,
                };
                lock_tasks(tasks).insert(spec.seq, task);
                steps.push(DecodeStep {
                    seq: spec.seq,
                    tokens: spec.policy.spec_gamma + 1,
                    enqueued: spec.enqueued,
                });
            }
            Err(DecodeError::Kv(KvError::UnknownSeq(_))) => bounced.push(spec),
            Err(e) => fail_branch(
                spec,
                anyhow!("prefix fork failed: {e}"),
                metrics,
                admission,
                active,
            ),
        }
    }
    batcher.push_decode_many(steps);
    bounced
}

/// Start a prefix holder for `req.prompt` under `key`: allocate (or
/// adopt, for a radix partial hit) its session now — cheap — then
/// ingest the prompt suffix. With `chunk_tokens == 0` the whole suffix
/// runs monolithically on a worker and reports back via
/// [`Msg::PrefixFilled`]; otherwise the fill becomes a resumable
/// sequence of chunk steps through the batcher's ingest lane
/// (scheduled against decode traffic, each landing as
/// [`Msg::ChunkDone`]). Branches queue on the holder meanwhile.
/// `base` is `None` for a full ingest (counted as a prefix miss) or
/// `Some((forked_session, covered))` when the leading `covered` tokens
/// were already forked off a matched holder and only the remaining
/// suffix needs projecting. A panic during the ingest is isolated: the
/// holder fails like any ingest error and its waiting branches get
/// typed errors instead of hanging.
#[allow(clippy::too_many_arguments)]
fn start_prefix_fill(
    key: u64,
    req: GenerateRequest,
    specs: Vec<BranchSpec>,
    base: Option<(DecodeSession, usize)>,
    ingest_admit: BranchAdmit,
    chunk_tokens: usize,
    holders: &mut HashMap<u64, Holder>,
    holder_clock: &mut u64,
    tables: PrefixTables<'_>,
    kv: &Arc<SharedKv>,
    model: &Arc<dyn DecodeBackend>,
    batcher: &mut Batcher,
    metrics: &Arc<Metrics>,
    admission: &Arc<Admission>,
    active: &Arc<AtomicUsize>,
    pool: &ThreadPool,
    tx: &mpsc::Sender<Msg>,
    faults: &Option<Arc<FaultPlan>>,
) {
    // `mut`: the monolithic closure below ingests through `&mut self`
    let (mut session, covered) = match base {
        Some((session, covered)) => (session, covered),
        None => {
            metrics.prefix_misses.fetch_add(1, Ordering::Relaxed);
            match DecodeSession::new(Arc::clone(kv), Arc::clone(model), req.policy, req.id) {
                Ok(s) => (s, 0),
                Err(e) => {
                    // KvAlloc fault injection surfaces here too: the
                    // whole group fails with the allocation error
                    let msg = format!("kv allocation failed: {e}");
                    release_ingest_share(admission, ingest_admit);
                    for spec in specs {
                        fail_branch(spec, anyhow!(msg.clone()), metrics, admission, active);
                    }
                    return;
                }
            }
        }
    };
    *holder_clock += 1;
    let holder_seq = session.seq_id();
    let suffix: Vec<i32> = req.prompt[covered..].to_vec();
    let n_suffix = suffix.len();
    if chunk_tokens > 0 && n_suffix > 0 {
        // chunked fill: the session parks inside the holder's IngestJob
        // and advances one ingest-lane step at a time (see the
        // AnyBatch::Ingest arm and Msg::ChunkDone)
        let deadline = specs.iter().filter_map(|s| s.deadline).min();
        holders.insert(
            key,
            Holder {
                seq: holder_seq,
                prompt: req.prompt.clone(),
                session: None,
                waiting: specs,
                ingest: Some(IngestJob {
                    session: Some(session),
                    suffix,
                    done: 0,
                    chunk: chunk_tokens,
                }),
                ingest_admit,
                last_used: *holder_clock,
            },
        );
        tables.insert(key, &req.prompt);
        batcher.push_ingest(IngestStep {
            key,
            tokens: n_suffix.min(chunk_tokens),
            deadline,
            enqueued: Instant::now(),
        });
        return;
    }
    holders.insert(
        key,
        Holder {
            seq: holder_seq,
            prompt: req.prompt.clone(),
            session: None,
            waiting: specs,
            ingest: None,
            ingest_admit,
            last_used: *holder_clock,
        },
    );
    tables.insert(key, &req.prompt);
    let metrics = Arc::clone(metrics);
    let faults = faults.clone();
    let tx = tx.clone();
    pool.submit(move || {
        if let Some(f) = &faults {
            f.maybe_stall();
        }
        // panic isolation: the PrefixFilled message MUST reach the
        // dispatcher either way, or the holder would sit mid-ingest
        // forever with branches queued on it. An unwinding panic drops
        // the moved-in session, freeing its pages.
        let res = match catch_unwind(AssertUnwindSafe(move || {
            session.extend_prompt(&suffix).map(|()| session)
        })) {
            Ok(Ok(session)) => {
                metrics.tokens_in.fetch_add(n_suffix as u64, Ordering::Relaxed);
                metrics
                    .trace
                    .record(holder_seq, EventKind::IngestDone { tokens: n_suffix as u32 });
                Ok(Box::new(session))
            }
            Ok(Err(e)) => Err(format!("prompt ingest failed: {e}")),
            Err(_) => {
                metrics.worker_panics.fetch_add(1, Ordering::Relaxed);
                metrics.trace.record(holder_seq, EventKind::Panic { site: PanicSite::Ingest });
                if let Some(r) = metrics.trace.recorder() {
                    let replay = faults.as_deref().map(|f| f.spec_string());
                    eprintln!("{}", r.render_failure_dump(Some(holder_seq), replay.as_deref()));
                }
                Err("worker panicked during prompt ingest".to_string())
            }
        };
        let _ = tx.send(Msg::PrefixFilled { key, session: res });
    });
}

/// Retire parked holders beyond `cap` (never one mid-ingest or with
/// branches still waiting). The cap is [`MAX_PREFIX_HOLDERS`] at full
/// service, shrunk by the degradation ladder under pressure
/// ([`Degrader::holder_cap`]). Victim selection is LCP-aware, not blind
/// LRU: the holder with the lowest covered-tokens × refcount weight
/// ([`SharedKv::seq_weight`]) goes first — an evicted or short,
/// unshared prefix before a long, heavily-forked one — with the LRU
/// clock as the tie-break. Dropping the session frees the prefix pages
/// not shared with live forks.
fn retire_excess_holders(
    holders: &mut HashMap<u64, Holder>,
    tables: PrefixTables<'_>,
    kv: &SharedKv,
    cap: usize,
) {
    while holders.len() > cap {
        let victim = holders
            .iter()
            .filter(|(_, h)| h.session.is_some() && h.waiting.is_empty())
            .min_by_key(|(_, h)| (kv.seq_weight(h.seq).ok().flatten().unwrap_or(0), h.last_used))
            .map(|(&k, _)| k);
        match victim {
            Some(k) => {
                if let Some(h) = holders.remove(&k) {
                    tables.remove(k, &h.prompt);
                }
            }
            None => break,
        }
    }
}

/// Advance one generation on a worker thread — one token for plain
/// decode, up to γ+1 tokens for a speculative draft/verify round — then
/// either complete it or hand it back to the dispatcher for its next
/// step. Either way the generation occupies exactly one decode-lane slot
/// per round, so fork fan-out siblings keep batching together whether or
/// not they speculate.
///
/// Failure handling, all while the task is exclusively owned (out of
/// the map): a raised cancel flag or passed deadline completes the
/// branch with its partial tokens ([`Finish::Cancelled`] /
/// [`Finish::DeadlineExceeded`]); a panic inside the step (real or
/// injected) is caught and becomes [`ServeError::WorkerPanic`] with the
/// same admission/active cleanup — dropping the task frees the branch's
/// KV pages either way.
fn run_decode_step(
    seq: u64,
    tasks: &DecodeTasks,
    metrics: &Arc<Metrics>,
    admission: &Arc<Admission>,
    active: &Arc<AtomicUsize>,
    tx: &mpsc::Sender<Msg>,
    faults: Option<&FaultPlan>,
) {
    let Some(mut task) = lock_tasks(tasks).remove(&seq) else {
        return; // task vanished (completed with an error elsewhere)
    };
    let finish = |task: DecodeTask, out: Result<GenerateResponse>| {
        let outcome = match &out {
            Ok(resp) => outcome_of(resp.finish),
            Err(_) => Outcome::Error,
        };
        metrics.trace.record(seq, EventKind::Finish { outcome });
        if let Err(e) = &out {
            metrics.record_error(e.to_string());
        } else {
            metrics.generates_completed.fetch_add(1, Ordering::Relaxed);
        }
        admission.release_work(task.admit_tokens, task.admit_ns);
        let _ = task.ch.send(out);
        active.fetch_sub(1, Ordering::SeqCst);
    };
    if task.cancel.load(Ordering::SeqCst) {
        // client cancelled (or abandoned the ticket): return the tokens
        // generated so far; dropping the task frees its pages
        metrics.cancelled.fetch_add(1, Ordering::Relaxed);
        metrics.trace.record(seq, EventKind::Cancel);
        let mut resp = generate_response(seq, &mut task);
        resp.finish = Finish::Cancelled;
        finish(task, Ok(resp));
        return;
    }
    if task.deadline.is_some_and(|d| Instant::now() >= d) {
        metrics.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
        metrics.trace.record(seq, EventKind::DeadlineExceeded);
        let mut resp = generate_response(seq, &mut task);
        resp.finish = Finish::DeadlineExceeded;
        finish(task, Ok(resp));
        return;
    }
    if task.first_step_at.is_none() {
        task.first_step_at = Some(Instant::now());
    }
    let gamma = task.session.policy().spec_gamma;
    // panic isolation: the session steps inside catch_unwind while the
    // task is owned by this worker, so a panic (injected DecodeStep
    // faults included) unwinds into a per-branch WorkerPanic error with
    // full cleanup instead of poisoning the serving stack
    let caught = catch_unwind(AssertUnwindSafe(|| {
        if let Some(f) = faults {
            if f.should_fire(FaultPoint::DecodeStep) {
                panic!("injected decode-step fault (chaos)");
            }
        }
        if gamma >= 1 {
            let remaining = task.max_new.saturating_sub(task.tokens.len()).max(1);
            task.session
                .spec_round(gamma.min(remaining), remaining, Some(vocab::END), |_| true)
                .map(|round| {
                    metrics.record_spec_round(
                        round.drafted as u64,
                        round.accepted as u64,
                        round.infos.len() as u64,
                    );
                    metrics.trace.record(
                        seq,
                        EventKind::SpecRound {
                            drafted: round.drafted as u32,
                            accepted: round.accepted as u32,
                        },
                    );
                    (round.infos, round.halt)
                })
        } else {
            task.session.step_once().map(|info| {
                let halt = info.token == vocab::END;
                (vec![info], halt)
            })
        }
    }));
    let stepped: Result<(Vec<StepInfo>, bool), DecodeError> = match caught {
        Ok(r) => r,
        Err(_) => {
            metrics.worker_panics.fetch_add(1, Ordering::Relaxed);
            metrics.trace.record(seq, EventKind::Panic { site: PanicSite::Decode });
            if let Some(r) = metrics.trace.recorder() {
                let replay = faults.map(|f| f.spec_string());
                eprintln!("{}", r.render_failure_dump(Some(seq), replay.as_deref()));
            }
            finish(task, Err(anyhow::Error::new(ServeError::WorkerPanic)));
            return;
        }
    };
    match stepped {
        Ok((infos, halt)) => {
            let committed_at = Instant::now();
            let was_empty = task.tokens.is_empty();
            for info in &infos {
                metrics.record_decode_step(
                    Duration::from_nanos(info.step_ns),
                    info.budget_fraction,
                    info.dense,
                );
                metrics.record_step_telemetry(info.n_ctx, &info.telemetry);
                task.tokens.push(info.token);
            }
            if !infos.is_empty() {
                if was_empty {
                    // generation TTFT: submit → first committed token,
                    // queueing and (chunked) ingest included — the
                    // latency chunked prefill exists to protect
                    metrics.gen_ttft.record(committed_at - task.enqueued);
                }
                if let Some(prev) = task.last_commit {
                    // inter-commit gap per generated token; speculative
                    // rounds committing k tokens amortize the gap over k
                    let per = (committed_at - prev) / infos.len() as u32;
                    for _ in 0..infos.len() {
                        metrics.tpot.record(per);
                    }
                }
                task.last_commit = Some(committed_at);
            }
            if let Some(last) = infos.last() {
                metrics.trace.record(
                    seq,
                    EventKind::DecodeStep {
                        tokens: infos.len() as u32,
                        n_ctx: last.n_ctx as u32,
                    },
                );
            }
            let done = task.tokens.len() >= task.max_new || halt;
            if done {
                let resp = generate_response(seq, &mut task);
                finish(task, Ok(resp));
            } else {
                lock_tasks(tasks).insert(seq, task);
                if tx.send(Msg::DecodeReady(seq, gamma + 1)).is_err() {
                    // dispatcher gone: complete what we have so the
                    // caller is not left hanging
                    if let Some(mut task) = lock_tasks(tasks).remove(&seq) {
                        let resp = generate_response(seq, &mut task);
                        finish(task, Ok(resp));
                    }
                }
            }
        }
        Err(e) => finish(task, Err(anyhow!("decode step failed: {e}"))),
    }
}

/// Assemble the final [`GenerateResponse`] from a task's accumulated
/// state (single construction point for the done, cancelled, deadline
/// and dispatcher-gone paths — callers override `finish` for partial
/// outcomes). `exec_us` is the *summed step execution time* from the
/// session's own clocks; scheduling gaps between steps show up in
/// end-to-end wall time, not here.
fn generate_response(seq: u64, task: &mut DecodeTask) -> GenerateResponse {
    let queue_us = task
        .first_step_at
        .map(|t| (t - task.enqueued).as_micros() as u64)
        .unwrap_or(0);
    let steps = task.tokens.len();
    GenerateResponse {
        id: seq,
        tokens: std::mem::take(&mut task.tokens),
        n_prompt: task.n_prompt,
        steps,
        mean_budget_fraction: task.session.mean_budget_fraction(),
        dense_steps: task.session.dense_steps(),
        queue_us,
        exec_us: task.session.decode_ns() / 1_000,
        ns_per_token: task.session.decode_ns() as f64 / steps.max(1) as f64,
        finish: Finish::Complete,
    }
}

fn execute_one(
    backend: &dyn PrefillBackend,
    kv: &SharedKv,
    kind: &'static str,
    bucket: usize,
    req: &PrefillRequest,
    faults: Option<&FaultPlan>,
) -> Result<PrefillResponse> {
    let queue_us = req.enqueued.elapsed().as_micros() as u64;
    // KV pages for the prefilled sequence. Pure-prefill requests read the
    // logits back and release immediately; generations hold their pages
    // through a `DecodeSession` for the whole token stream instead.
    kv.allocate(req.id, bucket)?;
    let mut ids = req.ids.clone();
    ids.resize(bucket, vocab::PAD);
    let t0 = Instant::now();
    // EngineExec injection point: an injected failure takes the exact
    // error path a real execution failure would, cleanup included
    let result = match faults {
        Some(f) if f.should_fire(FaultPoint::EngineExec) => {
            Err(anyhow!("injected engine-execution fault (chaos)"))
        }
        _ => backend.prefill(&req.checkpoint, kind, bucket, &ids, &req.method.scalars()),
    };
    let exec_us = t0.elapsed().as_micros() as u64;
    let _ = kv.release(req.id);
    let _ = kv.drop_seq(req.id);
    let out = result?;
    Ok(PrefillResponse {
        id: req.id,
        logits: out.logits,
        vocab: out.vocab,
        n_ctx: out.n_ctx,
        n_input: req.ids.len(),
        budget_fraction: out.budget_fraction,
        hidden: out.hidden,
        queue_us,
        exec_us,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::SyntheticEngine;

    #[test]
    fn prompt_hash_distinguishes_prompts_not_order_of_calls() {
        let a = prompt_hash(&[1, 2, 3]);
        assert_eq!(a, prompt_hash(&[1, 2, 3]), "hash must be deterministic");
        assert_ne!(a, prompt_hash(&[1, 2, 4]));
        assert_ne!(a, prompt_hash(&[3, 2, 1]));
        assert_ne!(prompt_hash(&[]), prompt_hash(&[0]));
    }

    fn tiny_coordinator() -> Coordinator {
        let backend = Arc::new(SyntheticEngine::new(&[64, 128]));
        Coordinator::with_backend(
            backend,
            CoordinatorConfig {
                workers: 2,
                kv_pages: 256,
                faults: None,
                ..CoordinatorConfig::default()
            },
        )
    }

    #[test]
    fn synthetic_backend_serves_prefill_and_generate() {
        let coord = tiny_coordinator();
        assert!(coord.engine().is_none(), "synthetic backend has no PJRT engine");
        let resp = coord
            .prefill_blocking(
                "tiny",
                Method::Stem { k_start: 4.0, mu: 0.7, beta: 0.2 },
                vec![1, 2, 3],
                false,
            )
            .expect("synthetic prefill");
        assert_eq!(resp.n_input, 3);
        let gen = coord
            .generate_blocking(vec![1, 2, 3, 4], 4, DecodePolicy::default())
            .expect("synthetic generate");
        assert_eq!(gen.finish, Finish::Complete);
        assert!(!gen.tokens.is_empty());
    }

    #[test]
    fn engine_decode_backend_serves_and_labels() {
        let backend = Arc::new(SyntheticEngine::new(&[64, 128]));
        let coord = Coordinator::with_backend(
            backend,
            CoordinatorConfig {
                workers: 2,
                kv_pages: 256,
                faults: None,
                decode_backend: DecodeBackendKind::Engine,
                ..CoordinatorConfig::default()
            },
        );
        assert_eq!(coord.decode_model().name(), "engine");
        let gen = coord
            .generate_blocking(vec![1, 2, 3, 4], 4, DecodePolicy::default())
            .expect("engine-backed generate");
        assert_eq!(gen.finish, Finish::Complete);
        assert!(!gen.tokens.is_empty());
        // the backend label reaches every observability surface
        assert!(coord.report().contains("decode backend: engine"), "{}", coord.report());
        let snap = coord.snapshot();
        assert_eq!(snap.decode_backend, Some("engine"));
        let j = crate::util::json::Json::parse(&snap.to_json().to_string()).unwrap();
        assert_eq!(j.path("decode.backend").unwrap().as_str(), Some("engine"));
        assert!(snap.to_prometheus().contains("stem_decode_backend_info{backend=\"engine\"} 1"));
    }

    #[test]
    fn default_backend_is_tiny_and_labeled() {
        let coord = tiny_coordinator();
        assert_eq!(coord.decode_model().name(), "tiny");
        assert!(coord.report().contains("decode backend: tiny"));
        assert_eq!(coord.snapshot().decode_backend, Some("tiny"));
    }

    #[test]
    fn expired_deadline_sheds_with_typed_error() {
        let coord = tiny_coordinator();
        let past = Instant::now() - Duration::from_millis(5);
        let mut tickets = coord
            .submit_generate_tickets(vec![1, 2, 3], 8, DecodePolicy::default(), 2, Some(past))
            .expect("admission accepts; the shed happens at dispatch");
        for t in &mut tickets {
            let err = t.recv().expect_err("expired deadline must not produce tokens");
            assert_eq!(
                err.downcast_ref::<ServeError>(),
                Some(&ServeError::DeadlineExceeded),
                "typed shed, got: {err}"
            );
        }
        assert!(coord.metrics.shed_deadline.load(Ordering::Relaxed) >= 2);
    }

    #[test]
    fn dropped_ticket_cancels_and_releases_everything() {
        let coord = tiny_coordinator();
        let admission = Arc::clone(coord.admission());
        let kv = Arc::clone(coord.shared_kv());
        // long generations the client abandons immediately
        let tickets = coord
            .submit_generate_tickets(vec![1, 2, 3, 4, 5], 5_000, DecodePolicy::default(), 2, None)
            .expect("submit");
        drop(tickets); // abandonment: raises every branch's cancel flag
        // the reap happens at each branch's next decode step
        let t0 = Instant::now();
        while admission.outstanding() != (0, 0) {
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "abandoned branches must release admission, still at {:?}",
                admission.outstanding()
            );
            thread::sleep(Duration::from_millis(2));
        }
        assert!(coord.metrics.cancelled.load(Ordering::Relaxed) >= 2, "both branches reaped");
        drop(coord);
        let (used, _, _) = kv.occupancy();
        assert_eq!(used, 0, "no leaked KV pages after drain");
    }

    #[test]
    fn cancel_handle_stops_decode_with_partial_result() {
        let coord = tiny_coordinator();
        // long prompt: its worker-side ingest gives the immediate cancel
        // below a deterministic head start over the branch launch
        let prompt: Vec<i32> = (0..1024).map(|i| 20 + (i % 64) as i32).collect();
        let mut tickets = coord
            .submit_generate_tickets(prompt, 64, DecodePolicy::default(), 1, None)
            .expect("submit");
        let mut ticket = tickets.pop().expect("one branch");
        let handle = ticket.cancel_handle();
        handle.cancel();
        assert!(handle.is_cancelled());
        let resp =
            ticket.recv_timeout(Duration::from_secs(10)).expect("cancelled branch still answers");
        assert_eq!(resp.finish, Finish::Cancelled);
        assert!(resp.tokens.len() < 64, "stopped before the length cap");
    }

    #[test]
    fn flight_recorder_captures_full_branch_span() {
        let coord = tiny_coordinator();
        let mut tickets = coord
            .submit_generate_tickets(vec![1, 2, 3, 4], 4, DecodePolicy::default(), 1, None)
            .expect("submit");
        let mut ticket = tickets.pop().expect("one branch");
        let seq = ticket.seq();
        let resp = ticket.recv().expect("generate");
        assert_eq!(resp.finish, Finish::Complete);
        let rec = coord.flight_recorder().expect("tracing is on by default");
        let ev = rec.span_events(seq);
        assert!(
            matches!(ev.first().map(|e| e.kind), Some(EventKind::Submit { tokens: 4 })),
            "span must open with submit: {ev:?}"
        );
        assert!(
            matches!(
                ev.last().map(|e| e.kind),
                Some(EventKind::Finish { outcome: Outcome::Complete })
            ),
            "span must close with its terminal outcome: {ev:?}"
        );
        for probe in ["prefix-route", "fork", "decode-step"] {
            assert!(
                ev.iter().any(|e| e.kind.to_string().starts_with(probe)),
                "span missing {probe}: {ev:?}"
            );
        }
    }

    #[test]
    fn trace_events_zero_disables_tracing() {
        let backend = Arc::new(SyntheticEngine::new(&[64, 128]));
        let coord = Coordinator::with_backend(
            backend,
            CoordinatorConfig {
                workers: 2,
                kv_pages: 256,
                faults: None,
                trace_events: 0,
                ..CoordinatorConfig::default()
            },
        );
        let gen = coord
            .generate_blocking(vec![1, 2, 3], 4, DecodePolicy::default())
            .expect("generate");
        assert_eq!(gen.finish, Finish::Complete);
        assert!(coord.flight_recorder().is_none());
        assert!(coord.snapshot().trace.is_none(), "snapshot reports tracing off");
    }

    #[test]
    fn snapshot_carries_kv_gauges_trace_stats_and_counters() {
        let coord = tiny_coordinator();
        coord
            .prefill_blocking(
                "tiny",
                Method::Stem { k_start: 4.0, mu: 0.7, beta: 0.2 },
                vec![1, 2, 3],
                false,
            )
            .expect("prefill");
        let gen = coord
            .generate_blocking(vec![1, 2, 3, 4], 4, DecodePolicy::default())
            .expect("generate");
        assert_eq!(gen.finish, Finish::Complete);
        let snap = coord.snapshot();
        assert_eq!(snap.submitted, 1);
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.generates_completed, 1);
        assert!(snap.decode_steps >= 4);
        let kv = snap.kv.expect("coordinator snapshots carry KV gauges");
        assert_eq!(kv.pages_total, 256);
        let trace = snap.trace.expect("tracing armed by default");
        assert!(trace.recorded > 0, "serving traffic must have recorded events");
        // kernel-level sparsity telemetry reached the aggregate bands
        let steps: u64 = snap.sparsity.iter().map(|b| b.steps).sum();
        assert_eq!(steps, snap.decode_steps, "every decode step observed once");
        let json = snap.to_json().to_string();
        assert!(json.contains("\"schema_version\""), "{json}");
    }

    fn chunked_coordinator(chunk: usize) -> Coordinator {
        let backend = Arc::new(SyntheticEngine::new(&[64, 128]));
        Coordinator::with_backend(
            backend,
            CoordinatorConfig {
                workers: 2,
                kv_pages: 256,
                faults: None,
                chunk_tokens: chunk,
                ..CoordinatorConfig::default()
            },
        )
    }

    #[test]
    fn chunked_ingest_runs_through_the_ingest_lane() {
        let coord = chunked_coordinator(16);
        let prompt: Vec<i32> = (0..100).map(|i| 20 + (i % 64) as i32).collect();
        let gen = coord
            .generate_blocking(prompt, 6, DecodePolicy::default())
            .expect("chunked generate");
        assert_eq!(gen.finish, Finish::Complete);
        assert!(!gen.tokens.is_empty());
        // 100 prompt tokens over 16-token chunks: ceil(100/16) = 7 steps
        assert_eq!(coord.metrics.ingest_chunks.load(Ordering::Relaxed), 7);
        assert!(coord.metrics.gen_ttft.count() >= 1, "TTFT observed for the branch");
        assert!(coord.metrics.tpot.count() >= 1, "TPOT gaps observed past the first token");
    }

    #[test]
    fn chunked_and_monolithic_streams_are_identical() {
        let prompt: Vec<i32> = (0..90).map(|i| 20 + (i * 7 % 64) as i32).collect();
        let chunked = chunked_coordinator(16)
            .generate_blocking(prompt.clone(), 12, DecodePolicy::default())
            .expect("chunked generate");
        let monolithic = chunked_coordinator(0)
            .generate_blocking(prompt, 12, DecodePolicy::default())
            .expect("monolithic generate");
        // K/V depend only on (token, position), decode is deterministic:
        // chunk granularity must be invisible in the token stream
        assert_eq!(chunked.tokens, monolithic.tokens, "byte-identical streams");
        assert_eq!(chunked.finish, monolithic.finish);
    }

    #[test]
    fn cancelled_mid_chunk_unwinds_admission_and_pages() {
        let coord = chunked_coordinator(32);
        let admission = Arc::clone(coord.admission());
        let kv = Arc::clone(coord.shared_kv());
        // long chunked ingest the client abandons immediately: the
        // boundary prune must answer the branches and drop the
        // half-ingested holder, unwinding admission and pages
        let prompt: Vec<i32> = (0..400).map(|i| 20 + (i % 64) as i32).collect();
        let tickets = coord
            .submit_generate_tickets(prompt, 64, DecodePolicy::default(), 2, None)
            .expect("submit");
        drop(tickets);
        let t0 = Instant::now();
        while admission.outstanding() != (0, 0) {
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "abandoned chunked ingest must release admission, still at {:?}",
                admission.outstanding()
            );
            thread::sleep(Duration::from_millis(2));
        }
        drop(coord);
        let (used, _, _) = kv.occupancy();
        assert_eq!(used, 0, "no leaked KV pages after an abandoned chunked ingest");
    }
}
