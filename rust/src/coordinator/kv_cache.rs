//! Paged KV-cache manager (PagedAttention-style page pool).
//!
//! Prefill produces per-layer K/V blocks; a decode phase (or a later
//! retrieval of prefill state) needs them resident. The pool hands out
//! fixed-size pages (one attention block per page per layer-group),
//! tracks per-sequence page tables, refcounts shared prefixes, and evicts
//! completed sequences LRU when under pressure.

use std::collections::HashMap;

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum KvError {
    #[error("out of KV pages: need {need}, free {free}")]
    OutOfPages { need: usize, free: usize },
    #[error("unknown sequence {0}")]
    UnknownSeq(u64),
}

#[derive(Debug, Clone)]
pub struct KvConfig {
    pub total_pages: usize,
    pub page_tokens: usize, // tokens per page (= attention block size)
}

#[derive(Debug)]
struct SeqEntry {
    pages: Vec<u32>,
    pinned: bool,
    last_touch: u64,
}

/// Page pool + per-sequence page tables.
pub struct KvCache {
    cfg: KvConfig,
    free: Vec<u32>,
    refcount: Vec<u16>,
    seqs: HashMap<u64, SeqEntry>,
    clock: u64,
    pub alloc_count: u64,
    pub evict_count: u64,
}

impl KvCache {
    pub fn new(cfg: KvConfig) -> Self {
        let free = (0..cfg.total_pages as u32).rev().collect();
        let refcount = vec![0u16; cfg.total_pages];
        KvCache { cfg, free, refcount, seqs: HashMap::new(), clock: 0, alloc_count: 0, evict_count: 0 }
    }

    pub fn pages_needed(&self, n_tokens: usize) -> usize {
        n_tokens.div_ceil(self.cfg.page_tokens)
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn used_pages(&self) -> usize {
        self.cfg.total_pages - self.free.len()
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Allocate a page table for a sequence; evicts unpinned LRU
    /// sequences if required.
    pub fn allocate(&mut self, seq_id: u64, n_tokens: usize) -> Result<&[u32], KvError> {
        let need = self.pages_needed(n_tokens);
        while self.free.len() < need {
            if !self.evict_lru() {
                return Err(KvError::OutOfPages { need, free: self.free.len() });
            }
        }
        let mut pages = Vec::with_capacity(need);
        for _ in 0..need {
            let p = self.free.pop().unwrap();
            self.refcount[p as usize] = 1;
            pages.push(p);
        }
        self.alloc_count += 1;
        let t = self.tick();
        let entry = SeqEntry { pages, pinned: true, last_touch: t };
        self.seqs.insert(seq_id, entry);
        Ok(&self.seqs[&seq_id].pages)
    }

    /// Fork `dst` from `src` sharing its pages (prefix sharing): pages are
    /// refcounted, copy-on-write is the caller's concern.
    pub fn fork(&mut self, src: u64, dst: u64) -> Result<(), KvError> {
        let pages = self.seqs.get(&src).ok_or(KvError::UnknownSeq(src))?.pages.clone();
        for &p in &pages {
            self.refcount[p as usize] += 1;
        }
        let t = self.tick();
        self.seqs.insert(dst, SeqEntry { pages, pinned: true, last_touch: t });
        Ok(())
    }

    /// Mark a sequence's prefill complete; it becomes evictable.
    pub fn release(&mut self, seq_id: u64) -> Result<(), KvError> {
        let t = self.tick();
        let e = self.seqs.get_mut(&seq_id).ok_or(KvError::UnknownSeq(seq_id))?;
        e.pinned = false;
        e.last_touch = t;
        Ok(())
    }

    /// Drop a sequence immediately, returning pages whose refcount hits 0.
    pub fn drop_seq(&mut self, seq_id: u64) -> Result<usize, KvError> {
        let e = self.seqs.remove(&seq_id).ok_or(KvError::UnknownSeq(seq_id))?;
        let mut freed = 0;
        for p in e.pages {
            let rc = &mut self.refcount[p as usize];
            debug_assert!(*rc > 0, "double free of page {p}");
            *rc -= 1;
            if *rc == 0 {
                self.free.push(p);
                freed += 1;
            }
        }
        Ok(freed)
    }

    fn evict_lru(&mut self) -> bool {
        let victim = self
            .seqs
            .iter()
            .filter(|(_, e)| !e.pinned)
            .min_by_key(|(_, e)| e.last_touch)
            .map(|(&id, _)| id);
        match victim {
            Some(id) => {
                let _ = self.drop_seq(id);
                self.evict_count += 1;
                true
            }
            None => false,
        }
    }

    pub fn page_table(&self, seq_id: u64) -> Option<&[u32]> {
        self.seqs.get(&seq_id).map(|e| e.pages.as_slice())
    }

    /// Invariant check used by property tests: every page is either free
    /// or referenced, with consistent refcounts.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut counted = vec![0u16; self.cfg.total_pages];
        for e in self.seqs.values() {
            for &p in &e.pages {
                counted[p as usize] += 1;
            }
        }
        for (p, (&rc, &ct)) in self.refcount.iter().zip(&counted).enumerate() {
            if rc != ct {
                return Err(format!("page {p}: refcount {rc} != table count {ct}"));
            }
        }
        let free_set: std::collections::HashSet<u32> = self.free.iter().copied().collect();
        if free_set.len() != self.free.len() {
            return Err("duplicate page in free list".into());
        }
        for &p in &self.free {
            if self.refcount[p as usize] != 0 {
                return Err(format!("free page {p} has refcount"));
            }
        }
        if self.free.len() + counted.iter().filter(|&&c| c > 0).count() != self.cfg.total_pages {
            // pages can be multiply referenced; free + referenced-distinct must cover all
            return Err("page accounting mismatch".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn cache(pages: usize) -> KvCache {
        KvCache::new(KvConfig { total_pages: pages, page_tokens: 64 })
    }

    #[test]
    fn alloc_release_drop() {
        let mut kv = cache(16);
        kv.allocate(1, 300).unwrap(); // 5 pages
        assert_eq!(kv.used_pages(), 5);
        kv.release(1).unwrap();
        assert_eq!(kv.drop_seq(1).unwrap(), 5);
        assert_eq!(kv.free_pages(), 16);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn eviction_frees_released_seqs() {
        let mut kv = cache(8);
        kv.allocate(1, 256).unwrap(); // 4 pages
        kv.release(1).unwrap();
        kv.allocate(2, 256).unwrap(); // 4 pages
        // pool full; seq 1 is evictable
        kv.allocate(3, 256).unwrap();
        assert_eq!(kv.evict_count, 1);
        assert!(kv.page_table(1).is_none());
        kv.check_invariants().unwrap();
    }

    #[test]
    fn pinned_seqs_never_evicted() {
        let mut kv = cache(8);
        kv.allocate(1, 512).unwrap(); // 8 pages, pinned
        let err = kv.allocate(2, 64).unwrap_err();
        assert!(matches!(err, KvError::OutOfPages { .. }));
        assert!(kv.page_table(1).is_some());
    }

    #[test]
    fn fork_shares_pages() {
        let mut kv = cache(8);
        kv.allocate(1, 128).unwrap(); // 2 pages
        kv.fork(1, 2).unwrap();
        assert_eq!(kv.used_pages(), 2);
        assert_eq!(kv.drop_seq(1).unwrap(), 0); // still referenced by 2
        assert_eq!(kv.drop_seq(2).unwrap(), 2);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn prop_random_workload_keeps_invariants() {
        forall(
            99,
            60,
            |r: &mut Rng| {
                let ops: Vec<(usize, usize)> =
                    (0..40).map(|_| (r.below(4) as usize, r.below(6) as usize + 1)).collect();
                ops
            },
            |ops| {
                let mut kv = cache(12);
                let mut next_id = 0u64;
                let mut live: Vec<u64> = vec![];
                for &(op, size) in ops {
                    match op {
                        0 => {
                            next_id += 1;
                            if kv.allocate(next_id, size * 64).is_ok() {
                                live.push(next_id);
                            }
                        }
                        1 => {
                            if let Some(&id) = live.first() {
                                let _ = kv.release(id);
                            }
                        }
                        2 => {
                            if !live.is_empty() {
                                let id = live.remove(0);
                                let _ = kv.drop_seq(id);
                            }
                        }
                        _ => {
                            if let Some(&src) = live.last() {
                                next_id += 1;
                                if kv.fork(src, next_id).is_ok() {
                                    live.push(next_id);
                                }
                            }
                        }
                    }
                    kv.check_invariants()?;
                }
                Ok(())
            },
        );
    }
}
