//! Paged KV-cache manager (PagedAttention-style page pool).
//!
//! Prefill produces per-layer K/V blocks; the decode phase (see
//! `decode::session`) keeps them resident and appends one token per step
//! through [`KvCache::append_tokens`]. The pool hands out fixed-size
//! pages (one attention block per page per layer-group), tracks
//! per-sequence page tables and token counts, refcounts shared prefixes
//! (fork), copy-on-write remaps a shared tail page before a decode
//! append writes into it, and evicts completed sequences LRU when under
//! pressure. The pool manages page *identity* only; the decode store
//! owns the slab payloads keyed by these page ids.

use std::collections::HashMap;

/// Errors of the paged KV pool and the shared store built on it.
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum KvError {
    /// The pool cannot free enough pages (nothing evictable is left).
    #[error("out of KV pages: need {need}, free {free}")]
    OutOfPages {
        /// Pages the operation required.
        need: usize,
        /// Pages actually free.
        free: usize,
    },
    /// The sequence id has no page table (never allocated, or evicted).
    #[error("unknown sequence {0}")]
    UnknownSeq(u64),
    /// Allocate/fork targeted a sequence id that already has a page
    /// table; silently replacing it would leak the old refcounts.
    #[error("sequence {0} already has a page table")]
    SeqExists(u64),
    /// A lock guarding the shared KV (identity pool or slab store) was
    /// poisoned by a panicking sibling session. Surfacing this instead of
    /// re-panicking keeps one broken session from taking down every fork
    /// sharing the store.
    #[error("shared KV lock poisoned by a panicked sibling session")]
    Poisoned,
    /// A fork would push a page's u16 refcount past its maximum; wrapping
    /// silently would corrupt the free-list/refcount invariants under
    /// mass fan-out.
    #[error("refcount overflow: page {page} is already at the u16 sharing limit")]
    RefcountOverflow {
        /// The saturated page id.
        page: u32,
    },
    /// A prefix fork asked for a split point that is not page-aligned
    /// (or exceeds the source). Forked page tables share whole pages, so
    /// a mid-page split would leak the source's tokens past the split
    /// into the fork.
    #[error("cannot fork a {n_tokens}-token prefix: split points must be multiples of {page_tokens} tokens within the source")]
    MisalignedFork {
        /// The requested split point, in tokens.
        n_tokens: usize,
        /// The pool's page size, in tokens.
        page_tokens: usize,
    },
    /// A tail truncation asked to keep more tokens than the sequence has
    /// cached — rollback can only move backwards.
    #[error("cannot truncate sequence to {n_tokens} tokens: only {have} cached")]
    TruncateBeyondEnd {
        /// The requested post-truncation token count.
        n_tokens: usize,
        /// Tokens actually cached.
        have: usize,
    },
    /// A fault deterministically injected by the active
    /// [`crate::util::fault::FaultPlan`] (chaos testing). The operation
    /// fails exactly as a real allocation failure would, exercising the
    /// caller's cleanup path.
    #[error("injected KV fault (chaos testing)")]
    Injected,
}

/// Pool geometry: how many pages exist and how many tokens each holds.
#[derive(Debug, Clone)]
pub struct KvConfig {
    /// Total pages in the pool.
    pub total_pages: usize,
    /// Tokens per page (= the attention block size).
    pub page_tokens: usize,
}

/// Outcome of [`KvCache::append_tokens`], telling the owner of the page
/// payloads what bookkeeping the append performed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Append {
    /// `(old_page, new_page)` if the shared tail page was copy-on-write
    /// remapped; the caller must copy the slab payload old -> new.
    pub cow: Option<(u32, u32)>,
    /// Pages newly appended to the table for growth (possibly empty).
    pub grown: Vec<u32>,
}

#[derive(Debug)]
struct SeqEntry {
    pages: Vec<u32>,
    n_tokens: usize,
    pinned: bool,
    last_touch: u64,
}

/// Page pool + per-sequence page tables.
pub struct KvCache {
    cfg: KvConfig,
    free: Vec<u32>,
    refcount: Vec<u16>,
    seqs: HashMap<u64, SeqEntry>,
    clock: u64,
    /// Pages whose refcount hit 0 since the last [`KvCache::take_freed`]
    /// drain — the slab-store owner uses this to drop payloads exactly
    /// when the identity is recycled (evictions free pages deep inside
    /// `allocate`/`append_tokens`, where the caller never sees the ids).
    freed_log: Vec<u32>,
    /// Lifetime count of successful `allocate` calls.
    pub alloc_count: u64,
    /// Lifetime count of LRU evictions.
    pub evict_count: u64,
}

impl KvCache {
    /// Build an empty pool with every page free.
    pub fn new(cfg: KvConfig) -> Self {
        let free = (0..cfg.total_pages as u32).rev().collect();
        let refcount = vec![0u16; cfg.total_pages];
        KvCache {
            cfg,
            free,
            refcount,
            seqs: HashMap::new(),
            clock: 0,
            freed_log: Vec::new(),
            alloc_count: 0,
            evict_count: 0,
        }
    }

    /// Pages required to hold `n_tokens` (ceiling division).
    pub fn pages_needed(&self, n_tokens: usize) -> usize {
        n_tokens.div_ceil(self.cfg.page_tokens)
    }

    /// Pages currently on the free list.
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// Pages currently referenced by at least one page table.
    pub fn used_pages(&self) -> usize {
        self.cfg.total_pages - self.free.len()
    }

    /// Total pages in the pool.
    pub fn total_pages(&self) -> usize {
        self.cfg.total_pages
    }

    /// Tokens per page.
    pub fn page_tokens(&self) -> usize {
        self.cfg.page_tokens
    }

    /// Fraction of the pool currently referenced (serving-report gauge).
    pub fn occupancy(&self) -> f64 {
        self.used_pages() as f64 / self.cfg.total_pages.max(1) as f64
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Allocate a page table for a sequence; evicts unpinned LRU
    /// sequences if required. A `seq_id` that already has a table is a
    /// hard [`KvError::SeqExists`] — silently replacing it would leak the
    /// old pages' refcounts.
    pub fn allocate(&mut self, seq_id: u64, n_tokens: usize) -> Result<&[u32], KvError> {
        if self.seqs.contains_key(&seq_id) {
            return Err(KvError::SeqExists(seq_id));
        }
        let need = self.pages_needed(n_tokens);
        while self.free.len() < need {
            if !self.evict_lru() {
                return Err(KvError::OutOfPages { need, free: self.free.len() });
            }
        }
        let mut pages = Vec::with_capacity(need);
        for _ in 0..need {
            // the eviction loop above guarantees enough free pages, but an
            // empty pop must stay a clean error, never a panic: roll back
            // the partial reservation and report out-of-pages
            let Some(p) = self.free.pop() else {
                let free_now = self.free.len() + pages.len();
                for p in pages {
                    self.refcount[p as usize] = 0;
                    self.free.push(p);
                }
                return Err(KvError::OutOfPages { need, free: free_now });
            };
            self.refcount[p as usize] = 1;
            pages.push(p);
        }
        self.alloc_count += 1;
        let t = self.tick();
        let entry = SeqEntry { pages, n_tokens, pinned: true, last_touch: t };
        self.seqs.insert(seq_id, entry);
        Ok(&self.seqs[&seq_id].pages)
    }

    /// Fork `dst` from `src` sharing its pages (prefix sharing): pages
    /// are refcounted; a decode append to either sequence copy-on-write
    /// remaps the shared tail ([`KvCache::append_tokens`]). The fork
    /// inherits the source's pin state — a fork of a released sequence
    /// is itself evictable, so nothing leaks if the caller never
    /// releases it.
    pub fn fork(&mut self, src: u64, dst: u64) -> Result<(), KvError> {
        if self.seqs.contains_key(&dst) {
            return Err(KvError::SeqExists(dst));
        }
        let n_tokens = self.seqs.get(&src).ok_or(KvError::UnknownSeq(src))?.n_tokens;
        self.fork_prefix(src, dst, n_tokens)
    }

    /// Fork `dst` from the leading `n_tokens` of `src` only (token-
    /// granular prefix sharing): the fork shares exactly the pages that
    /// hold those tokens and starts with `n_tokens` cached. The split
    /// must land on a page boundary — or cover the whole source, which
    /// is plain [`KvCache::fork`] — because a shared tail page would
    /// expose the source's tokens past the split to the fork
    /// ([`KvError::MisalignedFork`] otherwise). Like `fork`, a failed
    /// call is side-effect free.
    pub fn fork_prefix(&mut self, src: u64, dst: u64, n_tokens: usize) -> Result<(), KvError> {
        if self.seqs.contains_key(&dst) {
            return Err(KvError::SeqExists(dst));
        }
        let e = self.seqs.get(&src).ok_or(KvError::UnknownSeq(src))?;
        if n_tokens > e.n_tokens
            || (n_tokens % self.cfg.page_tokens != 0 && n_tokens != e.n_tokens)
        {
            return Err(KvError::MisalignedFork { n_tokens, page_tokens: self.cfg.page_tokens });
        }
        let pages = e.pages[..self.pages_needed(n_tokens)].to_vec();
        let pinned = e.pinned;
        // check-then-increment: refusing *before* touching any refcount
        // keeps a failed fork side-effect free (no partial increments)
        if let Some(&p) = pages.iter().find(|&&p| self.refcount[p as usize] == u16::MAX) {
            return Err(KvError::RefcountOverflow { page: p });
        }
        for &p in &pages {
            self.refcount[p as usize] += 1;
        }
        let t = self.tick();
        self.seqs.insert(dst, SeqEntry { pages, n_tokens, pinned, last_touch: t });
        Ok(())
    }

    /// Cached token count of a sequence.
    pub fn seq_tokens(&self, seq_id: u64) -> Option<usize> {
        self.seqs.get(&seq_id).map(|e| e.n_tokens)
    }

    /// Reuse weight of a cached sequence: the sum of its pages'
    /// refcounts times the page size — covered-token length scaled by
    /// how many sequences share each page. The coordinator retires the
    /// *lightest* prefix holders first (LCP-aware eviction): a long,
    /// heavily-forked prefix outweighs a short or unshared one.
    pub fn seq_share_weight(&self, seq_id: u64) -> Option<u64> {
        let e = self.seqs.get(&seq_id)?;
        let refs: u64 = e.pages.iter().map(|&p| self.refcount[p as usize] as u64).sum();
        Some(refs * self.cfg.page_tokens as u64)
    }

    /// Extend a sequence by `extra` tokens (the decode append path):
    /// copy-on-write remaps the tail page if it is shared and about to be
    /// written, then appends pages as the new tokens cross page
    /// boundaries, evicting unpinned LRU sequences (never this one) under
    /// pressure. Pages needed are reserved up front, so a failed append
    /// leaves the table untouched.
    pub fn append_tokens(&mut self, seq_id: u64, extra: usize) -> Result<Append, KvError> {
        let pt = self.cfg.page_tokens;
        let (cur, have) = {
            let e = self.seqs.get(&seq_id).ok_or(KvError::UnknownSeq(seq_id))?;
            (e.n_tokens, e.pages.len())
        };
        if extra == 0 {
            let t = self.tick();
            if let Some(e) = self.seqs.get_mut(&seq_id) {
                e.last_touch = t;
            }
            return Ok(Append { cow: None, grown: vec![] });
        }
        let tail_shared = |kv: &Self| -> bool {
            if cur % pt == 0 {
                return false; // next write opens a fresh page
            }
            let tail = kv.seqs[&seq_id].pages[cur / pt];
            kv.refcount[tail as usize] > 1
        };
        // reserve every page this append can need before mutating
        let grow = self.pages_needed(cur + extra).saturating_sub(have);
        let need = grow + tail_shared(self) as usize;
        while self.free.len() < need {
            if !self.evict_lru_excluding(seq_id) {
                return Err(KvError::OutOfPages { need, free: self.free.len() });
            }
        }
        // eviction may have dropped the sibling sharing our tail: re-check
        let mut cow = None;
        if tail_shared(self) {
            let Some(new) = self.free.pop() else {
                // unreachable given the reservation loop, but keep the
                // clean error path: nothing has been mutated yet
                return Err(KvError::OutOfPages { need, free: 0 });
            };
            self.refcount[new as usize] = 1;
            let Some(e) = self.seqs.get_mut(&seq_id) else {
                // the entry cannot vanish under our &mut borrow, but keep
                // the no-panic guarantee: hand the page back and report
                self.refcount[new as usize] = 0;
                self.free.push(new);
                return Err(KvError::UnknownSeq(seq_id));
            };
            let old = std::mem::replace(&mut e.pages[cur / pt], new);
            self.refcount[old as usize] -= 1;
            cow = Some((old, new));
        }
        let mut grown = Vec::with_capacity(grow);
        for _ in 0..grow {
            let Some(p) = self.free.pop() else {
                // roll back the partial growth + the CoW remap so a failed
                // append leaves the table untouched, as documented
                let free_now = self.free.len() + grown.len();
                for p in grown {
                    self.refcount[p as usize] = 0;
                    self.free.push(p);
                }
                if let Some((old, new)) = cow.take() {
                    if let Some(e) = self.seqs.get_mut(&seq_id) {
                        e.pages[cur / pt] = old;
                        self.refcount[old as usize] += 1;
                    }
                    self.refcount[new as usize] = 0;
                    self.free.push(new);
                }
                return Err(KvError::OutOfPages { need, free: free_now });
            };
            self.refcount[p as usize] = 1;
            grown.push(p);
        }
        let t = self.tick();
        let Some(e) = self.seqs.get_mut(&seq_id) else {
            // unreachable under the exclusive borrow; stay panic-free
            for p in grown {
                self.refcount[p as usize] = 0;
                self.free.push(p);
            }
            if let Some((_, new)) = cow {
                self.refcount[new as usize] = 0;
                self.free.push(new);
            }
            return Err(KvError::UnknownSeq(seq_id));
        };
        e.pages.extend_from_slice(&grown);
        e.n_tokens = cur + extra;
        e.last_touch = t;
        Ok(Append { cow, grown })
    }

    /// Roll a sequence's tail back to `n_tokens` cached tokens (the
    /// speculative-decode rollback path: drafted K/V past the committed
    /// prefix is discarded). Pages no longer needed leave the table with
    /// their refcount decremented — a page shared with a forked sibling
    /// survives through its refcount; exclusively-owned pages return to
    /// the free list and are appended to the freed-page log so the slab
    /// owner GCs their payloads. The surviving tail page may keep stale
    /// slots past `n_tokens`; the next append overwrites them (after the
    /// usual copy-on-write remap if the page is shared). Truncating to
    /// the current count is a no-op; growing is
    /// [`KvError::TruncateBeyondEnd`], side-effect free. Returns the
    /// number of pages freed.
    pub fn truncate_tail(&mut self, seq_id: u64, n_tokens: usize) -> Result<usize, KvError> {
        let have = self.seqs.get(&seq_id).ok_or(KvError::UnknownSeq(seq_id))?.n_tokens;
        if n_tokens > have {
            return Err(KvError::TruncateBeyondEnd { n_tokens, have });
        }
        let keep = self.pages_needed(n_tokens);
        let t = self.tick();
        let e = self.seqs.get_mut(&seq_id).ok_or(KvError::UnknownSeq(seq_id))?;
        e.n_tokens = n_tokens;
        e.last_touch = t;
        let dropped = e.pages.split_off(keep);
        let mut freed = 0;
        for p in dropped {
            let rc = &mut self.refcount[p as usize];
            debug_assert!(*rc > 0, "double free of page {p}");
            *rc -= 1;
            if *rc == 0 {
                self.free.push(p);
                self.freed_log.push(p);
                freed += 1;
            }
        }
        Ok(freed)
    }

    /// Mark a sequence's prefill complete; it becomes evictable.
    pub fn release(&mut self, seq_id: u64) -> Result<(), KvError> {
        let t = self.tick();
        let e = self.seqs.get_mut(&seq_id).ok_or(KvError::UnknownSeq(seq_id))?;
        e.pinned = false;
        e.last_touch = t;
        Ok(())
    }

    /// Re-pin a sequence (the inverse of [`KvCache::release`]): a fork
    /// taken from an unpinned prefix holder must not be LRU-evicted while
    /// it is actively decoding.
    pub fn pin(&mut self, seq_id: u64) -> Result<(), KvError> {
        let t = self.tick();
        let e = self.seqs.get_mut(&seq_id).ok_or(KvError::UnknownSeq(seq_id))?;
        e.pinned = true;
        e.last_touch = t;
        Ok(())
    }

    /// Drop a sequence immediately, returning pages whose refcount hits 0.
    pub fn drop_seq(&mut self, seq_id: u64) -> Result<usize, KvError> {
        let e = self.seqs.remove(&seq_id).ok_or(KvError::UnknownSeq(seq_id))?;
        let mut freed = 0;
        for p in e.pages {
            let rc = &mut self.refcount[p as usize];
            debug_assert!(*rc > 0, "double free of page {p}");
            *rc -= 1;
            if *rc == 0 {
                self.free.push(p);
                self.freed_log.push(p);
                freed += 1;
            }
        }
        Ok(freed)
    }

    /// Drain the freed-page log: every page id whose refcount reached 0
    /// since the previous drain, including pages freed by LRU eviction
    /// inside `allocate`/`append_tokens`. Owners of per-page payloads
    /// (the decode slab store) drain this after every mutating call to
    /// garbage-collect exactly the retired identities; callers that keep
    /// no payloads can ignore it — the log is cleared on drain and only
    /// grows while undrained.
    pub fn take_freed(&mut self) -> Vec<u32> {
        std::mem::take(&mut self.freed_log)
    }

    fn evict_lru(&mut self) -> bool {
        self.evict_victim(None)
    }

    /// LRU eviction that never selects `keep` — the appending sequence
    /// must not evict itself even if the caller released it early.
    fn evict_lru_excluding(&mut self, keep: u64) -> bool {
        self.evict_victim(Some(keep))
    }

    fn evict_victim(&mut self, keep: Option<u64>) -> bool {
        let victim = self
            .seqs
            .iter()
            .filter(|(&id, e)| !e.pinned && Some(id) != keep)
            .min_by_key(|(_, e)| e.last_touch)
            .map(|(&id, _)| id);
        match victim {
            Some(id) => {
                let _ = self.drop_seq(id);
                self.evict_count += 1;
                true
            }
            None => false,
        }
    }

    /// The page table of a live sequence (`None` if unknown/evicted).
    pub fn page_table(&self, seq_id: u64) -> Option<&[u32]> {
        self.seqs.get(&seq_id).map(|e| e.pages.as_slice())
    }

    /// Invariant check used by property tests: every page is either free
    /// or referenced, with consistent refcounts, and every page table is
    /// exactly sized for its token count.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut counted = vec![0u16; self.cfg.total_pages];
        for (id, e) in &self.seqs {
            if e.pages.len() != self.pages_needed(e.n_tokens) {
                return Err(format!(
                    "seq {id}: {} pages for {} tokens (want {})",
                    e.pages.len(),
                    e.n_tokens,
                    self.pages_needed(e.n_tokens)
                ));
            }
            for &p in &e.pages {
                counted[p as usize] += 1;
            }
        }
        for (p, (&rc, &ct)) in self.refcount.iter().zip(&counted).enumerate() {
            if rc != ct {
                return Err(format!("page {p}: refcount {rc} != table count {ct}"));
            }
        }
        let free_set: std::collections::HashSet<u32> = self.free.iter().copied().collect();
        if free_set.len() != self.free.len() {
            return Err("duplicate page in free list".into());
        }
        for &p in &self.free {
            if self.refcount[p as usize] != 0 {
                return Err(format!("free page {p} has refcount"));
            }
        }
        if self.free.len() + counted.iter().filter(|&&c| c > 0).count() != self.cfg.total_pages {
            // pages can be multiply referenced; free + referenced-distinct must cover all
            return Err("page accounting mismatch".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn cache(pages: usize) -> KvCache {
        KvCache::new(KvConfig { total_pages: pages, page_tokens: 64 })
    }

    #[test]
    fn alloc_release_drop() {
        let mut kv = cache(16);
        kv.allocate(1, 300).unwrap(); // 5 pages
        assert_eq!(kv.used_pages(), 5);
        kv.release(1).unwrap();
        assert_eq!(kv.drop_seq(1).unwrap(), 5);
        assert_eq!(kv.free_pages(), 16);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn eviction_frees_released_seqs() {
        let mut kv = cache(8);
        kv.allocate(1, 256).unwrap(); // 4 pages
        kv.release(1).unwrap();
        kv.allocate(2, 256).unwrap(); // 4 pages
        // pool full; seq 1 is evictable
        kv.allocate(3, 256).unwrap();
        assert_eq!(kv.evict_count, 1);
        assert!(kv.page_table(1).is_none());
        kv.check_invariants().unwrap();
    }

    #[test]
    fn pinned_seqs_never_evicted() {
        let mut kv = cache(8);
        kv.allocate(1, 512).unwrap(); // 8 pages, pinned
        let err = kv.allocate(2, 64).unwrap_err();
        assert!(matches!(err, KvError::OutOfPages { .. }));
        assert!(kv.page_table(1).is_some());
    }

    #[test]
    fn fork_shares_pages() {
        let mut kv = cache(8);
        kv.allocate(1, 128).unwrap(); // 2 pages
        kv.fork(1, 2).unwrap();
        assert_eq!(kv.used_pages(), 2);
        assert_eq!(kv.drop_seq(1).unwrap(), 0); // still referenced by 2
        assert_eq!(kv.drop_seq(2).unwrap(), 2);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn fork_prefix_shares_only_the_covered_pages() {
        let mut kv = cache(8); // page_tokens = 64
        kv.allocate(1, 300).unwrap(); // 5 pages, tail partial
        let src_pages = kv.page_table(1).unwrap().to_vec();
        kv.fork_prefix(1, 2, 128).unwrap(); // 2 whole pages
        assert_eq!(kv.seq_tokens(2), Some(128));
        assert_eq!(kv.page_table(2).unwrap(), &src_pages[..2]);
        assert_eq!(kv.used_pages(), 5, "prefix fork must not allocate");
        kv.check_invariants().unwrap();
        // the fork appends into a fresh page (its tail is exactly full)
        let a = kv.append_tokens(2, 1).unwrap();
        assert_eq!(a.cow, None);
        assert_eq!(a.grown.len(), 1);
        // misaligned or oversized splits are clean errors
        assert!(matches!(
            kv.fork_prefix(1, 3, 100),
            Err(KvError::MisalignedFork { n_tokens: 100, page_tokens: 64 })
        ));
        assert!(matches!(kv.fork_prefix(1, 3, 320), Err(KvError::MisalignedFork { .. })));
        assert!(kv.page_table(3).is_none(), "failed prefix fork must be side-effect free");
        // full-length split is allowed even when the tail is partial
        kv.fork_prefix(1, 3, 300).unwrap();
        assert_eq!(kv.page_table(3).unwrap(), &src_pages[..]);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn seq_share_weight_scales_with_length_and_sharing() {
        let mut kv = cache(16);
        kv.allocate(1, 128).unwrap(); // 2 pages
        kv.allocate(2, 320).unwrap(); // 5 pages
        let (w1, w2) = (kv.seq_share_weight(1).unwrap(), kv.seq_share_weight(2).unwrap());
        assert_eq!(w1, 2 * 64);
        assert!(w2 > w1, "longer prefixes must weigh more: {w2} vs {w1}");
        // two forks of seq 1 triple its pages' refcounts
        kv.fork(1, 3).unwrap();
        kv.fork(1, 4).unwrap();
        assert_eq!(kv.seq_share_weight(1).unwrap(), 3 * 2 * 64);
        assert_eq!(kv.seq_share_weight(99), None);
    }

    #[test]
    fn allocate_existing_seq_is_hard_error() {
        let mut kv = cache(8);
        kv.allocate(1, 64).unwrap();
        assert_eq!(kv.allocate(1, 64), Err(KvError::SeqExists(1)));
        kv.fork(1, 2).unwrap();
        assert_eq!(kv.fork(1, 2), Err(KvError::SeqExists(2)));
        assert_eq!(kv.used_pages(), 1);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn fork_inherits_pin_state() {
        let mut kv = cache(8);
        kv.allocate(1, 256).unwrap(); // 4 pages
        kv.release(1).unwrap();
        kv.fork(1, 2).unwrap(); // fork of a released seq is evictable
        // pool full after another pinned alloc; both 1 and 2 can evict
        kv.allocate(3, 256).unwrap();
        kv.allocate(4, 256).unwrap();
        assert!(kv.page_table(1).is_none() && kv.page_table(2).is_none());
        kv.check_invariants().unwrap();
        // a fork of a *pinned* seq stays pinned
        kv.drop_seq(3).unwrap();
        kv.drop_seq(4).unwrap();
        kv.allocate(5, 256).unwrap();
        kv.fork(5, 6).unwrap();
        assert!(matches!(kv.allocate(7, 512), Err(KvError::OutOfPages { .. })));
        assert!(kv.page_table(5).is_some() && kv.page_table(6).is_some());
    }

    #[test]
    fn append_crosses_page_boundary() {
        let mut kv = cache(8); // page_tokens = 64
        kv.allocate(1, 60).unwrap(); // 1 page, 60 tokens
        assert_eq!(kv.seq_tokens(1), Some(60));
        // 4 more tokens fill the page exactly: no growth, no cow
        let a = kv.append_tokens(1, 4).unwrap();
        assert_eq!(a, Append { cow: None, grown: vec![] });
        assert_eq!(kv.page_table(1).unwrap().len(), 1);
        // one more token opens a second page
        let a = kv.append_tokens(1, 1).unwrap();
        assert_eq!(a.grown.len(), 1);
        assert_eq!(kv.page_table(1).unwrap().len(), 2);
        // a long append spans several pages at once
        let a = kv.append_tokens(1, 200).unwrap();
        assert_eq!(kv.seq_tokens(1), Some(265));
        assert_eq!(kv.page_table(1).unwrap().len(), 5);
        assert_eq!(a.grown.len(), 3);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn append_cow_remaps_shared_tail() {
        let mut kv = cache(8);
        kv.allocate(1, 100).unwrap(); // 2 pages, tail partially filled
        let tail = kv.page_table(1).unwrap()[1];
        kv.fork(1, 2).unwrap();
        let a = kv.append_tokens(2, 1).unwrap();
        let (old, new) = a.cow.expect("shared tail must copy-on-write");
        assert_eq!(old, tail);
        assert_ne!(new, tail);
        assert_eq!(kv.page_table(2).unwrap()[1], new);
        assert_eq!(kv.page_table(1).unwrap()[1], tail);
        // the source now owns its tail alone: its own append needs no cow
        let a = kv.append_tokens(1, 1).unwrap();
        assert_eq!(a.cow, None);
        // a full tail page is never cow'd: writes go to a fresh page
        let mut kv = cache(8);
        kv.allocate(1, 64).unwrap();
        kv.fork(1, 2).unwrap();
        let a = kv.append_tokens(2, 1).unwrap();
        assert_eq!(a.cow, None);
        assert_eq!(a.grown.len(), 1);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn append_pressure_evicts_but_never_self() {
        let mut kv = cache(4);
        kv.allocate(1, 128).unwrap(); // 2 pages
        kv.release(1).unwrap();
        kv.allocate(2, 128).unwrap(); // 2 pages, pool full
        kv.release(2).unwrap(); // seq 2 unpinned but appending
        let a = kv.append_tokens(2, 64).unwrap(); // needs 1 page -> evict seq 1
        assert_eq!(a.grown.len(), 1);
        assert!(kv.page_table(1).is_none(), "LRU seq 1 must be evicted");
        assert!(kv.page_table(2).is_some(), "appender must never evict itself");
        assert_eq!(kv.evict_count, 1);
        kv.check_invariants().unwrap();
        // nothing evictable left: append past capacity is a clean error
        let err = kv.append_tokens(2, 256).unwrap_err();
        assert!(matches!(err, KvError::OutOfPages { .. }));
        assert_eq!(kv.seq_tokens(2), Some(192), "failed append must not change state");
        kv.check_invariants().unwrap();
    }

    #[test]
    fn fork_refcount_overflow_is_a_clean_error() {
        let mut kv = KvCache::new(KvConfig { total_pages: 1, page_tokens: 64 });
        kv.allocate(0, 64).unwrap(); // page 0, refcount 1
        for i in 1..u16::MAX as u64 {
            kv.fork(0, i).unwrap();
        }
        // page 0 is now referenced u16::MAX times: one more fork must
        // refuse instead of wrapping to 0
        let err = kv.fork(0, u16::MAX as u64).unwrap_err();
        assert_eq!(err, KvError::RefcountOverflow { page: 0 });
        // the failed fork left no sequence entry and no partial increment
        assert!(kv.page_table(u16::MAX as u64).is_none());
        kv.check_invariants().unwrap();
    }

    #[test]
    fn allocate_survives_eviction_that_frees_nothing() {
        // an evictable victim whose pages are all shared with a pinned
        // sequence "evicts" without freeing a single page; the allocation
        // loop must land on the out-of-pages error, not a free-list panic
        let mut kv = cache(4);
        kv.allocate(1, 128).unwrap(); // 2 pages, pinned
        kv.fork(1, 2).unwrap(); // shares both pages
        kv.release(2).unwrap(); // evictable, but frees 0 pages
        let err = kv.allocate(3, 256).unwrap_err();
        assert!(matches!(err, KvError::OutOfPages { .. }));
        assert_eq!(kv.used_pages(), 2, "failed allocate must not leak reservations");
        kv.check_invariants().unwrap();
    }

    #[test]
    fn pin_reverses_release() {
        let mut kv = cache(8);
        kv.allocate(1, 256).unwrap(); // 4 pages
        kv.release(1).unwrap();
        kv.pin(1).unwrap();
        // pool full after another pinned alloc; nothing is evictable now
        kv.allocate(2, 256).unwrap();
        assert!(matches!(kv.allocate(3, 64), Err(KvError::OutOfPages { .. })));
        assert!(kv.page_table(1).is_some(), "re-pinned seq must survive pressure");
        assert_eq!(kv.pin(99), Err(KvError::UnknownSeq(99)));
    }

    #[test]
    fn freed_log_reports_every_zero_refcount_page() {
        let mut kv = cache(8);
        kv.allocate(1, 128).unwrap(); // 2 pages
        kv.fork(1, 2).unwrap();
        assert_eq!(kv.take_freed(), vec![], "nothing freed yet");
        kv.drop_seq(1).unwrap(); // still shared: frees nothing
        assert_eq!(kv.take_freed(), vec![]);
        let pages: Vec<u32> = kv.page_table(2).unwrap().to_vec();
        kv.drop_seq(2).unwrap();
        let mut freed = kv.take_freed();
        freed.sort_unstable();
        let mut want = pages;
        want.sort_unstable();
        assert_eq!(freed, want);
        // eviction inside allocate logs too
        kv.allocate(3, 128).unwrap();
        kv.release(3).unwrap();
        kv.take_freed();
        kv.allocate(4, 512).unwrap(); // forces evicting seq 3
        assert_eq!(kv.take_freed().len(), 2, "evicted pages must be logged");
        kv.check_invariants().unwrap();
    }

    #[test]
    fn truncate_tail_frees_owned_pages_and_logs_them() {
        let mut kv = cache(8); // page_tokens = 64
        kv.allocate(1, 300).unwrap(); // 5 pages
        kv.take_freed();
        let pages: Vec<u32> = kv.page_table(1).unwrap().to_vec();
        // non-aligned rollback keeps the partial tail page
        assert_eq!(kv.truncate_tail(1, 130).unwrap(), 2);
        assert_eq!(kv.seq_tokens(1), Some(130));
        assert_eq!(kv.page_table(1).unwrap(), &pages[..3]);
        let mut freed = kv.take_freed();
        freed.sort_unstable();
        let mut want = pages[3..].to_vec();
        want.sort_unstable();
        assert_eq!(freed, want, "dropped pages must hit the freed log");
        kv.check_invariants().unwrap();
        // truncate to the same count is a no-op
        assert_eq!(kv.truncate_tail(1, 130).unwrap(), 0);
        assert_eq!(kv.page_table(1).unwrap().len(), 3);
        // growing is a clean, side-effect-free error
        assert_eq!(
            kv.truncate_tail(1, 131),
            Err(KvError::TruncateBeyondEnd { n_tokens: 131, have: 130 })
        );
        assert_eq!(kv.seq_tokens(1), Some(130));
        // truncating to zero releases everything but keeps the sequence
        assert_eq!(kv.truncate_tail(1, 0).unwrap(), 3);
        assert_eq!(kv.seq_tokens(1), Some(0));
        assert_eq!(kv.free_pages(), 8);
        kv.check_invariants().unwrap();
        // the empty sequence can grow again
        assert_eq!(kv.append_tokens(1, 65).unwrap().grown.len(), 2);
        kv.check_invariants().unwrap();
        assert_eq!(kv.truncate_tail(9, 0), Err(KvError::UnknownSeq(9)));
    }

    #[test]
    fn truncate_tail_never_frees_pages_shared_with_a_sibling() {
        // rollback invariant: a forked tail rolled back must decrement,
        // never free, pages a sibling still references
        let mut kv = cache(8); // page_tokens = 64
        kv.allocate(1, 200).unwrap(); // 4 pages, tail partial
        kv.fork(1, 2).unwrap();
        kv.take_freed();
        let shared: Vec<u32> = kv.page_table(1).unwrap().to_vec();
        // the fork diverges: CoW remaps its tail, then it grows a page
        let app = kv.append_tokens(2, 100).unwrap(); // 300 tokens -> 5 pages
        assert!(app.cow.is_some());
        assert_eq!(app.grown.len(), 1);
        kv.take_freed();
        // roll the fork all the way back to the shared prefix length
        let freed = kv.truncate_tail(2, 128).unwrap(); // keeps 2 shared pages
        // freed: the CoW'd tail copy + the grown page (exclusively owned);
        // the two surviving pages are shared and must stay referenced
        assert_eq!(freed, 2);
        assert_eq!(kv.take_freed().len(), 2);
        assert_eq!(kv.page_table(2).unwrap(), &shared[..2]);
        assert!(kv.page_table(1).unwrap() == &shared[..], "sibling table untouched");
        assert_eq!(kv.seq_tokens(1), Some(200), "sibling token count untouched");
        kv.check_invariants().unwrap();
        // dropping the rolled-back fork frees nothing shared
        kv.drop_seq(2).unwrap();
        assert_eq!(kv.take_freed(), vec![], "shared pages survive the fork");
        assert_eq!(kv.used_pages(), 4);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn append_after_truncate_cows_a_still_shared_tail() {
        // a rolled-back fork whose surviving tail page is still shared
        // must CoW before its next write, exactly like a fresh fork
        let mut kv = cache(8);
        kv.allocate(1, 100).unwrap(); // 2 pages, tail partial
        kv.fork(1, 2).unwrap();
        // diverge the fork (CoW) then roll it back INTO the shared page
        kv.append_tokens(2, 30).unwrap();
        kv.truncate_tail(2, 70).unwrap(); // 70 % 64 != 0: tail is page 1
        // after rollback the fork's tail slot holds its own CoW copy (the
        // remap happened before the rollback), so appends are direct...
        let a = kv.append_tokens(2, 1).unwrap();
        assert_eq!(a.cow, None);
        // ...but a fork rolled back before ever diverging still shares
        // its tail and must CoW on append
        kv.fork(1, 3).unwrap();
        kv.truncate_tail(3, 70).unwrap();
        let a = kv.append_tokens(3, 1).unwrap();
        assert!(a.cow.is_some(), "shared post-rollback tail must copy-on-write");
        kv.check_invariants().unwrap();
    }

    #[test]
    fn append_unknown_seq_and_zero_extra() {
        let mut kv = cache(4);
        assert_eq!(kv.append_tokens(9, 1), Err(KvError::UnknownSeq(9)));
        kv.allocate(1, 64).unwrap();
        assert_eq!(kv.append_tokens(1, 0).unwrap(), Append { cow: None, grown: vec![] });
        assert_eq!(kv.seq_tokens(1), Some(64));
    }

    #[test]
    fn prop_random_workload_keeps_invariants() {
        forall(
            99,
            60,
            |r: &mut Rng| {
                let ops: Vec<(usize, usize)> =
                    (0..40).map(|_| (r.below(4) as usize, r.below(6) as usize + 1)).collect();
                ops
            },
            |ops| {
                let mut kv = cache(12);
                let mut next_id = 0u64;
                let mut live: Vec<u64> = vec![];
                for &(op, size) in ops {
                    match op {
                        0 => {
                            next_id += 1;
                            if kv.allocate(next_id, size * 64).is_ok() {
                                live.push(next_id);
                            }
                        }
                        1 => {
                            if let Some(&id) = live.first() {
                                let _ = kv.release(id);
                            }
                        }
                        2 => {
                            if !live.is_empty() {
                                let id = live.remove(0);
                                let _ = kv.drop_seq(id);
                            }
                        }
                        _ => {
                            if let Some(&src) = live.last() {
                                next_id += 1;
                                if kv.fork(src, next_id).is_ok() {
                                    live.push(next_id);
                                }
                            }
                        }
                    }
                    kv.check_invariants()?;
                }
                Ok(())
            },
        );
    }
}
