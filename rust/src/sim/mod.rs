//! Analytic cost model + hardware projection.
//!
//! Two uses (DESIGN.md §4, experiment F1):
//!   * `cost` — FLOP/byte accounting of every method at any (N, model),
//!     from the paper's Eq. (2)/(4)/(8); drives the scheduler's estimates
//!     and the Figure-1 extrapolation beyond what this CPU can run.
//!   * `h20` — projection of those counts onto the paper's H20 testbed
//!     (and Llama-3.1-8B geometry), calibrated so the *ratios* — who wins,
//!     by how much, where crossovers sit — can be compared to Figure 1.

pub mod cost;
pub mod h20;

pub use cost::{
    engine_module_ns, estimate_core_prefill_ns, estimate_decode_step_ns,
    estimate_decode_step_ns_for, estimate_generate_ns, estimate_generate_ns_for,
    estimate_ingest_ns, estimate_spec_step_ns, estimate_spec_step_ns_for, method_cost,
    CostBreakdown, DecodeCostModel, EngineDecodeCalibration, Geometry, MethodCost,
    RustCoreCalibration, RustDecodeCalibration, DECODE_CORE, ENGINE_DECODE, RUST_CORE,
};
pub use h20::{project_figure1, H20Model, LLAMA31_8B};
