//! H20 projection of Figure 1 (latency vs context length, 16K–128K).
//!
//! The paper measures Llama-3.1-8B prefill on an NVIDIA H20 with
//! FlashAttention-2 vs sparse methods. We cannot run that hardware, so —
//! per the substitution rule — the *measured* half of F1 runs this repo's
//! compiled artifacts on CPU at 1K–8K (benches/bench_prefill.rs), and this
//! module projects the analytic cost model onto H20 constants to compare
//! *shape* against the paper's reported milliseconds:
//!
//!   paper, 128K: Dense 1540ms → Stem 420ms (3.7×); Stem metric ≈ 90ms;
//!   MInference slower than dense at 16K–32K due to pattern estimation.

use super::cost::{method_cost, Geometry, MethodCost};

/// Llama-3.1-8B geometry (GQA 32q/8kv ignored for FLOPs: scores are per
/// query head).
pub const LLAMA31_8B: Geometry =
    Geometry { n_layers: 32, n_heads: 32, d_head: 128, d_model: 4096, d_ff: 14336, block: 128 };

/// Figure 1 is an attention-*kernel* latency comparison: the paper's
/// dense point at 128K (1540 ms) is ~30× below a whole-32-layer-prefill
/// FLOP count on H20, i.e. it measures the attention stack of a single
/// layer (or equivalently per-layer kernel time). The projection
/// therefore uses the 1-layer geometry; whole-model prefill cost lives
/// in `method_cost` with the full geometry.
pub const LLAMA31_8B_LAYER: Geometry =
    Geometry { n_layers: 1, n_heads: 32, d_head: 128, d_model: 4096, d_ff: 14336, block: 128 };

/// Hardware/efficiency model for the projection.
#[derive(Debug, Clone, Copy)]
pub struct H20Model {
    /// achievable BF16 TFLOP/s on attention-shaped matmuls
    pub attn_tflops: f64,
    /// achievable TFLOP/s on the big linear layers
    pub linear_tflops: f64,
    /// fixed per-method pattern-estimation overhead at 128K, scaled
    /// quadratically in N/128K (metric/sampling passes), milliseconds
    pub overhead_128k_ms: f64,
    /// sparse-kernel inefficiency multiplier (gather/launch overheads)
    pub sparse_penalty: f64,
}

/// Calibrated H20 constants (dense 128K point matched to the paper).
pub const H20: H20Model = H20Model {
    // H20: 148 TFLOPs BF16 peak. 91 TFLOP/s effective reproduces the
    // paper's dense 128K point (1540 ms) exactly from the FLOP count.
    attn_tflops: 91.0,
    linear_tflops: 104.0,
    overhead_128k_ms: 0.0,
    sparse_penalty: 1.15,
};

/// One projected (method, context-length) latency sample of Figure 1.
#[derive(Debug, Clone)]
pub struct LatencyPoint {
    /// Method label (e.g. `"stem"`, `"dense"`).
    pub method: String,
    /// Context length projected at.
    pub n_ctx: usize,
    /// Attention-kernel milliseconds.
    pub kernel_ms: f64,
    /// Kernel + metric/pattern-estimation milliseconds.
    pub total_ms: f64,
    /// Fraction of causal pairs computed.
    pub budget_fraction: f64,
}

/// Project one method's prefill latency at length `n`.
///
/// `fixed_ms` models per-method constant pattern-estimation cost
/// (MInference's last-q scans dominate at short contexts — the paper's
/// "slower than Dense at 16K/32K" observation); `overhead_128k_ms` is the
/// O(N²/B²) block-metric cost pinned at 128K and scaled quadratically
/// (Stem ≈ 90 ms at 128K per the paper).
pub fn project_latency(
    g: &Geometry,
    hw: &H20Model,
    n: usize,
    method: &str,
    m: MethodCost,
    fixed_ms: f64,
    overhead_128k_ms: f64,
) -> LatencyPoint {
    let c = method_cost(g, n, m);
    let penalty = if matches!(m, MethodCost::Dense) { 1.0 } else { hw.sparse_penalty };
    let scale = (n as f64 / 131072.0).powi(2);
    let overhead_ms = fixed_ms + overhead_128k_ms * scale;
    // kernel = sparse attention execution; total adds the method's
    // metric/pattern-estimation passes (the paper's "Attention Kernel
    // Time / Total Time" split).
    let kernel_ms = c.attn_flops / (hw.attn_tflops * 1e12) * 1e3 * penalty;
    let metric_ms = c.metric_flops / (hw.attn_tflops * 1e12) * 1e3 + overhead_ms;
    LatencyPoint {
        method: method.to_string(),
        n_ctx: n,
        kernel_ms,
        total_ms: kernel_ms + metric_ms,
        budget_fraction: c.budget_fraction,
    }
}

/// The full Figure-1 grid: methods × context lengths. Per-layer kernel
/// geometry (see [`LLAMA31_8B_LAYER`]); budgets from the paper's Tables
/// 2/4 BUD columns; overheads from §3.3 "Empirical Latency".
pub fn project_figure1(lengths: &[usize]) -> Vec<LatencyPoint> {
    let g = &LLAMA31_8B_LAYER;
    let hw = &H20;
    let mut out = vec![];
    for &n in lengths {
        let nblk = (n / g.block) as f64;
        // paper §3.1: k_start = 0.2·N_blk for 8–16K, 0.1·N_blk above
        let frac = if n <= 16384 { 0.2 } else { 0.1 };
        out.push(project_latency(g, hw, n, "dense", MethodCost::Dense, 0.0, 0.0));
        out.push(project_latency(
            g,
            hw,
            n,
            "minference",
            // MInference: moderate budget + costly pattern estimation with
            // a large fixed component (slower than dense at 16K–32K).
            MethodCost::UniformBudget { budget_fraction: 0.55, metric_overhead: 0.0 },
            45.0,
            40.0,
        ));
        out.push(project_latency(
            g,
            hw,
            n,
            "flexprefill",
            MethodCost::UniformBudget { budget_fraction: 0.30, metric_overhead: 0.0 },
            5.0,
            160.0,
        ));
        out.push(project_latency(
            g,
            hw,
            n,
            "xattn",
            MethodCost::UniformBudget { budget_fraction: 0.28, metric_overhead: 0.0 },
            3.0,
            110.0,
        ));
        out.push(project_latency(
            g,
            hw,
            n,
            "stem",
            MethodCost::Stem { k_start_blocks: frac * nblk, mu: 0.7 },
            0.0,
            90.0,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure1_shape_matches_paper() {
        let pts = project_figure1(&[16384, 32768, 65536, 131072]);
        let get = |m: &str, n: usize| {
            pts.iter().find(|p| p.method == m && p.n_ctx == n).unwrap().clone()
        };
        // 128K: dense ~1.5s, stem several× faster (paper: 1540→420, 3.7×)
        let d = get("dense", 131072);
        let s = get("stem", 131072);
        assert!(d.total_ms > 800.0 && d.total_ms < 3000.0, "dense {:.0}ms", d.total_ms);
        let speedup = d.total_ms / s.total_ms;
        assert!(speedup > 2.0 && speedup < 6.0, "speedup {speedup:.2}");
        // MInference slower than dense at 16K (paper's observation)
        let m16 = get("minference", 16384);
        let d16 = get("dense", 16384);
        assert!(m16.total_ms > d16.total_ms, "minference must lose at 16K");
        // stem cheapest sparse method at every length
        for &n in &[16384usize, 32768, 65536, 131072] {
            let stem = get("stem", n);
            for m in ["flexprefill", "xattn", "minference"] {
                assert!(
                    stem.total_ms <= get(m, n).total_ms * 1.05,
                    "stem not fastest at {n} vs {m}"
                );
            }
        }
        // budgets in paper range
        let s128 = get("stem", 131072);
        assert!(s128.budget_fraction < 0.20, "bud {}", s128.budget_fraction);
        // stem metric overhead ≈ paper's 90ms at 128K
        let metric_ms = s128.total_ms - s128.kernel_ms;
        assert!(metric_ms > 60.0 && metric_ms < 120.0, "metric {metric_ms:.0}ms");
    }

    #[test]
    fn latency_grows_superlinearly_for_dense() {
        let pts = project_figure1(&[16384, 131072]);
        let d16 = pts.iter().find(|p| p.method == "dense" && p.n_ctx == 16384).unwrap();
        let d128 = pts.iter().find(|p| p.method == "dense" && p.n_ctx == 131072).unwrap();
        assert!(d128.total_ms / d16.total_ms > 8.0 * 1.5, "quadratic term must bite");
    }
}
