//! FLOP-level cost accounting for each attention method (per layer, per
//! head-set) on a given model geometry, plus the calibrated wall-clock
//! estimators the coordinator budgets admission against.
//!
//! Two layers:
//!
//! * **Analytic pair counts** — [`method_cost`] turns the paper's
//!   Eq. (2)/(4)/(8) budget algebra into attention/metric/linear FLOPs
//!   and the BUD fraction for any [`MethodCost`].
//! * **Calibrated estimators** — [`estimate_core_prefill_ns`],
//!   [`estimate_decode_step_ns`], [`estimate_spec_step_ns`],
//!   [`estimate_ingest_ns`] and [`estimate_generate_ns`] convert those
//!   counts into nanoseconds using measured per-op constants
//!   ([`RUST_CORE`], [`DECODE_CORE`], the speculative-round constants
//!   [`SPEC_EXTRA_ROW_COST`] / [`SPEC_ASSUMED_ACCEPTANCE`]).
//!
//! **Re-fitting the constants from `BENCH_*.json`:** the constants are
//! throughput measurements of the pure-rust kernels, so they drift
//! whenever the kernels change. Each bench emits a machine-readable
//! trajectory file — `cargo bench --bench bench_sparse_core` writes
//! `BENCH_sparse_core.json` (per-stage ns for selection/attention →
//! [`RUST_CORE`]'s `ns_per_pair_dh` / `ns_per_select_candidate` /
//! `ns_per_metric_flop`), `bench_decode` writes `BENCH_decode.json`
//! (sparse-vs-dense ns/token → [`DECODE_CORE`]; its per-backend
//! `decode_backend` rows re-fit [`ENGINE_DECODE`] — divide the measured
//! engine-minus-tiny ns/token gap by the padded-bucket MAC count
//! [`engine_module_ns`] charges for the same context), and
//! `bench_fanout` writes `BENCH_fanout.json` (ingest vs decode split →
//! sanity for [`estimate_ingest_ns`]'s `ns_per_proj_mac` share). To
//! re-fit, divide the measured ns by the op counts the estimator charges
//! for the same shape and update the constant; the admission limits
//! (`max_work_ns`) then keep rejecting at the same *wall-clock* backlog
//! after a kernel speedup, instead of at a stale token count.
//!
//! Decode estimates are **per backend** ([`DecodeCostModel`]): the base
//! [`DECODE_CORE`] constants price the `tiny` backend's matvec glue,
//! while the `engine` backend additionally executes one compiled
//! `decode_step` module per emitted position — a *full padded-bucket
//! forward*, not a single-token matvec — so the coordinator's admission
//! must budget through [`estimate_decode_step_ns_for`] /
//! [`estimate_spec_step_ns_for`] or it would underprice engine steps by
//! orders of magnitude.
//!
//! Token-granular prefix reuse relies on [`estimate_ingest_ns`] being
//! linear in the prompt length: the coordinator charges it on the
//! *uncovered suffix only*, so a radix partial hit admits more
//! concurrent work than a cold prompt of the same length.

use crate::sparse::schedule::{self, TpdConfig};

/// Model geometry the cost model needs.
#[derive(Debug, Clone, Copy)]
pub struct Geometry {
    /// Transformer layers.
    pub n_layers: usize,
    /// Query heads per layer.
    pub n_heads: usize,
    /// Head dimension.
    pub d_head: usize,
    /// Model width.
    pub d_model: usize,
    /// Feed-forward inner width.
    pub d_ff: usize,
    /// Attention block size (= KV page tokens).
    pub block: usize,
}

/// Attention method being costed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MethodCost {
    /// Full causal attention.
    Dense,
    /// Stem TPD+OAM with runtime schedule.
    Stem {
        /// Starting block budget of the TPD schedule.
        k_start_blocks: f64,
        /// Decay floor multiplier.
        mu: f64,
    },
    /// Uniform top-k (SAM baselines, MInference/XAttention effective
    /// budgets enter through `budget_fraction`).
    UniformBudget {
        /// Fraction of causal pairs kept.
        budget_fraction: f64,
        /// Flat metric/pattern-estimation FLOPs.
        metric_overhead: f64,
    },
    /// StreamingLLM-style sinks + local window.
    Streaming {
        /// Leading sink blocks kept per row.
        sink_blocks: f64,
        /// Trailing local blocks kept per row.
        local_blocks: f64,
    },
}

/// Per-prefill cost breakdown in FLOPs (attention path only vs whole model).
#[derive(Debug, Clone, Copy)]
pub struct CostBreakdown {
    /// Attention (QK^T + PV) FLOPs over the computed pairs.
    pub attn_flops: f64,
    /// Routing-metric FLOPs (sampling + pooling).
    pub metric_flops: f64,
    /// Non-attention linear-layer FLOPs.
    pub linear_flops: f64,
    /// Sum of the three components.
    pub total_flops: f64,
    /// fraction of causal pairs computed (the paper's BUD column)
    pub budget_fraction: f64,
}

/// FLOPs of non-attention linear layers for a length-N prefill.
pub fn linear_flops(g: &Geometry, n: usize) -> f64 {
    let nf = n as f64;
    let d = g.d_model as f64;
    let ff = g.d_ff as f64;
    // qkvo projections + SwiGLU (3 mats) per layer, 2 flops per MAC
    let per_layer = 2.0 * nf * d * (2.0 * d + 2.0 * d) + 2.0 * nf * d * ff * 3.0;
    per_layer * g.n_layers as f64
}

/// Attention pair-cost → FLOPs: each computed (query, key) pair costs
/// ~4·dh FLOPs (QK^T and PV, 2 flops/MAC each) per head.
fn pairs_to_flops(g: &Geometry, pairs: f64) -> f64 {
    pairs * 4.0 * g.d_head as f64 * g.n_heads as f64 * g.n_layers as f64
}

/// FLOP/budget breakdown of one length-`n` prefill under method `m`.
pub fn method_cost(g: &Geometry, n: usize, m: MethodCost) -> CostBreakdown {
    let nblk = (n / g.block).max(1);
    let dense_pairs = schedule::cost_dense(n);
    let (pairs, metric_flops) = match m {
        MethodCost::Dense => (dense_pairs, 0.0),
        MethodCost::Stem { k_start_blocks, mu } => {
            let cfg = TpdConfig { k_start: k_start_blocks, mu, ..Default::default() };
            let kavg_blocks = schedule::k_avg_blocks(nblk, &cfg);
            let pairs = kavg_blocks * g.block as f64 * n as f64;
            // metric: anti-diagonal sampling (B/stride rows per block pair)
            // + value pooling, per head per layer
            let stride = 16.0;
            let routing = (nblk * nblk) as f64 / 2.0 * (g.block as f64 / stride)
                * 2.0
                * g.d_head as f64;
            let pooling = n as f64 * 2.0 * g.d_head as f64;
            let metric =
                (routing + pooling) * g.n_heads as f64 * g.n_layers as f64;
            (pairs.min(dense_pairs), metric)
        }
        MethodCost::UniformBudget { budget_fraction, metric_overhead } => {
            (dense_pairs * budget_fraction, metric_overhead)
        }
        MethodCost::Streaming { sink_blocks, local_blocks } => {
            let per_row = ((sink_blocks + local_blocks) * g.block as f64).min(n as f64);
            (per_row * n as f64, 0.0)
        }
    };
    let attn = pairs_to_flops(g, pairs);
    let linear = linear_flops(g, n);
    CostBreakdown {
        attn_flops: attn,
        metric_flops,
        linear_flops: linear,
        total_flops: attn + metric_flops + linear,
        budget_fraction: pairs / dense_pairs,
    }
}

/// Throughput constants of the *pure-rust* reference core, used to turn
/// the pair counts above into wall-clock estimates for admission control.
///
/// Re-fit for the PR-1 flat-CSR parallel pipeline (partial top-k
/// selection + fused tiled execution): the fused kernel amortizes one
/// K/V-slab load per `block×block` tile instead of one gather per pair,
/// and selection dropped from a full per-row sort to an O(width·log k)
/// bounded heap, so the per-pair and per-candidate constants are ~2–3×
/// below the seed scalar path. Re-fit again for the SIMD layer
/// (`sparse::simd`): the pair/metric constants assume the Wide dispatch
/// arm (the runtime default) — `STEM_SIMD=scalar` makes these estimates
/// optimistic by the `simd` speedup row. Refresh against the explicit
/// `simd` section of `BENCH_sparse_core.json` (scalar_ns/wide_ns per
/// stage, emitted by `benches/bench_sparse_core.rs`) whenever the
/// kernels change: divide the measured wide-arm ns by the pair count the
/// estimator charges for the same shape.
#[derive(Debug, Clone, Copy)]
pub struct RustCoreCalibration {
    /// ns per computed (query, key) pair per head-dim unit, single thread,
    /// fused tiled kernel
    pub ns_per_pair_dh: f64,
    /// ns per metric FLOP (antidiag sampling + pooling), single thread
    pub ns_per_metric_flop: f64,
    /// ns per selection candidate (one bounded-heap offer)
    pub ns_per_select_candidate: f64,
    /// fraction of linear scaling realized per extra worker thread
    pub parallel_efficiency: f64,
}

/// Current prefill-core calibration (re-fit from `BENCH_sparse_core.json`).
pub const RUST_CORE: RustCoreCalibration = RustCoreCalibration {
    // 8-lane fma dot/axpy in the fused tile walk: ~2x the scalar arm's
    // 0.11 on the n=4096 `simd` bench row
    ns_per_pair_dh: 0.055,
    // antidiag sampling vectorizes its dots; pooling stays scalar
    ns_per_metric_flop: 0.25,
    // bounded-heap offers are branchy control flow: no lane win
    ns_per_select_candidate: 2.0,
    parallel_efficiency: 0.80,
};

/// Throughput constants of the pure-rust *decode* step (single-query
/// kernels + the reference-LM projections), used by the coordinator to
/// budget `submit_generate` admissions. Head-level fan-out is much
/// shallower than prefill's (head, query-block) grid, so the parallel
/// efficiency is lower. Like [`RUST_CORE`], these assume the Wide SIMD
/// dispatch arm; refresh against the `simd` section of
/// `BENCH_decode.json` (emitted by `benches/bench_decode.rs`) whenever
/// the decode kernels change.
#[derive(Debug, Clone, Copy)]
pub struct RustDecodeCalibration {
    /// ns per attended (key, query) pair per head-dim unit in the
    /// single-query online-softmax kernel
    pub ns_per_pair_dh: f64,
    /// ns per strided routing sample per head-dim unit (decode OAM)
    pub ns_per_metric_sample_dh: f64,
    /// ns per selection candidate (one bounded-heap offer)
    pub ns_per_select_candidate: f64,
    /// ns per projection/unembedding MAC of the per-step model glue
    pub ns_per_proj_mac: f64,
    /// fraction of linear scaling realized per extra worker thread
    pub parallel_efficiency: f64,
}

/// Current decode-step calibration (re-fit from `BENCH_decode.json`).
/// These price the `tiny` backend's per-step matvec glue; the `engine`
/// backend's module-execution surcharge lives in [`ENGINE_DECODE`].
pub const DECODE_CORE: RustDecodeCalibration = RustDecodeCalibration {
    // single-query online softmax through the lane dot/axpy: ~1.5x the
    // scalar arm's 0.15 on the `simd` decode_attention bench row
    ns_per_pair_dh: 0.10,
    ns_per_metric_sample_dh: 0.18,
    ns_per_select_candidate: 3.0,
    // TinyLm::matvec rows ride the same lane dot
    ns_per_proj_mac: 0.4,
    parallel_efficiency: 0.50,
};

/// Which decode backend an estimate prices
/// (`CoordinatorConfig::decode_backend` → admission).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DecodeCostModel {
    /// In-process reference LM: per-step matvec glue only
    /// ([`DECODE_CORE`]).
    #[default]
    Tiny,
    /// Compiled per-step decode modules: every emitted position
    /// additionally executes one padded-bucket ids→logits forward
    /// ([`engine_module_ns`]).
    Engine,
}

/// Throughput constants of one compiled `decode_step` module execution
/// (the `engine` decode backend). Re-fit from `BENCH_decode.json`'s
/// engine-backend rows: subtract the tiny-backend ns/token at the same
/// context, divide by the padded-bucket MAC count charged below.
#[derive(Debug, Clone, Copy)]
pub struct EngineDecodeCalibration {
    /// ns per model MAC of the compiled forward (projections + MLP over
    /// every padded position)
    pub ns_per_mac: f64,
    /// flat per-execution dispatch overhead (argument staging, runtime
    /// call, logits readback), ns
    pub dispatch_ns: f64,
}

/// Current engine decode calibration (re-fit from `BENCH_decode.json`).
pub const ENGINE_DECODE: EngineDecodeCalibration =
    EngineDecodeCalibration { ns_per_mac: 0.05, dispatch_ns: 50_000.0 };

/// Estimated ns of ONE compiled `decode_step` module execution at a
/// cached context of `n_ctx` tokens: the history is padded to its
/// context bucket (modeled as the next power of two, at least 512 — the
/// smallest bucket `python/compile/aot.py` lowers) and the whole padded
/// sequence runs the model's projections + MLP, so the cost is bucket-
/// shaped, not context-shaped — a 513-token history prices like 1024.
pub fn engine_module_ns(g: &Geometry, n_ctx: usize) -> f64 {
    let padded = n_ctx.max(1).next_power_of_two().max(512) as f64;
    // qkvo projections (4·d_model²) + SwiGLU MLP (3·d_model·d_ff) MACs
    // per position per layer
    let per_tok_macs =
        (4.0 * (g.d_model * g.d_model) as f64 + 3.0 * (g.d_model * g.d_ff) as f64)
            * g.n_layers as f64;
    padded * per_tok_macs * ENGINE_DECODE.ns_per_mac + ENGINE_DECODE.dispatch_ns
}

/// Estimated wall-clock ns for ONE decode step at a cached context of
/// `n_ctx` tokens. `budget_blocks = None` is the dense path (attend
/// everything, no metric/selection); `Some(k)` the Stem-sparse path with
/// a `k`-block budget and routing sampled every `stride` tokens.
pub fn estimate_decode_step_ns(
    g: &Geometry,
    n_ctx: usize,
    budget_blocks: Option<f64>,
    stride: usize,
    threads: usize,
) -> f64 {
    let cal = &DECODE_CORE;
    let heads_layers = (g.n_heads * g.n_layers) as f64;
    let nblk = n_ctx.div_ceil(g.block).max(1) as f64;
    let (attended, metric_samples, candidates) = match budget_blocks {
        None => (n_ctx as f64, 0.0, 0.0),
        Some(k) => {
            let attended = (k * g.block as f64).min(n_ctx as f64);
            let samples = nblk * (g.block as f64 / stride.max(1) as f64).ceil();
            (attended, samples, nblk)
        }
    };
    let attn_ns = attended * g.d_head as f64 * heads_layers * cal.ns_per_pair_dh
        + metric_samples * g.d_head as f64 * heads_layers * cal.ns_per_metric_sample_dh
        + candidates * heads_layers * cal.ns_per_select_candidate;
    let speedup = 1.0 + (threads.max(1) as f64 - 1.0) * cal.parallel_efficiency;
    attn_ns / speedup + decode_proj_ns(g, threads)
}

/// Thread-amortized projection + unembedding cost of one decode step
/// (qkv + output + tied unembed ≈ 4·d_model² MACs per layer): the TinyLm
/// matvec fans output-row chunks over the pool, but at half the
/// attention grid's efficiency — the chunks are fine-grained and the
/// narrow matrices stay serial. Shared by [`estimate_decode_step_ns`]
/// and [`estimate_spec_step_ns`] so a re-fit cannot skew one without the
/// other.
fn decode_proj_ns(g: &Geometry, threads: usize) -> f64 {
    let proj_speedup =
        1.0 + (threads.max(1) as f64 - 1.0) * DECODE_CORE.parallel_efficiency * 0.5;
    4.0 * (g.d_model * g.d_model) as f64 * g.n_layers as f64 * DECODE_CORE.ns_per_proj_mac
        / proj_speedup
}

/// Fraction of a verify row's attention cost charged to each position
/// beyond the first in the batched speculative verify kernel: the rows
/// share one K/V walk (`sparse::sparse_verify_attention` iterates blocks
/// outer, rows inner), so extra positions pay compute but mostly reuse
/// the first row's memory traffic. Re-fit from `BENCH_decode.json`'s
/// `spec` section (round ns vs sequential step ns at the same context).
pub const SPEC_EXTRA_ROW_COST: f64 = 0.45;

/// Draft acceptance rate assumed by admission when budgeting a
/// speculative generation: expected committed tokens per round is
/// `1 + gamma * SPEC_ASSUMED_ACCEPTANCE`. Deliberately conservative —
/// overestimating acceptance would under-charge admission and let the
/// decode lane overcommit. Re-fit from the measured `acceptance_rate` in
/// `BENCH_decode.json`'s `spec` section.
pub const SPEC_ASSUMED_ACCEPTANCE: f64 = 0.6;

/// Estimated wall-clock ns for ONE speculative draft/verify ROUND at a
/// cached context of `n_ctx` tokens: `gamma` cheap draft steps (budget
/// `draft_budget_blocks`, `None` = dense) plus one batched verify of
/// `gamma + 1` positions under the serving policy (budget
/// `serve_budget_blocks`), whose shared K/V walk discounts every row
/// beyond the first by [`SPEC_EXTRA_ROW_COST`]. Divide by the expected
/// commits per round (`1 + gamma ·` [`SPEC_ASSUMED_ACCEPTANCE`]) for a
/// per-token figure.
#[allow(clippy::too_many_arguments)]
pub fn estimate_spec_step_ns(
    g: &Geometry,
    n_ctx: usize,
    gamma: usize,
    draft_budget_blocks: Option<f64>,
    serve_budget_blocks: Option<f64>,
    stride: usize,
    threads: usize,
) -> f64 {
    let gamma = gamma.max(1);
    let draft_ns: f64 = (0..gamma)
        .map(|i| estimate_decode_step_ns(g, n_ctx + i, draft_budget_blocks, stride, threads))
        .sum();
    // first verify row pays full freight; each extra row a discounted
    // attention share (the walk is shared) plus its own thread-amortized
    // unembedding (rows project in parallel)
    let full = estimate_decode_step_ns(g, n_ctx + gamma, serve_budget_blocks, stride, threads);
    let proj_ns = decode_proj_ns(g, threads);
    let attn_ns = (full - proj_ns).max(0.0);
    draft_ns + full + gamma as f64 * (attn_ns * SPEC_EXTRA_ROW_COST + proj_ns)
}

/// Per-backend [`estimate_decode_step_ns`]: the `tiny` model is the base
/// estimate unchanged; the `engine` model adds one compiled module
/// execution ([`engine_module_ns`]) on top of the same kernel + glue
/// work (the attention path and K/V projections run in-process for both
/// backends — only the unembed routes through the module).
pub fn estimate_decode_step_ns_for(
    model: DecodeCostModel,
    g: &Geometry,
    n_ctx: usize,
    budget_blocks: Option<f64>,
    stride: usize,
    threads: usize,
) -> f64 {
    let base = estimate_decode_step_ns(g, n_ctx, budget_blocks, stride, threads);
    match model {
        DecodeCostModel::Tiny => base,
        DecodeCostModel::Engine => base + engine_module_ns(g, n_ctx),
    }
}

/// Per-backend [`estimate_spec_step_ns`]: under the `engine` model a
/// speculative round executes `2γ+1` compiled modules — one per draft
/// step plus one per verify position (each verify position re-runs its
/// own history prefix; the batched kernel shares the K/V walk but the
/// module executions do not batch) — all at the round's deepest context.
#[allow(clippy::too_many_arguments)]
pub fn estimate_spec_step_ns_for(
    model: DecodeCostModel,
    g: &Geometry,
    n_ctx: usize,
    gamma: usize,
    draft_budget_blocks: Option<f64>,
    serve_budget_blocks: Option<f64>,
    stride: usize,
    threads: usize,
) -> f64 {
    let base = estimate_spec_step_ns(
        g,
        n_ctx,
        gamma,
        draft_budget_blocks,
        serve_budget_blocks,
        stride,
        threads,
    );
    match model {
        DecodeCostModel::Tiny => base,
        DecodeCostModel::Engine => {
            let gamma = gamma.max(1);
            base + (2 * gamma + 1) as f64 * engine_module_ns(g, n_ctx + gamma)
        }
    }
}

/// Estimated wall-clock ns of prompt ingest alone (k/v projections per
/// token, no attention): the part of a generation that shared-prefix
/// fan-out pays exactly once per unique prefix, however many
/// continuations fork off it — the coordinator's prefix-aware admission
/// charges it to the first branch only.
pub fn estimate_ingest_ns(g: &Geometry, n_prompt: usize) -> f64 {
    // k/v projections per ingested prompt token: 2·d_model² MACs/layer
    n_prompt as f64
        * 2.0
        * (g.d_model * g.d_model) as f64
        * g.n_layers as f64
        * DECODE_CORE.ns_per_proj_mac
}

/// Estimated wall-clock ns for a whole `submit_generate` request:
/// prompt ingest ([`estimate_ingest_ns`]) plus `max_new` decode steps at
/// the mean context length.
pub fn estimate_generate_ns(
    g: &Geometry,
    n_prompt: usize,
    max_new: usize,
    budget_blocks: Option<f64>,
    stride: usize,
    threads: usize,
) -> f64 {
    let mean_ctx = n_prompt + max_new / 2;
    estimate_ingest_ns(g, n_prompt)
        + max_new as f64 * estimate_decode_step_ns(g, mean_ctx, budget_blocks, stride, threads)
}

/// Per-backend [`estimate_generate_ns`]: ingest (projection-only, the
/// same for both backends) plus `max_new` per-backend decode steps at
/// the mean context.
#[allow(clippy::too_many_arguments)]
pub fn estimate_generate_ns_for(
    model: DecodeCostModel,
    g: &Geometry,
    n_prompt: usize,
    max_new: usize,
    budget_blocks: Option<f64>,
    stride: usize,
    threads: usize,
) -> f64 {
    let mean_ctx = n_prompt + max_new / 2;
    estimate_ingest_ns(g, n_prompt)
        + max_new as f64
            * estimate_decode_step_ns_for(model, g, mean_ctx, budget_blocks, stride, threads)
}

/// Estimated wall-clock ns for one pure-rust reference prefill of length
/// `n` under `m` on `threads` workers — the quantity the coordinator's
/// admission control budgets against (see `coordinator::admission`).
pub fn estimate_core_prefill_ns(
    g: &Geometry,
    n: usize,
    m: MethodCost,
    threads: usize,
) -> f64 {
    let cal = &RUST_CORE;
    let c = method_cost(g, n, m);
    // attn_flops counts 4·dh FLOPs per pair: undo to pairs·dh units
    let pair_dh_units = c.attn_flops / 4.0;
    let nblk = (n / g.block).max(1) as f64;
    // only OAM-ranked selection scans every causal candidate per head per
    // layer; dense skips selection and streaming builds rows in O(nblk)
    let candidates = if matches!(m, MethodCost::Stem { .. }) {
        nblk * (nblk + 1.0) / 2.0 * g.n_heads as f64 * g.n_layers as f64
    } else {
        0.0
    };
    let serial_ns = pair_dh_units * cal.ns_per_pair_dh
        + c.metric_flops * cal.ns_per_metric_flop
        + candidates * cal.ns_per_select_candidate;
    let speedup = 1.0 + (threads.max(1) as f64 - 1.0) * cal.parallel_efficiency;
    serial_ns / speedup
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom() -> Geometry {
        Geometry { n_layers: 32, n_heads: 32, d_head: 128, d_model: 4096, d_ff: 14336, block: 128 }
    }

    #[test]
    fn dense_attention_dominates_at_long_context() {
        let g = geom();
        let c = method_cost(&g, 131072, MethodCost::Dense);
        assert!(c.attn_flops > c.linear_flops, "attention must dominate at 128K");
        let c16 = method_cost(&g, 16384, MethodCost::Dense);
        assert!(c16.attn_flops < c16.linear_flops * 2.0);
    }

    #[test]
    fn stem_cuts_attention_cost() {
        let g = geom();
        let dense = method_cost(&g, 131072, MethodCost::Dense);
        let stem = method_cost(&g, 131072, MethodCost::Stem { k_start_blocks: 102.4, mu: 0.7 });
        assert!(stem.budget_fraction < 0.3, "bud {}", stem.budget_fraction);
        assert!(stem.total_flops < 0.5 * dense.total_flops);
        assert!(stem.metric_flops < 0.1 * stem.attn_flops, "metric must be negligible");
    }

    #[test]
    fn streaming_is_linear() {
        let g = geom();
        let c1 = method_cost(&g, 32768, MethodCost::Streaming { sink_blocks: 4.0, local_blocks: 8.0 });
        let c2 = method_cost(&g, 65536, MethodCost::Streaming { sink_blocks: 4.0, local_blocks: 8.0 });
        let r = c2.attn_flops / c1.attn_flops;
        assert!((r - 2.0).abs() < 0.05, "ratio {r}");
    }

    #[test]
    fn core_estimate_scales_down_with_threads_and_sparsity() {
        let g = geom();
        let stem = MethodCost::Stem { k_start_blocks: 25.6, mu: 0.7 };
        let e1 = estimate_core_prefill_ns(&g, 32768, stem, 1);
        let e8 = estimate_core_prefill_ns(&g, 32768, stem, 8);
        assert!(e1 > 0.0 && e8 > 0.0);
        assert!(e1 / e8 > 4.0, "8 threads must cut the estimate >4x, got {:.2}", e1 / e8);
        let dense = estimate_core_prefill_ns(&g, 32768, MethodCost::Dense, 1);
        assert!(e1 < dense, "stem estimate {e1} must undercut dense {dense}");
    }

    #[test]
    fn decode_step_estimate_sparse_beats_dense_at_long_context() {
        let g = Geometry { n_layers: 1, n_heads: 8, d_head: 32, d_model: 256, d_ff: 1024, block: 64 };
        for &n in &[2048usize, 8192, 65536] {
            let dense = estimate_decode_step_ns(&g, n, None, 8, 4);
            let sparse = estimate_decode_step_ns(&g, n, Some(8.0), 8, 4);
            assert!(
                sparse < dense,
                "sparse step {sparse} must undercut dense {dense} at n={n}"
            );
        }
        // short contexts: selection overhead makes sparse a wash or worse,
        // which is exactly why DecodePolicy::dense_below exists
        let short_dense = estimate_decode_step_ns(&g, 256, None, 8, 4);
        let short_sparse = estimate_decode_step_ns(&g, 256, Some(8.0), 8, 4);
        assert!(short_sparse >= 0.9 * short_dense);
        // more threads cut the attention part
        let t1 = estimate_decode_step_ns(&g, 65536, None, 8, 1);
        let t8 = estimate_decode_step_ns(&g, 65536, None, 8, 8);
        assert!(t1 > t8);
    }

    #[test]
    fn spec_round_estimate_is_conservative_and_bounded() {
        // admission must never *under*-charge a speculative round: the
        // estimate sits between one sequential step and the fully
        // unshared equivalent (γ drafts + γ+1 independent serving steps)
        let g = Geometry { n_layers: 1, n_heads: 8, d_head: 32, d_model: 256, d_ff: 1024, block: 64 };
        let (n, gamma, stride, threads) = (8192usize, 4usize, 8usize, 8usize);
        // dense serving, sparse 8-block draft — the bench_decode scenario
        let round = estimate_spec_step_ns(&g, n, gamma, Some(8.0), None, stride, threads);
        let seq_step = estimate_decode_step_ns(&g, n, None, stride, threads);
        let draft_step = estimate_decode_step_ns(&g, n, Some(8.0), stride, threads);
        assert!(round > seq_step, "a round does strictly more work than one step");
        assert!(
            round < gamma as f64 * draft_step + (gamma + 1) as f64 * seq_step,
            "the shared verify walk must undercut γ+1 independent serving steps"
        );
        // monotone in gamma, and the cheap draft policy matters
        let r2 = estimate_spec_step_ns(&g, n, 2, Some(8.0), None, stride, threads);
        assert!(r2 < round, "fewer drafted positions must cost less");
        let dense_draft = estimate_spec_step_ns(&g, n, gamma, None, None, stride, threads);
        assert!(round < dense_draft, "sparse drafts must undercut dense drafts");
    }

    #[test]
    fn ingest_split_decomposes_generate_estimate() {
        let g = Geometry { n_layers: 1, n_heads: 8, d_head: 32, d_model: 256, d_ff: 1024, block: 64 };
        // the fan-out admission math relies on generate = ingest + decode
        let full = estimate_generate_ns(&g, 2048, 32, Some(8.0), 8, 4);
        let ingest = estimate_ingest_ns(&g, 2048);
        let decode_only = full - ingest;
        assert!(ingest > 0.0 && decode_only > 0.0);
        // exact decomposition: full = ingest + max_new * step(mean_ctx)
        let step = estimate_decode_step_ns(&g, 2048 + 16, Some(8.0), 8, 4);
        assert!((full - (ingest + 32.0 * step)).abs() / full < 1e-9);
        // ingest is linear in the prompt
        assert!((estimate_ingest_ns(&g, 4096) / ingest - 2.0).abs() < 1e-9);
        assert_eq!(estimate_ingest_ns(&g, 0), 0.0);
    }

    #[test]
    fn generate_estimate_monotone() {
        let g = Geometry { n_layers: 1, n_heads: 8, d_head: 32, d_model: 256, d_ff: 1024, block: 64 };
        let e32 = estimate_generate_ns(&g, 2048, 32, Some(8.0), 8, 4);
        let e64 = estimate_generate_ns(&g, 2048, 64, Some(8.0), 8, 4);
        let long_prompt = estimate_generate_ns(&g, 8192, 32, Some(8.0), 8, 4);
        assert!(e64 > e32, "more steps must cost more");
        assert!(long_prompt > e32, "longer prompts must cost more");
        assert!(e32 > 0.0);
    }

    #[test]
    fn engine_cost_model_never_underprices_tiny() {
        // the whole point of the per-backend split: admission under the
        // engine model charges strictly more per step/round than tiny,
        // and the tiny model is byte-identical to the un-suffixed fns
        let g = Geometry { n_layers: 1, n_heads: 8, d_head: 32, d_model: 256, d_ff: 1024, block: 64 };
        for &n in &[256usize, 2048, 8192] {
            let tiny = estimate_decode_step_ns_for(DecodeCostModel::Tiny, &g, n, Some(8.0), 8, 4);
            assert_eq!(tiny, estimate_decode_step_ns(&g, n, Some(8.0), 8, 4));
            let engine =
                estimate_decode_step_ns_for(DecodeCostModel::Engine, &g, n, Some(8.0), 8, 4);
            assert!(
                engine >= tiny + ENGINE_DECODE.dispatch_ns,
                "engine step at n={n} must add at least the dispatch overhead"
            );
        }
        let tiny_round =
            estimate_spec_step_ns_for(DecodeCostModel::Tiny, &g, 2048, 4, Some(8.0), None, 8, 4);
        assert_eq!(tiny_round, estimate_spec_step_ns(&g, 2048, 4, Some(8.0), None, 8, 4));
        let engine_round =
            estimate_spec_step_ns_for(DecodeCostModel::Engine, &g, 2048, 4, Some(8.0), None, 8, 4);
        // γ drafts + γ+1 verify positions each execute one module
        assert!(engine_round >= tiny_round + 9.0 * ENGINE_DECODE.dispatch_ns);
        let tiny_gen =
            estimate_generate_ns_for(DecodeCostModel::Tiny, &g, 2048, 32, Some(8.0), 8, 4);
        assert_eq!(tiny_gen, estimate_generate_ns(&g, 2048, 32, Some(8.0), 8, 4));
        assert!(
            estimate_generate_ns_for(DecodeCostModel::Engine, &g, 2048, 32, Some(8.0), 8, 4)
                > tiny_gen
        );
    }

    #[test]
    fn engine_module_cost_is_bucket_shaped() {
        let g = Geometry { n_layers: 1, n_heads: 8, d_head: 32, d_model: 256, d_ff: 1024, block: 64 };
        // within one padded bucket the charge is flat...
        assert_eq!(engine_module_ns(&g, 513), engine_module_ns(&g, 1024));
        // ...and stepping over a bucket boundary doubles the forward
        assert!(engine_module_ns(&g, 1025) > 1.9 * engine_module_ns(&g, 1024));
        // short histories still pay the smallest lowered bucket (512)
        assert_eq!(engine_module_ns(&g, 1), engine_module_ns(&g, 512));
        assert!(engine_module_ns(&g, 1) > ENGINE_DECODE.dispatch_ns);
    }

    #[test]
    fn budget_fraction_sane() {
        let g = geom();
        for &n in &[16384usize, 65536, 131072] {
            let c = method_cost(&g, n, MethodCost::Stem { k_start_blocks: 0.2 * (n / 128) as f64, mu: 0.7 });
            assert!(c.budget_fraction > 0.0 && c.budget_fraction <= 1.0);
        }
    }
}
