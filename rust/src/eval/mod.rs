//! Evaluation harness: accuracy scoring + the drivers that regenerate
//! every table and figure of the paper (experiment index in DESIGN.md §6).

pub mod runner;
pub mod scoring;
pub mod tables;

pub use runner::{EvalOutcome, Evaluator};
pub use scoring::{score_sample, SampleScore};
