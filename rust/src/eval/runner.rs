//! Generic evaluation runner: fan an eval set through the coordinator
//! under a given method and aggregate scores. All table drivers build on
//! this.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::{Coordinator, Method};
use crate::eval::scoring::{score_sample, Aggregate};
use crate::model::manifest::ServingDefaults;
use crate::util::threadpool;
use crate::workload::{load_eval_set, EvalSample};

/// Aggregated scores of one method over a (family × bucket) grid.
#[derive(Debug, Clone)]
pub struct EvalOutcome {
    /// (family, n_ctx) -> aggregate
    pub cells: BTreeMap<(String, usize), Aggregate>,
    /// The method name the grid was run under.
    pub method_label: String,
}

impl EvalOutcome {
    /// Aggregate over every bucket of one family.
    pub fn family_avg(&self, family: &str) -> Aggregate {
        let mut a = Aggregate::default();
        for ((f, _), agg) in &self.cells {
            if f == family {
                a.merge(agg);
            }
        }
        a
    }

    /// Aggregate over every family of one bucket.
    pub fn bucket_avg(&self, n_ctx: usize) -> Aggregate {
        let mut a = Aggregate::default();
        for ((_, n), agg) in &self.cells {
            if *n == n_ctx {
                a.merge(agg);
            }
        }
        a
    }

    /// Aggregate over the whole grid.
    pub fn overall(&self) -> Aggregate {
        let mut a = Aggregate::default();
        for agg in self.cells.values() {
            a.merge(agg);
        }
        a
    }
}

/// Runs eval sets through a live coordinator (see module docs).
pub struct Evaluator {
    /// The coordinator requests are fanned into.
    pub coordinator: Arc<Coordinator>,
    /// limit samples per set (fast mode); 0 = all
    pub limit: usize,
}

impl Evaluator {
    /// Method instance for `name` at a bucket's serving defaults.
    /// `uniform` and `tpd` are the Table-5 ablation arms (budget-matched).
    pub fn method_for(name: &str, d: &ServingDefaults) -> Method {
        match name {
            "dense" => Method::Dense,
            "stem" => Method::Stem {
                k_start: d.k_start as f32,
                mu: d.mu as f32,
                beta: d.beta as f32,
            },
            "uniform" => Method::Stem {
                k_start: d.k_uni_matched as f32,
                mu: 1.0,
                beta: 0.0,
            },
            "tpd" => Method::Stem {
                k_start: d.k_start as f32,
                mu: d.mu as f32,
                beta: 0.0,
            },
            "streaming" => Method::Streaming {
                sink: d.sink_blocks as i32,
                local: d.local_blocks as i32,
            },
            "xattn" => Method::XAttn { tau: d.xattn_tau as f32 },
            "minference" => Method::MInference {
                vertical: d.minf_vertical as i32,
                slash: d.minf_slash as i32,
            },
            "flexprefill" => Method::FlexPrefill {
                gamma: d.flex_gamma as f32,
                entropy: d.flex_entropy as f32,
            },
            other => panic!("unknown method name `{other}`"),
        }
    }

    fn samples_for(&self, suite: &str, family: &str, n_ctx: usize) -> Result<Vec<EvalSample>> {
        let man = self.coordinator.manifest();
        let info = man
            .eval_sets
            .iter()
            .find(|e| e.suite == suite && e.family == family && e.n_ctx == n_ctx)
            .ok_or_else(|| anyhow::anyhow!("no eval set {suite}/{family}/{n_ctx}"))?;
        let mut samples = load_eval_set(&man.root.join(&info.file))?;
        if self.limit > 0 {
            samples.truncate(self.limit);
        }
        Ok(samples)
    }

    /// Evaluate `method_name` (or an explicit Method) over a suite grid.
    pub fn run(
        &self,
        checkpoint: &str,
        method_name: &str,
        explicit: Option<Method>,
        suite: &str,
        families: &[&str],
        buckets: &[usize],
    ) -> Result<EvalOutcome> {
        let man = self.coordinator.manifest();
        let mut cells = BTreeMap::new();
        for &n_ctx in buckets {
            let defaults = man.defaults_for(n_ctx)?.clone();
            let method = explicit.unwrap_or_else(|| Self::method_for(method_name, &defaults));
            for family in families {
                let samples = self.samples_for(suite, family, n_ctx)?;
                let mut agg = Aggregate::default();
                // fan the whole set into the coordinator, then collect —
                // this exercises batching rather than serializing requests
                let rxs: Vec<_> = samples
                    .iter()
                    .map(|s| {
                        self.coordinator.submit(checkpoint, method, s.ids.clone(), false)
                    })
                    .collect::<Result<Vec<_>>>()?;
                let resps: Vec<_> = rxs
                    .into_iter()
                    .map(|rx| rx.recv()?)
                    .collect::<Result<Vec<_>>>()?;
                // teacher-forced scoring is per-sample independent: fan it
                // over the sparse-core pool
                let scores = threadpool::scope_parallel_borrowed(
                    threadpool::global(),
                    resps.len(),
                    |i| score_sample(&resps[i], &samples[i]),
                );
                for s in scores {
                    agg.add(s);
                }
                cells.insert((family.to_string(), n_ctx), agg);
            }
        }
        Ok(EvalOutcome { cells, method_label: method_name.to_string() })
    }
}
