//! Teacher-forced scoring (DESIGN.md §4: one prefill pass scores a
//! sample — logits at position p-1 predict token p, so the answer span is
//! judged by argmax exact match, the decode-free analogue of the greedy
//! generation used by lm-evaluation-harness on these short-answer tasks).

use crate::coordinator::PrefillResponse;
use crate::workload::EvalSample;

/// Per-sample teacher-forced scores.
#[derive(Debug, Clone, Copy)]
pub struct SampleScore {
    /// every answer token predicted correctly
    pub exact_match: bool,
    /// fraction of answer tokens predicted correctly
    pub token_acc: f64,
    /// Budget fraction the serving response reported.
    pub budget_fraction: f64,
}

/// Score one prefill response against its sample's answer span.
pub fn score_sample(resp: &PrefillResponse, sample: &EvalSample) -> SampleScore {
    let ans = sample.answer_tokens();
    let mut correct = 0usize;
    for (i, &tok) in ans.iter().enumerate() {
        let pos = sample.answer_start + i - 1; // logits[p-1] predict p
        if resp.argmax_at(pos) == tok {
            correct += 1;
        }
    }
    SampleScore {
        exact_match: correct == ans.len(),
        token_acc: correct as f64 / ans.len().max(1) as f64,
        budget_fraction: resp.budget_fraction as f64,
    }
}

/// Aggregate of many sample scores.
#[derive(Debug, Clone, Copy, Default)]
pub struct Aggregate {
    /// Samples aggregated.
    pub n: usize,
    /// Summed exact-match indicators.
    pub em_sum: f64,
    /// Summed token accuracies.
    pub tok_sum: f64,
    /// Summed budget fractions.
    pub budget_sum: f64,
}

impl Aggregate {
    /// Fold one sample score in.
    pub fn add(&mut self, s: SampleScore) {
        self.n += 1;
        self.em_sum += s.exact_match as u8 as f64;
        self.tok_sum += s.token_acc;
        self.budget_sum += s.budget_fraction;
    }

    /// Exact-match percentage.
    pub fn em(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            100.0 * self.em_sum / self.n as f64
        }
    }

    /// Mean token accuracy, in percent.
    pub fn token_acc(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            100.0 * self.tok_sum / self.n as f64
        }
    }

    /// Mean budget fraction.
    pub fn budget(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.budget_sum / self.n as f64
        }
    }

    /// Fold another aggregate in.
    pub fn merge(&mut self, other: &Aggregate) {
        self.n += other.n;
        self.em_sum += other.em_sum;
        self.tok_sum += other.tok_sum;
        self.budget_sum += other.budget_sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(logits: Vec<f32>, vocab: usize) -> PrefillResponse {
        let n = logits.len() / vocab;
        PrefillResponse {
            id: 0,
            logits,
            vocab,
            n_ctx: n,
            n_input: n,
            budget_fraction: 0.5,
            hidden: None,
            queue_us: 0,
            exec_us: 0,
        }
    }

    #[test]
    fn scores_exact_match() {
        // vocab 4, seq: [_, _, answer=2, answer=3] starting at 2
        // logits at pos1 must argmax 2; at pos2 must argmax 3
        let mut logits = vec![0.0; 4 * 4];
        logits[1 * 4 + 2] = 5.0;
        logits[2 * 4 + 3] = 5.0;
        let sample =
            EvalSample { ids: vec![1, 0, 2, 3], answer_start: 2, answer_len: 2 };
        let s = score_sample(&resp(logits, 4), &sample);
        assert!(s.exact_match);
        assert_eq!(s.token_acc, 1.0);
    }

    #[test]
    fn partial_credit() {
        let mut logits = vec![0.0; 4 * 4];
        logits[1 * 4 + 2] = 5.0; // right
        logits[2 * 4 + 1] = 5.0; // wrong (want 3)
        let sample =
            EvalSample { ids: vec![1, 0, 2, 3], answer_start: 2, answer_len: 2 };
        let s = score_sample(&resp(logits, 4), &sample);
        assert!(!s.exact_match);
        assert_eq!(s.token_acc, 0.5);
    }

    #[test]
    fn aggregate_math() {
        let mut a = Aggregate::default();
        a.add(SampleScore { exact_match: true, token_acc: 1.0, budget_fraction: 0.2 });
        a.add(SampleScore { exact_match: false, token_acc: 0.5, budget_fraction: 0.4 });
        assert_eq!(a.em(), 50.0);
        assert_eq!(a.token_acc(), 75.0);
        assert!((a.budget() - 0.3).abs() < 1e-12);
    }
}
