//! Drivers that regenerate every table and figure of the paper
//! (experiment index: DESIGN.md §6). Each `tableN`/`figureN` function
//! returns the rendered ASCII table so the CLI, the examples and the
//! integration tests all share one implementation.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::coordinator::{Coordinator, Method};
use crate::eval::runner::Evaluator;
use crate::eval::scoring::Aggregate;
use crate::sim::{project_figure1, LLAMA31_8B};
use crate::util::render_table;
use crate::workload::load_eval_set;

/// LongBench proxy families (mirror of python `tasks.FAMILIES`).
pub const FAMILIES: [&str; 7] = ["cc", "cp", "fsl", "md1", "md2", "sum", "syn"];
/// RULER proxy tasks (mirror of python `tasks.RULER_TASKS`).
pub const RULER_TASKS: [&str; 4] = ["needle", "multikey", "vt", "cp"];
/// Table 2/4 method roster, paper order.
pub const METHODS: [&str; 5] = ["dense", "minference", "flexprefill", "xattn", "stem"];

fn pct(v: f64) -> String {
    format!("{v:.2}")
}

fn bud_pct(v: f64) -> String {
    format!("{:.0}%", 100.0 * v)
}

// ---------------------------------------------------------------------------
// Table 1 — SAM vs OAM sparse loss at depths + head logits
// ---------------------------------------------------------------------------

/// Per-layer hidden-state MSE + head-logit MSE of a sparse run against the
/// dense run on the same inputs (diag modules expose `hidden [L, N, d]`).
pub struct DiagLoss {
    /// MSE per layer, length n_layers.
    pub layer_mse: Vec<f64>,
    /// MSE of the final logits against the dense run.
    pub logit_mse: f64,
    /// Budget fraction the sparse run reported.
    pub budget_fraction: f64,
}

fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut s = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        let d = (*x - *y) as f64;
        s += d * d;
    }
    s / a.len() as f64
}

/// Run `method` and dense on the same ids through the diag graphs and
/// compare representations (Table 1 / Figure 3 primitive).
pub fn diag_loss(
    coord: &Coordinator,
    checkpoint: &str,
    method: Method,
    ids: &[i32],
) -> Result<DiagLoss> {
    let dense = coord.prefill_blocking(checkpoint, Method::Dense, ids.to_vec(), true)?;
    let sparse = coord.prefill_blocking(checkpoint, method, ids.to_vec(), true)?;
    let man = coord.manifest();
    let (l, n, d) = (man.model.n_layers, dense.n_ctx, man.model.d_model);
    let dh = dense.hidden.as_ref().ok_or_else(|| anyhow!("dense diag returned no hidden"))?;
    let sh = sparse.hidden.as_ref().ok_or_else(|| anyhow!("sparse diag returned no hidden"))?;
    let mut layer_mse = Vec::with_capacity(l);
    for li in 0..l {
        let a = &dh[li * n * d..(li + 1) * n * d];
        let b = &sh[li * n * d..(li + 1) * n * d];
        layer_mse.push(mse(a, b));
    }
    Ok(DiagLoss {
        layer_mse,
        logit_mse: mse(&dense.logits, &sparse.logits),
        budget_fraction: sparse.budget_fraction as f64,
    })
}

/// Table 1: SAM (β=0) vs OAM (β=0.2) reconstruction error at several
/// depths plus the head-logit loss, averaged over `limit` samples of the
/// `syn` family at the largest diag bucket.
pub fn table1(coord: &Arc<Coordinator>, limit: usize) -> Result<String> {
    let man = coord.manifest();
    let n_ctx = man
        .modules
        .iter()
        .filter(|m| m.kind == "diag_stem")
        .map(|m| m.n_ctx)
        .max()
        .ok_or_else(|| anyhow!("no diag_stem module"))?;
    let d = man.defaults_for(n_ctx)?.clone();
    let set = man
        .eval_sets
        .iter()
        .find(|e| e.family == "syn" && e.n_ctx == n_ctx)
        .ok_or_else(|| anyhow!("no syn eval set at {n_ctx}"))?;
    let mut samples = load_eval_set(&man.root.join(&set.file))?;
    samples.truncate(limit.max(1));

    let n_layers = man.model.n_layers;
    let d_model = man.model.d_model;
    // paper reports L5/L15/L25/L35 of 36; scale to our depth: quartiles.
    let depths: Vec<usize> =
        (1..=4).map(|q| (q * n_layers / 4).saturating_sub(1)).collect();

    // one dense reference per sample, shared by both arms
    let arms = [("SAM", 0.0f32), ("OAM", d.beta as f32)];
    let mut acc = vec![vec![0.0f64; n_layers]; arms.len()];
    let mut logit = vec![0.0f64; arms.len()];
    for s in &samples {
        let mut ids = s.ids.clone();
        ids.resize(n_ctx, crate::model::vocab::PAD);
        let dense = coord.prefill_blocking("base", Method::Dense, ids.clone(), true)?;
        let dh = dense.hidden.as_ref().ok_or_else(|| anyhow!("no hidden"))?;
        for (ai, (_, beta)) in arms.iter().enumerate() {
            let method =
                Method::Stem { k_start: d.k_start as f32, mu: d.mu as f32, beta: *beta };
            let sparse = coord.prefill_blocking("base", method, ids.clone(), true)?;
            let sh = sparse.hidden.as_ref().unwrap();
            for li in 0..n_layers {
                let span = li * n_ctx * d_model..(li + 1) * n_ctx * d_model;
                acc[ai][li] += mse(&dh[span.clone()], &sh[span]);
            }
            logit[ai] += mse(&dense.logits, &sparse.logits);
        }
    }
    let k = samples.len() as f64;
    let mut rows = vec![];
    for (ai, (label, _)) in arms.iter().enumerate() {
        let mut row = vec![label.to_string()];
        for &di in &depths {
            row.push(format!("{:.2e}", acc[ai][di] / k));
        }
        row.push(format!("{:.4}", logit[ai] / k));
        rows.push(row);
    }
    let mut header = vec!["Method".to_string()];
    header.extend(depths.iter().map(|d| format!("L{}", d + 1)));
    header.push("Head Logits".to_string());
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    Ok(render_table(
        &format!("Table 1 — SAM vs OAM sparse loss (n_ctx={n_ctx}, {} samples)", samples.len()),
        &header_refs,
        &rows,
    ))
}

// ---------------------------------------------------------------------------
// Tables 2/4 — LongBench / RULER accuracy × method × budget
// ---------------------------------------------------------------------------

fn accuracy_table(
    ev: &Evaluator,
    checkpoint: &str,
    suite: &str,
    title: &str,
    families: &[&str],
    buckets: &[usize],
    by_family: bool,
) -> Result<String> {
    let mut rows = vec![];
    for m in METHODS {
        let out = ev.run(checkpoint, m, None, suite, families, buckets)?;
        let mut row = vec![m.to_uppercase()];
        let mut cols: Vec<Aggregate> = vec![];
        if by_family {
            cols.extend(families.iter().map(|f| out.family_avg(f)));
        } else {
            cols.extend(buckets.iter().map(|&b| out.bucket_avg(b)));
        }
        for a in &cols {
            row.push(pct(a.token_acc()));
        }
        let all = out.overall();
        row.push(pct(all.token_acc()));
        row.push(bud_pct(if m == "dense" { 1.0 } else { all.budget() }));
        rows.push(row);
    }
    let mut header = vec!["METHOD".to_string()];
    if by_family {
        header.extend(families.iter().map(|f| f.to_uppercase()));
    } else {
        header.extend(buckets.iter().map(|b| b.to_string()));
    }
    header.push("AVG".into());
    header.push("BUD".into());
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    Ok(render_table(title, &header_refs, &rows))
}

/// Table 2: LongBench-proxy accuracy per family, all methods.
pub fn table2(ev: &Evaluator, buckets: &[usize]) -> Result<String> {
    accuracy_table(
        ev,
        "base",
        "longbench",
        "Table 2 — LongBench proxy accuracy (%)",
        &FAMILIES,
        buckets,
        true,
    )
}

/// Table 4: RULER-proxy accuracy per context length, all methods.
pub fn table4(ev: &Evaluator, buckets: &[usize]) -> Result<String> {
    accuracy_table(
        ev,
        "base",
        "ruler",
        "Table 4 — RULER proxy accuracy (%) by context length",
        &RULER_TASKS,
        buckets,
        false,
    )
}

// ---------------------------------------------------------------------------
// Table 3 — Stem plugged into the training-based sparse model
// ---------------------------------------------------------------------------

/// Table 3: the `native` checkpoint (trained WITH uniform block-top-k,
/// the DSA/InfLLMv2 stand-in) evaluated under its native uniform budget
/// vs native + Stem (decay schedule + OAM on the same k_start).
pub fn table3(ev: &Evaluator, buckets: &[usize], native_k: f32) -> Result<String> {
    let arms: [(&str, Method); 2] = [
        ("NATIVE-TOPK", Method::Stem { k_start: native_k, mu: 1.0, beta: 0.0 }),
        ("+ STEM", Method::Stem { k_start: native_k, mu: 0.7, beta: 0.2 }),
    ];
    let mut rows = vec![];
    let mut budgets = vec![];
    for (label, m) in arms {
        let out =
            ev.run("native", label, Some(m), "longbench", &FAMILIES, buckets)?;
        let mut row = vec![label.to_string()];
        for f in FAMILIES {
            row.push(pct(out.family_avg(f).token_acc()));
        }
        let all = out.overall();
        row.push(pct(all.token_acc()));
        row.push(bud_pct(all.budget()));
        budgets.push(all.budget());
        rows.push(row);
    }
    let reduction = 100.0 * (1.0 - budgets[1] / budgets[0].max(1e-9));
    let mut header = vec!["METHOD".to_string()];
    header.extend(FAMILIES.iter().map(|f| f.to_uppercase()));
    header.push("AVG".into());
    header.push("BUD".into());
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut t = render_table(
        "Table 3 — Stem on the training-based sparse checkpoint",
        &header_refs,
        &rows,
    );
    t.push_str(&format!("budget reduction from Stem: {reduction:.0}% (paper: 15–18%)\n"));
    Ok(t)
}

// ---------------------------------------------------------------------------
// Table 5 — ablation: Uniform / +TPD / +OAM at matched budget
// ---------------------------------------------------------------------------

/// Table 5: budget-matched ablation. `uniform` uses k_uni = k_start(1+μ)/2
/// with β=0; `tpd` adds the decay schedule; `stem` adds OAM.
pub fn table5(ev: &Evaluator, buckets: &[usize]) -> Result<String> {
    let arms = [("UNIFORM", "uniform"), ("+ TPD", "tpd"), ("+ OAM (STEM)", "stem")];
    let mut rows = vec![];
    for (label, name) in arms {
        let out = ev.run("base", name, None, "longbench", &FAMILIES, buckets)?;
        let mut row = vec![label.to_string()];
        for f in FAMILIES {
            row.push(pct(out.family_avg(f).token_acc()));
        }
        let all = out.overall();
        row.push(pct(all.token_acc()));
        row.push(bud_pct(all.budget()));
        rows.push(row);
    }
    let mut header = vec!["ARM".to_string()];
    header.extend(FAMILIES.iter().map(|f| f.to_uppercase()));
    header.push("AVG".into());
    header.push("BUD".into());
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    Ok(render_table("Table 5 — ablation at matched budget", &header_refs, &rows))
}

// ---------------------------------------------------------------------------
// Table 6 — decode backends: real-model decode latency rows
// ---------------------------------------------------------------------------

/// Table 6: per-backend decode rows. For each decode backend buildable
/// over the coordinator's serving backend — the TinyLm projection core,
/// and compiled `decode_step` modules when the artifacts carry them —
/// decode one synthetic prompt sequentially and speculatively (γ=4)
/// through a paged session and report µs/token, spec speedup and
/// acceptance, with the spec stream checked byte-exact against the
/// sequential stream (the STREAM column). An artifact set predating the
/// decode lowering renders an `unavailable` engine row instead of
/// failing the whole report.
pub fn decode_table(coord: &Arc<Coordinator>, max_new: usize) -> Result<String> {
    use std::time::Instant;

    use crate::coordinator::kv_cache::KvConfig;
    use crate::decode::{DecodeBackendKind, DecodePolicy, DecodeSession, SharedKv};
    use crate::model::vocab;
    use crate::util::rng::Rng;

    let block = coord.manifest().model.block.max(1);
    let n0 = 256usize;
    let max_new = max_new.max(4);
    let mut rows = vec![];
    for kind in [DecodeBackendKind::Tiny, DecodeBackendKind::Engine] {
        let model = match kind.build(coord.prefill_backend()) {
            Ok(m) => m,
            Err(e) => {
                rows.push(vec![
                    kind.label().to_string(),
                    format!("unavailable ({e:#})"),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
                continue;
            }
        };
        let run = |gamma: usize| -> Result<(Vec<i32>, f64, f64)> {
            let kv = SharedKv::new(
                KvConfig { total_pages: 4096, page_tokens: block },
                model.kv_heads(),
                model.head_dim(),
            );
            let policy = DecodePolicy { spec_gamma: gamma, ..DecodePolicy::default() };
            let mut s = DecodeSession::new(kv, Arc::clone(&model), policy, 1)?;
            let mut r = Rng::new(17);
            let prompt: Vec<i32> =
                (0..n0).map(|_| vocab::WORD0 + r.below(64) as i32).collect();
            s.prefill(&prompt)?;
            let t = Instant::now();
            let st = s.generate(max_new, None, |_| true)?;
            let wall = t.elapsed().as_nanos() as f64;
            Ok((st.tokens, wall / st.steps.max(1) as f64, st.spec.acceptance_rate()))
        };
        let (seq_tokens, seq_ns, _) = run(0)?;
        let (spec_tokens, spec_ns, acc) = run(4)?;
        rows.push(vec![
            kind.label().to_string(),
            format!("{:.1}", seq_ns / 1e3),
            format!("{:.1}", spec_ns / 1e3),
            format!("{:.2}x", seq_ns / spec_ns.max(1e-9)),
            format!("{:.0}%", 100.0 * acc),
            if seq_tokens == spec_tokens { "spec==seq".into() } else { "DIVERGED".into() },
        ]);
    }
    Ok(render_table(
        &format!("Table 6 — decode backends (µs/token, {max_new} new tokens, spec γ=4)"),
        &["BACKEND", "SEQ µs/TOK", "SPEC µs/TOK", "SPEC SPEEDUP", "ACCEPT", "STREAM"],
        &rows,
    ))
}

// ---------------------------------------------------------------------------
// Figure 1 — latency vs context length (analytic H20 projection half)
// ---------------------------------------------------------------------------

/// Figure 1, analytic half: project the Eq. (2)/(4)/(8) cost model onto
/// H20 + Llama-3.1-8B geometry at the paper's lengths. The measured half
/// is `benches/bench_prefill.rs` on this repo's artifacts.
pub fn figure1() -> String {
    let lengths = [16384usize, 32768, 65536, 131072];
    let pts = project_figure1(&lengths);
    let mut rows = vec![];
    for m in ["dense", "minference", "flexprefill", "xattn", "stem"] {
        let mut row = vec![m.to_uppercase()];
        for &n in &lengths {
            let p = pts.iter().find(|p| p.method == m && p.n_ctx == n).unwrap();
            row.push(format!("{:.0}/{:.0}", p.kernel_ms, p.total_ms));
        }
        rows.push(row);
    }
    let mut header = vec!["METHOD".to_string()];
    header.extend(lengths.iter().map(|n| format!("{}K", n / 1024)));
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut t = render_table(
        &format!(
            "Figure 1 — projected H20 latency ms (kernel/total), {} geometry",
            "Llama-3.1-8B"
        ),
        &header_refs,
        &rows,
    );
    let d = pts.iter().find(|p| p.method == "dense" && p.n_ctx == 131072).unwrap();
    let s = pts.iter().find(|p| p.method == "stem" && p.n_ctx == 131072).unwrap();
    t.push_str(&format!(
        "128K speedup dense/stem: {:.1}x (paper: 1540ms -> 420ms, 3.7x)\n",
        d.total_ms / s.total_ms
    ));
    let _ = &LLAMA31_8B;
    t
}

// ---------------------------------------------------------------------------
// Figure 3 — positional sensitivity of sparsification
// ---------------------------------------------------------------------------

/// Figure 3: sparsify one query-block segment at a time (fixed budget and
/// dynamic ratio arms) and report head-logit MSE vs the segment position.
pub fn figure3(coord: &Arc<Coordinator>, limit: usize) -> Result<String> {
    let man = coord.manifest();
    let n_ctx = man
        .modules
        .iter()
        .filter(|m| m.kind == "diag_segment")
        .map(|m| m.n_ctx)
        .max()
        .ok_or_else(|| anyhow!("no diag_segment module"))?;
    let block = man.model.block;
    let nblk = n_ctx / block;
    let set = man
        .eval_sets
        .iter()
        .find(|e| e.family == "syn" && e.n_ctx == n_ctx)
        .ok_or_else(|| anyhow!("no syn eval set at {n_ctx}"))?;
    let mut samples = load_eval_set(&man.root.join(&set.file))?;
    samples.truncate(limit.max(1));

    // 4 equal segments of the block range, like the paper's [0,2k)..[6k,8k)
    let seg_w = nblk / 4;
    let arms: Vec<(String, i32, f32)> = vec![
        ("fixed k=2".into(), 2, 0.0),
        ("fixed k=4".into(), 4, 0.0),
        ("dynamic 15%".into(), 0, 0.15),
        ("dynamic 30%".into(), 0, 0.30),
    ];
    // one dense diag per sample, shared by all (arm, segment) cells
    let mut dense_logits = vec![];
    let mut padded = vec![];
    for s in &samples {
        let mut ids = s.ids.clone();
        ids.resize(n_ctx, crate::model::vocab::PAD);
        let dense = coord.prefill_blocking("base", Method::Dense, ids.clone(), true)?;
        dense_logits.push(dense.logits);
        padded.push(ids);
    }
    let mut rows = vec![];
    for (label, k_seg, ratio) in arms {
        let mut row = vec![label.clone()];
        for seg in 0..4 {
            let lo = (seg * seg_w) as i32;
            let hi = ((seg + 1) * seg_w) as i32;
            let mut acc = 0.0f64;
            for (ids, dl) in padded.iter().zip(&dense_logits) {
                let sparse = coord.prefill_blocking(
                    "base",
                    Method::Segment { lo, hi, k_seg, ratio },
                    ids.clone(),
                    true,
                )?;
                acc += mse(dl, &sparse.logits);
            }
            row.push(format!("{:.4}", acc / samples.len() as f64));
        }
        rows.push(row);
    }
    let mut header = vec!["ARM".to_string()];
    for seg in 0..4usize {
        header.push(format!(
            "[{},{})",
            seg * seg_w * block,
            (seg + 1) * seg_w * block
        ));
    }
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    Ok(render_table(
        &format!("Figure 3 — head-logit MSE by sparsified segment (n_ctx={n_ctx})"),
        &header_refs,
        &rows,
    ))
}

// ---------------------------------------------------------------------------
// Figure 5 — μ and β sweeps
// ---------------------------------------------------------------------------

/// Figure 5: accuracy as a function of μ (decay ratio) and β (magnitude
/// coefficient) on the LongBench proxy at matched k_start; plus the
/// sparse-vs-dense head-logit MSE sweeps at the largest diag bucket,
/// where the schedule has dynamic range (at tiny block grids the forced
/// sink/local floors clamp every μ to the same budget — the small-scale
/// analogue of the paper's 54-block minimum).
pub fn figure5(ev: &Evaluator, buckets: &[usize]) -> Result<String> {
    let man = ev.coordinator.manifest();
    let mut out = String::new();

    // μ sweep (β fixed at default)
    let mus = [0.5f32, 0.6, 0.7, 0.8, 0.9, 1.0];
    let mut rows = vec![];
    for &mu in &mus {
        let mut row = vec![format!("mu={mu:.1}")];
        let mut merged = Aggregate::default();
        for &b in buckets {
            let d = man.defaults_for(b)?.clone();
            let m = Method::Stem { k_start: d.k_start as f32, mu, beta: d.beta as f32 };
            let o = ev.run("base", "stem", Some(m), "longbench", &FAMILIES, &[b])?;
            merged.merge(&o.overall());
        }
        row.push(pct(merged.token_acc()));
        row.push(bud_pct(merged.budget()));
        rows.push(row);
    }
    out.push_str(&render_table(
        "Figure 5 (left) — decay ratio μ sweep",
        &["ARM", "ACC", "BUD"],
        &rows,
    ));

    // β sweep (μ fixed at default)
    let betas = [0.0f32, 0.1, 0.2, 0.3, 0.4, 0.5];
    let mut rows = vec![];
    for &beta in &betas {
        let mut row = vec![format!("beta={beta:.1}")];
        let mut merged = Aggregate::default();
        for &b in buckets {
            let d = man.defaults_for(b)?.clone();
            let m = Method::Stem { k_start: d.k_start as f32, mu: d.mu as f32, beta };
            let o = ev.run("base", "stem", Some(m), "longbench", &FAMILIES, &[b])?;
            merged.merge(&o.overall());
        }
        row.push(pct(merged.token_acc()));
        row.push(bud_pct(merged.budget()));
        rows.push(row);
    }
    out.push_str(&render_table(
        "Figure 5 (right) — magnitude coefficient β sweep",
        &["ARM", "ACC", "BUD"],
        &rows,
    ));

    // MSE sweeps at the largest diag bucket (schedule has range there)
    let coord = &ev.coordinator;
    if let Some(n_ctx) =
        man.modules.iter().filter(|m| m.kind == "diag_stem").map(|m| m.n_ctx).max()
    {
        let d = man.defaults_for(n_ctx)?.clone();
        let set = man
            .eval_sets
            .iter()
            .find(|e| e.family == "cp" && e.n_ctx == n_ctx)
            .or_else(|| man.eval_sets.iter().find(|e| e.n_ctx == n_ctx))
            .ok_or_else(|| anyhow!("no eval set at {n_ctx}"))?;
        let mut samples = load_eval_set(&man.root.join(&set.file))?;
        samples.truncate(ev.limit.max(1).min(6));
        let mut dense_logits = vec![];
        let mut padded = vec![];
        for s in &samples {
            let mut ids = s.ids.clone();
            ids.resize(n_ctx, crate::model::vocab::PAD);
            let dense = coord.prefill_blocking("base", Method::Dense, ids.clone(), true)?;
            dense_logits.push(dense.logits);
            padded.push(ids);
        }
        let sweep = |label: &str, ms: Vec<(String, Method)>| -> Result<String> {
            let mut rows = vec![];
            for (arm, m) in ms {
                let mut acc = 0.0f64;
                let mut bud = 0.0f64;
                for (ids, dl) in padded.iter().zip(&dense_logits) {
                    let sp = coord.prefill_blocking("base", m, ids.clone(), true)?;
                    acc += mse(dl, &sp.logits);
                    bud += sp.budget_fraction as f64;
                }
                let k = samples.len() as f64;
                rows.push(vec![
                    arm,
                    format!("{:.4}", acc / k),
                    bud_pct(bud / k),
                ]);
            }
            Ok(render_table(label, &["ARM", "LOGIT MSE", "BUD"], &rows))
        };
        let mus: Vec<(String, Method)> = [0.5f32, 0.6, 0.7, 0.8, 0.9, 1.0]
            .iter()
            .map(|&mu| {
                (format!("mu={mu:.1}"),
                 Method::Stem { k_start: d.k_start as f32, mu, beta: d.beta as f32 })
            })
            .collect();
        out.push_str(&sweep(
            &format!("Figure 5 (left, MSE@{n_ctx}) — μ sweep vs dense"),
            mus,
        )?);
        let betas: Vec<(String, Method)> = [0.0f32, 0.1, 0.2, 0.3, 0.4, 0.5]
            .iter()
            .map(|&beta| {
                (format!("beta={beta:.1}"),
                 Method::Stem { k_start: d.k_start as f32, mu: d.mu as f32, beta })
            })
            .collect();
        out.push_str(&sweep(
            &format!("Figure 5 (right, MSE@{n_ctx}) — β sweep vs dense"),
            betas,
        )?);
    }
    Ok(out)
}
