//! # Stem — causal-information-flow-aligned sparse attention, reproduced.
//!
//! Rust coordinator (L3) for the three-layer Stem reproduction:
//!
//! * **L1** — Pallas block-sparse / metric kernels (`python/compile/kernels`,
//!   build-time, checked against pure-jnp oracles).
//! * **L2** — JAX transformer with pluggable attention methods
//!   (`python/compile/model.py`), lowered once to HLO text.
//! * **L3** — this crate: PJRT runtime, serving coordinator (router,
//!   dynamic batcher, paged KV pool, admission control), the pure-rust
//!   reference implementation of the Stem pipeline, the analytic cost
//!   model / H20 projection, and the eval harness that regenerates every
//!   table and figure of the paper.
//!
//! Python never runs on the request path: `make artifacts` lowers every
//! (method, bucket) prefill graph to `artifacts/modules/*.hlo.txt`, and the
//! [`runtime::Engine`] compiles and executes them natively via PJRT-CPU.
//!
//! Entry points:
//! * [`runtime::Engine`] — load artifacts, execute prefill graphs.
//! * [`coordinator::Coordinator`] — the serving runtime (prefill
//!   requests and decode generations over the paged KV pool).
//! * [`decode`] — autoregressive decode subsystem: per-step sparsity
//!   policy, single-query sparse attention steps, paged KV sessions.
//! * [`sparse`] — pure-rust Stem (TPD schedule + OAM selection + block
//!   sparse attention + single-query decode kernels) used by tests, the
//!   simulator and the scheduler.
//! * [`eval`] — accuracy harness + paper table/figure drivers.
//! * [`sim`] — Eq. (2)/(4)/(8) cost model and H20 latency projection.
//! * [`obs`] — observability: flight-recorder tracing, structured metrics
//!   snapshots (JSON + Prometheus), per-band sparsity telemetry.
//!
//! The serving-stack architecture (dataflow, KV ownership, the page
//! refcount/CoW lifecycle) is documented in `docs/ARCHITECTURE.md`.

#![warn(missing_docs)]

pub mod coordinator;
pub mod decode;
pub mod eval;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod sim;
pub mod sparse;
pub mod util;
pub mod workload;

use std::path::PathBuf;

/// Locate the artifacts directory: `$STEM_ARTIFACTS` or `./artifacts`
/// relative to the current dir, walking up to the workspace root.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("STEM_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}
