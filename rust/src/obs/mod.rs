//! Observability subsystem: flight-recorder tracing, structured metrics
//! export, and sparsity telemetry.
//!
//! Three layers, threaded through the whole serving stack:
//!
//! * [`trace`] — a lock-free ring-buffer **flight recorder** of typed
//!   trace events (submit, shed, batch, exec, fork, prefix routing,
//!   decode steps, speculative rounds, degradation transitions,
//!   deadline/cancel/panic, terminal outcomes) keyed by per-request span
//!   ids. Dumped automatically — together with the `STEM_FAULTS` replay
//!   line — when a chaos test fails or a worker panic is caught.
//! * [`snapshot`] — [`MetricsSnapshot`]: a machine-readable point-in-time
//!   export of every serving counter with *exact* histogram buckets, as
//!   JSON (`util::json`) and Prometheus text exposition. Written
//!   periodically by `stem serve --metrics-out FILE
//!   --metrics-interval-ms N` and schema-checked in CI.
//! * [`sparsity`] — per-context-band telemetry from the decode kernels
//!   up: blocks visited vs kept, realized k vs the TPD schedule,
//!   dense-fallback causes, and captured OAM score mass — the
//!   measurement substrate for the paper's decode-stage sparsity claims.
//!
//! The recorder handle ([`Trace`]) and the band counters
//! ([`sparsity::SparsityStats`]) live *inside* `coordinator::Metrics`, so
//! any code path holding the shared metrics block can trace and observe
//! without new plumbing; both are branch-on-`Option`/relaxed-atomic cheap
//! (the `telemetry_overhead` gate in `BENCH_serve.json` holds the whole
//! layer to ≤ 5% of admitted throughput).

pub mod snapshot;
pub mod sparsity;
pub mod trace;

pub use snapshot::{HistoBucket, HistoSnapshot, KvGauges, MetricsSnapshot, TraceStats};
pub use sparsity::{band_index, band_label, BandSnapshot, DenseCause, SparsityStats, StepTelemetry};
pub use trace::{EventKind, FlightRecorder, Outcome, PanicSite, RouteKind, Trace, TraceEvent};
