//! Sparsity telemetry: what the decode kernels *actually realized* of the
//! paper's TPD budget schedule and OAM block selection, aggregated by
//! context-length band.
//!
//! Every decode/verify attention call emits one [`StepTelemetry`]
//! observation: how many key blocks existed, how many the TPD schedule
//! planned to keep, how many the selection really kept, whether the step
//! fell back to dense (and why — `Lil`'s short-context floor vs the budget
//! simply covering every block), and how much of the softmax score mass
//! over the OAM block scores the kept set captured. [`SparsityStats`]
//! folds those observations into lock-free per-band counters surfaced by
//! the metrics snapshot and `report()` — the measurement substrate for the
//! paper's claim that decode-stage sparsity behaves differently across
//! position regimes.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of context-length bands tracked by [`SparsityStats`].
pub const N_BANDS: usize = 5;

const BAND_LABELS: [&str; N_BANDS] = ["lt1k", "1k-4k", "4k-16k", "16k-64k", "ge64k"];

/// Band index for a context length (tokens).
pub fn band_index(n_ctx: usize) -> usize {
    match n_ctx {
        0..=1023 => 0,
        1024..=4095 => 1,
        4096..=16383 => 2,
        16384..=65535 => 3,
        _ => 4,
    }
}

/// Human label for a band index (e.g. `"4k-16k"`).
pub fn band_label(band: usize) -> &'static str {
    BAND_LABELS[band.min(N_BANDS - 1)]
}

/// Why a decode step ran dense attention instead of the sparse kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DenseCause {
    /// Context below the policy's `dense_below` floor (Lil's finding that
    /// short-context sparsity hurts — sparsity is not worth it yet).
    ShortContext,
    /// The TPD budget at this position covers every causal block, so the
    /// "sparse" selection would be the full set anyway.
    BudgetCovers,
}

/// One attention call's sparsity observation, emitted by the kernels.
///
/// Dense steps report `blocks_kept == blocks_planned == blocks_total` and
/// `score_mass == 1.0` (dense attention captures all mass by definition);
/// sparse steps report the realized selection against the schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepTelemetry {
    /// Cached key blocks visible to the step (the causal total).
    pub blocks_total: u32,
    /// Blocks the selection actually kept (realized k).
    pub blocks_kept: u32,
    /// Blocks the TPD schedule budgeted for this position (planned k).
    pub blocks_planned: u32,
    /// `Some(cause)` when the step ran the dense path.
    pub dense_cause: Option<DenseCause>,
    /// Fraction of the softmax mass over the OAM block scores captured by
    /// the kept blocks, in `[0, 1]` (1.0 for dense steps).
    pub score_mass: f32,
}

impl StepTelemetry {
    /// Telemetry for a dense step over `nblk` blocks.
    pub fn dense(nblk: usize, cause: DenseCause) -> StepTelemetry {
        StepTelemetry {
            blocks_total: nblk as u32,
            blocks_kept: nblk as u32,
            blocks_planned: nblk as u32,
            dense_cause: Some(cause),
            score_mass: 1.0,
        }
    }

    /// Telemetry for a sparse step: `kept` of `nblk` blocks retained
    /// against a planned budget of `planned`, capturing `score_mass`.
    pub fn sparse(nblk: usize, kept: usize, planned: usize, score_mass: f64) -> StepTelemetry {
        StepTelemetry {
            blocks_total: nblk as u32,
            blocks_kept: kept as u32,
            blocks_planned: planned as u32,
            dense_cause: None,
            score_mass: score_mass.clamp(0.0, 1.0) as f32,
        }
    }
}

/// Fixed-point scale for accumulating score mass in an integer atomic.
const MASS_SCALE: f64 = 1e6;

#[derive(Default)]
struct Band {
    steps: AtomicU64,
    dense_short_context: AtomicU64,
    dense_budget_covers: AtomicU64,
    blocks_total: AtomicU64,
    blocks_kept: AtomicU64,
    blocks_planned: AtomicU64,
    score_mass_micro: AtomicU64,
}

/// A plain-data snapshot of one band's counters (see [`SparsityStats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BandSnapshot {
    /// Band label (`"lt1k"` .. `"ge64k"`).
    pub label: &'static str,
    /// Decode/verify steps observed in this band.
    pub steps: u64,
    /// Steps that ran dense because the context was under `dense_below`.
    pub dense_short_context: u64,
    /// Steps that ran dense because the budget covered every block.
    pub dense_budget_covers: u64,
    /// Sum of causal blocks visible across steps.
    pub blocks_total: u64,
    /// Sum of blocks actually kept across steps.
    pub blocks_kept: u64,
    /// Sum of blocks the TPD schedule planned across steps.
    pub blocks_planned: u64,
    /// Sum of captured score mass, in micro-units (1e-6).
    pub score_mass_micro: u64,
}

impl BandSnapshot {
    /// Steps that took the sparse kernel path.
    pub fn sparse_steps(&self) -> u64 {
        self.steps - self.dense_short_context - self.dense_budget_covers
    }

    /// Mean fraction of visible blocks kept (realized sparsity).
    pub fn kept_fraction(&self) -> f64 {
        if self.blocks_total == 0 {
            return 0.0;
        }
        self.blocks_kept as f64 / self.blocks_total as f64
    }

    /// Mean fraction of visible blocks the schedule planned to keep.
    pub fn planned_fraction(&self) -> f64 {
        if self.blocks_total == 0 {
            return 0.0;
        }
        self.blocks_planned as f64 / self.blocks_total as f64
    }

    /// Mean captured OAM score mass per step, in `[0, 1]`.
    pub fn mean_score_mass(&self) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        (self.score_mass_micro as f64 / MASS_SCALE) / self.steps as f64
    }
}

/// Lock-free per-band sparsity counters: one [`StepTelemetry`] observation
/// per decode/verify attention call, folded with relaxed atomics so the
/// decode hot path pays a handful of uncontended `fetch_add`s.
#[derive(Default)]
pub struct SparsityStats {
    bands: [Band; N_BANDS],
}

impl SparsityStats {
    /// Fold one step's observation into the band of `n_ctx`.
    pub fn observe(&self, n_ctx: usize, t: &StepTelemetry) {
        let b = &self.bands[band_index(n_ctx)];
        b.steps.fetch_add(1, Ordering::Relaxed);
        match t.dense_cause {
            Some(DenseCause::ShortContext) => {
                b.dense_short_context.fetch_add(1, Ordering::Relaxed);
            }
            Some(DenseCause::BudgetCovers) => {
                b.dense_budget_covers.fetch_add(1, Ordering::Relaxed);
            }
            None => {}
        }
        b.blocks_total.fetch_add(t.blocks_total as u64, Ordering::Relaxed);
        b.blocks_kept.fetch_add(t.blocks_kept as u64, Ordering::Relaxed);
        b.blocks_planned.fetch_add(t.blocks_planned as u64, Ordering::Relaxed);
        let micro = (t.score_mass.clamp(0.0, 1.0) as f64 * MASS_SCALE) as u64;
        b.score_mass_micro.fetch_add(micro, Ordering::Relaxed);
    }

    /// Snapshot one band's counters.
    pub fn band(&self, i: usize) -> BandSnapshot {
        let b = &self.bands[i.min(N_BANDS - 1)];
        BandSnapshot {
            label: band_label(i),
            steps: b.steps.load(Ordering::Relaxed),
            dense_short_context: b.dense_short_context.load(Ordering::Relaxed),
            dense_budget_covers: b.dense_budget_covers.load(Ordering::Relaxed),
            blocks_total: b.blocks_total.load(Ordering::Relaxed),
            blocks_kept: b.blocks_kept.load(Ordering::Relaxed),
            blocks_planned: b.blocks_planned.load(Ordering::Relaxed),
            score_mass_micro: b.score_mass_micro.load(Ordering::Relaxed),
        }
    }

    /// Snapshot every band, lowest context band first.
    pub fn bands(&self) -> Vec<BandSnapshot> {
        (0..N_BANDS).map(|i| self.band(i)).collect()
    }

    /// Total steps observed across all bands.
    pub fn total_steps(&self) -> u64 {
        (0..N_BANDS).map(|i| self.band(i).steps).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn band_index_covers_boundaries() {
        assert_eq!(band_index(0), 0);
        assert_eq!(band_index(1023), 0);
        assert_eq!(band_index(1024), 1);
        assert_eq!(band_index(4096), 2);
        assert_eq!(band_index(16384), 3);
        assert_eq!(band_index(65536), 4);
        assert_eq!(band_index(1 << 30), 4);
        for i in 0..N_BANDS {
            assert!(!band_label(i).is_empty());
        }
    }

    #[test]
    fn observe_aggregates_by_band_and_cause() {
        let s = SparsityStats::default();
        s.observe(100, &StepTelemetry::dense(2, DenseCause::ShortContext));
        s.observe(100, &StepTelemetry::dense(3, DenseCause::BudgetCovers));
        s.observe(5000, &StepTelemetry::sparse(100, 25, 30, 0.9));
        s.observe(5000, &StepTelemetry::sparse(100, 25, 30, 0.7));

        let b0 = s.band(band_index(100));
        assert_eq!(b0.steps, 2);
        assert_eq!(b0.dense_short_context, 1);
        assert_eq!(b0.dense_budget_covers, 1);
        assert_eq!(b0.sparse_steps(), 0);
        assert!((b0.mean_score_mass() - 1.0).abs() < 1e-6);

        let b2 = s.band(band_index(5000));
        assert_eq!(b2.steps, 2);
        assert_eq!(b2.sparse_steps(), 2);
        assert_eq!(b2.blocks_total, 200);
        assert_eq!(b2.blocks_kept, 50);
        assert_eq!(b2.blocks_planned, 60);
        assert!((b2.kept_fraction() - 0.25).abs() < 1e-9);
        assert!((b2.planned_fraction() - 0.30).abs() < 1e-9);
        assert!((b2.mean_score_mass() - 0.8).abs() < 1e-6);

        assert_eq!(s.total_steps(), 4);
        assert_eq!(s.bands().len(), N_BANDS);
    }

    #[test]
    fn score_mass_is_clamped() {
        let t = StepTelemetry::sparse(10, 5, 5, 1.7);
        assert_eq!(t.score_mass, 1.0);
        let s = SparsityStats::default();
        s.observe(10, &t);
        assert!((s.band(0).mean_score_mass() - 1.0).abs() < 1e-6);
    }
}
