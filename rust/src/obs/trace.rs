//! Lock-free flight recorder: a fixed-capacity ring of typed, POD trace
//! events with per-request span ids.
//!
//! The recorder is built for the serving hot path: [`FlightRecorder::record`]
//! is one `fetch_add` on the ring head plus five relaxed/release atomic
//! stores into the claimed slot — no locks, no allocation, no formatting.
//! Events are encoded as `(discriminant, packed args)` pairs of `u64`s so a
//! slot is pure POD; readers use a per-slot sequence counter (seqlock
//! discipline) to skip slots that are mid-write or were lapped by the ring,
//! which makes dumping safe while writers keep appending.
//!
//! Every event carries a *span*: the request id (prefill) or branch
//! sequence id (generation) it belongs to, so a failure dump can replay one
//! request's full timeline — submit → terminal event — out of the global
//! ring. Dumps are rendered by [`FlightRecorder::render_failure_dump`],
//! which also carries the `STEM_FAULTS` replay line when fault injection is
//! armed (see `util::fault`).

use std::fmt;
use std::sync::atomic::{fence, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Prefix-route outcome recorded for a generation group (see
/// `coordinator::prefix` for the matching disciplines).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteKind {
    /// Exact prefix hit — branches fork the parked holder directly.
    Hit,
    /// Radix partial hit — covered pages forked, suffix ingested.
    Partial,
    /// No usable prefix — full prompt ingest on a worker.
    Miss,
    /// A holder for this prompt is still filling; branches queued on it.
    Filling,
    /// The matched holder was unusable (e.g. evicted pages) and the prompt
    /// is being re-ingested from scratch.
    Refill,
}

/// Which `catch_unwind` boundary caught a worker panic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PanicSite {
    /// Prefill batch execution.
    Prefill,
    /// Prompt (or suffix) ingest into a prefix holder.
    Ingest,
    /// A decode step / speculative round.
    Decode,
}

/// Terminal outcome of a span.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Finished normally.
    Complete,
    /// Cancelled by the client (explicit or ticket-drop abandonment).
    Cancelled,
    /// Deadline expired mid-flight; partial result returned.
    DeadlineExceeded,
    /// Terminated with a typed error (KV exhaustion, worker panic, ...).
    Error,
}

/// One typed trace event. All payloads are small POD integers so the event
/// fits the lock-free ring slot; strings never enter the hot path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Request passed admission and entered the pipeline.
    Submit {
        /// Prompt tokens carried by the request.
        tokens: u32,
    },
    /// Admission rejected the request at submit (typed, never queued).
    Reject,
    /// Queued work shed at dispatch because its deadline had passed.
    Shed,
    /// The request was placed into a prefill batch.
    Batch {
        /// Number of requests in the emitted batch.
        size: u32,
    },
    /// A worker finished executing the request's prefill.
    Exec {
        /// Execution wall time in microseconds.
        us: u32,
    },
    /// Prefix-route decision for a generation group.
    PrefixRoute {
        /// Which way the prompt routed.
        outcome: RouteKind,
        /// Prompt tokens covered by the cached prefix.
        covered: u32,
    },
    /// A branch forked off a prefix holder (CoW, no payload copy).
    Fork,
    /// Prompt (or suffix) ingest into a prefix holder completed.
    IngestDone {
        /// Tokens ingested.
        tokens: u32,
    },
    /// One decode advance: a single step, or a committed speculative round.
    DecodeStep {
        /// Tokens committed by this advance (1, or γ+1 under speculation).
        tokens: u32,
        /// Context length after the advance.
        n_ctx: u32,
    },
    /// One speculative draft/verify round.
    SpecRound {
        /// Tokens drafted this round.
        drafted: u32,
        /// Drafted tokens accepted by the verifier.
        accepted: u32,
    },
    /// The degradation ladder moved between levels (span 0: global).
    Degrade {
        /// Level before the transition.
        from: u8,
        /// Level after the transition.
        to: u8,
    },
    /// The branch was cancelled by its client.
    Cancel,
    /// The deadline expired mid-flight.
    DeadlineExceeded,
    /// A worker panic was caught for this span.
    Panic {
        /// Which `catch_unwind` boundary caught it.
        site: PanicSite,
    },
    /// The span reached its terminal outcome.
    Finish {
        /// How it ended.
        outcome: Outcome,
    },
}

impl fmt::Display for EventKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EventKind::Submit { tokens } => write!(f, "submit tokens={tokens}"),
            EventKind::Reject => write!(f, "reject"),
            EventKind::Shed => write!(f, "shed (deadline passed in queue)"),
            EventKind::Batch { size } => write!(f, "batch size={size}"),
            EventKind::Exec { us } => write!(f, "exec us={us}"),
            EventKind::PrefixRoute { outcome, covered } => {
                let o = match outcome {
                    RouteKind::Hit => "hit",
                    RouteKind::Partial => "partial",
                    RouteKind::Miss => "miss",
                    RouteKind::Filling => "filling",
                    RouteKind::Refill => "refill",
                };
                write!(f, "prefix-route {o} covered={covered}")
            }
            EventKind::Fork => write!(f, "fork"),
            EventKind::IngestDone { tokens } => write!(f, "ingest-done tokens={tokens}"),
            EventKind::DecodeStep { tokens, n_ctx } => {
                write!(f, "decode-step tokens={tokens} n_ctx={n_ctx}")
            }
            EventKind::SpecRound { drafted, accepted } => {
                write!(f, "spec-round drafted={drafted} accepted={accepted}")
            }
            EventKind::Degrade { from, to } => write!(f, "degrade {from}->{to}"),
            EventKind::Cancel => write!(f, "cancel"),
            EventKind::DeadlineExceeded => write!(f, "deadline-exceeded"),
            EventKind::Panic { site } => {
                let s = match site {
                    PanicSite::Prefill => "prefill",
                    PanicSite::Ingest => "ingest",
                    PanicSite::Decode => "decode",
                };
                write!(f, "panic site={s}")
            }
            EventKind::Finish { outcome } => {
                let o = match outcome {
                    Outcome::Complete => "complete",
                    Outcome::Cancelled => "cancelled",
                    Outcome::DeadlineExceeded => "deadline-exceeded",
                    Outcome::Error => "error",
                };
                write!(f, "finish outcome={o}")
            }
        }
    }
}

/// A decoded event read back out of the ring.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    /// Microseconds since the recorder was created.
    pub ts_us: u64,
    /// The request/branch span the event belongs to (0 = global).
    pub span: u64,
    /// What happened.
    pub kind: EventKind,
}

impl fmt::Display for TraceEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{:>10}us] span {:>6}  {}", self.ts_us, self.span, self.kind)
    }
}

// -- POD encoding -----------------------------------------------------------

fn pack(a: u32, b: u32) -> u64 {
    (a as u64) | ((b as u64) << 32)
}

fn unpack(arg: u64) -> (u32, u32) {
    (arg as u32, (arg >> 32) as u32)
}

fn encode(kind: EventKind) -> (u64, u64) {
    match kind {
        EventKind::Submit { tokens } => (0, pack(tokens, 0)),
        EventKind::Reject => (1, 0),
        EventKind::Shed => (2, 0),
        EventKind::Batch { size } => (3, pack(size, 0)),
        EventKind::Exec { us } => (4, pack(us, 0)),
        EventKind::PrefixRoute { outcome, covered } => (5, pack(outcome as u32, covered)),
        EventKind::Fork => (6, 0),
        EventKind::IngestDone { tokens } => (7, pack(tokens, 0)),
        EventKind::DecodeStep { tokens, n_ctx } => (8, pack(tokens, n_ctx)),
        EventKind::SpecRound { drafted, accepted } => (9, pack(drafted, accepted)),
        EventKind::Degrade { from, to } => (10, pack(from as u32, to as u32)),
        EventKind::Cancel => (11, 0),
        EventKind::DeadlineExceeded => (12, 0),
        EventKind::Panic { site } => (13, pack(site as u32, 0)),
        EventKind::Finish { outcome } => (14, pack(outcome as u32, 0)),
    }
}

fn decode(code: u64, arg: u64) -> Option<EventKind> {
    let (a, b) = unpack(arg);
    Some(match code {
        0 => EventKind::Submit { tokens: a },
        1 => EventKind::Reject,
        2 => EventKind::Shed,
        3 => EventKind::Batch { size: a },
        4 => EventKind::Exec { us: a },
        5 => EventKind::PrefixRoute {
            outcome: match a {
                0 => RouteKind::Hit,
                1 => RouteKind::Partial,
                2 => RouteKind::Miss,
                3 => RouteKind::Filling,
                _ => RouteKind::Refill,
            },
            covered: b,
        },
        6 => EventKind::Fork,
        7 => EventKind::IngestDone { tokens: a },
        8 => EventKind::DecodeStep { tokens: a, n_ctx: b },
        9 => EventKind::SpecRound { drafted: a, accepted: b },
        10 => EventKind::Degrade { from: a as u8, to: b as u8 },
        11 => EventKind::Cancel,
        12 => EventKind::DeadlineExceeded,
        13 => EventKind::Panic {
            site: match a {
                0 => PanicSite::Prefill,
                1 => PanicSite::Ingest,
                _ => PanicSite::Decode,
            },
        },
        14 => EventKind::Finish {
            outcome: match a {
                0 => Outcome::Complete,
                1 => Outcome::Cancelled,
                2 => Outcome::DeadlineExceeded,
                _ => Outcome::Error,
            },
        },
        _ => return None,
    })
}

// -- the ring ---------------------------------------------------------------

/// One ring slot: a per-slot seqlock (`seq` odd = mid-write; even = stable,
/// encoding the writer generation) guarding four POD payload words.
#[derive(Default)]
struct Slot {
    seq: AtomicU64,
    ts_us: AtomicU64,
    span: AtomicU64,
    code: AtomicU64,
    arg: AtomicU64,
}

/// Fixed-capacity, lock-free ring buffer of [`TraceEvent`]s.
///
/// Writers claim a slot with one `fetch_add` and overwrite the oldest event
/// once the ring is full (`recorded() - capacity()` events have been
/// dropped). Readers ([`FlightRecorder::events`] and the render helpers)
/// take a best-effort consistent snapshot: slots that are mid-write or got
/// lapped between the two seqlock reads are skipped, never torn.
pub struct FlightRecorder {
    epoch: Instant,
    head: AtomicU64,
    slots: Vec<Slot>,
}

impl FlightRecorder {
    /// A recorder holding the last `capacity` events (min 16).
    pub fn new(capacity: usize) -> FlightRecorder {
        let capacity = capacity.max(16);
        FlightRecorder {
            epoch: Instant::now(),
            head: AtomicU64::new(0),
            slots: (0..capacity).map(|_| Slot::default()).collect(),
        }
    }

    /// Append one event under `span`. Lock-free; callable from any thread.
    #[inline]
    pub fn record(&self, span: u64, kind: EventKind) {
        let n = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(n % self.slots.len() as u64) as usize];
        let (code, arg) = encode(kind);
        let ts = self.epoch.elapsed().as_micros() as u64;
        // seqlock write: odd while mutating, even (generation-stamped) when
        // stable — a concurrent reader seeing seq change discards the slot
        slot.seq.store(2 * n + 1, Ordering::Release);
        slot.ts_us.store(ts, Ordering::Relaxed);
        slot.span.store(span, Ordering::Relaxed);
        slot.code.store(code, Ordering::Relaxed);
        slot.arg.store(arg, Ordering::Relaxed);
        slot.seq.store(2 * (n + 1), Ordering::Release);
    }

    /// Total events ever recorded (including ones the ring dropped).
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Acquire)
    }

    /// Ring capacity in events.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Events lost to ring wrap so far.
    pub fn dropped(&self) -> u64 {
        self.recorded().saturating_sub(self.slots.len() as u64)
    }

    fn read_slot(&self, idx: usize) -> Option<TraceEvent> {
        let slot = &self.slots[idx];
        let s1 = slot.seq.load(Ordering::Acquire);
        if s1 == 0 || s1 % 2 == 1 {
            return None; // never written, or mid-write
        }
        let ts_us = slot.ts_us.load(Ordering::Relaxed);
        let span = slot.span.load(Ordering::Relaxed);
        let code = slot.code.load(Ordering::Relaxed);
        let arg = slot.arg.load(Ordering::Relaxed);
        fence(Ordering::Acquire);
        if slot.seq.load(Ordering::Relaxed) != s1 {
            return None; // lapped while reading
        }
        decode(code, arg).map(|kind| TraceEvent { ts_us, span, kind })
    }

    /// Best-effort snapshot of the ring, oldest first.
    pub fn events(&self) -> Vec<TraceEvent> {
        let head = self.head.load(Ordering::Acquire);
        let cap = self.slots.len() as u64;
        let start = head.saturating_sub(cap);
        let mut out: Vec<TraceEvent> = (start..head)
            .filter_map(|n| self.read_slot((n % cap) as usize))
            .collect();
        // concurrent writers can lap the cursor mid-scan; timestamps restore
        // a coherent order (sort is stable, ties keep scan order)
        out.sort_by_key(|e| e.ts_us);
        out
    }

    /// The events of one span, oldest first.
    pub fn span_events(&self, span: u64) -> Vec<TraceEvent> {
        self.events().into_iter().filter(|e| e.span == span).collect()
    }

    /// Render the whole ring as one human-readable block.
    pub fn render(&self) -> String {
        let mut s = String::new();
        for e in self.events() {
            s.push_str(&format!("{e}\n"));
        }
        s
    }

    /// Render a failure dump: the full timeline of `span` (or the whole
    /// ring when `span` is `None`), headed by the `STEM_FAULTS` replay line
    /// when fault injection was armed. This is what the chaos suite and the
    /// worker-panic handlers print.
    pub fn render_failure_dump(&self, span: Option<u64>, replay: Option<&str>) -> String {
        let mut s = String::new();
        s.push_str("=== flight-recorder dump ===\n");
        if let Some(r) = replay {
            s.push_str(&format!("replay: STEM_FAULTS='{r}'\n"));
        }
        s.push_str(&format!(
            "events recorded={} capacity={} dropped={}\n",
            self.recorded(),
            self.capacity(),
            self.dropped()
        ));
        let events = match span {
            Some(id) => {
                s.push_str(&format!("--- span {id} ---\n"));
                self.span_events(id)
            }
            None => self.events(),
        };
        if events.is_empty() {
            s.push_str("(no events — tracing disabled or span evicted from the ring)\n");
        }
        for e in events {
            s.push_str(&format!("{e}\n"));
        }
        s.push_str("=== end dump ===\n");
        s
    }
}

/// Cheap clonable tracing handle: `Some(recorder)` when tracing is on,
/// `None` when off. The disabled path is a single branch on an `Option`, so
/// threading a `Trace` through the hot path costs nothing when tracing is
/// not configured (the `telemetry_overhead` bench gate depends on this).
#[derive(Clone, Default)]
pub struct Trace(Option<Arc<FlightRecorder>>);

impl Trace {
    /// A tracing handle with a `capacity`-event ring; `capacity == 0`
    /// disables tracing entirely.
    pub fn new(capacity: usize) -> Trace {
        if capacity == 0 {
            Trace(None)
        } else {
            Trace(Some(Arc::new(FlightRecorder::new(capacity))))
        }
    }

    /// The always-off handle (what `Trace::default()` gives you).
    pub fn off() -> Trace {
        Trace(None)
    }

    /// Whether events are being recorded.
    pub fn enabled(&self) -> bool {
        self.0.is_some()
    }

    /// Record one event (no-op when disabled).
    #[inline]
    pub fn record(&self, span: u64, kind: EventKind) {
        if let Some(r) = &self.0 {
            r.record(span, kind);
        }
    }

    /// The underlying recorder, when tracing is on.
    pub fn recorder(&self) -> Option<&FlightRecorder> {
        self.0.as_deref()
    }
}

impl fmt::Debug for Trace {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.0 {
            Some(r) => write!(f, "Trace(on, {} events)", r.recorded()),
            None => write!(f, "Trace(off)"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_kinds() -> Vec<EventKind> {
        vec![
            EventKind::Submit { tokens: 16 },
            EventKind::Reject,
            EventKind::Shed,
            EventKind::Batch { size: 4 },
            EventKind::Exec { us: 1234 },
            EventKind::PrefixRoute { outcome: RouteKind::Partial, covered: 96 },
            EventKind::Fork,
            EventKind::IngestDone { tokens: 64 },
            EventKind::DecodeStep { tokens: 3, n_ctx: 2048 },
            EventKind::SpecRound { drafted: 4, accepted: 2 },
            EventKind::Degrade { from: 1, to: 2 },
            EventKind::Cancel,
            EventKind::DeadlineExceeded,
            EventKind::Panic { site: PanicSite::Decode },
            EventKind::Finish { outcome: Outcome::Complete },
        ]
    }

    #[test]
    fn encode_decode_roundtrips_every_kind() {
        for k in all_kinds() {
            let (code, arg) = encode(k);
            assert_eq!(decode(code, arg), Some(k), "roundtrip failed for {k:?}");
        }
        assert_eq!(decode(999, 0), None);
    }

    #[test]
    fn ring_keeps_newest_and_counts_drops() {
        let r = FlightRecorder::new(16);
        for i in 0..40u64 {
            r.record(i, EventKind::Submit { tokens: i as u32 });
        }
        assert_eq!(r.recorded(), 40);
        assert_eq!(r.dropped(), 24);
        let ev = r.events();
        assert_eq!(ev.len(), 16);
        // only the newest 16 survive, in order
        let spans: Vec<u64> = ev.iter().map(|e| e.span).collect();
        assert_eq!(spans, (24..40).collect::<Vec<u64>>());
    }

    #[test]
    fn span_filter_reconstructs_one_request() {
        let r = FlightRecorder::new(64);
        r.record(7, EventKind::Submit { tokens: 8 });
        r.record(9, EventKind::Submit { tokens: 8 });
        r.record(7, EventKind::Batch { size: 2 });
        r.record(9, EventKind::Cancel);
        r.record(7, EventKind::Finish { outcome: Outcome::Complete });
        let ev = r.span_events(7);
        assert_eq!(ev.len(), 3);
        assert_eq!(ev[0].kind, EventKind::Submit { tokens: 8 });
        assert_eq!(ev[2].kind, EventKind::Finish { outcome: Outcome::Complete });
    }

    #[test]
    fn failure_dump_carries_replay_line_and_span() {
        let r = FlightRecorder::new(64);
        r.record(3, EventKind::Submit { tokens: 4 });
        r.record(3, EventKind::Panic { site: PanicSite::Prefill });
        r.record(3, EventKind::Finish { outcome: Outcome::Error });
        let dump = r.render_failure_dump(Some(3), Some("seed=42,kv=0.1"));
        assert!(dump.contains("STEM_FAULTS='seed=42,kv=0.1'"));
        assert!(dump.contains("submit tokens=4"));
        assert!(dump.contains("panic site=prefill"));
        assert!(dump.contains("finish outcome=error"));
    }

    #[test]
    fn concurrent_writers_never_tear_reads() {
        use std::sync::atomic::AtomicBool;
        let r = Arc::new(FlightRecorder::new(128));
        let stop = Arc::new(AtomicBool::new(false));
        let writers: Vec<_> = (0..4)
            .map(|w| {
                let r = Arc::clone(&r);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    let mut i = 0u32;
                    while !stop.load(Ordering::Relaxed) {
                        r.record(w, EventKind::DecodeStep { tokens: 1, n_ctx: i });
                        i = i.wrapping_add(1);
                    }
                })
            })
            .collect();
        for _ in 0..50 {
            // every event read back must decode to a valid DecodeStep with a
            // writer-id span — a torn read would mix spans/args arbitrarily
            for e in r.events() {
                assert!(e.span < 4);
                assert!(matches!(e.kind, EventKind::DecodeStep { tokens: 1, .. }));
            }
        }
        stop.store(true, Ordering::Relaxed);
        for w in writers {
            w.join().unwrap();
        }
    }

    #[test]
    fn disabled_trace_is_inert() {
        let t = Trace::off();
        assert!(!t.enabled());
        t.record(1, EventKind::Reject); // must not panic
        assert!(t.recorder().is_none());
        let on = Trace::new(32);
        on.record(1, EventKind::Reject);
        assert_eq!(on.recorder().unwrap().recorded(), 1);
    }
}
