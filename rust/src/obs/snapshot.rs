//! Structured metrics export: a point-in-time [`MetricsSnapshot`] of the
//! serving counters, exact histogram buckets, sparsity bands and trace
//! stats, serializable as JSON (via `util::json`, the crate has no serde)
//! and as Prometheus text exposition.
//!
//! `stem serve --metrics-out FILE --metrics-interval-ms N` writes the JSON
//! form periodically (plus a final artifact at shutdown) and the Prometheus
//! form next to it as `FILE.prom`; `benches/bench_serve.rs` emits one as
//! `metrics.json` so CI can schema-check the export. The snapshot is
//! collected with relaxed atomic loads — taking one costs microseconds and
//! never blocks the serving path.

use std::sync::atomic::Ordering;
use std::time::Duration;

use crate::coordinator::metrics::{LatencyHisto, Metrics};
use crate::obs::sparsity::BandSnapshot;
use crate::util::json::Json;

/// Schema version stamped into the JSON export; bump on breaking changes.
pub const SNAPSHOT_SCHEMA_VERSION: u64 = 1;

/// One cumulative histogram bucket: samples `<= le_us` microseconds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HistoBucket {
    /// Inclusive upper bound of the bucket in microseconds.
    pub le_us: u64,
    /// Cumulative sample count at or below `le_us`.
    pub count: u64,
}

/// Exact export of one [`LatencyHisto`]: cumulative power-of-two buckets
/// (Prometheus `le` convention) plus count/sum/max and clamped percentiles.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistoSnapshot {
    /// Samples recorded.
    pub count: u64,
    /// Sum of all samples in microseconds.
    pub sum_us: u64,
    /// Largest sample in microseconds.
    pub max_us: u64,
    /// p50 estimate (bucket bound clamped to `max_us`).
    pub p50_us: u64,
    /// p90 estimate.
    pub p90_us: u64,
    /// p99 estimate.
    pub p99_us: u64,
    /// Cumulative buckets up to the highest non-empty one (empty when no
    /// samples were recorded). The implicit `+Inf` bucket equals `count`.
    pub buckets: Vec<HistoBucket>,
}

impl HistoSnapshot {
    /// Snapshot a live histogram.
    pub fn collect(h: &LatencyHisto) -> HistoSnapshot {
        let raw = h.bucket_counts();
        let hi = raw.iter().rposition(|&c| c > 0);
        let mut buckets = Vec::new();
        if let Some(hi) = hi {
            let mut acc = 0u64;
            for (i, &c) in raw.iter().enumerate().take(hi + 1) {
                acc += c;
                buckets.push(HistoBucket { le_us: (1u64 << (i + 1)) - 1, count: acc });
            }
        }
        HistoSnapshot {
            count: h.count(),
            sum_us: h.sum_us(),
            max_us: h.max_us(),
            p50_us: h.percentile_us(0.5),
            p90_us: h.percentile_us(0.9),
            p99_us: h.percentile_us(0.99),
            buckets,
        }
    }

    /// JSON form (`{count, sum_us, max_us, p50_us, p90_us, p99_us,
    /// buckets: [{le_us, count}, ...]}`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("sum_us", Json::Num(self.sum_us as f64)),
            ("max_us", Json::Num(self.max_us as f64)),
            ("p50_us", Json::Num(self.p50_us as f64)),
            ("p90_us", Json::Num(self.p90_us as f64)),
            ("p99_us", Json::Num(self.p99_us as f64)),
            (
                "buckets",
                Json::arr(self.buckets.iter().map(|b| {
                    Json::obj(vec![
                        ("le_us", Json::Num(b.le_us as f64)),
                        ("count", Json::Num(b.count as f64)),
                    ])
                })),
            ),
        ])
    }
}

/// KV-pool gauges attached by the coordinator (absent when snapshotting a
/// bare [`Metrics`] block with no pool).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvGauges {
    /// Pages currently allocated to live sequences.
    pub pages_used: u64,
    /// Total pages in the pool.
    pub pages_total: u64,
    /// K/V slab pages resident in the payload store.
    pub slab_pages: u64,
}

/// Flight-recorder stats attached when tracing is armed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceStats {
    /// Events ever recorded.
    pub recorded: u64,
    /// Ring capacity in events.
    pub capacity: u64,
    /// Events lost to ring wrap.
    pub dropped: u64,
}

/// A point-in-time, plain-data view of the whole serving metrics surface.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    /// Coordinator uptime when the snapshot was taken, microseconds.
    pub uptime_us: u64,
    /// Prefill requests accepted by admission.
    pub submitted: u64,
    /// Prefill requests completed.
    pub completed: u64,
    /// Requests shed by admission.
    pub rejected: u64,
    /// Prefill batches emitted.
    pub batches: u64,
    /// Tokens ingested.
    pub tokens_in: u64,
    /// Generation branches accepted.
    pub generates_submitted: u64,
    /// Generation branches completed.
    pub generates_completed: u64,
    /// Queue-wait latency histogram.
    pub queue: HistoSnapshot,
    /// Worker-execution latency histogram.
    pub exec: HistoSnapshot,
    /// Time-to-first-token histogram.
    pub ttft: HistoSnapshot,
    /// Per-decode-step latency histogram.
    pub decode_step: HistoSnapshot,
    /// Generation time-to-first-token histogram (submit → first
    /// committed token; the SLO chunked ingest protects).
    pub gen_ttft: HistoSnapshot,
    /// Time-per-output-token histogram (inter-commit gap per token).
    pub tpot: HistoSnapshot,
    /// Decode-step batches emitted by the continuous-batching lane.
    pub decode_batches: u64,
    /// Decode steps executed (== tokens generated).
    pub decode_steps: u64,
    /// Steps that ran the dense fallback.
    pub decode_dense_steps: u64,
    /// Mean per-step decode budget fraction.
    pub mean_decode_budget: f64,
    /// Speculative rounds executed.
    pub spec_rounds: u64,
    /// Draft tokens proposed.
    pub spec_drafted: u64,
    /// Draft tokens accepted by the verifier.
    pub spec_accepted: u64,
    /// Tokens committed by speculative rounds.
    pub spec_committed: u64,
    /// Branch sessions forked off a cached prefix.
    pub forks: u64,
    /// Exact prefix hits.
    pub prefix_hits: u64,
    /// Radix partial prefix hits.
    pub prefix_partial_hits: u64,
    /// Prefix misses (full ingest).
    pub prefix_misses: u64,
    /// Prompt tokens routed (covered-ratio denominator).
    pub prefix_tokens_total: u64,
    /// Prompt tokens served from cached prefixes.
    pub prefix_tokens_covered: u64,
    /// Ingest chunk steps completed by the chunked-prefill lane.
    pub ingest_chunks: u64,
    /// Requests shed in queue by their deadline.
    pub shed_deadline: u64,
    /// Branches cut mid-decode by their deadline.
    pub deadline_exceeded: u64,
    /// Branches cancelled or abandoned.
    pub cancelled: u64,
    /// Worker panics caught and isolated.
    pub worker_panics: u64,
    /// Current degradation level (gauge).
    pub degradation_level: u64,
    /// Degradation transitions since start.
    pub degradation_transitions: u64,
    /// Total errors ever logged.
    pub errors_logged: u64,
    /// Errors evicted from the capped ring.
    pub errors_dropped: u64,
    /// The retained (newest) error strings, oldest first.
    pub recent_errors: Vec<String>,
    /// Per-context-band sparsity telemetry.
    pub sparsity: Vec<BandSnapshot>,
    /// KV-pool gauges, when a pool was attached.
    pub kv: Option<KvGauges>,
    /// Flight-recorder stats, when tracing is armed.
    pub trace: Option<TraceStats>,
    /// Stable label of the decode backend serving generations
    /// (`"tiny"` / `"engine"`), set post-collect by the coordinator;
    /// `None` when snapshotting a bare [`Metrics`] block.
    pub decode_backend: Option<&'static str>,
    /// Resolved SIMD dispatch arm of the sparse kernels (`"scalar"` /
    /// `"wide-avx2"` / `"wide-portable"`), read from the process-global
    /// dispatch state at collect time so a wrong-arm regression is
    /// visible from metrics alone.
    pub simd_dispatch: &'static str,
}

impl MetricsSnapshot {
    /// Collect a snapshot from a live metrics block. `kv` carries the
    /// pool gauges when the caller owns one (the coordinator does).
    pub fn collect(m: &Metrics, kv: Option<KvGauges>, uptime: Duration) -> MetricsSnapshot {
        let (errors_logged, errors_dropped, recent_errors) = {
            let e = m.errors.lock().unwrap_or_else(|p| p.into_inner());
            (e.logged(), e.dropped(), e.to_vec())
        };
        let trace = m.trace.recorder().map(|r| TraceStats {
            recorded: r.recorded(),
            capacity: r.capacity() as u64,
            dropped: r.dropped(),
        });
        MetricsSnapshot {
            uptime_us: uptime.as_micros() as u64,
            submitted: m.submitted.load(Ordering::Relaxed),
            completed: m.completed.load(Ordering::Relaxed),
            rejected: m.rejected.load(Ordering::Relaxed),
            batches: m.batches.load(Ordering::Relaxed),
            tokens_in: m.tokens_in.load(Ordering::Relaxed),
            generates_submitted: m.generates_submitted.load(Ordering::Relaxed),
            generates_completed: m.generates_completed.load(Ordering::Relaxed),
            queue: HistoSnapshot::collect(&m.queue),
            exec: HistoSnapshot::collect(&m.exec),
            ttft: HistoSnapshot::collect(&m.ttft),
            decode_step: HistoSnapshot::collect(&m.decode_step),
            gen_ttft: HistoSnapshot::collect(&m.gen_ttft),
            tpot: HistoSnapshot::collect(&m.tpot),
            decode_batches: m.decode_batches.load(Ordering::Relaxed),
            decode_steps: m.decode_steps.load(Ordering::Relaxed),
            decode_dense_steps: m.decode_dense_steps.load(Ordering::Relaxed),
            mean_decode_budget: m.mean_decode_budget(),
            spec_rounds: m.spec_rounds.load(Ordering::Relaxed),
            spec_drafted: m.spec_drafted.load(Ordering::Relaxed),
            spec_accepted: m.spec_accepted.load(Ordering::Relaxed),
            spec_committed: m.spec_committed.load(Ordering::Relaxed),
            forks: m.forks.load(Ordering::Relaxed),
            prefix_hits: m.prefix_hits.load(Ordering::Relaxed),
            prefix_partial_hits: m.prefix_partial_hits.load(Ordering::Relaxed),
            prefix_misses: m.prefix_misses.load(Ordering::Relaxed),
            prefix_tokens_total: m.prefix_tokens_total.load(Ordering::Relaxed),
            prefix_tokens_covered: m.prefix_tokens_covered.load(Ordering::Relaxed),
            ingest_chunks: m.ingest_chunks.load(Ordering::Relaxed),
            shed_deadline: m.shed_deadline.load(Ordering::Relaxed),
            deadline_exceeded: m.deadline_exceeded.load(Ordering::Relaxed),
            cancelled: m.cancelled.load(Ordering::Relaxed),
            worker_panics: m.worker_panics.load(Ordering::Relaxed),
            degradation_level: m.degradation_level.load(Ordering::Relaxed),
            degradation_transitions: m.degradation_transitions.load(Ordering::Relaxed),
            errors_logged,
            errors_dropped,
            recent_errors,
            sparsity: m.sparsity.bands(),
            kv,
            trace,
            decode_backend: None,
            simd_dispatch: crate::sparse::simd::dispatch_label(),
        }
    }

    /// Serialize as the versioned JSON schema checked by CI (see the
    /// bench-smoke schema step in `.github/workflows/ci.yml`).
    pub fn to_json(&self) -> Json {
        let band_json = |b: &BandSnapshot| {
            Json::obj(vec![
                ("band", Json::str(b.label)),
                ("steps", Json::Num(b.steps as f64)),
                ("sparse_steps", Json::Num(b.sparse_steps() as f64)),
                ("dense_short_context", Json::Num(b.dense_short_context as f64)),
                ("dense_budget_covers", Json::Num(b.dense_budget_covers as f64)),
                ("blocks_total", Json::Num(b.blocks_total as f64)),
                ("blocks_kept", Json::Num(b.blocks_kept as f64)),
                ("blocks_planned", Json::Num(b.blocks_planned as f64)),
                ("kept_fraction", Json::Num(b.kept_fraction())),
                ("planned_fraction", Json::Num(b.planned_fraction())),
                ("mean_score_mass", Json::Num(b.mean_score_mass())),
            ])
        };
        let spec_acceptance = if self.spec_drafted == 0 {
            0.0
        } else {
            self.spec_accepted as f64 / self.spec_drafted as f64
        };
        let covered_ratio = if self.prefix_tokens_total == 0 {
            0.0
        } else {
            self.prefix_tokens_covered as f64 / self.prefix_tokens_total as f64
        };
        Json::obj(vec![
            ("schema_version", Json::Num(SNAPSHOT_SCHEMA_VERSION as f64)),
            ("uptime_us", Json::Num(self.uptime_us as f64)),
            (
                "requests",
                Json::obj(vec![
                    ("submitted", Json::Num(self.submitted as f64)),
                    ("completed", Json::Num(self.completed as f64)),
                    ("rejected", Json::Num(self.rejected as f64)),
                    ("batches", Json::Num(self.batches as f64)),
                    ("tokens_in", Json::Num(self.tokens_in as f64)),
                    ("generates_submitted", Json::Num(self.generates_submitted as f64)),
                    ("generates_completed", Json::Num(self.generates_completed as f64)),
                ]),
            ),
            (
                "latency_us",
                Json::obj(vec![
                    ("queue", self.queue.to_json()),
                    ("exec", self.exec.to_json()),
                    ("ttft", self.ttft.to_json()),
                    ("decode_step", self.decode_step.to_json()),
                    ("gen_ttft", self.gen_ttft.to_json()),
                    ("tpot", self.tpot.to_json()),
                ]),
            ),
            (
                "decode",
                Json::obj(vec![
                    (
                        "backend",
                        match self.decode_backend {
                            Some(b) => Json::str(b),
                            None => Json::Null,
                        },
                    ),
                    ("batches", Json::Num(self.decode_batches as f64)),
                    ("steps", Json::Num(self.decode_steps as f64)),
                    ("dense_steps", Json::Num(self.decode_dense_steps as f64)),
                    ("mean_budget_fraction", Json::Num(self.mean_decode_budget)),
                ]),
            ),
            ("simd", Json::obj(vec![("dispatch", Json::str(self.simd_dispatch))])),
            (
                "spec",
                Json::obj(vec![
                    ("rounds", Json::Num(self.spec_rounds as f64)),
                    ("drafted", Json::Num(self.spec_drafted as f64)),
                    ("accepted", Json::Num(self.spec_accepted as f64)),
                    ("committed", Json::Num(self.spec_committed as f64)),
                    ("acceptance", Json::Num(spec_acceptance)),
                ]),
            ),
            (
                "prefix",
                Json::obj(vec![
                    ("hits", Json::Num(self.prefix_hits as f64)),
                    ("partial_hits", Json::Num(self.prefix_partial_hits as f64)),
                    ("misses", Json::Num(self.prefix_misses as f64)),
                    ("forks", Json::Num(self.forks as f64)),
                    ("tokens_total", Json::Num(self.prefix_tokens_total as f64)),
                    ("tokens_covered", Json::Num(self.prefix_tokens_covered as f64)),
                    ("covered_ratio", Json::Num(covered_ratio)),
                    ("ingest_chunks", Json::Num(self.ingest_chunks as f64)),
                ]),
            ),
            (
                "failures",
                Json::obj(vec![
                    ("shed_deadline", Json::Num(self.shed_deadline as f64)),
                    ("deadline_exceeded", Json::Num(self.deadline_exceeded as f64)),
                    ("cancelled", Json::Num(self.cancelled as f64)),
                    ("worker_panics", Json::Num(self.worker_panics as f64)),
                    ("errors_logged", Json::Num(self.errors_logged as f64)),
                    ("errors_dropped", Json::Num(self.errors_dropped as f64)),
                    (
                        "recent_errors",
                        Json::arr(self.recent_errors.iter().map(|e| Json::str(e.clone()))),
                    ),
                ]),
            ),
            (
                "degradation",
                Json::obj(vec![
                    ("level", Json::Num(self.degradation_level as f64)),
                    ("transitions", Json::Num(self.degradation_transitions as f64)),
                ]),
            ),
            (
                "kv",
                match &self.kv {
                    Some(kv) => Json::obj(vec![
                        ("pages_used", Json::Num(kv.pages_used as f64)),
                        ("pages_total", Json::Num(kv.pages_total as f64)),
                        (
                            "occupancy",
                            Json::Num(if kv.pages_total == 0 {
                                0.0
                            } else {
                                kv.pages_used as f64 / kv.pages_total as f64
                            }),
                        ),
                        ("slab_pages", Json::Num(kv.slab_pages as f64)),
                    ]),
                    None => Json::Null,
                },
            ),
            (
                "sparsity",
                Json::obj(vec![("bands", Json::arr(self.sparsity.iter().map(band_json)))]),
            ),
            (
                "trace",
                match &self.trace {
                    Some(t) => Json::obj(vec![
                        ("recorded", Json::Num(t.recorded as f64)),
                        ("capacity", Json::Num(t.capacity as f64)),
                        ("dropped", Json::Num(t.dropped as f64)),
                    ]),
                    None => Json::Null,
                },
            ),
        ])
    }

    /// Serialize as Prometheus text exposition (counters, gauges, and
    /// full `_bucket{le=...}` histograms with `_sum`/`_count`).
    pub fn to_prometheus(&self) -> String {
        let mut s = String::new();
        let mut counter = |name: &str, v: u64| {
            s.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
        };
        counter("stem_requests_submitted_total", self.submitted);
        counter("stem_requests_completed_total", self.completed);
        counter("stem_requests_rejected_total", self.rejected);
        counter("stem_prefill_batches_total", self.batches);
        counter("stem_tokens_in_total", self.tokens_in);
        counter("stem_generates_submitted_total", self.generates_submitted);
        counter("stem_generates_completed_total", self.generates_completed);
        counter("stem_decode_batches_total", self.decode_batches);
        counter("stem_decode_steps_total", self.decode_steps);
        counter("stem_decode_dense_steps_total", self.decode_dense_steps);
        counter("stem_spec_rounds_total", self.spec_rounds);
        counter("stem_spec_drafted_total", self.spec_drafted);
        counter("stem_spec_accepted_total", self.spec_accepted);
        counter("stem_spec_committed_total", self.spec_committed);
        counter("stem_forks_total", self.forks);
        counter("stem_prefix_hits_total", self.prefix_hits);
        counter("stem_prefix_partial_hits_total", self.prefix_partial_hits);
        counter("stem_prefix_misses_total", self.prefix_misses);
        counter("stem_prefix_tokens_total", self.prefix_tokens_total);
        counter("stem_prefix_tokens_covered_total", self.prefix_tokens_covered);
        counter("stem_ingest_chunks_total", self.ingest_chunks);
        counter("stem_shed_deadline_total", self.shed_deadline);
        counter("stem_deadline_exceeded_total", self.deadline_exceeded);
        counter("stem_cancelled_total", self.cancelled);
        counter("stem_worker_panics_total", self.worker_panics);
        counter("stem_errors_logged_total", self.errors_logged);
        counter("stem_errors_dropped_total", self.errors_dropped);
        counter("stem_degradation_transitions_total", self.degradation_transitions);

        let mut gauge = |name: &str, v: f64| {
            s.push_str(&format!("# TYPE {name} gauge\n{name} {v}\n"));
        };
        gauge("stem_uptime_seconds", self.uptime_us as f64 / 1e6);
        gauge("stem_degradation_level", self.degradation_level as f64);
        gauge("stem_decode_mean_budget_fraction", self.mean_decode_budget);
        if let Some(kv) = &self.kv {
            gauge("stem_kv_pages_used", kv.pages_used as f64);
            gauge("stem_kv_pages_total", kv.pages_total as f64);
            gauge("stem_kv_slab_pages", kv.slab_pages as f64);
        }
        if let Some(t) = &self.trace {
            gauge("stem_trace_events_recorded", t.recorded as f64);
            gauge("stem_trace_events_dropped", t.dropped as f64);
        }
        if let Some(b) = self.decode_backend {
            // info-style series: the label carries the value
            s.push_str(&format!(
                "# TYPE stem_decode_backend_info gauge\nstem_decode_backend_info{{backend=\"{b}\"}} 1\n"
            ));
        }
        let arm = self.simd_dispatch;
        s.push_str(&format!(
            "# TYPE stem_simd_dispatch_info gauge\nstem_simd_dispatch_info{{arm=\"{arm}\"}} 1\n"
        ));

        let mut histo = |name: &str, h: &HistoSnapshot| {
            s.push_str(&format!("# TYPE {name} histogram\n"));
            for b in &h.buckets {
                s.push_str(&format!("{name}_bucket{{le=\"{}\"}} {}\n", b.le_us, b.count));
            }
            s.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count));
            s.push_str(&format!("{name}_sum {}\n", h.sum_us));
            s.push_str(&format!("{name}_count {}\n", h.count));
        };
        histo("stem_queue_us", &self.queue);
        histo("stem_exec_us", &self.exec);
        histo("stem_ttft_us", &self.ttft);
        histo("stem_decode_step_us", &self.decode_step);
        histo("stem_gen_ttft_us", &self.gen_ttft);
        histo("stem_tpot_us", &self.tpot);

        for b in &self.sparsity {
            if b.steps == 0 {
                continue;
            }
            let l = b.label;
            s.push_str(&format!("stem_sparsity_steps_total{{band=\"{l}\"}} {}\n", b.steps));
            s.push_str(&format!(
                "stem_sparsity_dense_steps_total{{band=\"{l}\",cause=\"short_context\"}} {}\n",
                b.dense_short_context
            ));
            s.push_str(&format!(
                "stem_sparsity_dense_steps_total{{band=\"{l}\",cause=\"budget_covers\"}} {}\n",
                b.dense_budget_covers
            ));
            s.push_str(&format!(
                "stem_sparsity_kept_fraction{{band=\"{l}\"}} {}\n",
                b.kept_fraction()
            ));
            s.push_str(&format!(
                "stem_sparsity_planned_fraction{{band=\"{l}\"}} {}\n",
                b.planned_fraction()
            ));
            s.push_str(&format!(
                "stem_sparsity_score_mass{{band=\"{l}\"}} {}\n",
                b.mean_score_mass()
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::degrade::{DegradeConfig, Degrader};
    use crate::obs::sparsity::StepTelemetry;
    use std::time::Instant;

    fn busy_metrics() -> Metrics {
        let m = Metrics::new();
        m.submitted.store(10, Ordering::Relaxed);
        m.completed.store(9, Ordering::Relaxed);
        m.rejected.store(1, Ordering::Relaxed);
        m.tokens_in.store(1024, Ordering::Relaxed);
        for us in [100u64, 900, 4000] {
            m.ttft.record(Duration::from_micros(us));
            m.queue.record(Duration::from_micros(us / 2));
            m.exec.record(Duration::from_micros(us / 2));
        }
        m.record_decode_step(Duration::from_micros(150), 0.3, false);
        m.gen_ttft.record(Duration::from_micros(2500));
        m.tpot.record(Duration::from_micros(180));
        m.ingest_chunks.store(5, Ordering::Relaxed);
        m.record_step_telemetry(5000, &StepTelemetry::sparse(80, 20, 24, 0.93));
        m.record_error("one bad thing".into());
        m
    }

    #[test]
    fn histo_snapshot_buckets_are_cumulative_and_exact() {
        let h = LatencyHisto::new();
        for us in [1u64, 3, 3, 1000] {
            h.record(Duration::from_micros(us));
        }
        let s = HistoSnapshot::collect(&h);
        assert_eq!(s.count, 4);
        assert_eq!(s.sum_us, 1007);
        assert_eq!(s.max_us, 1000);
        // cumulative counts never decrease, bounds strictly increase, and
        // the last bucket carries every sample
        let mut prev_le = 0u64;
        let mut prev_c = 0u64;
        for b in &s.buckets {
            assert!(b.le_us > prev_le);
            assert!(b.count >= prev_c);
            prev_le = b.le_us;
            prev_c = b.count;
        }
        assert_eq!(s.buckets.last().unwrap().count, s.count);
        // bucket bounds: 1µs -> le 1, 3µs -> le 3, 1000µs -> le 1023
        assert_eq!(s.buckets[0], HistoBucket { le_us: 1, count: 1 });
        assert_eq!(s.buckets[1], HistoBucket { le_us: 3, count: 3 });
        assert_eq!(s.buckets.last().unwrap().le_us, 1023);
    }

    #[test]
    fn empty_histo_snapshot_has_no_buckets() {
        let s = HistoSnapshot::collect(&LatencyHisto::new());
        assert_eq!(s.count, 0);
        assert!(s.buckets.is_empty());
    }

    #[test]
    fn json_roundtrips_with_required_keys() {
        let m = busy_metrics();
        let snap = MetricsSnapshot::collect(
            &m,
            Some(KvGauges { pages_used: 10, pages_total: 100, slab_pages: 8 }),
            Duration::from_secs(2),
        );
        let j = Json::parse(&snap.to_json().to_string()).expect("export must be valid JSON");
        for key in [
            "schema_version",
            "uptime_us",
            "requests.submitted",
            "requests.completed",
            "latency_us.ttft.count",
            "latency_us.ttft.buckets",
            "latency_us.queue.p99_us",
            "latency_us.decode_step.count",
            "latency_us.gen_ttft.p99_us",
            "latency_us.tpot.p99_us",
            "decode.steps",
            "spec.rounds",
            "prefix.covered_ratio",
            "prefix.ingest_chunks",
            "failures.worker_panics",
            "failures.errors_dropped",
            "degradation.level",
            "kv.occupancy",
            "sparsity.bands",
            "trace",
        ] {
            assert!(j.path(key).is_some(), "missing key {key}");
        }
        assert_eq!(j.path("requests.submitted").unwrap().as_i64(), Some(10));
        let bands = j.path("sparsity.bands").unwrap().as_arr().unwrap();
        assert_eq!(bands.len(), crate::obs::sparsity::N_BANDS);
        // the 4k-16k band saw our sparse step
        let b = bands.iter().find(|b| b.get("band").unwrap().as_str() == Some("4k-16k")).unwrap();
        assert_eq!(b.get("steps").unwrap().as_i64(), Some(1));
        assert!((b.get("mean_score_mass").unwrap().as_f64().unwrap() - 0.93).abs() < 1e-3);
        assert_eq!(
            j.path("failures.recent_errors").unwrap().idx(0).unwrap().as_str(),
            Some("one bad thing")
        );
    }

    #[test]
    fn prometheus_exposition_is_well_formed() {
        let mut m = busy_metrics();
        m.trace = crate::obs::trace::Trace::new(64);
        m.trace.record(1, crate::obs::trace::EventKind::Reject);
        let snap = MetricsSnapshot::collect(
            &m,
            Some(KvGauges { pages_used: 1, pages_total: 4, slab_pages: 1 }),
            Duration::from_secs(1),
        );
        let text = snap.to_prometheus();
        assert!(text.contains("# TYPE stem_requests_submitted_total counter"));
        assert!(text.contains("stem_requests_submitted_total 10"));
        assert!(text.contains("# TYPE stem_ttft_us histogram"));
        assert!(text.contains("stem_ttft_us_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("stem_ttft_us_count 3"));
        assert!(text.contains("stem_kv_pages_total 4"));
        assert!(text.contains("stem_sparsity_steps_total{band=\"4k-16k\"} 1"));
        assert!(text.contains("stem_trace_events_recorded 1"));
        assert!(text.contains("stem_ingest_chunks_total 5"));
        // every +Inf bucket count equals its _count line
        for name in [
            "stem_queue_us",
            "stem_exec_us",
            "stem_ttft_us",
            "stem_decode_step_us",
            "stem_gen_ttft_us",
            "stem_tpot_us",
        ] {
            let inf = text
                .lines()
                .find(|l| l.starts_with(&format!("{name}_bucket{{le=\"+Inf\"}}")))
                .unwrap();
            let cnt =
                text.lines().find(|l| l.starts_with(&format!("{name}_count"))).unwrap();
            assert_eq!(
                inf.rsplit(' ').next().unwrap(),
                cnt.rsplit(' ').next().unwrap(),
                "{name}"
            );
        }
    }

    #[test]
    fn decode_backend_label_flows_to_json_and_prometheus() {
        let m = busy_metrics();
        let mut snap = MetricsSnapshot::collect(&m, None, Duration::from_secs(1));
        // a bare metrics block has no serving backend attached
        assert_eq!(snap.decode_backend, None);
        let j = Json::parse(&snap.to_json().to_string()).unwrap();
        assert!(j.path("decode.backend").is_some(), "key present even when null");
        assert!(!snap.to_prometheus().contains("stem_decode_backend_info"));
        // the coordinator stamps its backend post-collect
        snap.decode_backend = Some("engine");
        let j = Json::parse(&snap.to_json().to_string()).unwrap();
        assert_eq!(j.path("decode.backend").unwrap().as_str(), Some("engine"));
        assert!(snap
            .to_prometheus()
            .contains("stem_decode_backend_info{backend=\"engine\"} 1"));
    }

    #[test]
    fn simd_dispatch_label_flows_to_json_and_prometheus() {
        let m = busy_metrics();
        let snap = MetricsSnapshot::collect(&m, None, Duration::from_secs(1));
        // collect reads the process-global dispatch state; whatever arm
        // is active, the label must be one of the stable three and must
        // flow through both exports verbatim
        let arm = snap.simd_dispatch;
        assert!(["scalar", "wide-avx2", "wide-portable"].contains(&arm), "{arm}");
        let j = Json::parse(&snap.to_json().to_string()).unwrap();
        assert_eq!(j.path("simd.dispatch").unwrap().as_str(), Some(arm));
        assert!(snap
            .to_prometheus()
            .contains(&format!("stem_simd_dispatch_info{{arm=\"{arm}\"}} 1")));
    }

    /// Satellite: the `degradation_level` / `degradation_transitions`
    /// gauges surfaced in the snapshot must track `coordinator::degrade`
    /// state exactly across a forced up-then-down cycle, mirroring the
    /// dispatcher's wiring (store level + bump transitions on change).
    #[test]
    fn degradation_gauges_track_ladder_cycle() {
        let m = Metrics::new();
        let cfg = DegradeConfig {
            up_patience: 2,
            down_patience: 2,
            eval_every: Duration::from_millis(1),
            ..Default::default()
        };
        let mut d = Degrader::new(cfg);
        let t0 = Instant::now();
        let mut now = t0;
        let mut transitions = 0u64;
        let mirror = |d: &Degrader, before: u8, transitions: &mut u64| {
            if d.level() != before {
                *transitions += 1;
                m.degradation_level.store(d.level() as u64, Ordering::Relaxed);
                m.degradation_transitions.fetch_add(1, Ordering::Relaxed);
            }
        };
        // force the ladder all the way up under sustained pressure
        for _ in 0..40 {
            now += Duration::from_millis(2);
            let before = d.level();
            d.observe(now, 0.99, 10);
            mirror(&d, before, &mut transitions);
        }
        assert!(d.level() > 0, "sustained pressure must degrade");
        let top = d.level();
        let snap = MetricsSnapshot::collect(&m, None, t0.elapsed());
        assert_eq!(snap.degradation_level, top as u64, "snapshot gauge != ladder level");
        assert_eq!(snap.degradation_transitions, transitions);

        // then all the way back down under sustained calm
        for _ in 0..200 {
            now += Duration::from_millis(2);
            let before = d.level();
            d.observe(now, 0.0, 0);
            mirror(&d, before, &mut transitions);
        }
        assert_eq!(d.level(), 0, "sustained calm must fully recover");
        let snap = MetricsSnapshot::collect(&m, None, t0.elapsed());
        assert_eq!(snap.degradation_level, 0);
        assert_eq!(snap.degradation_transitions, transitions);
        assert!(
            snap.degradation_transitions >= 2 * top as u64,
            "a full cycle transitions at least up and down through each level"
        );
    }
}
