//! Deterministic PRNG substrate (the `rand` crate is not vendored).
//!
//! SplitMix64 for seeding + xoshiro256** for the stream — the standard
//! pairing; passes BigCrush per its authors. Used by the workload
//! generators, the property-test harness and the simulator.

/// xoshiro256** stream seeded via SplitMix64 (see module docs).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Deterministic stream from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, n). Unbiased via rejection sampling.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (self.f64()).max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate lambda (inter-arrival times).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Bernoulli draw with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Uniformly chosen element of a non-empty slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// k distinct indices from 0..n.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(2);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02, "mean off: {}", sum / 10_000.0);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            m += x;
            v += x * x;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.05 && (v - 1.0).abs() < 0.1);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let s = r.sample_indices(20, 10);
        let mut d = s.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), 10);
    }
}
