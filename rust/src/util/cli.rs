//! Tiny argument-parsing substrate (clap is not vendored).
//!
//! Supports `binary <subcommand> [--flag] [--key value] [positional...]`
//! with typed accessors and a generated usage string.

use std::collections::BTreeMap;

/// Parsed command line (see module docs for the grammar).
#[derive(Debug, Clone)]
pub struct Args {
    /// First bare token, when subcommands are enabled.
    pub subcommand: Option<String>,
    /// Bare tokens that are not the subcommand.
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]); `has_subcommand`
    /// controls whether the first bare token is the subcommand.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I, has_subcommand: bool) -> Args {
        let mut out = Args { subcommand: None, positional: vec![], flags: BTreeMap::new() };
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // value-style flag if next token is not itself a flag
                    match it.peek() {
                        Some(nx) if !nx.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.flags.insert(name.to_string(), v);
                        }
                        _ => {
                            out.flags.insert(name.to_string(), "true".to_string());
                        }
                    }
                }
            } else if has_subcommand && out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse the process arguments (argv[0] skipped).
    pub fn from_env(has_subcommand: bool) -> Args {
        Args::parse(std::env::args().skip(1), has_subcommand)
    }

    /// Boolean flag: present and not `"false"`.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).map(|v| v != "false").unwrap_or(false)
    }

    /// Raw value of `--name`, if given.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// String value of `--name`, or `default`.
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// `usize` value of `--name`, or `default` on absence/parse failure.
    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// `f64` value of `--name`, or `default` on absence/parse failure.
    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// `u64` value of `--name`, or `default` on absence/parse failure.
    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Comma-separated list, e.g. `--methods stem,dense`.
    pub fn list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// Worker-thread count for the sparse-core pool: `--threads N` wins,
    /// else `STEM_THREADS`, else every available core.
    pub fn threads(&self) -> usize {
        self.get("threads")
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or_else(crate::util::threadpool::configured_threads)
    }

    /// Install the global sparse-core pool from [`Args::threads`]; call
    /// once near process start (later calls keep the first pool).
    pub fn init_thread_pool(&self) -> usize {
        let n = self.threads();
        crate::util::threadpool::init_global(n);
        crate::util::threadpool::global().workers()
    }

    /// Pin the sparse-kernel SIMD arm from `--simd auto|scalar|wide`;
    /// call once near process start, before any kernel runs. Without the
    /// flag the `STEM_SIMD` env var (then auto-detection) decides — see
    /// [`crate::sparse::simd::active`]. Returns the resolved dispatch
    /// label, or an error for an unrecognized flag value.
    pub fn init_simd(&self) -> Result<&'static str, String> {
        if let Some(v) = self.get("simd") {
            let arm = crate::sparse::simd::parse(v).map_err(|e| format!("--simd: {e}"))?;
            crate::sparse::simd::set_override(arm);
        }
        Ok(crate::sparse::simd::dispatch_label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str], sub: bool) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()), sub)
    }

    #[test]
    fn subcommand_and_flags() {
        let a = args(&["serve", "--port", "8080", "--verbose", "--rate=2.5"], true);
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.usize_or("port", 0), 8080);
        assert!(a.flag("verbose"));
        assert_eq!(a.f64_or("rate", 0.0), 2.5);
    }

    #[test]
    fn positional() {
        let a = args(&["eval", "input.json", "--n", "4", "out.json"], true);
        assert_eq!(a.positional, vec!["input.json", "out.json"]);
        assert_eq!(a.usize_or("n", 0), 4);
    }

    #[test]
    fn trailing_bool_flag() {
        let a = args(&["--fast"], false);
        assert!(a.flag("fast"));
        assert!(!a.flag("slow"));
    }

    #[test]
    fn threads_flag_overrides() {
        let a = args(&["--threads", "3"], false);
        assert_eq!(a.threads(), 3);
        let a = args(&["--threads", "0"], false); // invalid: fall through
        assert!(a.threads() >= 1);
        let a = args(&[], false);
        assert!(a.threads() >= 1);
    }

    #[test]
    fn init_simd_rejects_unknown_arm_without_touching_dispatch() {
        // the error path must fire before the global override is written,
        // so this is safe to run alongside dispatch-sensitive tests
        let a = args(&["--simd", "turbo"], false);
        assert!(a.init_simd().is_err());
    }

    #[test]
    fn list_flag() {
        let a = args(&["--methods", "stem,dense , xattn"], false);
        assert_eq!(a.list_or("methods", &[]), vec!["stem", "dense", "xattn"]);
        assert_eq!(a.list_or("other", &["a"]), vec!["a"]);
    }
}
