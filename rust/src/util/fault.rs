//! Deterministic fault injection for chaos-testing the serving stack.
//!
//! A [`FaultPlan`] is a seeded, rate-based schedule of injected failures.
//! Components that opt in (the shared KV store, the dispatcher's worker
//! closures) ask `should_fire(point)` at well-defined injection points:
//!
//! * [`FaultPoint::KvAlloc`] — KV page allocation fails with
//!   [`crate::coordinator::kv_cache::KvError::Injected`].
//! * [`FaultPoint::EngineExec`] — a prefill execution returns an error.
//! * [`FaultPoint::DecodeStep`] — a decode-step worker panics (exercising
//!   the coordinator's `catch_unwind` isolation).
//! * [`FaultPoint::WorkerStall`] — a worker sleeps for `stall` before its
//!   work item, widening race windows.
//! * [`FaultPoint::IngestChunk`] — one chunk of a chunked prompt ingest
//!   panics on a worker (exercising chunk-boundary unwind paths).
//!
//! Decisions are a pure function of `(seed, point, nth-call)` via a
//! splitmix64 hash, so a given seed replays the same per-call decision
//! sequence; under concurrency only the interleaving varies. Plans are
//! carried as `Option<Arc<FaultPlan>>` — `None` (the default) costs one
//! branch at each injection point.
//!
//! The env var `STEM_FAULTS` configures a plan for binaries and CI:
//! `seed=42,kv=0.05,exec=0.05,step=0.02,stall=0.05,stall_us=200`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Where in the serving path a fault is injected (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultPoint {
    /// KV page allocation (`SharedKv::allocate`).
    KvAlloc = 0,
    /// Engine prefill execution on a worker.
    EngineExec = 1,
    /// Decode-step dispatch on a worker (injected as a panic).
    DecodeStep = 2,
    /// Artificial worker stall before a work item.
    WorkerStall = 3,
    /// One chunk of a chunked prompt ingest (injected as a panic).
    IngestChunk = 4,
}

const N_POINTS: usize = 5;

const POINT_NAMES: [&str; N_POINTS] = ["kv", "exec", "step", "stall", "ingest"];

/// A seeded, rate-based fault schedule (see module docs).
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    rates: [f64; N_POINTS],
    stall: Duration,
    calls: [AtomicU64; N_POINTS],
    hits: [AtomicU64; N_POINTS],
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

impl FaultPlan {
    /// A plan with the given seed and every rate zero (nothing fires
    /// until rates are set via the builder methods).
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            rates: [0.0; N_POINTS],
            stall: Duration::from_micros(200),
            calls: Default::default(),
            hits: Default::default(),
        }
    }

    /// Builder: set the firing probability of one injection point.
    pub fn with_rate(mut self, point: FaultPoint, rate: f64) -> FaultPlan {
        self.rates[point as usize] = rate.clamp(0.0, 1.0);
        self
    }

    /// Builder: set how long an injected worker stall sleeps.
    pub fn with_stall(mut self, stall: Duration) -> FaultPlan {
        self.stall = stall;
        self
    }

    /// The plan's seed (chaos tests print it on failure for replay).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Deterministically decide whether the n-th call at `point` fires;
    /// fired faults are counted for [`FaultPlan::injected`].
    pub fn should_fire(&self, point: FaultPoint) -> bool {
        let i = point as usize;
        let rate = self.rates[i];
        if rate <= 0.0 {
            return false;
        }
        let n = self.calls[i].fetch_add(1, Ordering::Relaxed);
        let h = splitmix64(self.seed ^ ((i as u64 + 1) << 56) ^ n);
        let frac = (h >> 11) as f64 / (1u64 << 53) as f64;
        let fire = frac < rate;
        if fire {
            self.hits[i].fetch_add(1, Ordering::Relaxed);
        }
        fire
    }

    /// Sleep for the configured stall when the stall point fires.
    pub fn maybe_stall(&self) {
        if self.should_fire(FaultPoint::WorkerStall) {
            std::thread::sleep(self.stall);
        }
    }

    /// Faults injected so far at `point`.
    pub fn injected(&self, point: FaultPoint) -> u64 {
        self.hits[point as usize].load(Ordering::Relaxed)
    }

    /// Total injection-point calls observed so far at `point`.
    pub fn calls(&self, point: FaultPoint) -> u64 {
        self.calls[point as usize].load(Ordering::Relaxed)
    }

    /// Render the plan back as a `STEM_FAULTS` spec that
    /// [`FaultPlan::parse`] accepts — the replay line printed at the head
    /// of flight-recorder failure dumps (see `obs::trace`).
    pub fn spec_string(&self) -> String {
        let mut s = format!("seed={}", self.seed);
        for (i, name) in POINT_NAMES.iter().enumerate() {
            if self.rates[i] > 0.0 {
                s.push_str(&format!(",{name}={}", self.rates[i]));
            }
        }
        if self.rates[FaultPoint::WorkerStall as usize] > 0.0 {
            s.push_str(&format!(",stall_us={}", self.stall.as_micros()));
        }
        s
    }

    /// Parse a `STEM_FAULTS`-style spec, e.g.
    /// `seed=42,kv=0.05,exec=0.05,step=0.02,stall=0.05,stall_us=200`.
    /// Unknown keys are an error so typos cannot silently disable chaos.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::new(0);
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (k, v) = part.split_once('=').ok_or_else(|| format!("missing `=` in `{part}`"))?;
            let (k, v) = (k.trim(), v.trim());
            match k {
                "seed" => plan.seed = v.parse().map_err(|_| format!("bad seed `{v}`"))?,
                "stall_us" => {
                    let us: u64 = v.parse().map_err(|_| format!("bad stall_us `{v}`"))?;
                    plan.stall = Duration::from_micros(us);
                }
                _ => {
                    let i = POINT_NAMES
                        .iter()
                        .position(|n| *n == k)
                        .ok_or_else(|| format!("unknown fault key `{k}`"))?;
                    let rate: f64 = v.parse().map_err(|_| format!("bad rate `{v}` for `{k}`"))?;
                    plan.rates[i] = rate.clamp(0.0, 1.0);
                }
            }
        }
        Ok(plan)
    }

    /// Build a plan from the `STEM_FAULTS` env var; `None` when unset or
    /// empty. A malformed spec aborts loudly — silently running a chaos
    /// job with no faults would pass vacuously.
    pub fn from_env() -> Option<FaultPlan> {
        let spec = std::env::var("STEM_FAULTS").ok()?;
        if spec.trim().is_empty() {
            return None;
        }
        match FaultPlan::parse(&spec) {
            Ok(p) => Some(p),
            Err(e) => panic!("invalid STEM_FAULTS=`{spec}`: {e}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_rate_never_fires_and_counts_nothing() {
        let p = FaultPlan::new(7);
        for _ in 0..100 {
            assert!(!p.should_fire(FaultPoint::KvAlloc));
        }
        assert_eq!(p.injected(FaultPoint::KvAlloc), 0);
        assert_eq!(p.calls(FaultPoint::KvAlloc), 0, "disabled points skip the counter");
    }

    #[test]
    fn same_seed_replays_the_same_decision_sequence() {
        let run = |seed| {
            let p = FaultPlan::new(seed).with_rate(FaultPoint::EngineExec, 0.3);
            (0..200).map(|_| p.should_fire(FaultPoint::EngineExec)).collect::<Vec<_>>()
        };
        assert_eq!(run(1), run(1));
        assert_ne!(run(1), run(2), "different seeds diverge");
        let fired = run(1).iter().filter(|&&f| f).count();
        assert!(fired > 20 && fired < 120, "rate roughly honored: {fired}/200");
    }

    #[test]
    fn points_are_independent_streams() {
        let p = FaultPlan::new(3).with_rate(FaultPoint::KvAlloc, 1.0);
        assert!(p.should_fire(FaultPoint::KvAlloc));
        assert!(!p.should_fire(FaultPoint::DecodeStep), "other points stay silent");
        assert_eq!(p.injected(FaultPoint::KvAlloc), 1);
        assert_eq!(p.injected(FaultPoint::DecodeStep), 0);
    }

    #[test]
    fn parses_full_spec() {
        let p = FaultPlan::parse("seed=42, kv=0.5, exec=0.25, step=0.1, stall=1.5, stall_us=99")
            .expect("valid spec");
        assert_eq!(p.seed(), 42);
        assert_eq!(p.rates, [0.5, 0.25, 0.1, 1.0, 0.0], "rates clamp to [0,1]");
        assert_eq!(p.stall, Duration::from_micros(99));
        // the chunk-boundary point parses and roundtrips like the others
        let q = FaultPlan::parse("seed=7,ingest=0.3").expect("ingest key");
        assert_eq!(q.rates[FaultPoint::IngestChunk as usize], 0.3);
        assert_eq!(q.spec_string(), "seed=7,ingest=0.3");
    }

    #[test]
    fn spec_string_roundtrips_through_parse() {
        let p = FaultPlan::new(42)
            .with_rate(FaultPoint::KvAlloc, 0.05)
            .with_rate(FaultPoint::DecodeStep, 0.02)
            .with_rate(FaultPoint::WorkerStall, 0.1)
            .with_stall(Duration::from_micros(250));
        let spec = p.spec_string();
        assert!(spec.starts_with("seed=42"), "{spec}");
        let q = FaultPlan::parse(&spec).expect("spec_string must parse back");
        assert_eq!(q.seed(), 42);
        assert_eq!(q.rates, p.rates);
        assert_eq!(q.stall, p.stall);
        // quiet points are omitted so the replay line stays short
        assert!(!spec.contains("exec="), "{spec}");
        // a stall-free plan omits stall_us entirely
        let bare = FaultPlan::new(7).with_rate(FaultPoint::EngineExec, 1.0).spec_string();
        assert_eq!(bare, "seed=7,exec=1");
    }

    #[test]
    fn rejects_unknown_keys_and_garbage() {
        assert!(FaultPlan::parse("kv").is_err(), "missing =");
        assert!(FaultPlan::parse("bogus=1").is_err(), "unknown key");
        assert!(FaultPlan::parse("kv=abc").is_err(), "bad rate");
        assert!(FaultPlan::parse("seed=x").is_err(), "bad seed");
    }
}
