//! Property-testing substrate (proptest is not vendored).
//!
//! `forall(seed, cases, gen, check)` runs `check` on `cases` random values
//! from `gen`; on failure it performs greedy shrinking via the value's
//! `Shrink` implementation and reports the minimal counterexample. Used by
//! the coordinator/sparse invariant tests (DESIGN.md §7).

use crate::util::rng::Rng;

/// Types that can propose smaller versions of themselves for
/// counterexample minimization.
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    /// Candidate strictly-smaller values, tried in order.
    fn shrink(&self) -> Vec<Self> {
        vec![]
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        let mut out = vec![];
        if *self > 0 {
            out.push(self / 2);
            out.push(self - 1);
        }
        out
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = vec![];
        if *self > 0 {
            out.push(self / 2);
            out.push(self - 1);
        }
        out
    }
}

impl Shrink for u32 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = vec![];
        if *self > 0 {
            out.push(self / 2);
            out.push(self - 1);
        }
        out
    }
}

impl Shrink for i32 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = vec![];
        if *self != 0 {
            out.push(self / 2);
            out.push(self - self.signum());
        }
        out
    }
}

impl Shrink for bool {
    fn shrink(&self) -> Vec<Self> {
        if *self {
            vec![false]
        } else {
            vec![]
        }
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = vec![];
        if self.abs() > 1e-9 {
            out.push(self / 2.0);
            out.push(0.0);
        }
        out
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = vec![];
        if !self.is_empty() {
            out.push(self[..self.len() / 2].to_vec());
            out.push(self[1..].to_vec());
            // shrink one element
            for (i, x) in self.iter().enumerate() {
                for s in x.shrink().into_iter().take(1) {
                    let mut v = self.clone();
                    v[i] = s;
                    out.push(v);
                }
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self.0.shrink().into_iter().map(|a| (a, self.1.clone())).collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

impl<A: Shrink, B: Shrink, C: Shrink> Shrink for (A, B, C) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> =
            self.0.shrink().into_iter().map(|a| (a, self.1.clone(), self.2.clone())).collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b, self.2.clone())));
        out.extend(self.2.shrink().into_iter().map(|c| (self.0.clone(), self.1.clone(), c)));
        out
    }
}

impl<A: Shrink, B: Shrink, C: Shrink, D: Shrink> Shrink for (A, B, C, D) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone(), self.3.clone()))
            .collect();
        out.extend(
            self.1.shrink().into_iter().map(|b| (self.0.clone(), b, self.2.clone(), self.3.clone())),
        );
        out.extend(
            self.2.shrink().into_iter().map(|c| (self.0.clone(), self.1.clone(), c, self.3.clone())),
        );
        out.extend(
            self.3.shrink().into_iter().map(|d| (self.0.clone(), self.1.clone(), self.2.clone(), d)),
        );
        out
    }
}

impl<A: Shrink, B: Shrink, C: Shrink, D: Shrink, E: Shrink> Shrink for (A, B, C, D, E) {
    fn shrink(&self) -> Vec<Self> {
        // delegate to the 4-tuple impl over a nested split
        let nested = ((self.0.clone(), self.1.clone()), self.2.clone(), self.3.clone(), self.4.clone());
        nested
            .shrink()
            .into_iter()
            .map(|((a, b), c, d, e)| (a, b, c, d, e))
            .collect()
    }
}

/// Run the property; panics with the minimal counterexample on failure.
pub fn forall<T, G, C>(seed: u64, cases: usize, mut gen: G, check: C)
where
    T: Shrink,
    G: FnMut(&mut Rng) -> T,
    C: Fn(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let value = gen(&mut rng);
        if let Err(msg) = check(&value) {
            // greedy shrink
            let mut cur = value;
            let mut cur_msg = msg;
            let mut budget = 200;
            'outer: while budget > 0 {
                for cand in cur.shrink() {
                    budget -= 1;
                    if let Err(m) = check(&cand) {
                        cur = cand;
                        cur_msg = m;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}, seed {seed}):\n  value: {cur:?}\n  error: {cur_msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        forall(1, 200, |r| r.below(100) as usize, |&x| {
            if x < 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_and_shrinks() {
        forall(2, 200, |r| r.below(1000) as usize, |&x| {
            if x < 500 {
                Ok(())
            } else {
                Err(format!("{x} too big"))
            }
        });
    }

    #[test]
    fn shrink_vec_reduces() {
        let v = vec![3usize, 4, 5];
        assert!(v.shrink().iter().all(|s| s.len() < v.len() || s.iter().sum::<usize>() < 12));
    }

    #[test]
    fn shrink_tuple5_and_scalar_impls_reduce() {
        let t = (4usize, 2u64, 1.0f64, 8usize, 3u32);
        for cand in t.shrink() {
            let changed = [
                cand.0 != t.0,
                cand.1 != t.1,
                cand.2 != t.2,
                cand.3 != t.3,
                cand.4 != t.4,
            ];
            assert_eq!(changed.iter().filter(|&&c| c).count(), 1, "{cand:?}");
        }
        assert!(!t.shrink().is_empty());
        assert_eq!(0u32.shrink(), vec![]);
        assert!((-4i32).shrink().contains(&-3));
        assert_eq!(true.shrink(), vec![false]);
        assert!(false.shrink().is_empty());
    }

    #[test]
    fn shrink_tuple4_varies_one_component() {
        let t = (4usize, 2u64, 1.0f64, 8usize);
        for cand in t.shrink() {
            let changed = [cand.0 != t.0, cand.1 != t.1, cand.2 != t.2, cand.3 != t.3];
            assert_eq!(changed.iter().filter(|&&c| c).count(), 1, "{cand:?}");
        }
        assert!(!t.shrink().is_empty());
    }
}
