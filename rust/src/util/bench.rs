//! Micro-benchmark substrate (criterion is not vendored).
//!
//! Warmup + calibrated iteration count + robust statistics (median, MAD,
//! p10/p90), printed in a criterion-like one-liner. `benches/*.rs` use
//! `harness = false` and drive this directly, so `cargo bench` works.

use std::time::{Duration, Instant};

/// Robust timing statistics of one benchmark.
#[derive(Debug, Clone)]
pub struct Stats {
    /// Benchmark name.
    pub name: String,
    /// Measured iterations.
    pub iters: usize,
    /// Median ns per iteration.
    pub median_ns: f64,
    /// Mean ns per iteration.
    pub mean_ns: f64,
    /// 10th-percentile ns.
    pub p10_ns: f64,
    /// 90th-percentile ns.
    pub p90_ns: f64,
    /// Median absolute deviation, ns.
    pub mad_ns: f64,
}

impl Stats {
    /// Median as a `Duration`.
    pub fn median(&self) -> Duration {
        Duration::from_nanos(self.median_ns as u64)
    }

    /// Print the criterion-style one-liner.
    pub fn print(&self) {
        println!(
            "bench {:<44} {:>12} med {:>12} p90   ({} iters, ±{})",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.p90_ns),
            self.iters,
            fmt_ns(self.mad_ns),
        );
    }
}

/// Human-format a nanosecond count (ns/µs/ms/s).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Warmup/iteration policy driving [`Bencher::run`].
pub struct Bencher {
    /// Warmup wall-time before measuring.
    pub warmup: Duration,
    /// Total measurement wall-time budget.
    pub target: Duration,
    /// Iteration ceiling.
    pub max_iters: usize,
    /// Iteration floor.
    pub min_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            target: Duration::from_secs(1),
            max_iters: 10_000,
            min_iters: 5,
        }
    }
}

impl Bencher {
    /// Short policy for CI smoke runs (`--quick`).
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            target: Duration::from_millis(300),
            max_iters: 2_000,
            min_iters: 3,
        }
    }

    /// Benchmark `f`, which performs ONE operation per call.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Stats {
        // warmup + single-shot estimate
        let w0 = Instant::now();
        let mut warm_iters = 0usize;
        while w0.elapsed() < self.warmup || warm_iters == 0 {
            f();
            warm_iters += 1;
            if warm_iters > self.max_iters {
                break;
            }
        }
        let per = w0.elapsed().as_secs_f64() / warm_iters as f64;
        let iters = ((self.target.as_secs_f64() / per.max(1e-9)) as usize)
            .clamp(self.min_iters, self.max_iters);

        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        stats_from(name, samples)
    }
}

/// Compute [`Stats`] from raw per-iteration nanosecond samples.
pub fn stats_from(name: &str, mut samples: Vec<f64>) -> Stats {
    assert!(!samples.is_empty());
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let q = |p: f64| samples[(((n - 1) as f64) * p) as usize];
    let median = q(0.5);
    let mean = samples.iter().sum::<f64>() / n as f64;
    let mut dev: Vec<f64> = samples.iter().map(|x| (x - median).abs()).collect();
    dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Stats {
        name: name.to_string(),
        iters: n,
        median_ns: median,
        mean_ns: mean,
        p10_ns: q(0.1),
        p90_ns: q(0.9),
        mad_ns: dev[n / 2],
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = stats_from("t", vec![5.0, 1.0, 3.0, 2.0, 4.0]);
        assert_eq!(s.median_ns, 3.0);
        assert!(s.p10_ns <= s.median_ns && s.median_ns <= s.p90_ns);
    }

    #[test]
    fn run_measures_something() {
        let b = Bencher::quick();
        let mut acc = 0u64;
        let s = b.run("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(s.iters >= 3);
        assert!(s.median_ns >= 0.0);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5e4).ends_with("µs"));
        assert!(fmt_ns(5e7).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with('s'));
    }
}
