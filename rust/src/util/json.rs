//! Minimal-but-complete JSON substrate (serde is not in the offline crate
//! universe — see DESIGN.md §2). Parses the full grammar (RFC 8259) and
//! serializes with stable key order. Used for the artifact manifest, eval
//! sets, golden vectors, run reports and config files.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (stored as f64, like javascript).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, keys sorted (BTreeMap) for stable serialization.
    Obj(BTreeMap<String, Json>),
}

/// Parse failure with its byte position.
#[derive(Debug, thiserror::Error)]
#[error("json error at byte {pos}: {msg}")]
pub struct JsonError {
    /// Byte offset the parser stopped at.
    pub pos: usize,
    /// What went wrong.
    pub msg: String,
}

impl Json {
    /// Parse a complete JSON document (trailing data is an error).
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors (None on type mismatch / missing key) -------------

    /// Object member by key.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array element by index.
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    /// The number, if this is one.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The number truncated to i64, if this is a number.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    /// The number truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    /// The string, if this is one.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The bool, if this is one.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The array slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The object map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.path("a.b.c")` — dotted lookup.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    // -- builders ------------------------------------------------------------

    /// Object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Array from an iterator of values.
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    /// Number from anything convertible to f64.
    pub fn num<N: Into<f64>>(n: N) -> Json {
        Json::Num(n.into())
    }

    /// String from anything convertible to `String`.
    pub fn str<S: Into<String>>(s: S) -> Json {
        Json::Str(s.into())
    }

    /// Number array from an f64 slice.
    pub fn f64s(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("bad low surrogate"));
                            }
                            0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            cp
                        };
                        out.push(
                            char::from_u32(ch).ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences byte-wise
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    self.pos = start + len;
                    if self.pos > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.path("a").unwrap().idx(2).unwrap().get("b"), Some(&Json::Null));
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_garbage() {
        for s in ["{", "[1,", "\"a", "01x", "{\"a\" 1}", "[1 2]", "nul"] {
            assert!(Json::parse(s).is_err(), "should reject {s}");
        }
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("-2.5E-1").unwrap().as_f64(), Some(-0.25));
    }

    #[test]
    fn display_escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }
}
