//! Thread-pool substrate (tokio is not vendored; the request path is
//! CPU-bound PJRT execution, so an OS-thread pool with an mpsc work queue
//! is the right shape anyway).
//!
//! `ThreadPool` — fixed workers pulling `FnOnce` jobs from a shared queue.
//! `scope_parallel` — fork-join helper used by the eval harness to fan an
//! indexed job list over the pool and collect results in order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<std::collections::VecDeque<Job>>,
    available: Condvar,
    shutdown: Mutex<bool>,
    in_flight: AtomicUsize,
    done: Condvar,
    done_lock: Mutex<()>,
}

pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(std::collections::VecDeque::new()),
            available: Condvar::new(),
            shutdown: Mutex::new(false),
            in_flight: AtomicUsize::new(0),
            done: Condvar::new(),
            done_lock: Mutex::new(()),
        });
        let workers = (0..n.max(1))
            .map(|_| {
                let sh = Arc::clone(&shared);
                thread::spawn(move || worker_loop(sh))
            })
            .collect();
        ThreadPool { shared, workers }
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        self.shared.queue.lock().unwrap().push_back(Box::new(f));
        self.shared.available.notify_one();
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.done_lock.lock().unwrap();
        while self.shared.in_flight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.done.wait(guard).unwrap();
        }
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break Some(j);
                }
                if *sh.shutdown.lock().unwrap() {
                    break None;
                }
                q = sh.available.wait(q).unwrap();
            }
        };
        match job {
            None => return,
            Some(j) => {
                j();
                if sh.in_flight.fetch_sub(1, Ordering::SeqCst) == 1 {
                    let _g = sh.done_lock.lock().unwrap();
                    sh.done.notify_all();
                }
            }
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `jobs(i)` for i in 0..n on `pool`, returning results in index order.
pub fn scope_parallel<T, F>(pool: &ThreadPool, n: usize, f: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(usize) -> T + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    for i in 0..n {
        let f = Arc::clone(&f);
        let tx = tx.clone();
        pool.submit(move || {
            let r = f(i);
            let _ = tx.send((i, r));
        });
    }
    drop(tx);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (i, r) in rx {
        out[i] = Some(r);
    }
    out.into_iter().map(|x| x.expect("worker panicked")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scope_parallel_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = scope_parallel(&pool, 50, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }
}
