//! Thread-pool substrate (tokio is not vendored; the request path is
//! CPU-bound PJRT execution, so an OS-thread pool with an mpsc work queue
//! is the right shape anyway).
//!
//! `ThreadPool` — fixed workers pulling `FnOnce` jobs from a shared queue.
//! `scope_parallel` — fork-join helper used by the eval harness to fan an
//! indexed job list over the pool and collect results in order.
//! `scope_parallel_borrowed` — same fork-join shape, but the closure may
//! borrow from the caller's stack; this is what the sparse-core kernels
//! fan (head, query-block) work items through.
//! `global()` — lazily-initialized process-wide pool sized by
//! `STEM_THREADS` (env) falling back to `available_parallelism()`.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<std::collections::VecDeque<Job>>,
    available: Condvar,
    shutdown: Mutex<bool>,
    in_flight: AtomicUsize,
    done: Condvar,
    done_lock: Mutex<()>,
}

/// Fixed-size OS-thread pool over an mpsc work queue (see module docs).
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn a pool of `n` workers (at least one).
    pub fn new(n: usize) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(std::collections::VecDeque::new()),
            available: Condvar::new(),
            shutdown: Mutex::new(false),
            in_flight: AtomicUsize::new(0),
            done: Condvar::new(),
            done_lock: Mutex::new(()),
        });
        let workers = (0..n.max(1))
            .map(|_| {
                let sh = Arc::clone(&shared);
                thread::spawn(move || worker_loop(sh))
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Worker-thread count.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue one job for any worker to run.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        self.shared.queue.lock().unwrap().push_back(Box::new(f));
        self.shared.available.notify_one();
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.done_lock.lock().unwrap();
        while self.shared.in_flight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.done.wait(guard).unwrap();
        }
    }

    /// Pop and execute one queued job on the calling thread, with the same
    /// accounting a worker would perform. Returns false if the queue was
    /// empty. Lets a blocked forker help drain the queue, which keeps
    /// nested `scope_parallel_borrowed` calls deadlock-free. A panicking
    /// job is contained (see the private `run_job` helper): it must not
    /// unwind through a forker whose other jobs still borrow its stack
    /// frame.
    pub fn run_pending_one(&self) -> bool {
        let job = self.shared.queue.lock().unwrap().pop_front();
        match job {
            Some(j) => {
                run_job(&self.shared, j);
                true
            }
            None => false,
        }
    }
}

/// Execute one job with pool accounting. The job is run under
/// `catch_unwind` so a panic can neither kill a worker thread, leak
/// `in_flight` (which would hang `wait_idle`), nor unwind through a
/// `scope_parallel_borrowed` caller draining the queue. Fork-join callers
/// observe panics through their own channels/flags instead.
fn run_job(sh: &Shared, j: Job) {
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(j));
    if sh.in_flight.fetch_sub(1, Ordering::SeqCst) == 1 {
        let _g = sh.done_lock.lock().unwrap();
        sh.done.notify_all();
    }
    if outcome.is_err() {
        eprintln!("[stem] thread-pool job panicked (contained)");
    }
}

/// Worker-thread count for the global pool: `STEM_THREADS` (if set to a
/// positive integer) else `available_parallelism()`.
pub fn configured_threads() -> usize {
    std::env::var("STEM_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();

/// Install the global pool with an explicit worker count (e.g. from a
/// `--threads` flag). Returns false if the pool was already initialized,
/// in which case the existing pool is kept.
pub fn init_global(n: usize) -> bool {
    if GLOBAL.get().is_some() {
        return false;
    }
    GLOBAL.set(ThreadPool::new(n.max(1))).is_ok()
}

/// The process-wide pool used by the sparse-core kernels and the eval
/// harness. First use wins: `init_global` (CLI) or `configured_threads()`.
pub fn global() -> &'static ThreadPool {
    GLOBAL.get_or_init(|| ThreadPool::new(configured_threads()))
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break Some(j);
                }
                if *sh.shutdown.lock().unwrap() {
                    break None;
                }
                q = sh.available.wait(q).unwrap();
            }
        };
        match job {
            None => return,
            Some(j) => run_job(&sh, j),
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `jobs(i)` for i in 0..n on `pool`, returning results in index order.
pub fn scope_parallel<T, F>(pool: &ThreadPool, n: usize, f: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(usize) -> T + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    for i in 0..n {
        let f = Arc::clone(&f);
        let tx = tx.clone();
        pool.submit(move || {
            let r = f(i);
            let _ = tx.send((i, r));
        });
    }
    drop(tx);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (i, r) in rx {
        out[i] = Some(r);
    }
    out.into_iter().map(|x| x.expect("worker panicked")).collect()
}

/// Fork-join over borrowed state: run `f(i)` for i in 0..n on `pool` and
/// return results in index order. Unlike [`scope_parallel`], `f` (and `T`)
/// may borrow from the caller's stack: the call only returns once every
/// job has finished, which is what makes the lifetime erasure below sound.
/// While blocked, the calling thread helps drain the pool's queue, so the
/// caller acts as an extra worker and nested calls cannot deadlock.
pub fn scope_parallel_borrowed<T, F>(pool: &ThreadPool, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    if n == 1 {
        return vec![f(0)];
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let pending = Arc::new((Mutex::new(n), Condvar::new()));
    let panicked = Arc::new(AtomicBool::new(false));
    // Smuggle the borrows through the pool's `'static` job type as raw
    // addresses. SAFETY: this frame blocks on `pending` below until all n
    // jobs have run, so `f` and `out` outlive every access; each job
    // writes a distinct slot, so slots never alias.
    let f_addr = &f as *const F as usize;
    let out_addr = out.as_mut_ptr() as usize;
    for i in 0..n {
        let pending = Arc::clone(&pending);
        let panicked = Arc::clone(&panicked);
        pool.submit(move || {
            let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
                let f = &*(f_addr as *const F);
                let slot = (out_addr as *mut Option<T>).add(i);
                *slot = Some(f(i));
            }));
            if run.is_err() {
                panicked.store(true, Ordering::SeqCst);
            }
            let (lock, cv) = &*pending;
            let mut left = lock.lock().unwrap();
            *left -= 1;
            if *left == 0 {
                cv.notify_all();
            }
        });
    }
    let (lock, cv) = &*pending;
    loop {
        if *lock.lock().unwrap() == 0 {
            break;
        }
        if pool.run_pending_one() {
            continue;
        }
        // Queue drained from our side; block until in-flight jobs finish.
        // The completion path locks `pending.0` before notifying, so this
        // re-check-then-wait cannot miss a wakeup.
        let left = lock.lock().unwrap();
        if *left == 0 {
            break;
        }
        drop(cv.wait(left).unwrap());
    }
    if panicked.load(Ordering::SeqCst) {
        panic!("scope_parallel_borrowed: a parallel job panicked");
    }
    out.into_iter().map(|x| x.expect("job did not run")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scope_parallel_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = scope_parallel(&pool, 50, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn scope_parallel_borrowed_borrows_caller_state() {
        let pool = ThreadPool::new(4);
        let data: Vec<u64> = (0..256).collect();
        let out = scope_parallel_borrowed(&pool, data.len(), |i| data[i] * 3);
        assert_eq!(out, data.iter().map(|x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    fn scope_parallel_borrowed_handles_nesting() {
        // inner fork-join from inside an outer job must not deadlock even
        // on a single-worker pool (the forker helps drain the queue)
        let pool = ThreadPool::new(1);
        let out = scope_parallel_borrowed(&pool, 4, |i| {
            scope_parallel_borrowed(&pool, 3, |j| i * 10 + j).iter().sum::<usize>()
        });
        assert_eq!(out, vec![3, 33, 63, 93]);
    }

    #[test]
    fn run_pending_one_drains_queue() {
        let pool = ThreadPool::new(1);
        // saturate the single worker so jobs stay queued; wait until the
        // worker actually holds the gate job so we cannot pop it ourselves
        let gate = Arc::new(AtomicU64::new(0));
        let started = Arc::new(AtomicU64::new(0));
        let (g, s) = (Arc::clone(&gate), Arc::clone(&started));
        pool.submit(move || {
            s.store(1, Ordering::SeqCst);
            while g.load(Ordering::SeqCst) == 0 {
                std::thread::yield_now();
            }
        });
        while started.load(Ordering::SeqCst) == 0 {
            std::thread::yield_now();
        }
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..5 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        while pool.run_pending_one() {}
        assert_eq!(counter.load(Ordering::SeqCst), 5);
        gate.store(1, Ordering::SeqCst);
        pool.wait_idle();
    }

    #[test]
    fn configured_threads_is_positive() {
        assert!(configured_threads() >= 1);
        assert!(global().workers() >= 1);
    }
}
