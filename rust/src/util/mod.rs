//! Hand-rolled substrates standing in for crates absent from the offline
//! vendor set (DESIGN.md §2): JSON, CLI parsing, PRNG, thread pool,
//! micro-benchmarks, property testing, and a tiny logger.

pub mod bench;
pub mod cli;
pub mod fault;
pub mod json;
pub mod prop;
pub mod rng;
pub mod threadpool;

use std::sync::atomic::{AtomicU8, Ordering};

static LOG_LEVEL: AtomicU8 = AtomicU8::new(2); // 0=off 1=error 2=info 3=debug

/// Set the global log level (0=off, 1=error, 2=info, 3=debug).
pub fn set_log_level(level: u8) {
    LOG_LEVEL.store(level, Ordering::Relaxed);
}

/// Whether messages at `level` are currently emitted.
pub fn log_enabled(level: u8) -> bool {
    LOG_LEVEL.load(Ordering::Relaxed) >= level
}

/// Log at info level (level 2) to stderr.
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        if $crate::util::log_enabled(2) {
            eprintln!("[stem] {}", format!($($arg)*));
        }
    };
}

/// Log at debug level (level 3) to stderr.
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        if $crate::util::log_enabled(3) {
            eprintln!("[stem:debug] {}", format!($($arg)*));
        }
    };
}

/// Format a float table cell with fixed width.
pub fn cell(v: f64, prec: usize) -> String {
    format!("{v:>8.prec$}")
}

/// Render an ASCII table (used by every `stem tableN` command).
pub fn render_table(title: &str, header: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, c) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(c.len());
            }
        }
    }
    let mut out = String::new();
    out.push_str(&format!("## {title}\n"));
    let line = |cells: Vec<String>| -> String {
        let mut s = String::from("|");
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
        }
        s.push('\n');
        s
    };
    out.push_str(&line(header.iter().map(|s| s.to_string()).collect()));
    out.push_str(&line(widths.iter().map(|w| "-".repeat(*w)).collect()));
    for row in rows {
        out.push_str(&line(row.clone()));
    }
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn table_renders() {
        let t = super::render_table(
            "T",
            &["a", "bb"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
        assert!(t.contains("## T"));
        assert!(t.lines().count() == 5);
    }
}
