//! Pure-rust reference implementation of the Stem attention pipeline:
//! pooling, the Output-Aware Metric, selection and block-sparse attention.
//!
//! Role (DESIGN.md §7): (a) golden cross-check against the python oracles,
//! (b) the compute model behind the simulator and the scheduler's cost
//! estimates, (c) the subject of the L3 property tests. The request path
//! runs the XLA-compiled artifacts, not this.

use super::schedule::TpdConfig;
use super::tensor::{axpy, dot, norm2, Tensor};

pub const NEG_INF: f32 = -1e30;

/// Dual-diagonal block routing scores (mirror of
/// ref.pool_antidiag_scores): anti-diagonal samples cover odd within-block
/// relative offsets, diagonal samples cover the even band (pure
/// anti-diagonal is blind to copy/induction edges at exact block
/// multiples). q: [H, N, dh], k: [Hk, N, dh] -> [H, nq, nk] row-major.
pub fn antidiag_scores(q: &Tensor, k: &Tensor, block: usize, stride: usize) -> Tensor {
    let (h, n, dh) = (q.shape[0], q.shape[1], q.shape[2]);
    let hk = k.shape[0];
    let rep = h / hk;
    let nblk = n / block;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut out = Tensor::zeros(&[h, nblk, nblk]);
    for hh in 0..h {
        let hkv = hh / rep;
        for i in 0..nblk {
            for j in 0..nblk {
                let mut s = 0.0f32;
                let mut t = 0;
                while t < block {
                    let qrow = q.row3(hh, i * block + t);
                    s += dot(qrow, k.row3(hkv, j * block + (block - 1 - t)));
                    s += dot(qrow, k.row3(hkv, j * block + t));
                    t += stride;
                }
                out.set3(hh, i, j, s * scale);
            }
        }
    }
    out
}

/// Block max-pooled log||V|| (mirror of ref.value_block_logmag).
/// v: [Hk, N, dh] -> [Hk, nblk].
pub fn value_block_logmag(v: &Tensor, block: usize) -> Tensor {
    let (hk, n, _) = (v.shape[0], v.shape[1], v.shape[2]);
    let nblk = n / block;
    let mut out = Tensor::zeros(&[hk, nblk, 1]);
    for h in 0..hk {
        for b in 0..nblk {
            let mut m = f32::MIN;
            for t in 0..block {
                m = m.max((norm2(v.row3(h, b * block + t)) + 1e-12).ln());
            }
            out.set3(h, b, 0, m);
        }
    }
    out
}

/// Output-Aware Metric Eq. (7): routing + beta * max(0, logmag), causal.
pub fn oam_scores(q: &Tensor, k: &Tensor, v: &Tensor, block: usize, stride: usize, beta: f32) -> Tensor {
    let mut scores = antidiag_scores(q, k, block, stride);
    let mv = value_block_logmag(v, block);
    let (h, nblk) = (scores.shape[0], scores.shape[1]);
    let rep = h / mv.shape[0];
    for hh in 0..h {
        for i in 0..nblk {
            for j in 0..nblk {
                let s = if j <= i {
                    scores.at3(hh, i, j) + beta * mv.at3(hh / rep, j, 0).max(0.0)
                } else {
                    NEG_INF
                };
                scores.set3(hh, i, j, s);
            }
        }
    }
    scores
}

/// A block selection in the uniform kernel interface.
#[derive(Debug, Clone)]
pub struct Selection {
    pub nblk: usize,
    /// [H][nq] -> selected block ids (first `counts` entries valid).
    pub indices: Vec<Vec<Vec<u32>>>,
    pub counts: Vec<Vec<u32>>,
}

impl Selection {
    pub fn budget_fraction(&self) -> f64 {
        let nblk = self.nblk as f64;
        let total = self.counts.len() as f64 * nblk * (nblk + 1.0) / 2.0;
        let used: u64 = self.counts.iter().flatten().map(|&c| c as u64).sum();
        used as f64 / total
    }

    /// Validate the kernel-interface invariants (tests + debug builds).
    pub fn validate(&self) -> Result<(), String> {
        for (h, rows) in self.indices.iter().enumerate() {
            for (i, row) in rows.iter().enumerate() {
                let c = self.counts[h][i] as usize;
                if c == 0 || c > i + 1 {
                    return Err(format!("h{h} row{i}: count {c} out of range"));
                }
                let mut seen = vec![false; self.nblk];
                for &b in &row[..c] {
                    if b as usize > i {
                        return Err(format!("h{h} row{i}: non-causal block {b}"));
                    }
                    if seen[b as usize] {
                        return Err(format!("h{h} row{i}: duplicate block {b}"));
                    }
                    seen[b as usize] = true;
                }
            }
        }
        Ok(())
    }
}

/// Stem selection: OAM ranking + TPD budget (mirror of select_stem).
pub fn select_stem(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    block: usize,
    stride: usize,
    cfg: &TpdConfig,
    beta: f32,
) -> Selection {
    let scores = oam_scores(q, k, v, block, stride, beta);
    let (h, nblk) = (scores.shape[0], scores.shape[1]);
    let kvec = super::schedule::block_budget_schedule(nblk, cfg);
    let mut indices = vec![vec![Vec::with_capacity(nblk); nblk]; h];
    let mut counts = vec![vec![0u32; nblk]; h];
    for hh in 0..h {
        for i in 0..nblk {
            // forced: sinks + local window
            let mut key: Vec<(f32, u32)> = (0..=i)
                .map(|j| {
                    let forced = j < cfg.init_keep || j + cfg.local_keep > i;
                    let bias = if forced { 1e9 } else { 0.0 };
                    (scores.at3(hh, i, j) + bias, j as u32)
                })
                .collect();
            key.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
            indices[hh][i] = key.iter().map(|&(_, j)| j).collect();
            counts[hh][i] = kvec[i] as u32;
        }
    }
    Selection { nblk, indices, counts }
}

/// StreamingLLM selection (sinks + local window).
pub fn select_streaming(h: usize, nblk: usize, sink: usize, local: usize) -> Selection {
    let mut indices = vec![vec![Vec::new(); nblk]; h];
    let mut counts = vec![vec![0u32; nblk]; h];
    for hh in 0..h {
        for i in 0..nblk {
            let mut row: Vec<u32> = vec![];
            for j in (0..=i).rev().take(local) {
                row.push(j as u32);
            }
            for j in 0..sink.min(i + 1) {
                if !row.contains(&(j as u32)) {
                    row.push(j as u32);
                }
            }
            counts[hh][i] = row.len() as u32;
            // pad with the remaining causal blocks for interface width
            for j in 0..=i {
                if !row.contains(&(j as u32)) {
                    row.push(j as u32);
                }
            }
            indices[hh][i] = row;
        }
    }
    Selection { nblk, indices, counts }
}

/// Exact dense causal attention (reference). q:[H,N,dh] k,v:[Hk,N,dh].
pub fn dense_attention(q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
    let (h, n, dh) = (q.shape[0], q.shape[1], q.shape[2]);
    let hk = k.shape[0];
    let rep = h / hk;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut out = Tensor::zeros(&[h, n, dh]);
    let mut probs = vec![0.0f32; n];
    for hh in 0..h {
        let hkv = hh / rep;
        for i in 0..n {
            let qrow = q.row3(hh, i);
            let mut m = f32::MIN;
            for j in 0..=i {
                probs[j] = dot(qrow, k.row3(hkv, j)) * scale;
                m = m.max(probs[j]);
            }
            let mut l = 0.0f32;
            for p in probs.iter_mut().take(i + 1) {
                *p = (*p - m).exp();
                l += *p;
            }
            let orow = out.row3_mut(hh, i);
            for j in 0..=i {
                axpy(orow, probs[j] / l, v.row3(hkv, j));
            }
        }
    }
    out
}

/// Block-sparse attention under a `Selection` (renormalized softmax over
/// the selected blocks; within-block causal mask on the diagonal block).
pub fn block_sparse_attention(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    sel: &Selection,
    block: usize,
) -> Tensor {
    let (h, n, dh) = (q.shape[0], q.shape[1], q.shape[2]);
    let hk = k.shape[0];
    let rep = h / hk;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut out = Tensor::zeros(&[h, n, dh]);
    let mut svals: Vec<f32> = Vec::new();
    for hh in 0..h {
        let hkv = hh / rep;
        for qb in 0..sel.nblk {
            let c = sel.counts[hh][qb] as usize;
            let blocks = &sel.indices[hh][qb][..c];
            for r in 0..block {
                let i = qb * block + r;
                let qrow = q.row3(hh, i);
                svals.clear();
                let mut m = f32::MIN;
                for &b in blocks {
                    let b = b as usize;
                    for t in 0..block {
                        let j = b * block + t;
                        let s = if j <= i { dot(qrow, k.row3(hkv, j)) * scale } else { NEG_INF };
                        svals.push(s);
                        m = m.max(s);
                    }
                }
                let mut l = 0.0f32;
                for s in svals.iter_mut() {
                    *s = (*s - m).exp();
                    l += *s;
                }
                let orow = out.row3_mut(hh, i);
                let mut idx = 0;
                for &b in blocks {
                    let b = b as usize;
                    for t in 0..block {
                        let p = svals[idx] / l;
                        if p > 0.0 {
                            axpy(orow, p, v.row3(hkv, b * block + t));
                        }
                        idx += 1;
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn qkv(seed: u64, h: usize, hk: usize, n: usize, dh: usize) -> (Tensor, Tensor, Tensor) {
        let mut r = Rng::new(seed);
        (
            Tensor::randn(&[h, n, dh], &mut r),
            Tensor::randn(&[hk, n, dh], &mut r),
            Tensor::randn(&[hk, n, dh], &mut r),
        )
    }

    #[test]
    fn full_selection_matches_dense() {
        let (q, k, v) = qkv(1, 2, 1, 128, 16);
        let nblk = 4;
        let sel = Selection {
            nblk,
            indices: vec![(0..nblk).map(|i| (0..=i as u32).rev().collect()).collect(); 2],
            counts: vec![(1..=nblk as u32).collect(); 2],
        };
        sel.validate().unwrap();
        let sparse = block_sparse_attention(&q, &k, &v, &sel, 32);
        let dense = dense_attention(&q, &k, &v);
        assert!(sparse.max_abs_diff(&dense) < 1e-4, "diff {}", sparse.max_abs_diff(&dense));
    }

    #[test]
    fn stem_selection_valid() {
        let (q, k, v) = qkv(2, 4, 2, 256, 16);
        let sel = select_stem(&q, &k, &v, 32, 8, &TpdConfig::default(), 0.2);
        sel.validate().unwrap();
        // forced blocks present
        for h in 0..4 {
            for i in 0..sel.nblk {
                let c = sel.counts[h][i] as usize;
                let set: Vec<u32> = sel.indices[h][i][..c].to_vec();
                assert!(set.contains(&0), "sink missing h{h} i{i}");
                assert!(set.contains(&(i as u32)), "diag missing h{h} i{i}");
            }
        }
    }

    #[test]
    fn streaming_pattern_correct() {
        let sel = select_streaming(1, 8, 1, 2);
        sel.validate().unwrap();
        for i in 0..8usize {
            let c = sel.counts[0][i] as usize;
            let mut set: Vec<u32> = sel.indices[0][i][..c].to_vec();
            set.sort();
            let mut want: Vec<u32> = vec![0];
            for j in i.saturating_sub(1)..=i {
                if !want.contains(&(j as u32)) {
                    want.push(j as u32);
                }
            }
            want.sort();
            assert_eq!(set, want, "row {i}");
        }
    }

    #[test]
    fn more_budget_less_error() {
        let (q, k, v) = qkv(3, 2, 1, 256, 16);
        let dense = dense_attention(&q, &k, &v);
        let mut errs = vec![];
        for ks in [2.0, 4.0, 8.0] {
            let cfg = TpdConfig { k_start: ks, ..Default::default() };
            let sel = select_stem(&q, &k, &v, 32, 8, &cfg, 0.2);
            let o = block_sparse_attention(&q, &k, &v, &sel, 32);
            errs.push(o.mse(&dense));
        }
        assert!(errs[0] >= errs[1] && errs[1] >= errs[2], "{errs:?}");
    }

    #[test]
    fn oam_respects_causality() {
        let (q, k, v) = qkv(4, 2, 1, 128, 16);
        let s = oam_scores(&q, &k, &v, 32, 8, 0.2);
        for h in 0..2 {
            for i in 0..4 {
                for j in (i + 1)..4 {
                    assert_eq!(s.at3(h, i, j), NEG_INF);
                }
            }
        }
    }
}
