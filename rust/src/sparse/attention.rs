//! Pure-rust reference implementation of the Stem attention pipeline:
//! pooling, the Output-Aware Metric, selection and block-sparse attention.
//!
//! Role (DESIGN.md §7): (a) golden cross-check against the python oracles,
//! (b) the compute model behind the simulator and the scheduler's cost
//! estimates, (c) the subject of the L3 property tests. The request path
//! runs the XLA-compiled artifacts, not this.
//!
//! # Flat CSR selection layout
//!
//! A [`Selection`] stores every (head, query-block) row in one contiguous
//! `indices: Vec<u32>` addressed through `row_offsets` (length
//! `n_heads·nblk + 1`, CSR-style): row `(h, i)` owns
//! `indices[row_offsets[h·nblk+i] .. row_offsets[h·nblk+i+1]]`, of which
//! the first `counts[h·nblk+i]` entries are the *selected* key blocks
//! (any remainder is interface padding, e.g. from fixed-width python
//! goldens). Stem rows are emitted sorted ascending by block id so the
//! execution kernel walks K/V monotonically.
//!
//! # SIMD dispatch
//!
//! Every vectorizable kernel comes in two forms: `kernel(...)` resolves
//! the process-wide [`SimdArm`] once via [`simd::active`] and
//! `kernel_with(arm, ...)` takes it explicitly (what benches and the
//! differential suite use to force an arm). Inner loops route through
//! [`super::simd`] — the scalar arm is bit-identical to the seed scalar
//! loops, the wide arm matches it within 1e-5. The scalar `*_reference`
//! oracles never dispatch: they call the seed loops directly.
//!
//! # Parallel decomposition
//!
//! Every stage fans independent `(head, query-block)` work items over the
//! process-wide pool (`util::threadpool::global()`, sized by
//! `STEM_THREADS` / `--threads` / `available_parallelism`):
//!
//! * `antidiag_scores` / `oam_scores` — one item per (head, query-block
//!   row) of the routing-score matrix; OAM only computes the causal
//!   triangle.
//! * `select_stem` — one item per (head, query-block) row; each performs
//!   an O(width·log k) bounded-heap partial selection instead of a full
//!   sort (only the top `k(i)` entries are ever consumed), then writes a
//!   pre-sized CSR slice.
//! * `block_sparse_attention` — fused tiled kernel: one item per (head,
//!   query-block) walks the row's selected key blocks once, computes the
//!   whole `block×block` score tile with the K slab held in cache, runs
//!   the online-softmax update per query row, and skips the within-block
//!   causal mask entirely for off-diagonal blocks.
//! * `dense_attention` — one item per (head, query-row-chunk).
//!
//! Work items return owned row buffers that are stitched into the output
//! tensor on the calling thread, so no unsafe aliasing leaks out of the
//! pool helper. The scalar seed-shaped paths are retained as
//! [`select_stem_reference`] / [`block_sparse_attention_reference`] and
//! the property tests pin the parallel kernels to them within 1e-5.
//!
//! # Single-query decode kernels
//!
//! The decode phase scores one new query row against the cached K/V at a
//! time. Cached K/V is addressed through the storage-agnostic [`KvBlocks`]
//! trait (one attention block per paged-KV page; the last block may be
//! partial), so the same kernels run over a dense [`TensorKv`] view in
//! tests/benches and over the coordinator's paged store in serving:
//!
//! * [`decode_block_scores`] — per-(head, key-block) Output-Aware routing
//!   scores for the single query row: max strided q·k sample plus the
//!   value-magnitude term of Eq. (7), parallel across heads.
//! * [`select_decode`] — bounded-heap partial top-k over those scores
//!   (reusing the prefill `TopK`), with forced sink/recent blocks, emitted
//!   as a decode-shaped [`Selection`] (one CSR row per head,
//!   [`Selection::validate_decode`]).
//! * [`sparse_decode_attention`] — single-query online-softmax attention
//!   over the selected blocks, parallel across heads.
//! * [`dense_decode_attention`] — the selection-free dense fast path:
//!   when the policy resolves to the dense plan there is nothing to rank,
//!   so the kernel walks every cached block directly without
//!   materializing a [`Selection`] (bit-identical to the sparse kernel
//!   under a full selection).
//! * [`dense_decode_attention_reference`] — scalar full-context oracle the
//!   property tests pin the sparse kernel to within 1e-5.
//!
//! # Batched multi-query verify kernels (speculative decode)
//!
//! The speculative draft/verify loop (`decode::spec`) re-scores a block
//! of G consecutive stream positions in one pass. Position `g`'s causal
//! width is `base_tokens + g` cached tokens, so the batch is a causal
//! staircase over one K/V view:
//!
//! * [`KvPrefix`] — clamps any [`KvBlocks`] view to its leading
//!   `n_tokens`, giving each verify position exactly the context a
//!   sequential decode step would have seen.
//! * [`Selection::verify_full`] / [`Selection::validate_verify`] — one
//!   CSR selection object covering the whole (head × position) verify
//!   grid.
//! * [`sparse_verify_attention`] — the batched kernel: blocks outer,
//!   query rows inner within each head, so one K/V slab load serves
//!   every position that selected it (the bandwidth win of batching),
//!   while each row folds its blocks in ascending order through the
//!   same online-softmax update as the single-query kernel — making
//!   every row bit-identical to a sequential pass at the same width.
//! * [`dense_verify_attention_reference`] — scalar per-position oracle
//!   ([`dense_decode_attention_reference`] over a clamped [`KvPrefix`]),
//!   pinned at 1e-5 by the verify property tests.

use super::schedule::TpdConfig;
use super::simd::{self, SimdArm};
use super::tensor::{axpy, dot, norm2, Tensor};
use crate::util::threadpool;

/// Masked-score sentinel: finite (unlike `f32::NEG_INFINITY`) so the
/// online-softmax rescaling never produces NaNs on fully-masked tiles.
pub const NEG_INF: f32 = -1e30;

/// Fan `f(i)` for `i in 0..n_items` over the global pool, serially when
/// the pool is single-threaded (or there is nothing to fan out).
fn parallel_items<T, F>(n_items: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let pool = threadpool::global();
    if n_items <= 1 || pool.workers() == 1 {
        (0..n_items).map(f).collect()
    } else {
        threadpool::scope_parallel_borrowed(pool, n_items, f)
    }
}

/// One (head, query-block-row) of the dual-diagonal routing scores; on
/// the scalar arm this is bitwise-identical to the seed scalar loop so
/// parallelism cannot move floats.
#[allow(clippy::too_many_arguments)]
fn antidiag_row(
    arm: SimdArm,
    q: &Tensor,
    k: &Tensor,
    hh: usize,
    hkv: usize,
    i: usize,
    j_hi: usize,
    block: usize,
    stride: usize,
    scale: f32,
    out: &mut [f32],
) {
    for (j, o) in out.iter_mut().enumerate().take(j_hi) {
        let mut s = 0.0f32;
        let mut t = 0;
        while t < block {
            let qrow = q.row3(hh, i * block + t);
            s += simd::dot(arm, qrow, k.row3(hkv, j * block + (block - 1 - t)));
            s += simd::dot(arm, qrow, k.row3(hkv, j * block + t));
            t += stride;
        }
        *o = s * scale;
    }
}

/// Dual-diagonal block routing scores (mirror of
/// ref.pool_antidiag_scores): anti-diagonal samples cover odd within-block
/// relative offsets, diagonal samples cover the even band (pure
/// anti-diagonal is blind to copy/induction edges at exact block
/// multiples). q: [H, N, dh], k: [Hk, N, dh] -> [H, nq, nk] row-major.
/// Parallel across (head, query-block-row) items. Dispatches on
/// [`simd::active`]; see [`antidiag_scores_with`].
pub fn antidiag_scores(q: &Tensor, k: &Tensor, block: usize, stride: usize) -> Tensor {
    antidiag_scores_with(simd::active(), q, k, block, stride)
}

/// [`antidiag_scores`] with an explicit SIMD arm.
pub fn antidiag_scores_with(
    arm: SimdArm,
    q: &Tensor,
    k: &Tensor,
    block: usize,
    stride: usize,
) -> Tensor {
    let (h, dh) = (q.shape[0], q.shape[2]);
    let hk = k.shape[0];
    let rep = h / hk;
    let nblk = q.shape[1] / block;
    let scale = 1.0 / (dh as f32).sqrt();
    let rows = parallel_items(h * nblk, |item| {
        let (hh, i) = (item / nblk, item % nblk);
        let mut row = vec![0.0f32; nblk];
        antidiag_row(arm, q, k, hh, hh / rep, i, nblk, block, stride, scale, &mut row);
        row
    });
    let mut out = Tensor::zeros(&[h, nblk, nblk]);
    for (item, row) in rows.iter().enumerate() {
        let off = item * nblk;
        out.data[off..off + nblk].copy_from_slice(row);
    }
    out
}

/// Block max-pooled log||V|| (mirror of ref.value_block_logmag).
/// v: [Hk, N, dh] -> [Hk, nblk].
pub fn value_block_logmag(v: &Tensor, block: usize) -> Tensor {
    let (hk, n, _) = (v.shape[0], v.shape[1], v.shape[2]);
    let nblk = n / block;
    let mut out = Tensor::zeros(&[hk, nblk, 1]);
    for h in 0..hk {
        for b in 0..nblk {
            let mut m = f32::MIN;
            for t in 0..block {
                m = m.max((norm2(v.row3(h, b * block + t)) + 1e-12).ln());
            }
            out.set3(h, b, 0, m);
        }
    }
    out
}

/// Output-Aware Metric Eq. (7): routing + beta * max(0, logmag), causal.
/// Only the causal triangle is computed (the strict upper triangle is
/// NEG_INF by construction); parallel across (head, query-block-row).
/// Dispatches on [`simd::active`]; see [`oam_scores_with`].
pub fn oam_scores(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    block: usize,
    stride: usize,
    beta: f32,
) -> Tensor {
    oam_scores_with(simd::active(), q, k, v, block, stride, beta)
}

/// [`oam_scores`] with an explicit SIMD arm. The value-magnitude pooling
/// ([`value_block_logmag`]) stays scalar on both arms — it is O(N·dh)
/// against the routing scores' O(N²·dh/stride), and keeping it common
/// means the arms differ only in dot-product reduction order.
pub fn oam_scores_with(
    arm: SimdArm,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    block: usize,
    stride: usize,
    beta: f32,
) -> Tensor {
    let (h, dh) = (q.shape[0], q.shape[2]);
    let hk = k.shape[0];
    let rep = h / hk;
    let nblk = q.shape[1] / block;
    let scale = 1.0 / (dh as f32).sqrt();
    let mv = value_block_logmag(v, block);
    let rows = parallel_items(h * nblk, |item| {
        let (hh, i) = (item / nblk, item % nblk);
        let hkv = hh / rep;
        let mut row = vec![NEG_INF; nblk];
        antidiag_row(arm, q, k, hh, hkv, i, i + 1, block, stride, scale, &mut row);
        for (j, o) in row.iter_mut().enumerate().take(i + 1) {
            *o += beta * mv.at3(hkv, j, 0).max(0.0);
        }
        row
    });
    let mut out = Tensor::zeros(&[h, nblk, nblk]);
    for (item, row) in rows.iter().enumerate() {
        let off = item * nblk;
        out.data[off..off + nblk].copy_from_slice(row);
    }
    out
}

/// A block selection in the uniform kernel interface, flat CSR layout
/// (see the module docs for the row addressing scheme).
#[derive(Debug, Clone)]
pub struct Selection {
    /// Query/key blocks per head (the causal grid is `nblk × nblk`).
    pub nblk: usize,
    /// Heads the selection covers.
    pub n_heads: usize,
    /// Concatenated per-row key-block ids for all `n_heads·nblk` rows.
    pub indices: Vec<u32>,
    /// CSR row starts into `indices`; length `n_heads·nblk + 1`.
    pub row_offsets: Vec<u32>,
    /// Selected entries per row (prefix of the row slice); length
    /// `n_heads·nblk`.
    pub counts: Vec<u32>,
}

impl Selection {
    #[inline]
    fn row_id(&self, h: usize, i: usize) -> usize {
        h * self.nblk + i
    }

    /// Full stored row (selected prefix + interface padding).
    #[inline]
    pub fn row(&self, h: usize, i: usize) -> &[u32] {
        let r = self.row_id(h, i);
        &self.indices[self.row_offsets[r] as usize..self.row_offsets[r + 1] as usize]
    }

    /// Number of selected key blocks in row `(h, i)`.
    #[inline]
    pub fn count(&self, h: usize, i: usize) -> usize {
        self.counts[self.row_id(h, i)] as usize
    }

    /// The selected key blocks of row `(h, i)` (first `count` entries).
    #[inline]
    pub fn selected(&self, h: usize, i: usize) -> &[u32] {
        let r = self.row_id(h, i);
        &self.indices[self.row_offsets[r] as usize
            ..self.row_offsets[r] as usize + self.counts[r] as usize]
    }

    /// The full causal selection (every row keeps all its causal blocks) —
    /// the dense-equivalence fixture used by tests and benches.
    pub fn full_causal(n_heads: usize, nblk: usize) -> Selection {
        let mut b = SelectionBuilder::with_capacity(n_heads, nblk, n_heads * nblk * (nblk + 1) / 2);
        for _ in 0..n_heads {
            for i in 0..nblk {
                let row: Vec<u32> = (0..=i as u32).rev().collect();
                b.push_row(&row, (i + 1) as u32);
            }
        }
        b.finish()
    }

    /// Fraction of causal block pairs this selection keeps.
    pub fn budget_fraction(&self) -> f64 {
        let nblk = self.nblk as f64;
        let total = self.n_heads as f64 * nblk * (nblk + 1.0) / 2.0;
        let used: u64 = self.counts.iter().map(|&c| c as u64).sum();
        used as f64 / total
    }

    /// Validate the kernel-interface invariants (tests + debug builds):
    /// CSR structure, per-row count range, causality and no duplicates in
    /// each selected prefix.
    pub fn validate(&self) -> Result<(), String> {
        let rows = self.n_heads * self.nblk;
        if self.row_offsets.len() != rows + 1 {
            return Err(format!(
                "row_offsets length {} != rows+1 {}",
                self.row_offsets.len(),
                rows + 1
            ));
        }
        if self.counts.len() != rows {
            return Err(format!("counts length {} != rows {rows}", self.counts.len()));
        }
        if self.row_offsets[0] != 0 || self.row_offsets[rows] as usize != self.indices.len() {
            return Err("row_offsets must span exactly indices".into());
        }
        // one seen-mask reused across rows via epoch stamps: O(total) work
        let mut seen = vec![0u32; self.nblk];
        let mut stamp = 0u32;
        for h in 0..self.n_heads {
            for i in 0..self.nblk {
                let r = self.row_id(h, i);
                let (lo, hi) = (self.row_offsets[r] as usize, self.row_offsets[r + 1] as usize);
                if hi < lo || hi > self.indices.len() {
                    return Err(format!("h{h} row{i}: row_offsets not monotone"));
                }
                let c = self.counts[r] as usize;
                if c == 0 || c > i + 1 {
                    return Err(format!("h{h} row{i}: count {c} out of range"));
                }
                if c > hi - lo {
                    return Err(format!("h{h} row{i}: count {c} exceeds row width {}", hi - lo));
                }
                stamp += 1;
                for &b in &self.indices[lo..lo + c] {
                    if b as usize > i {
                        return Err(format!("h{h} row{i}: non-causal block {b}"));
                    }
                    if seen[b as usize] == stamp {
                        return Err(format!("h{h} row{i}: duplicate block {b}"));
                    }
                    seen[b as usize] = stamp;
                }
            }
        }
        Ok(())
    }

    /// A decode-shaped selection covering the whole cached context: one
    /// row per query head (`nblk == 1`), every key block selected. This is
    /// the dense decode path and the dense-equivalence fixture.
    pub fn decode_full(n_heads: usize, n_key_blocks: usize) -> Selection {
        let mut b = SelectionBuilder::with_capacity(n_heads, 1, n_heads * n_key_blocks);
        let row: Vec<u32> = (0..n_key_blocks as u32).collect();
        for _ in 0..n_heads {
            b.push_row(&row, n_key_blocks as u32);
        }
        b.finish()
    }

    /// Validate a decode-shaped selection: `nblk == 1` (a single query
    /// row per head) whose causal width is the whole cached context of
    /// `n_key_blocks` blocks rather than the prefill row index — so
    /// [`Selection::validate`]'s per-row causality bound does not apply.
    /// Checks CSR structure, non-empty rows, block ids in range and
    /// strictly ascending order (the monotone K/V walk the kernel needs).
    pub fn validate_decode(&self, n_key_blocks: usize) -> Result<(), String> {
        if self.nblk != 1 {
            return Err(format!("decode selection must have nblk=1, got {}", self.nblk));
        }
        let rows = self.n_heads;
        if self.row_offsets.len() != rows + 1 || self.counts.len() != rows {
            return Err("decode selection: CSR length mismatch".into());
        }
        if self.row_offsets[0] != 0 || self.row_offsets[rows] as usize != self.indices.len() {
            return Err("decode selection: row_offsets must span exactly indices".into());
        }
        for h in 0..rows {
            let (lo, hi) = (self.row_offsets[h] as usize, self.row_offsets[h + 1] as usize);
            if hi < lo || hi > self.indices.len() {
                return Err(format!("head {h}: row_offsets not monotone"));
            }
            let c = self.counts[h] as usize;
            if c == 0 || c > n_key_blocks {
                return Err(format!("head {h}: count {c} out of range (ctx {n_key_blocks})"));
            }
            if c > hi - lo {
                return Err(format!("head {h}: count {c} exceeds row width {}", hi - lo));
            }
            let sel = &self.indices[lo..lo + c];
            for (t, &b) in sel.iter().enumerate() {
                if b as usize >= n_key_blocks {
                    return Err(format!("head {h}: block {b} beyond context"));
                }
                if t > 0 && sel[t - 1] >= b {
                    return Err(format!("head {h}: blocks not strictly ascending"));
                }
            }
        }
        Ok(())
    }

    /// A verify-shaped selection shared by a whole speculative query
    /// block: `n_rows` consecutive stream positions per head, every row
    /// keeping all `n_key_blocks` cached blocks. Positions narrower than
    /// the widest clamp the excess blocks away at execution
    /// ([`sparse_verify_attention`]), so one CSR object serves the whole
    /// causal staircase — the dense-plan fast path of the batched verify.
    pub fn verify_full(n_heads: usize, n_rows: usize, n_key_blocks: usize) -> Selection {
        let mut b =
            SelectionBuilder::with_capacity(n_heads, n_rows, n_heads * n_rows * n_key_blocks);
        let row: Vec<u32> = (0..n_key_blocks as u32).collect();
        for _ in 0..n_heads * n_rows {
            b.push_row(&row, n_key_blocks as u32);
        }
        b.finish()
    }

    /// Validate a verify-shaped selection: `self.nblk` query positions
    /// per head over a widest causal width of `n_key_blocks` cached
    /// blocks. Checks CSR structure, non-empty rows, ids in range and
    /// strictly ascending order. Rows narrower than the widest may list
    /// blocks beyond their own causal width — the kernel clamps those to
    /// zero valid tokens — so the id bound checked here is the widest
    /// position's.
    pub fn validate_verify(&self, n_key_blocks: usize) -> Result<(), String> {
        let rows = self.n_heads * self.nblk;
        if self.row_offsets.len() != rows + 1 || self.counts.len() != rows {
            return Err("verify selection: CSR length mismatch".into());
        }
        if self.row_offsets[0] != 0 || self.row_offsets[rows] as usize != self.indices.len() {
            return Err("verify selection: row_offsets must span exactly indices".into());
        }
        for r in 0..rows {
            let (lo, hi) = (self.row_offsets[r] as usize, self.row_offsets[r + 1] as usize);
            if hi < lo || hi > self.indices.len() {
                return Err(format!("row {r}: row_offsets not monotone"));
            }
            let c = self.counts[r] as usize;
            if c == 0 || c > n_key_blocks {
                return Err(format!("row {r}: count {c} out of range (ctx {n_key_blocks})"));
            }
            if c > hi - lo {
                return Err(format!("row {r}: count {c} exceeds row width {}", hi - lo));
            }
            let sel = &self.indices[lo..lo + c];
            for (t, &b) in sel.iter().enumerate() {
                if b as usize >= n_key_blocks {
                    return Err(format!("row {r}: block {b} beyond context"));
                }
                if t > 0 && sel[t - 1] >= b {
                    return Err(format!("row {r}: blocks not strictly ascending"));
                }
            }
        }
        Ok(())
    }
}

/// Incremental builder for the flat CSR [`Selection`]; rows must be pushed
/// in `(head-major, query-block)` order.
pub struct SelectionBuilder {
    nblk: usize,
    n_heads: usize,
    indices: Vec<u32>,
    row_offsets: Vec<u32>,
    counts: Vec<u32>,
}

impl SelectionBuilder {
    /// Builder for an `n_heads × nblk`-row selection.
    pub fn new(n_heads: usize, nblk: usize) -> Self {
        Self::with_capacity(n_heads, nblk, 0)
    }

    /// Like [`SelectionBuilder::new`] with `cap` entries preallocated.
    pub fn with_capacity(n_heads: usize, nblk: usize, cap: usize) -> Self {
        let rows = n_heads * nblk;
        let mut row_offsets = Vec::with_capacity(rows + 1);
        row_offsets.push(0);
        SelectionBuilder {
            nblk,
            n_heads,
            indices: Vec::with_capacity(cap),
            row_offsets,
            counts: Vec::with_capacity(rows),
        }
    }

    /// Append the next row: `row` is the stored slice (selected prefix +
    /// optional padding), `count` the number of selected entries.
    pub fn push_row(&mut self, row: &[u32], count: u32) {
        debug_assert!(count as usize <= row.len());
        self.indices.extend_from_slice(row);
        self.row_offsets.push(self.indices.len() as u32);
        self.counts.push(count);
    }

    /// Seal the builder into a validated-shape [`Selection`].
    pub fn finish(self) -> Selection {
        assert_eq!(
            self.counts.len(),
            self.n_heads * self.nblk,
            "SelectionBuilder: pushed {} rows, expected {}",
            self.counts.len(),
            self.n_heads * self.nblk
        );
        Selection {
            nblk: self.nblk,
            n_heads: self.n_heads,
            indices: self.indices,
            row_offsets: self.row_offsets,
            counts: self.counts,
        }
    }
}

/// Bounded worst-at-root heap keeping the `k` best (score desc, block id
/// asc on ties) entries of a streamed row: O(width·log k) per row versus
/// the full sort's O(width·log width).
struct TopK {
    buf: Vec<(f32, u32)>,
    k: usize,
}

impl TopK {
    fn new(k: usize) -> Self {
        TopK { buf: Vec::with_capacity(k), k }
    }

    /// `a` ranks strictly below `b` under (score desc, id asc).
    #[inline]
    fn worse(a: (f32, u32), b: (f32, u32)) -> bool {
        a.0 < b.0 || (a.0 == b.0 && a.1 > b.1)
    }

    fn offer(&mut self, cand: (f32, u32)) {
        if self.buf.len() < self.k {
            self.buf.push(cand);
            let mut i = self.buf.len() - 1;
            while i > 0 {
                let p = (i - 1) / 2;
                if Self::worse(self.buf[i], self.buf[p]) {
                    self.buf.swap(i, p);
                    i = p;
                } else {
                    break;
                }
            }
        } else if Self::worse(self.buf[0], cand) {
            self.buf[0] = cand;
            let mut i = 0;
            loop {
                let (lc, rc) = (2 * i + 1, 2 * i + 2);
                let mut w = i;
                if lc < self.buf.len() && Self::worse(self.buf[lc], self.buf[w]) {
                    w = lc;
                }
                if rc < self.buf.len() && Self::worse(self.buf[rc], self.buf[w]) {
                    w = rc;
                }
                if w == i {
                    break;
                }
                self.buf.swap(i, w);
                i = w;
            }
        }
    }

    /// Drain into ascending block-id order (cache-friendly K/V walk).
    fn into_sorted_ids(self) -> Vec<u32> {
        let mut ids: Vec<u32> = self.buf.into_iter().map(|(_, j)| j).collect();
        ids.sort_unstable();
        ids
    }
}

#[inline]
fn forced_bias(j: usize, i: usize, cfg: &TpdConfig) -> f32 {
    // forced: sinks + local window
    if j < cfg.init_keep || j + cfg.local_keep > i {
        1e9
    } else {
        0.0
    }
}

/// Stem selection: OAM ranking + TPD budget (mirror of select_stem).
/// Partial top-k per row (bounded heap sized by the TPD budget `k(i)`),
/// parallel across (head, query-block) rows, emitting the flat CSR layout
/// directly (row `(h, i)` holds exactly `k(i)` sorted block ids).
pub fn select_stem(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    block: usize,
    stride: usize,
    cfg: &TpdConfig,
    beta: f32,
) -> Selection {
    let scores = oam_scores(q, k, v, block, stride, beta);
    let (h, nblk) = (scores.shape[0], scores.shape[1]);
    let kvec = super::schedule::block_budget_schedule(nblk, cfg);
    let rows = parallel_items(h * nblk, |item| {
        let (hh, i) = (item / nblk, item % nblk);
        let ki = kvec[i];
        if ki >= i + 1 {
            // budget covers the whole causal width: no ranking needed
            return (0..=i as u32).collect::<Vec<u32>>();
        }
        let mut top = TopK::new(ki);
        for j in 0..=i {
            top.offer((scores.at3(hh, i, j) + forced_bias(j, i, cfg), j as u32));
        }
        top.into_sorted_ids()
    });
    let mut b = SelectionBuilder::with_capacity(
        h,
        nblk,
        h * super::schedule::block_budget_total(nblk, cfg),
    );
    for row in &rows {
        b.push_row(row, row.len() as u32);
    }
    b.finish()
}

/// The seed-shaped scalar selection path, retained as the equivalence
/// oracle for [`select_stem`]: full sort of every row, single thread.
pub fn select_stem_reference(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    block: usize,
    stride: usize,
    cfg: &TpdConfig,
    beta: f32,
) -> Selection {
    let scores = oam_scores(q, k, v, block, stride, beta);
    let (h, nblk) = (scores.shape[0], scores.shape[1]);
    let kvec = super::schedule::block_budget_schedule(nblk, cfg);
    let mut b = SelectionBuilder::with_capacity(
        h,
        nblk,
        h * super::schedule::block_budget_total(nblk, cfg),
    );
    for hh in 0..h {
        for i in 0..nblk {
            let mut key: Vec<(f32, u32)> = (0..=i)
                .map(|j| (scores.at3(hh, i, j) + forced_bias(j, i, cfg), j as u32))
                .collect();
            key.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
            let mut row: Vec<u32> = key.iter().take(kvec[i]).map(|&(_, j)| j).collect();
            row.sort_unstable();
            b.push_row(&row, kvec[i] as u32);
        }
    }
    b.finish()
}

/// StreamingLLM selection (sinks + local window). Each row is built in
/// one pass over its causal width with an epoch-stamped seen-mask (the
/// seed version re-scanned the row per candidate block, O(width²)).
pub fn select_streaming(h: usize, nblk: usize, sink: usize, local: usize) -> Selection {
    // rows are identical across heads: build head 0 once, replicate
    let mut rows: Vec<(Vec<u32>, u32)> = Vec::with_capacity(nblk);
    let mut seen = vec![0u32; nblk];
    for i in 0..nblk {
        let stamp = i as u32 + 1;
        let mut row: Vec<u32> = Vec::with_capacity(i + 1);
        for j in (0..=i).rev().take(local) {
            row.push(j as u32);
            seen[j] = stamp;
        }
        for j in 0..sink.min(i + 1) {
            if seen[j] != stamp {
                row.push(j as u32);
                seen[j] = stamp;
            }
        }
        let count = row.len() as u32;
        // pad with the remaining causal blocks for interface width
        for j in 0..=i {
            if seen[j] != stamp {
                row.push(j as u32);
            }
        }
        rows.push((row, count));
    }
    let per_head: usize = rows.iter().map(|(r, _)| r.len()).sum();
    let mut b = SelectionBuilder::with_capacity(h, nblk, h * per_head);
    for _ in 0..h {
        for (row, count) in &rows {
            b.push_row(row, *count);
        }
    }
    b.finish()
}

/// Exact dense causal attention (reference). q:[H,N,dh] k,v:[Hk,N,dh].
/// Parallel across (head, query-row-chunk) items; per-row math is
/// unchanged, so the result is identical at any thread count.
/// Dispatches on [`simd::active`]; see [`dense_attention_with`].
pub fn dense_attention(q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
    dense_attention_with(simd::active(), q, k, v)
}

/// [`dense_attention`] with an explicit SIMD arm.
pub fn dense_attention_with(arm: SimdArm, q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
    let (h, n, dh) = (q.shape[0], q.shape[1], q.shape[2]);
    let hk = k.shape[0];
    let rep = h / hk;
    let scale = 1.0 / (dh as f32).sqrt();
    const CHUNK: usize = 64;
    let chunks_per_head = n.div_ceil(CHUNK);
    let bufs = parallel_items(h * chunks_per_head, |item| {
        let (hh, c) = (item / chunks_per_head, item % chunks_per_head);
        let hkv = hh / rep;
        let (lo, hi) = (c * CHUNK, ((c + 1) * CHUNK).min(n));
        let mut out = vec![0.0f32; (hi - lo) * dh];
        let mut probs = vec![0.0f32; hi];
        for i in lo..hi {
            let qrow = q.row3(hh, i);
            // running max initialized from the first computed score
            let mut m = f32::NEG_INFINITY;
            for j in 0..=i {
                probs[j] = simd::dot(arm, qrow, k.row3(hkv, j)) * scale;
                m = m.max(probs[j]);
            }
            let mut l = 0.0f32;
            for p in probs.iter_mut().take(i + 1) {
                *p = (*p - m).exp();
                l += *p;
            }
            if l == 0.0 {
                continue; // degenerate row: emit zeros, not NaN
            }
            let orow = &mut out[(i - lo) * dh..(i - lo + 1) * dh];
            for j in 0..=i {
                simd::axpy(arm, orow, probs[j] / l, v.row3(hkv, j));
            }
        }
        out
    });
    let mut out = Tensor::zeros(&[h, n, dh]);
    for (item, buf) in bufs.iter().enumerate() {
        let (hh, c) = (item / chunks_per_head, item % chunks_per_head);
        let lo = c * CHUNK;
        let off = (hh * n + lo) * dh;
        out.data[off..off + buf.len()].copy_from_slice(buf);
    }
    out
}

/// Block-sparse attention under a [`Selection`] (renormalized softmax over
/// the selected blocks; within-block causal mask on the diagonal block).
///
/// Fused tiled kernel: each (head, query-block) work item walks its
/// selected key blocks once, computes the `block×block` score tile with
/// the K slab reused from cache ([`score_tile`]), applies the within-block
/// causal mask only on the diagonal block ([`score_tile_causal`] — fully
/// causal off-diagonal blocks skip masking entirely), and folds the tile
/// into a per-row online softmax. Rows with no computable score (all
/// selected blocks non-causal) yield zeros rather than NaN.
/// Dispatches on [`simd::active`]; see [`block_sparse_attention_with`].
pub fn block_sparse_attention(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    sel: &Selection,
    block: usize,
) -> Tensor {
    block_sparse_attention_with(simd::active(), q, k, v, sel, block)
}

/// [`block_sparse_attention`] with an explicit SIMD arm.
pub fn block_sparse_attention_with(
    arm: SimdArm,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    sel: &Selection,
    block: usize,
) -> Tensor {
    let (h, n, dh) = (q.shape[0], q.shape[1], q.shape[2]);
    let hk = k.shape[0];
    let rep = h / hk;
    let scale = 1.0 / (dh as f32).sqrt();
    let nblk = sel.nblk;
    let bufs = parallel_items(h * nblk, |item| {
        let (hh, qb) = (item / nblk, item % nblk);
        let hkv = hh / rep;
        let qs = q.block3(hh, qb, block);
        let mut tile = vec![0.0f32; block * block];
        let mut m = vec![f32::NEG_INFINITY; block];
        let mut l = vec![0.0f32; block];
        let mut acc = vec![0.0f32; block * dh];
        for &kb in sel.selected(hh, qb) {
            let kb = kb as usize;
            if kb > qb {
                continue; // fully non-causal block: every entry masked
            }
            let ks = k.block3(hkv, kb, block);
            let vs = v.block3(hkv, kb, block);
            let diag = kb == qb;
            if diag {
                simd::score_tile_causal(arm, qs, ks, dh, block, scale, &mut tile);
            } else {
                simd::score_tile(arm, qs, ks, dh, block, scale, &mut tile);
            }
            for r in 0..block {
                let nvalid = if diag { r + 1 } else { block };
                let trow = &tile[r * block..r * block + nvalid];
                // running max initialized from the first computed score
                let mut tmax = trow[0];
                for &s in &trow[1..] {
                    if s > tmax {
                        tmax = s;
                    }
                }
                let new_m = if m[r] > tmax { m[r] } else { tmax };
                let arow = &mut acc[r * dh..(r + 1) * dh];
                if l[r] > 0.0 && new_m > m[r] {
                    let corr = (m[r] - new_m).exp();
                    l[r] *= corr;
                    simd::scale(arm, arow, corr);
                }
                m[r] = new_m;
                for (t, &s) in trow.iter().enumerate() {
                    let p = (s - new_m).exp();
                    l[r] += p;
                    simd::axpy(arm, arow, p, &vs[t * dh..(t + 1) * dh]);
                }
            }
        }
        let mut out = vec![0.0f32; block * dh];
        for r in 0..block {
            if l[r] > 0.0 {
                let inv = 1.0 / l[r];
                for (o, a) in out[r * dh..(r + 1) * dh].iter_mut().zip(&acc[r * dh..]) {
                    *o = a * inv;
                }
            }
        }
        out
    });
    let mut out = Tensor::zeros(&[h, n, dh]);
    for (item, buf) in bufs.iter().enumerate() {
        let (hh, qb) = (item / nblk, item % nblk);
        let off = (hh * n + qb * block) * dh;
        out.data[off..off + buf.len()].copy_from_slice(buf);
    }
    out
}

/// The seed-shaped scalar execution path, retained as the equivalence
/// oracle for the fused parallel kernel: per-query-row gather of every
/// selected score, one global max, one normalize pass. Masked entries are
/// skipped (not exponentiated), and a row with no computable score yields
/// zeros rather than NaN — the same semantics as the fused kernel.
pub fn block_sparse_attention_reference(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    sel: &Selection,
    block: usize,
) -> Tensor {
    let (h, n, dh) = (q.shape[0], q.shape[1], q.shape[2]);
    let hk = k.shape[0];
    let rep = h / hk;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut out = Tensor::zeros(&[h, n, dh]);
    let mut svals: Vec<f32> = Vec::new();
    let mut sidx: Vec<u32> = Vec::new();
    for hh in 0..h {
        let hkv = hh / rep;
        for qb in 0..sel.nblk {
            let blocks = sel.selected(hh, qb);
            for r in 0..block {
                let i = qb * block + r;
                let qrow = q.row3(hh, i);
                svals.clear();
                sidx.clear();
                let mut m = f32::NEG_INFINITY;
                for &b in blocks {
                    let b = b as usize;
                    for t in 0..block {
                        let j = b * block + t;
                        if j <= i {
                            let s = dot(qrow, k.row3(hkv, j)) * scale;
                            if s > m {
                                m = s;
                            }
                            svals.push(s);
                            sidx.push(j as u32);
                        }
                    }
                }
                let mut l = 0.0f32;
                for s in svals.iter_mut() {
                    *s = (*s - m).exp();
                    l += *s;
                }
                if l == 0.0 {
                    continue; // degenerate row: zeros, not NaN
                }
                let orow = out.row3_mut(hh, i);
                for (p, &j) in svals.iter().zip(&sidx) {
                    axpy(orow, p / l, v.row3(hkv, j as usize));
                }
            }
        }
    }
    out
}

/// Storage-agnostic block view of a decoded sequence's cached K/V.
///
/// One logical block holds `block_tokens` consecutive tokens (the paged
/// KV cache maps one block to one page); the final block may be partial.
/// Implementations: [`TensorKv`] (contiguous tensors, tests/benches) and
/// the shared slab store's view (`decode::store::SeqKvView`), through
/// which any number of forked sequences expose refcounted pages of one
/// `decode::store::SharedKv` to the same kernels — the view carries only
/// (store ref, page table, token count), so aliased prefixes cost
/// nothing per session.
pub trait KvBlocks: Sync {
    /// Cached tokens (the causal width of the next query row).
    fn n_tokens(&self) -> usize;
    /// Tokens per block (= KV page size = attention block).
    fn block_tokens(&self) -> usize;
    /// K/V heads stored (GQA groups).
    fn n_kv_heads(&self) -> usize;
    /// Head dimension of the stored rows.
    fn head_dim(&self) -> usize;
    /// Contiguous `[block_len(b), head_dim]` K slab of block `b` for
    /// kv-head `hkv`.
    fn k_block(&self, hkv: usize, b: usize) -> &[f32];
    /// Contiguous `[block_len(b), head_dim]` V slab of block `b` for
    /// kv-head `hkv`.
    fn v_block(&self, hkv: usize, b: usize) -> &[f32];

    /// Blocks covering the cached tokens (tail partial).
    fn n_blocks(&self) -> usize {
        self.n_tokens().div_ceil(self.block_tokens())
    }

    /// Valid tokens in block `b` (full except possibly the last).
    fn block_len(&self, b: usize) -> usize {
        let bt = self.block_tokens();
        self.n_tokens().saturating_sub(b * bt).min(bt)
    }
}

/// [`KvBlocks`] over contiguous `[Hk, N, dh]` tensors with a logical
/// token count `n_tokens <= N` — the dense fixture decode tests and
/// benches score the paged kernels against.
pub struct TensorKv<'a> {
    /// Keys, `[Hk, N, dh]`.
    pub k: &'a Tensor,
    /// Values, `[Hk, N, dh]`.
    pub v: &'a Tensor,
    /// Logical token count (`<= N`; the tail block is partial).
    pub n_tokens: usize,
    /// Tokens per attention block.
    pub block: usize,
}

impl TensorKv<'_> {
    fn slab(t: &Tensor, hkv: usize, b: usize, block: usize, len: usize) -> &[f32] {
        let (n, dh) = (t.shape[1], t.shape[2]);
        let off = (hkv * n + b * block) * dh;
        &t.data[off..off + len * dh]
    }
}

impl KvBlocks for TensorKv<'_> {
    fn n_tokens(&self) -> usize {
        self.n_tokens
    }

    fn block_tokens(&self) -> usize {
        self.block
    }

    fn n_kv_heads(&self) -> usize {
        self.k.shape[0]
    }

    fn head_dim(&self) -> usize {
        self.k.shape[2]
    }

    fn k_block(&self, hkv: usize, b: usize) -> &[f32] {
        Self::slab(self.k, hkv, b, self.block, self.block_len(b))
    }

    fn v_block(&self, hkv: usize, b: usize) -> &[f32] {
        Self::slab(self.v, hkv, b, self.block, self.block_len(b))
    }
}

/// A causal-prefix view over cached K/V: the same blocks as `inner`,
/// clamped to the leading `n_tokens`. The speculative verify path wraps
/// one shared view in per-position prefixes so each batched query row
/// scores and attends *exactly* the context a sequential decode step
/// would have seen — the planning/scoring half of the bit-exact
/// decode-equivalence guarantee.
pub struct KvPrefix<'a, K: KvBlocks> {
    inner: &'a K,
    n_tokens: usize,
}

impl<'a, K: KvBlocks> KvPrefix<'a, K> {
    /// Clamp `inner` to its leading `n_tokens` (`<= inner.n_tokens()`).
    pub fn new(inner: &'a K, n_tokens: usize) -> Self {
        debug_assert!(n_tokens <= inner.n_tokens(), "prefix cannot exceed the cached context");
        KvPrefix { inner, n_tokens }
    }
}

impl<K: KvBlocks> KvBlocks for KvPrefix<'_, K> {
    fn n_tokens(&self) -> usize {
        self.n_tokens
    }

    fn block_tokens(&self) -> usize {
        self.inner.block_tokens()
    }

    fn n_kv_heads(&self) -> usize {
        self.inner.n_kv_heads()
    }

    fn head_dim(&self) -> usize {
        self.inner.head_dim()
    }

    fn k_block(&self, hkv: usize, b: usize) -> &[f32] {
        &self.inner.k_block(hkv, b)[..self.block_len(b) * self.head_dim()]
    }

    fn v_block(&self, hkv: usize, b: usize) -> &[f32] {
        &self.inner.v_block(hkv, b)[..self.block_len(b) * self.head_dim()]
    }
}

/// Decode-time Output-Aware routing scores: for the single query row of
/// each head, score every cached key block as the *max* strided q·k
/// sample in the block (scaled) plus the `beta·max(0, log‖v‖)`
/// value-magnitude term of Eq. (7) over the same samples. One row per
/// query head; parallel across heads. q: `[H, dh]` -> `[H, n_blocks]`.
/// Dispatches on [`simd::active`]; see [`decode_block_scores_with`].
pub fn decode_block_scores(q: &Tensor, kv: &impl KvBlocks, stride: usize, beta: f32) -> Tensor {
    decode_block_scores_with(simd::active(), q, kv, stride, beta)
}

/// [`decode_block_scores`] with an explicit SIMD arm.
pub fn decode_block_scores_with(
    arm: SimdArm,
    q: &Tensor,
    kv: &impl KvBlocks,
    stride: usize,
    beta: f32,
) -> Tensor {
    let (h, dh) = (q.shape[0], q.shape[1]);
    let hk = kv.n_kv_heads();
    let rep = h / hk;
    let nblk = kv.n_blocks();
    let scale = 1.0 / (dh as f32).sqrt();
    let stride = stride.max(1);
    let rows = parallel_items(h, |hh| {
        let hkv = hh / rep;
        let qrow = &q.data[hh * dh..(hh + 1) * dh];
        let mut row = vec![NEG_INF; nblk];
        for (b, o) in row.iter_mut().enumerate() {
            let len = kv.block_len(b);
            let ks = kv.k_block(hkv, b);
            let vs = kv.v_block(hkv, b);
            let mut s = f32::NEG_INFINITY;
            let mut vmag = f32::MIN;
            let mut t = 0;
            while t < len {
                let d = simd::dot(arm, qrow, &ks[t * dh..(t + 1) * dh]);
                if d > s {
                    s = d;
                }
                vmag = vmag.max((simd::norm2(arm, &vs[t * dh..(t + 1) * dh]) + 1e-12).ln());
                t += stride;
            }
            *o = s * scale + beta * vmag.max(0.0);
        }
        row
    });
    let mut out = Tensor::zeros(&[h, nblk]);
    for (hh, row) in rows.iter().enumerate() {
        out.data[hh * nblk..(hh + 1) * nblk].copy_from_slice(row);
    }
    out
}

/// Decode selection: bounded-heap partial top-`budget` over the per-head
/// block scores (the prefill `TopK` machinery, O(nblk·log budget)), with
/// the first `sink` and last `recent` blocks force-kept (Lil's finding:
/// dropping sinks or the local window is what hurts long decode). Emits a
/// decode-shaped CSR [`Selection`] — one ascending row per head. The
/// forced sets are only fully kept when `budget >= sink + recent`
/// (`DecodePolicy` maintains that floor); a smaller budget ranks and
/// truncates the forced set itself.
pub fn select_decode(
    scores: &Tensor,
    budget: usize,
    sink: usize,
    recent: usize,
) -> Selection {
    let (h, nblk) = (scores.shape[0], scores.shape[1]);
    let budget = budget.max(1);
    let rows = parallel_items(h, |hh| {
        if budget >= nblk {
            return (0..nblk as u32).collect::<Vec<u32>>();
        }
        let mut top = TopK::new(budget);
        for b in 0..nblk {
            let forced = if b < sink || b + recent >= nblk { 1e9 } else { 0.0 };
            top.offer((scores.at2(hh, b) + forced, b as u32));
        }
        top.into_sorted_ids()
    });
    let mut b = SelectionBuilder::with_capacity(h, 1, h * budget.min(nblk));
    for row in &rows {
        b.push_row(row, row.len() as u32);
    }
    b.finish()
}

/// Softmax mass of one score row captured by a kept subset: with
/// `p = softmax(row)`, returns `sum(p[kept])`. Max-subtracted for
/// stability; degenerate rows (empty, or all mass at `-inf`) report 1.0
/// so telemetry never blames the selection for an empty context.
pub fn score_mass_row(row: &[f32], kept: &[u32]) -> f64 {
    if row.is_empty() || kept.is_empty() {
        return 1.0;
    }
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return 1.0;
    }
    let total: f64 = row.iter().map(|&s| ((s - m) as f64).exp()).sum();
    if total <= 0.0 {
        return 1.0;
    }
    let got: f64 = kept
        .iter()
        .filter(|&&b| (b as usize) < row.len())
        .map(|&b| ((row[b as usize] - m) as f64).exp())
        .sum();
    (got / total).clamp(0.0, 1.0)
}

/// Captured OAM score mass of a decode-shaped selection: for each head,
/// the softmax mass of that head's block-score row falling on its kept
/// blocks, averaged over heads. `scores` is the `[H, nblk]` output of
/// [`decode_block_scores`] and `sel` the matching [`select_decode`]
/// result — this is the sparsity-telemetry measure of how much of the
/// router's probability mass the realized selection retained.
pub fn selection_score_mass(scores: &Tensor, sel: &Selection) -> f64 {
    let (h, nblk) = (scores.shape[0], scores.shape[1]);
    if h == 0 || nblk == 0 || sel.n_heads != h {
        return 1.0;
    }
    let mut sum = 0.0;
    for hh in 0..h {
        let row = &scores.data[hh * nblk..(hh + 1) * nblk];
        sum += score_mass_row(row, sel.selected(hh, 0));
    }
    sum / h as f64
}

// The shared single-query online-softmax block update lives in
// [`simd::online_softmax_block`]: every decode/verify kernel routes
// through it so the per-row floating-point operation sequence is
// *identical* across the single-query, dense-fast-path and
// batched-verify kernels within one arm — the speculative
// decode-equivalence guarantee depends on that, not on an epsilon.

/// Single-query block-sparse attention over cached K/V: one online-softmax
/// pass per head over that head's selected blocks (decode-shaped
/// [`Selection`], see [`select_decode`]), the last partial block handled
/// by [`KvBlocks::block_len`]. Causality is structural — only cached
/// tokens exist. Parallel across heads; returns `[H·dh]` row-major.
/// Dispatches on [`simd::active`]; see [`sparse_decode_attention_with`].
pub fn sparse_decode_attention(q: &Tensor, kv: &impl KvBlocks, sel: &Selection) -> Vec<f32> {
    sparse_decode_attention_with(simd::active(), q, kv, sel)
}

/// [`sparse_decode_attention`] with an explicit SIMD arm.
///
/// Debug builds validate `sel` against the cached context first
/// ([`Selection::validate_decode`]), so a malformed selection fails
/// loudly instead of silently skipping or double-counting blocks; in
/// release the kernel remains robust to out-of-range ids (an id beyond
/// the cached context resolves to a zero-length block and is skipped).
pub fn sparse_decode_attention_with(
    arm: SimdArm,
    q: &Tensor,
    kv: &impl KvBlocks,
    sel: &Selection,
) -> Vec<f32> {
    debug_assert_eq!(
        sel.validate_decode(kv.n_blocks()).map_err(|e| format!("decode selection: {e}")),
        Ok(()),
    );
    let (h, dh) = (q.shape[0], q.shape[1]);
    let hk = kv.n_kv_heads();
    let rep = h / hk;
    let scale = 1.0 / (dh as f32).sqrt();
    let rows = parallel_items(h, |hh| {
        let hkv = hh / rep;
        let qrow = &q.data[hh * dh..(hh + 1) * dh];
        let mut m = f32::NEG_INFINITY;
        let mut l = 0.0f32;
        let mut acc = vec![0.0f32; dh];
        for &b in sel.selected(hh, 0) {
            let b = b as usize;
            let len = kv.block_len(b);
            if len == 0 {
                continue;
            }
            let ks = kv.k_block(hkv, b);
            let vs = kv.v_block(hkv, b);
            simd::online_softmax_block(arm, qrow, ks, vs, len, dh, scale, &mut m, &mut l, &mut acc);
        }
        if l > 0.0 {
            let inv = 1.0 / l;
            for a in acc.iter_mut() {
                *a *= inv;
            }
        }
        acc
    });
    let mut out = vec![0.0f32; h * dh];
    for (hh, row) in rows.iter().enumerate() {
        out[hh * dh..(hh + 1) * dh].copy_from_slice(row);
    }
    out
}

/// Selection-free single-query dense attention over the whole cached
/// context — the decode fast path when the policy resolves to the dense
/// plan. Walks every cached block in ascending order through the same
/// online-softmax update as [`sparse_decode_attention`] under a full
/// selection (bit-identical output) without materializing a
/// [`Selection`] or ranking anything. Parallel across heads; returns
/// `[H·dh]` row-major. Dispatches on [`simd::active`]; see
/// [`dense_decode_attention_with`].
pub fn dense_decode_attention(q: &Tensor, kv: &impl KvBlocks) -> Vec<f32> {
    dense_decode_attention_with(simd::active(), q, kv)
}

/// [`dense_decode_attention`] with an explicit SIMD arm.
pub fn dense_decode_attention_with(arm: SimdArm, q: &Tensor, kv: &impl KvBlocks) -> Vec<f32> {
    let (h, dh) = (q.shape[0], q.shape[1]);
    let hk = kv.n_kv_heads();
    let rep = h / hk;
    let scale = 1.0 / (dh as f32).sqrt();
    let nblk = kv.n_blocks();
    let rows = parallel_items(h, |hh| {
        let hkv = hh / rep;
        let qrow = &q.data[hh * dh..(hh + 1) * dh];
        let mut m = f32::NEG_INFINITY;
        let mut l = 0.0f32;
        let mut acc = vec![0.0f32; dh];
        for b in 0..nblk {
            let len = kv.block_len(b);
            if len == 0 {
                continue;
            }
            let ks = kv.k_block(hkv, b);
            let vs = kv.v_block(hkv, b);
            simd::online_softmax_block(arm, qrow, ks, vs, len, dh, scale, &mut m, &mut l, &mut acc);
        }
        if l > 0.0 {
            let inv = 1.0 / l;
            for a in acc.iter_mut() {
                *a *= inv;
            }
        }
        acc
    });
    let mut out = vec![0.0f32; h * dh];
    for (hh, row) in rows.iter().enumerate() {
        out[hh * dh..(hh + 1) * dh].copy_from_slice(row);
    }
    out
}

/// Batched multi-query sparse attention for the speculative verify step.
///
/// `q` is `[G, H, dh]` — the query rows of G *consecutive* stream
/// positions — and row `(h, g)` of the verify-shaped `sel` lists the key
/// blocks position `g` attends. Position `g`'s causal width is
/// `base_tokens + g` cached tokens (its own K/V included), so the batch
/// is a causal staircase; selected blocks (or block tails) beyond a
/// row's width are clamped away, which is what lets one shared
/// [`Selection::verify_full`] serve every row of a dense-plan batch.
///
/// Within each head the kernel walks blocks OUTER and query rows INNER,
/// so one K/V slab load serves every row that selected it — the
/// bandwidth win of batching γ+1 positions. Each row still folds its
/// blocks in ascending order through `online_softmax_block`, the exact
/// update of the single-query kernels, so every row's output is
/// bit-identical to a sequential [`sparse_decode_attention`] pass over
/// the same selection at the same width. Parallel across heads; returns
/// `[G·H·dh]` position-major (`out[g·H·dh..]` is position `g`'s output).
/// Dispatches on [`simd::active`]; see [`sparse_verify_attention_with`].
pub fn sparse_verify_attention(
    q: &Tensor,
    kv: &impl KvBlocks,
    sel: &Selection,
    base_tokens: usize,
) -> Vec<f32> {
    sparse_verify_attention_with(simd::active(), q, kv, sel, base_tokens)
}

/// [`sparse_verify_attention`] with an explicit SIMD arm.
///
/// Debug builds validate `sel` first ([`Selection::validate_verify`]):
/// the per-row cursor walk assumes strictly ascending ids, and a
/// malformed row would otherwise silently skip blocks instead of
/// failing — release builds remain memory-safe either way (out-of-range
/// ids clamp to zero-length blocks before any slab is fetched).
pub fn sparse_verify_attention_with(
    arm: SimdArm,
    q: &Tensor,
    kv: &impl KvBlocks,
    sel: &Selection,
    base_tokens: usize,
) -> Vec<f32> {
    let (g_rows, h, dh) = (q.shape[0], q.shape[1], q.shape[2]);
    debug_assert_eq!(sel.n_heads, h, "verify selection must cover every query head");
    debug_assert_eq!(sel.nblk, g_rows, "verify selection must cover every position");
    debug_assert!(
        base_tokens >= 1 && base_tokens + g_rows - 1 <= kv.n_tokens(),
        "verify positions must fit the cached context"
    );
    debug_assert_eq!(
        sel.validate_verify(kv.n_blocks()).map_err(|e| format!("verify selection: {e}")),
        Ok(()),
    );
    let hk = kv.n_kv_heads();
    let rep = h / hk;
    let bt = kv.block_tokens();
    let nblk = kv.n_blocks();
    let scale = 1.0 / (dh as f32).sqrt();
    let heads = parallel_items(h, |hh| {
        let hkv = hh / rep;
        let mut m = vec![f32::NEG_INFINITY; g_rows];
        let mut l = vec![0.0f32; g_rows];
        let mut acc = vec![0.0f32; g_rows * dh];
        let mut cursor = vec![0usize; g_rows];
        let sel_rows: Vec<&[u32]> = (0..g_rows).map(|g| sel.selected(hh, g)).collect();
        for b in 0..nblk {
            // fetch the slabs lazily: blocks nobody selected cost nothing
            let mut slabs: Option<(&[f32], &[f32])> = None;
            for g in 0..g_rows {
                let row = sel_rows[g];
                if cursor[g] >= row.len() || row[cursor[g]] as usize != b {
                    continue;
                }
                cursor[g] += 1;
                let width = base_tokens + g;
                if width <= b * bt {
                    continue; // block fully beyond this row's causal width
                }
                let len = kv.block_len(b).min(width - b * bt);
                if len == 0 {
                    continue;
                }
                let (ks, vs) =
                    *slabs.get_or_insert_with(|| (kv.k_block(hkv, b), kv.v_block(hkv, b)));
                simd::online_softmax_block(
                    arm,
                    q.row3(g, hh),
                    ks,
                    vs,
                    len,
                    dh,
                    scale,
                    &mut m[g],
                    &mut l[g],
                    &mut acc[g * dh..(g + 1) * dh],
                );
            }
        }
        for g in 0..g_rows {
            if l[g] > 0.0 {
                let inv = 1.0 / l[g];
                for a in acc[g * dh..(g + 1) * dh].iter_mut() {
                    *a *= inv;
                }
            }
        }
        acc
    });
    let mut out = vec![0.0f32; g_rows * h * dh];
    for (hh, buf) in heads.iter().enumerate() {
        for g in 0..g_rows {
            let dst = (g * h + hh) * dh;
            out[dst..dst + dh].copy_from_slice(&buf[g * dh..(g + 1) * dh]);
        }
    }
    out
}

/// Scalar multi-query verify oracle: position `g` scored independently by
/// [`dense_decode_attention_reference`] over a [`KvPrefix`] clamped to
/// its own causal width `base_tokens + g`. The verify property tests pin
/// [`sparse_verify_attention`] under a full verify selection to this
/// within 1e-5.
pub fn dense_verify_attention_reference(
    q: &Tensor,
    kv: &impl KvBlocks,
    base_tokens: usize,
) -> Vec<f32> {
    let (g_rows, h, dh) = (q.shape[0], q.shape[1], q.shape[2]);
    let mut out = vec![0.0f32; g_rows * h * dh];
    for g in 0..g_rows {
        let qg = Tensor::from_vec(&[h, dh], q.data[g * h * dh..(g + 1) * h * dh].to_vec());
        let pre = KvPrefix::new(kv, base_tokens + g);
        let row = dense_decode_attention_reference(&qg, &pre);
        out[g * h * dh..(g + 1) * h * dh].copy_from_slice(&row);
    }
    out
}

/// Scalar single-query dense attention over the whole cached context —
/// the equivalence oracle for [`sparse_decode_attention`] under a full
/// selection (one gather of every score, one global max, one normalize
/// pass; single thread).
pub fn dense_decode_attention_reference(q: &Tensor, kv: &impl KvBlocks) -> Vec<f32> {
    let (h, dh) = (q.shape[0], q.shape[1]);
    let hk = kv.n_kv_heads();
    let rep = h / hk;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut out = vec![0.0f32; h * dh];
    let mut svals: Vec<f32> = Vec::new();
    for hh in 0..h {
        let hkv = hh / rep;
        let qrow = &q.data[hh * dh..(hh + 1) * dh];
        svals.clear();
        let mut m = f32::NEG_INFINITY;
        for b in 0..kv.n_blocks() {
            let len = kv.block_len(b);
            let ks = kv.k_block(hkv, b);
            for t in 0..len {
                let s = dot(qrow, &ks[t * dh..(t + 1) * dh]) * scale;
                if s > m {
                    m = s;
                }
                svals.push(s);
            }
        }
        let mut l = 0.0f32;
        for s in svals.iter_mut() {
            *s = (*s - m).exp();
            l += *s;
        }
        if l == 0.0 {
            continue; // empty context: zeros, not NaN
        }
        let orow = &mut out[hh * dh..(hh + 1) * dh];
        let mut idx = 0;
        for b in 0..kv.n_blocks() {
            let len = kv.block_len(b);
            let vs = kv.v_block(hkv, b);
            for t in 0..len {
                axpy(orow, svals[idx] / l, &vs[t * dh..(t + 1) * dh]);
                idx += 1;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn qkv(seed: u64, h: usize, hk: usize, n: usize, dh: usize) -> (Tensor, Tensor, Tensor) {
        let mut r = Rng::new(seed);
        (
            Tensor::randn(&[h, n, dh], &mut r),
            Tensor::randn(&[hk, n, dh], &mut r),
            Tensor::randn(&[hk, n, dh], &mut r),
        )
    }

    #[test]
    fn full_selection_matches_dense() {
        let (q, k, v) = qkv(1, 2, 1, 128, 16);
        let sel = Selection::full_causal(2, 4);
        sel.validate().unwrap();
        let sparse = block_sparse_attention(&q, &k, &v, &sel, 32);
        let dense = dense_attention(&q, &k, &v);
        assert!(sparse.max_abs_diff(&dense) < 1e-4, "diff {}", sparse.max_abs_diff(&dense));
        let reference = block_sparse_attention_reference(&q, &k, &v, &sel, 32);
        assert!(reference.max_abs_diff(&dense) < 1e-4, "ref diff {}", reference.max_abs_diff(&dense));
    }

    #[test]
    fn stem_selection_valid() {
        let (q, k, v) = qkv(2, 4, 2, 256, 16);
        let sel = select_stem(&q, &k, &v, 32, 8, &TpdConfig::default(), 0.2);
        sel.validate().unwrap();
        // forced blocks present
        for h in 0..4 {
            for i in 0..sel.nblk {
                let set = sel.selected(h, i);
                assert!(set.contains(&0), "sink missing h{h} i{i}");
                assert!(set.contains(&(i as u32)), "diag missing h{h} i{i}");
            }
        }
    }

    #[test]
    fn partial_topk_matches_full_sort_reference() {
        for seed in [7u64, 8, 9] {
            let (q, k, v) = qkv(seed, 4, 2, 256, 16);
            let cfg = TpdConfig { k_start: 3.0, mu: 0.6, ..Default::default() };
            let fast = select_stem(&q, &k, &v, 32, 8, &cfg, 0.2);
            let slow = select_stem_reference(&q, &k, &v, 32, 8, &cfg, 0.2);
            assert_eq!(fast.counts, slow.counts);
            assert_eq!(fast.row_offsets, slow.row_offsets);
            assert_eq!(fast.indices, slow.indices, "selected sets diverge (seed {seed})");
        }
    }

    #[test]
    fn streaming_pattern_correct() {
        let sel = select_streaming(1, 8, 1, 2);
        sel.validate().unwrap();
        for i in 0..8usize {
            let mut set: Vec<u32> = sel.selected(0, i).to_vec();
            set.sort();
            let mut want: Vec<u32> = vec![0];
            for j in i.saturating_sub(1)..=i {
                if !want.contains(&(j as u32)) {
                    want.push(j as u32);
                }
            }
            want.sort();
            assert_eq!(set, want, "row {i}");
            // padding must complete the causal width without duplicates
            let mut full: Vec<u32> = sel.row(0, i).to_vec();
            full.sort();
            assert_eq!(full, (0..=i as u32).collect::<Vec<_>>(), "padding row {i}");
        }
    }

    #[test]
    fn more_budget_less_error() {
        let (q, k, v) = qkv(3, 2, 1, 256, 16);
        let dense = dense_attention(&q, &k, &v);
        let mut errs = vec![];
        for ks in [2.0, 4.0, 8.0] {
            let cfg = TpdConfig { k_start: ks, ..Default::default() };
            let sel = select_stem(&q, &k, &v, 32, 8, &cfg, 0.2);
            let o = block_sparse_attention(&q, &k, &v, &sel, 32);
            errs.push(o.mse(&dense));
        }
        assert!(errs[0] >= errs[1] && errs[1] >= errs[2], "{errs:?}");
    }

    #[test]
    fn fused_matches_reference_kernel() {
        let (q, k, v) = qkv(5, 4, 2, 256, 16);
        let cfg = TpdConfig { k_start: 3.0, ..Default::default() };
        let sel = select_stem(&q, &k, &v, 32, 8, &cfg, 0.2);
        let fused = block_sparse_attention(&q, &k, &v, &sel, 32);
        let reference = block_sparse_attention_reference(&q, &k, &v, &sel, 32);
        let d = fused.max_abs_diff(&reference);
        assert!(d < 1e-5, "fused deviates from reference by {d}");
    }

    #[test]
    fn degenerate_all_masked_row_yields_zeros() {
        let (q, k, v) = qkv(6, 1, 1, 64, 8);
        // row 0 selects only block 1 (non-causal): every score is masked
        let mut b = SelectionBuilder::new(1, 2);
        b.push_row(&[1], 1);
        b.push_row(&[1, 0], 2);
        let sel = b.finish();
        assert!(sel.validate().is_err(), "non-causal selection must not validate");
        for out in [
            block_sparse_attention(&q, &k, &v, &sel, 32),
            block_sparse_attention_reference(&q, &k, &v, &sel, 32),
        ] {
            assert!(out.data.iter().all(|x| x.is_finite()), "NaN leaked from masked row");
            assert!(out.data[..32 * 8].iter().all(|&x| x == 0.0), "masked rows must be zero");
            assert!(out.data[32 * 8..].iter().any(|&x| x != 0.0), "live rows must attend");
        }
    }

    #[test]
    fn oam_respects_causality() {
        let (q, k, v) = qkv(4, 2, 1, 128, 16);
        let s = oam_scores(&q, &k, &v, 32, 8, 0.2);
        for h in 0..2 {
            for i in 0..4 {
                for j in (i + 1)..4 {
                    assert_eq!(s.at3(h, i, j), NEG_INF);
                }
            }
        }
    }

    fn decode_qkv(seed: u64, h: usize, hk: usize, n_cap: usize, dh: usize) -> (Tensor, Tensor, Tensor) {
        let mut r = Rng::new(seed);
        (
            Tensor::randn(&[h, dh], &mut r),
            Tensor::randn(&[hk, n_cap, dh], &mut r),
            Tensor::randn(&[hk, n_cap, dh], &mut r),
        )
    }

    #[test]
    fn decode_full_selection_matches_dense_reference() {
        // 200 = 6 full blocks + one 8-token partial block at block=32
        for n_tokens in [1usize, 31, 32, 200] {
            let (q, k, v) = decode_qkv(11, 4, 2, 256, 16);
            let kv = TensorKv { k: &k, v: &v, n_tokens, block: 32 };
            let sel = Selection::decode_full(4, kv.n_blocks());
            sel.validate_decode(kv.n_blocks()).unwrap();
            let sparse = sparse_decode_attention(&q, &kv, &sel);
            let dense = dense_decode_attention_reference(&q, &kv);
            let d = sparse
                .iter()
                .zip(&dense)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(d < 1e-5, "n_tokens={n_tokens}: sparse deviates from dense by {d}");
        }
    }

    #[test]
    fn select_decode_keeps_forced_blocks_and_budget() {
        let (q, k, v) = decode_qkv(12, 4, 2, 512, 16);
        let kv = TensorKv { k: &k, v: &v, n_tokens: 512, block: 32 };
        let scores = decode_block_scores(&q, &kv, 8, 0.2);
        assert_eq!(scores.shape, vec![4, 16]);
        let sel = select_decode(&scores, 6, 2, 2);
        sel.validate_decode(16).unwrap();
        for h in 0..4 {
            let row = sel.selected(h, 0);
            assert_eq!(row.len(), 6, "head {h} must fill its budget");
            for s in 0..2u32 {
                assert!(row.contains(&s), "sink {s} missing in head {h}");
            }
            for r in 14..16u32 {
                assert!(row.contains(&r), "recent {r} missing in head {h}");
            }
        }
        // budget >= context keeps everything
        let full = select_decode(&scores, 99, 1, 1);
        for h in 0..4 {
            assert_eq!(full.selected(h, 0).len(), 16);
        }
    }

    #[test]
    fn decode_more_budget_less_error() {
        let (q, k, v) = decode_qkv(13, 2, 1, 512, 16);
        let kv = TensorKv { k: &k, v: &v, n_tokens: 500, block: 32 };
        let dense = dense_decode_attention_reference(&q, &kv);
        let scores = decode_block_scores(&q, &kv, 4, 0.2);
        let mut errs = vec![];
        for budget in [3usize, 6, 12] {
            let sel = select_decode(&scores, budget, 1, 2);
            let out = sparse_decode_attention(&q, &kv, &sel);
            let mse: f64 = out
                .iter()
                .zip(&dense)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                / out.len() as f64;
            errs.push(mse);
        }
        assert!(errs[0] >= errs[1] && errs[1] >= errs[2], "{errs:?}");
    }

    #[test]
    fn validate_decode_rejects_malformed_rows() {
        // empty row
        let mut b = SelectionBuilder::new(1, 1);
        b.push_row(&[], 0);
        assert!(b.finish().validate_decode(4).is_err());
        // out-of-range block
        let mut b = SelectionBuilder::new(1, 1);
        b.push_row(&[4], 1);
        assert!(b.finish().validate_decode(4).is_err());
        // non-ascending
        let mut b = SelectionBuilder::new(1, 1);
        b.push_row(&[2, 1], 2);
        assert!(b.finish().validate_decode(4).is_err());
        // well-formed
        let mut b = SelectionBuilder::new(1, 1);
        b.push_row(&[0, 2, 3], 3);
        b.finish().validate_decode(4).unwrap();
    }

    #[test]
    fn dense_fast_path_is_bitwise_equal_to_full_selection() {
        // the dense decode fast path must not merely approximate the
        // full-selection kernel: speculative equivalence relies on the
        // two producing the same bits
        for n_tokens in [1usize, 31, 32, 200] {
            let (q, k, v) = decode_qkv(17, 4, 2, 256, 16);
            let kv = TensorKv { k: &k, v: &v, n_tokens, block: 32 };
            let sel = Selection::decode_full(4, kv.n_blocks());
            let full = sparse_decode_attention(&q, &kv, &sel);
            let fast = dense_decode_attention(&q, &kv);
            assert_eq!(full, fast, "n_tokens={n_tokens}: fast path diverges from full selection");
        }
    }

    #[test]
    fn verify_kernel_matches_per_position_dense_oracle() {
        // degenerate rows (width 1), G > base context, page-boundary
        // straddles and partial tails, all against the scalar oracle
        for (base, g_rows, n_cap, block) in [
            (1usize, 3usize, 64usize, 32usize), // widths 1..3: G > base
            (31, 4, 128, 32),                   // staircase straddles block 0 -> 1
            (64, 2, 128, 32),                   // base exactly on a boundary
            (197, 6, 256, 32),                  // deep context, partial tail
            (5, 1, 64, 16),                     // single-row batch
        ] {
            let mut r = Rng::new(23 + base as u64);
            let (h, hk, dh) = (4usize, 2usize, 16usize);
            let q = Tensor::randn(&[g_rows, h, dh], &mut r);
            let k = Tensor::randn(&[hk, n_cap, dh], &mut r);
            let v = Tensor::randn(&[hk, n_cap, dh], &mut r);
            let n_tokens = base + g_rows - 1;
            let kv = TensorKv { k: &k, v: &v, n_tokens, block };
            let sel = Selection::verify_full(h, g_rows, kv.n_blocks());
            sel.validate_verify(kv.n_blocks()).unwrap();
            let got = sparse_verify_attention(&q, &kv, &sel, base);
            let want = dense_verify_attention_reference(&q, &kv, base);
            let d = got.iter().zip(&want).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
            assert!(d < 1e-5, "base={base} G={g_rows} block={block}: verify deviates by {d}");
        }
    }

    #[test]
    fn verify_rows_are_bitwise_equal_to_single_query_passes() {
        // the speculative decode-equivalence guarantee: each verify row
        // must reproduce a sequential single-query pass over the same
        // per-row selection at the same width, bit for bit
        let mut r = Rng::new(29);
        let (g_rows, h, hk, dh, block, base) = (5usize, 4usize, 2usize, 16usize, 32usize, 150usize);
        let q = Tensor::randn(&[g_rows, h, dh], &mut r);
        let k = Tensor::randn(&[hk, 256, dh], &mut r);
        let v = Tensor::randn(&[hk, 256, dh], &mut r);
        let kv = TensorKv { k: &k, v: &v, n_tokens: base + g_rows - 1, block };
        // per-row sparse selections, exactly as the sequential step would
        // compute them over its own clamped width
        let mut row_sels: Vec<Selection> = vec![];
        for g in 0..g_rows {
            let pre = KvPrefix::new(&kv, base + g);
            let qg = Tensor::from_vec(&[h, dh], q.data[g * h * dh..(g + 1) * h * dh].to_vec());
            let scores = decode_block_scores(&qg, &pre, 8, 0.2);
            row_sels.push(select_decode(&scores, 3, 1, 1));
        }
        let mut b = SelectionBuilder::new(h, g_rows);
        for hh in 0..h {
            for s in &row_sels {
                let row = s.selected(hh, 0);
                b.push_row(row, row.len() as u32);
            }
        }
        let sel = b.finish();
        sel.validate_verify(kv.n_blocks()).unwrap();
        let got = sparse_verify_attention(&q, &kv, &sel, base);
        for g in 0..g_rows {
            let pre = KvPrefix::new(&kv, base + g);
            let qg = Tensor::from_vec(&[h, dh], q.data[g * h * dh..(g + 1) * h * dh].to_vec());
            let want = sparse_decode_attention(&qg, &pre, &row_sels[g]);
            assert_eq!(
                &got[g * h * dh..(g + 1) * h * dh],
                &want[..],
                "row {g} is not bitwise-equal to its sequential pass"
            );
        }
    }

    #[test]
    fn kv_prefix_clamps_blocks_and_tokens() {
        let mut r = Rng::new(31);
        let k = Tensor::randn(&[1, 64, 8], &mut r);
        let v = Tensor::randn(&[1, 64, 8], &mut r);
        let kv = TensorKv { k: &k, v: &v, n_tokens: 50, block: 16 };
        let pre = KvPrefix::new(&kv, 35); // 2 full blocks + a 3-token tail
        assert_eq!(pre.n_tokens(), 35);
        assert_eq!(pre.n_blocks(), 3);
        assert_eq!(pre.block_len(2), 3);
        assert_eq!(pre.k_block(0, 2).len(), 3 * 8);
        assert_eq!(pre.k_block(0, 0), kv.k_block(0, 0), "full blocks pass through");
        assert_eq!(&kv.v_block(0, 2)[..3 * 8], pre.v_block(0, 2), "tail is a prefix slice");
    }

    #[test]
    fn validate_verify_rejects_malformed_rows() {
        // shared full selection validates
        Selection::verify_full(2, 3, 4).validate_verify(4).unwrap();
        // empty row
        let mut b = SelectionBuilder::new(1, 2);
        b.push_row(&[0], 1);
        b.push_row(&[], 0);
        assert!(b.finish().validate_verify(4).is_err());
        // out-of-range block
        let mut b = SelectionBuilder::new(1, 2);
        b.push_row(&[0], 1);
        b.push_row(&[4], 1);
        assert!(b.finish().validate_verify(4).is_err());
        // non-ascending
        let mut b = SelectionBuilder::new(1, 2);
        b.push_row(&[0], 1);
        b.push_row(&[2, 1], 2);
        assert!(b.finish().validate_verify(4).is_err());
    }

    #[test]
    fn score_mass_row_matches_hand_softmax() {
        // softmax([0, ln2, ln4]) = [1/7, 2/7, 4/7]
        let row = [0.0f32, 2.0f32.ln(), 4.0f32.ln()];
        assert!((score_mass_row(&row, &[2]) - 4.0 / 7.0).abs() < 1e-6);
        assert!((score_mass_row(&row, &[0, 1]) - 3.0 / 7.0).abs() < 1e-6);
        assert!((score_mass_row(&row, &[0, 1, 2]) - 1.0).abs() < 1e-12);
        // out-of-range kept ids contribute nothing
        assert!((score_mass_row(&row, &[2, 9]) - 4.0 / 7.0).abs() < 1e-6);
        // degenerate rows report full mass
        assert_eq!(score_mass_row(&[], &[0]), 1.0);
        assert_eq!(score_mass_row(&[1.0], &[]), 1.0);
        assert_eq!(score_mass_row(&[NEG_INF, NEG_INF], &[0]), 1.0);
    }

    #[test]
    fn selection_score_mass_tracks_budget() {
        let (q, k, v) = decode_qkv(37, 4, 2, 512, 16);
        let kv = TensorKv { k: &k, v: &v, n_tokens: 512, block: 32 };
        let scores = decode_block_scores(&q, &kv, 8, 0.2);
        let mut masses = vec![];
        for budget in [2usize, 6, 16] {
            let sel = select_decode(&scores, budget, 1, 1);
            masses.push(selection_score_mass(&scores, &sel));
        }
        for &m in &masses {
            assert!((0.0..=1.0).contains(&m), "mass {m} out of range");
        }
        assert!(masses[0] <= masses[1] + 1e-9 && masses[1] <= masses[2] + 1e-9, "{masses:?}");
        assert!((masses[2] - 1.0).abs() < 1e-9, "full budget must capture all mass");
    }

    #[test]
    fn csr_accessors_roundtrip() {
        let mut b = SelectionBuilder::new(2, 3);
        for _ in 0..2 {
            b.push_row(&[0], 1);
            b.push_row(&[0, 1], 2);
            b.push_row(&[2, 0, 1], 2); // one padding entry
        }
        let sel = b.finish();
        sel.validate().unwrap();
        assert_eq!(sel.count(1, 2), 2);
        assert_eq!(sel.selected(1, 2), &[2, 0]);
        assert_eq!(sel.row(1, 2), &[2, 0, 1]);
        assert!((sel.budget_fraction() - 5.0 / 6.0).abs() < 1e-12);
    }
}
