//! Pure-rust reference implementation of the Stem pipeline (schedule,
//! pooling, OAM metric, selection, block-sparse attention) plus the small
//! tensor type it runs on. Serves tests, the simulator and the
//! coordinator's cost estimates; the request path executes XLA artifacts.

pub mod attention;
pub mod schedule;
pub mod simd;
pub mod tensor;

pub use attention::{
    antidiag_scores, antidiag_scores_with, block_sparse_attention,
    block_sparse_attention_reference, block_sparse_attention_with, decode_block_scores,
    decode_block_scores_with, dense_attention, dense_attention_with, dense_decode_attention,
    dense_decode_attention_reference, dense_decode_attention_with,
    dense_verify_attention_reference, oam_scores, oam_scores_with, score_mass_row, select_decode,
    select_stem, select_stem_reference, select_streaming, selection_score_mass,
    sparse_decode_attention, sparse_decode_attention_with, sparse_verify_attention,
    sparse_verify_attention_with, value_block_logmag, KvBlocks, KvPrefix, Selection,
    SelectionBuilder, TensorKv,
};
pub use schedule::TpdConfig;
pub use simd::SimdArm;
pub use tensor::Tensor;
