//! Token Position-Decay schedule + cost model — rust mirror of
//! `python/compile/schedule.py` (paper Eq. 2-4, 8; §3.3).
//!
//! The coordinator uses these for admission-control cost estimates and the
//! benchmark harness uses them for the Figure-1 analytic projection; they
//! are cross-checked against the python oracle through golden tests.

/// Hyper-parameters of the Token Position-Decay strategy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TpdConfig {
    /// Block budget at the first position.
    pub k_start: f64,
    /// Decay floor multiplier: budget approaches `mu·k_start`.
    pub mu: f64,
    /// Leading blocks always kept (attention sinks).
    pub init_keep: usize,
    /// Trailing blocks always kept (local window).
    pub local_keep: usize,
    /// Hard floor on kept blocks per row.
    pub min_total: usize,
}

impl Default for TpdConfig {
    fn default() -> Self {
        TpdConfig { k_start: 8.0, mu: 0.7, init_keep: 1, local_keep: 2, min_total: 4 }
    }
}

impl TpdConfig {
    /// Reject configurations outside the schedule's domain.
    pub fn validate(&self) -> Result<(), String> {
        if !(self.mu > 0.0 && self.mu <= 1.0) {
            return Err(format!("mu must be in (0,1], got {}", self.mu));
        }
        if self.k_start <= 0.0 {
            return Err(format!("k_start must be > 0, got {}", self.k_start));
        }
        if self.local_keep < 1 {
            return Err("local_keep must be >= 1".into());
        }
        Ok(())
    }
}

/// Eq. (3): per-position budget k(i), 0-based `i` (shifted to the paper's
/// 1-based indexing internally), floored, min 1.
pub fn k_at(i: usize, n: usize, k_start: f64, mu: f64) -> f64 {
    let i1 = (i + 1) as f64;
    (k_start - (k_start * (1.0 - mu) / n as f64) * i1).floor().max(1.0)
}

/// Effective per-query-block budget with causal clamping (Algorithm 1).
pub fn block_budget_schedule(n_blocks: usize, cfg: &TpdConfig) -> Vec<usize> {
    (0..n_blocks)
        .map(|i| {
            let raw = k_at(i, n_blocks, cfg.k_start, cfg.mu);
            let forced = (cfg.init_keep + cfg.local_keep).min(i + 1);
            let k = raw.max(cfg.min_total as f64).max(forced as f64);
            (k as usize).min(i + 1)
        })
        .collect()
}

/// Full causal attention pairs: N(N+1)/2.
pub fn cost_dense(n: usize) -> f64 {
    n as f64 * (n as f64 + 1.0) / 2.0
}

/// Eq. (2): C_uni ≈ N·k − k²/2.
pub fn cost_uniform(n: usize, k_uni: f64) -> f64 {
    n as f64 * k_uni - 0.5 * k_uni * k_uni
}

/// Eq. (4): uniform baseline minus the decay-savings term.
pub fn cost_decay(n: usize, k_start: f64, mu: f64) -> f64 {
    let base = n as f64 * k_start - 0.5 * k_start * k_start;
    let savings = 0.5 * k_start * (1.0 - mu) * (n as f64 - k_start);
    base - savings
}

/// Eq. (8): metric calculation + sparse execution cost of Stem.
pub fn cost_stem(n: usize, d: usize, block: usize, k_avg_tokens: f64) -> f64 {
    let (nf, df, bf) = (n as f64, d as f64, block as f64);
    let metric = 2.0 * nf * nf * df / (bf * bf) + nf * df / bf;
    let sparse = 4.0 * nf * k_avg_tokens * df + 3.0 * nf * k_avg_tokens;
    metric + sparse
}

/// Dense attention FLOP-ish cost on the same scale as `cost_stem`.
pub fn cost_dense_flops(n: usize, d: usize) -> f64 {
    let (nf, df) = (n as f64, d as f64);
    4.0 * nf * nf * df + 3.0 * nf * nf
}

/// §3.3 budget-matching: k_uni with the same total cost as TPD(k_start, mu).
pub fn k_uniform_matched(k_start: f64, mu: f64) -> f64 {
    k_start * (1.0 + mu) / 2.0
}

/// Average per-block budget under the schedule (blocks).
pub fn k_avg_blocks(n_blocks: usize, cfg: &TpdConfig) -> f64 {
    let k = block_budget_schedule(n_blocks, cfg);
    k.iter().sum::<usize>() as f64 / n_blocks as f64
}

/// Total selected (query-block, key-block) pairs per head under the
/// schedule — the exact CSR `indices` length one head of a Stem
/// [`crate::sparse::Selection`] occupies, used to pre-size the flat layout.
pub fn block_budget_total(n_blocks: usize, cfg: &TpdConfig) -> usize {
    block_budget_schedule(n_blocks, cfg).iter().sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    #[test]
    fn schedule_monotone_and_bounded() {
        forall(
            11,
            200,
            |r: &mut Rng| (r.below(60) as usize + 4, r.f64() * 0.7 + 0.3, r.f64() * 30.0 + 2.0),
            |&(nblk, mu, ks)| {
                let cfg = TpdConfig { k_start: ks, mu, ..Default::default() };
                let k = block_budget_schedule(nblk, &cfg);
                for i in 0..nblk {
                    if k[i] > i + 1 {
                        return Err(format!("k[{i}]={} > width", k[i]));
                    }
                    if k[i] == 0 {
                        return Err("zero budget".into());
                    }
                }
                // The raw schedule is non-increasing. Inside the causal
                // triangle (k(i) ≥ width) the effective budget equals the
                // width i+1 and grows by construction, so an increase is
                // only a bug when the next row is NOT width-clamped.
                for i in cfg.min_total.max(cfg.init_keep + cfg.local_keep)..nblk.saturating_sub(1) {
                    if k[i + 1] > k[i] && k[i + 1] != i + 2 {
                        return Err(format!("not non-increasing at {i}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn decay_cheaper_iff_mu_below_one() {
        forall(
            12,
            200,
            |r: &mut Rng| (r.below(8000) as usize + 200, r.f64() * 0.69 + 0.3, r.f64() * 50.0 + 8.0),
            |&(n, mu, ks)| {
                if ks >= n as f64 {
                    return Ok(());
                }
                let cd = cost_decay(n, ks, mu);
                let cu = cost_uniform(n, ks);
                if cd < cu {
                    Ok(())
                } else {
                    Err(format!("C_decay {cd} !< C_uni {cu}"))
                }
            },
        );
        assert!((cost_decay(4096, 32.0, 1.0) - cost_uniform(4096, 32.0)).abs() < 1e-6);
    }

    #[test]
    fn budget_matching_rule_close() {
        let (ks, mu, n) = (48.0, 0.7, 1 << 16);
        let cu = cost_uniform(n, k_uniform_matched(ks, mu));
        let cd = cost_decay(n, ks, mu);
        assert!((cu - cd).abs() / cd < 0.02, "cu={cu} cd={cd}");
    }

    #[test]
    fn stem_cost_below_dense_at_scale() {
        let c_stem = cost_stem(131072, 256, 64, 8192.0);
        let c_dense = cost_dense_flops(131072, 256);
        assert!(c_stem < 0.2 * c_dense, "stem {c_stem} dense {c_dense}");
    }

    #[test]
    fn budget_total_is_schedule_sum() {
        let cfg = TpdConfig::default();
        for nblk in [1usize, 7, 32] {
            let want: usize = block_budget_schedule(nblk, &cfg).iter().sum();
            assert_eq!(block_budget_total(nblk, &cfg), want);
        }
    }

    #[test]
    fn k_at_endpoints() {
        let (n, ks, mu) = (1000, 100.0, 0.7);
        assert!(k_at(0, n, ks, mu) >= ks - 1.0);
        assert!((k_at(n - 1, n, ks, mu) - mu * ks).abs() <= 1.0);
    }
}
