//! Runtime-dispatched SIMD inner loops for the sparse-attention core.
//!
//! Every hot kernel in this crate bottoms out in one of three inner-loop
//! families: score dots (`q·k` over `dh` lanes), online-softmax
//! accumulation (`acc += p·v` plus the rescale correction), and the
//! `TinyLm` projection matvec (one dot per output row). This module owns
//! vectorized implementations of exactly those primitives behind an
//! explicit [`SimdArm`] parameter, so the dispatch decision is made once
//! per kernel invocation (never per element) and every call site can be
//! forced onto either arm for differential testing.
//!
//! # Arms
//!
//! * [`SimdArm::Scalar`] — delegates to the seed scalar loops in
//!   [`super::tensor`] *unchanged*. This arm is the property-pinned
//!   oracle: its floating-point operation sequence is bit-identical to
//!   the pre-SIMD crate, so every existing golden/property suite keeps
//!   its meaning.
//! * [`SimdArm::Wide`] — 8-lane `f32` loops. On `x86_64` with AVX2+FMA
//!   detected at runtime the loops run as `std::arch` intrinsics
//!   (unaligned 256-bit loads, fused multiply-add, four independent
//!   accumulators); everywhere else a portable unrolled-lane fallback
//!   with the same lane structure runs, which LLVM autovectorizes to
//!   whatever the target has. The wide arm matches the scalar arm within
//!   1e-5 (different reduction order and FMA rounding), and is
//!   internally deterministic: one process always takes the same code
//!   path, so the byte-exact speculative-decode equivalence guarantee
//!   holds *within* an arm.
//!
//! # Dispatch
//!
//! [`active`] resolves the process-wide arm: a programmatic override
//! ([`set_override`], used by benches and the `--simd` CLI flag) wins,
//! else the `STEM_SIMD` environment variable (`auto` / `scalar` /
//! `wide`, read once), else `auto` = wide. [`dispatch_label`] exposes
//! the resolved decision (including whether the AVX2 or the portable
//! wide path is live) to the obs snapshot as the `simd_dispatch` label
//! and the `stem_simd_dispatch_info` Prometheus series.
//!
//! # Data-layout contract
//!
//! All primitives take contiguous `&[f32]` slices: K/V slabs are
//! `[len, dh]` row-major (exactly what [`super::attention::KvBlocks`]
//! hands out), score tiles are `[block, block]` row-major. No alignment
//! is required — the intrinsics use unaligned loads, which cost nothing
//! on post-Nehalem cores — but rows must be contiguous; the scalar tail
//! (`len % 8` lanes) is handled inside each primitive.

use super::tensor;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Which inner-loop implementation the dispatched kernels execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdArm {
    /// The seed scalar loops (bit-identical to the pre-SIMD crate); the
    /// property-pinned oracle arm.
    Scalar,
    /// 8-lane vector loops: AVX2+FMA intrinsics when the CPU has them,
    /// otherwise the portable unrolled-lane fallback.
    Wide,
}

/// Both arms, in oracle-first order — the iteration fixture for tests
/// that must cover every dispatch target.
pub const ARMS: [SimdArm; 2] = [SimdArm::Scalar, SimdArm::Wide];

// 0 = no override, 1 = scalar, 2 = wide.
static OVERRIDE: AtomicU8 = AtomicU8::new(0);
static ENV_CHOICE: OnceLock<SimdArm> = OnceLock::new();
static HAVE_AVX2: OnceLock<bool> = OnceLock::new();

/// Whether the wide arm runs as AVX2+FMA intrinsics on this machine
/// (false = portable fallback). Detected once, then cached.
pub fn wide_is_avx2() -> bool {
    *HAVE_AVX2.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("fma")
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            false
        }
    })
}

/// Parse a `STEM_SIMD` / `--simd` value. `Ok(None)` means `auto`
/// (clear any override and fall back to env/default resolution).
pub fn parse(s: &str) -> Result<Option<SimdArm>, String> {
    match s.trim().to_ascii_lowercase().as_str() {
        "auto" => Ok(None),
        "scalar" => Ok(Some(SimdArm::Scalar)),
        "wide" => Ok(Some(SimdArm::Wide)),
        other => Err(format!("unknown simd arm {other:?} (expected auto|scalar|wide)")),
    }
}

/// Force the dispatched kernels onto one arm (`None` restores env/auto
/// resolution). Process-global; meant for benches, the `--simd` CLI
/// flag, and the differential suite's dispatch test — not for flipping
/// mid-flight while kernels run on other threads.
pub fn set_override(arm: Option<SimdArm>) {
    let v = match arm {
        None => 0,
        Some(SimdArm::Scalar) => 1,
        Some(SimdArm::Wide) => 2,
    };
    OVERRIDE.store(v, Ordering::Relaxed);
}

fn env_choice() -> SimdArm {
    *ENV_CHOICE.get_or_init(|| {
        match std::env::var("STEM_SIMD").ok().as_deref().map(parse) {
            Some(Ok(Some(arm))) => arm,
            Some(Err(e)) => {
                eprintln!("STEM_SIMD ignored: {e}");
                SimdArm::Wide
            }
            // unset or explicit auto: the wide arm always works (the
            // portable fallback needs no CPU features), so auto = wide
            _ => SimdArm::Wide,
        }
    })
}

/// The arm the dispatched kernel wrappers execute right now:
/// [`set_override`] wins, else `STEM_SIMD` (`auto`/`scalar`/`wide`,
/// read once), else wide.
pub fn active() -> SimdArm {
    match OVERRIDE.load(Ordering::Relaxed) {
        1 => SimdArm::Scalar,
        2 => SimdArm::Wide,
        _ => env_choice(),
    }
}

/// Stable label of the live dispatch decision for observability:
/// `"scalar"`, `"wide-avx2"` or `"wide-portable"`.
pub fn dispatch_label() -> &'static str {
    arm_label(active())
}

/// Stable label of a specific arm (see [`dispatch_label`]).
pub fn arm_label(arm: SimdArm) -> &'static str {
    match arm {
        SimdArm::Scalar => "scalar",
        SimdArm::Wide => {
            if wide_is_avx2() {
                "wide-avx2"
            } else {
                "wide-portable"
            }
        }
    }
}

const LANES: usize = 8;

/// Dot product of two equal-length slices on the chosen arm.
#[inline]
pub fn dot(arm: SimdArm, a: &[f32], b: &[f32]) -> f32 {
    match arm {
        SimdArm::Scalar => tensor::dot(a, b),
        SimdArm::Wide => {
            #[cfg(target_arch = "x86_64")]
            if wide_is_avx2() {
                // SAFETY: avx2+fma presence just checked.
                return unsafe { avx2::dot(a, b) };
            }
            dot_lanes(a, b)
        }
    }
}

/// `acc += alpha · x`, elementwise, on the chosen arm.
#[inline]
pub fn axpy(arm: SimdArm, acc: &mut [f32], alpha: f32, x: &[f32]) {
    match arm {
        SimdArm::Scalar => tensor::axpy(acc, alpha, x),
        SimdArm::Wide => {
            #[cfg(target_arch = "x86_64")]
            if wide_is_avx2() {
                // SAFETY: avx2+fma presence just checked.
                unsafe { avx2::axpy(acc, alpha, x) };
                return;
            }
            for (a, b) in acc.iter_mut().zip(x) {
                *a += alpha * b;
            }
        }
    }
}

/// `xs *= c`, elementwise, on the chosen arm — the online-softmax
/// rescale correction.
#[inline]
pub fn scale(arm: SimdArm, xs: &mut [f32], c: f32) {
    match arm {
        SimdArm::Scalar => {
            for x in xs.iter_mut() {
                *x *= c;
            }
        }
        SimdArm::Wide => {
            #[cfg(target_arch = "x86_64")]
            if wide_is_avx2() {
                // SAFETY: avx2+fma presence just checked.
                unsafe { avx2::scale(xs, c) };
                return;
            }
            for x in xs.iter_mut() {
                *x *= c;
            }
        }
    }
}

/// Euclidean norm of a slice on the chosen arm.
#[inline]
pub fn norm2(arm: SimdArm, x: &[f32]) -> f32 {
    match arm {
        SimdArm::Scalar => tensor::norm2(x),
        SimdArm::Wide => dot(SimdArm::Wide, x, x).sqrt(),
    }
}

/// Portable 8-lane dot: per-lane partial sums accumulated across full
/// lane groups, reduced pairwise, scalar tail. LLVM turns the lane loop
/// into whatever vector ISA the target offers.
#[inline]
fn dot_lanes(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n8 = a.len() / LANES * LANES;
    let mut lanes = [0.0f32; LANES];
    let mut i = 0;
    while i < n8 {
        for (l, (x, y)) in lanes.iter_mut().zip(a[i..i + LANES].iter().zip(&b[i..i + LANES])) {
            *l += x * y;
        }
        i += LANES;
    }
    let mut s = ((lanes[0] + lanes[4]) + (lanes[1] + lanes[5]))
        + ((lanes[2] + lanes[6]) + (lanes[3] + lanes[7]));
    for j in n8..a.len() {
        s += a[j] * b[j];
    }
    s
}

/// Scaled `block × block` score tile between a query slab and a key slab
/// (both `[block, d]` row-major) on the chosen arm; the scalar arm is
/// exactly [`tensor::score_tile`].
pub fn score_tile(
    arm: SimdArm,
    qs: &[f32],
    ks: &[f32],
    d: usize,
    block: usize,
    sc: f32,
    out: &mut [f32],
) {
    if arm == SimdArm::Scalar {
        return tensor::score_tile(qs, ks, d, block, sc, out);
    }
    debug_assert_eq!(qs.len(), block * d);
    debug_assert_eq!(ks.len(), block * d);
    debug_assert!(out.len() >= block * block);
    for r in 0..block {
        let qrow = &qs[r * d..(r + 1) * d];
        let orow = &mut out[r * block..(r + 1) * block];
        for (t, o) in orow.iter_mut().enumerate() {
            *o = dot(SimdArm::Wide, qrow, &ks[t * d..(t + 1) * d]) * sc;
        }
    }
}

/// Like [`score_tile`] but only the within-block causal triangle
/// (`t <= r`); entries above the diagonal are left untouched. The scalar
/// arm is exactly [`tensor::score_tile_causal`].
pub fn score_tile_causal(
    arm: SimdArm,
    qs: &[f32],
    ks: &[f32],
    d: usize,
    block: usize,
    sc: f32,
    out: &mut [f32],
) {
    if arm == SimdArm::Scalar {
        return tensor::score_tile_causal(qs, ks, d, block, sc, out);
    }
    debug_assert_eq!(qs.len(), block * d);
    debug_assert_eq!(ks.len(), block * d);
    debug_assert!(out.len() >= block * block);
    for r in 0..block {
        let qrow = &qs[r * d..(r + 1) * d];
        let orow = &mut out[r * block..r * block + r + 1];
        for (t, o) in orow.iter_mut().enumerate() {
            *o = dot(SimdArm::Wide, qrow, &ks[t * d..(t + 1) * d]) * sc;
        }
    }
}

/// One block's worth of the single-query online-softmax update on the
/// chosen arm: fold `len` cached tokens of a `[len, dh]` K/V slab pair
/// into the running `(m, l, acc)` state.
///
/// Both arms run the *same* control flow (score, conditional rescale,
/// exp-accumulate) with the arm's dot/scale/axpy primitives, so the
/// degenerate-row semantics are identical: a row that never accumulates
/// positive mass leaves `l == 0` and the caller emits zeros, and the
/// `NEG_INF`-sentinel score (`-1e30`, finite) flows through `exp`
/// without producing NaN on either arm. Every decode/verify kernel
/// routes through this helper, which keeps the per-row floating-point
/// operation sequence identical across the single-query,
/// dense-fast-path and batched-verify kernels *within an arm* — the
/// byte-exact speculative-decode equivalence guarantee.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn online_softmax_block(
    arm: SimdArm,
    qrow: &[f32],
    ks: &[f32],
    vs: &[f32],
    len: usize,
    dh: usize,
    sc: f32,
    m: &mut f32,
    l: &mut f32,
    acc: &mut [f32],
) {
    for t in 0..len {
        let s = dot(arm, qrow, &ks[t * dh..(t + 1) * dh]) * sc;
        if s > *m {
            if *l > 0.0 {
                let corr = (*m - s).exp();
                *l *= corr;
                scale(arm, acc, corr);
            }
            *m = s;
        }
        let p = (s - *m).exp();
        *l += p;
        axpy(arm, acc, p, &vs[t * dh..(t + 1) * dh]);
    }
}

/// AVX2+FMA implementations. Callers must gate on [`wide_is_avx2`];
/// the functions themselves only assume the features they enable.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// Horizontal sum of a 256-bit accumulator.
    ///
    /// # Safety
    /// Requires AVX2 at runtime.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn hsum(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps(v, 1);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 0b01));
        _mm_cvtss_f32(s)
    }

    /// 8-lane FMA dot with four independent accumulators (32 elements
    /// per iteration), unaligned loads, scalar tail.
    ///
    /// # Safety
    /// Requires AVX2+FMA at runtime; `a` and `b` must be equal-length.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut acc2 = _mm256_setzero_ps();
        let mut acc3 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 32 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)), acc0);
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(i + 8)),
                _mm256_loadu_ps(bp.add(i + 8)),
                acc1,
            );
            acc2 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(i + 16)),
                _mm256_loadu_ps(bp.add(i + 16)),
                acc2,
            );
            acc3 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(i + 24)),
                _mm256_loadu_ps(bp.add(i + 24)),
                acc3,
            );
            i += 32;
        }
        while i + 8 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)), acc0);
            i += 8;
        }
        let mut s = hsum(_mm256_add_ps(_mm256_add_ps(acc0, acc1), _mm256_add_ps(acc2, acc3)));
        while i < n {
            s += *ap.add(i) * *bp.add(i);
            i += 1;
        }
        s
    }

    /// `acc += alpha · x`, 8 lanes per FMA, scalar tail.
    ///
    /// # Safety
    /// Requires AVX2+FMA at runtime; `acc` and `x` must be equal-length.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy(acc: &mut [f32], alpha: f32, x: &[f32]) {
        debug_assert_eq!(acc.len(), x.len());
        let n = acc.len();
        let av = _mm256_set1_ps(alpha);
        let (ap, xp) = (acc.as_mut_ptr(), x.as_ptr());
        let mut i = 0usize;
        while i + 8 <= n {
            let r = _mm256_fmadd_ps(av, _mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(ap.add(i)));
            _mm256_storeu_ps(ap.add(i), r);
            i += 8;
        }
        while i < n {
            *ap.add(i) += alpha * *xp.add(i);
            i += 1;
        }
    }

    /// `xs *= c`, 8 lanes per multiply, scalar tail.
    ///
    /// # Safety
    /// Requires AVX2+FMA at runtime.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn scale(xs: &mut [f32], c: f32) {
        let n = xs.len();
        let cv = _mm256_set1_ps(c);
        let xp = xs.as_mut_ptr();
        let mut i = 0usize;
        while i + 8 <= n {
            _mm256_storeu_ps(xp.add(i), _mm256_mul_ps(cv, _mm256_loadu_ps(xp.add(i))));
            i += 8;
        }
        while i < n {
            *xp.add(i) *= c;
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(seed: u64, n: usize) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal() as f32).collect()
    }

    #[test]
    fn parse_accepts_the_three_arms() {
        assert_eq!(parse("auto").unwrap(), None);
        assert_eq!(parse("scalar").unwrap(), Some(SimdArm::Scalar));
        assert_eq!(parse(" Wide ").unwrap(), Some(SimdArm::Wide));
        assert!(parse("avx512").is_err());
    }

    #[test]
    fn arm_labels_are_stable() {
        assert_eq!(arm_label(SimdArm::Scalar), "scalar");
        let w = arm_label(SimdArm::Wide);
        assert!(w == "wide-avx2" || w == "wide-portable");
    }

    #[test]
    fn wide_dot_matches_scalar_across_tail_lengths() {
        // covers len < 8 (pure tail), len % 32 != 0 (8-lane loop), and
        // the 32-element fast loop
        for n in [0usize, 1, 3, 5, 7, 8, 9, 31, 32, 33, 64, 100, 257] {
            let a = randv(1 + n as u64, n);
            let b = randv(1000 + n as u64, n);
            let s = dot(SimdArm::Scalar, &a, &b);
            let w = dot(SimdArm::Wide, &a, &b);
            assert!(
                (s - w).abs() <= 1e-4 * (1.0 + s.abs()),
                "dot mismatch at n={n}: scalar {s} wide {w}"
            );
        }
    }

    #[test]
    fn wide_axpy_and_scale_match_scalar() {
        for n in [1usize, 7, 8, 17, 64, 130] {
            let x = randv(n as u64, n);
            let mut s_acc = randv(7 * n as u64, n);
            let mut w_acc = s_acc.clone();
            axpy(SimdArm::Scalar, &mut s_acc, 0.37, &x);
            axpy(SimdArm::Wide, &mut w_acc, 0.37, &x);
            for (s, w) in s_acc.iter().zip(&w_acc) {
                assert!((s - w).abs() <= 1e-5, "axpy mismatch at n={n}");
            }
            scale(SimdArm::Scalar, &mut s_acc, 0.83);
            scale(SimdArm::Wide, &mut w_acc, 0.83);
            for (s, w) in s_acc.iter().zip(&w_acc) {
                assert!((s - w).abs() <= 1e-5, "scale mismatch at n={n}");
            }
        }
    }

    #[test]
    fn portable_lane_dot_matches_scalar_regardless_of_detection() {
        // dot_lanes is the Wide arm's fallback on non-avx2 hosts; pin it
        // directly so CI running on avx2 machines still covers it
        for n in [0usize, 5, 8, 23, 64, 129] {
            let a = randv(5 + n as u64, n);
            let b = randv(500 + n as u64, n);
            let s = crate::sparse::tensor::dot(&a, &b);
            let w = dot_lanes(&a, &b);
            assert!((s - w).abs() <= 1e-4 * (1.0 + s.abs()), "lane-dot mismatch at n={n}");
        }
    }

    #[test]
    fn wide_norm2_matches_scalar() {
        for n in [1usize, 5, 8, 33, 100] {
            let x = randv(n as u64, n);
            assert!((norm2(SimdArm::Scalar, &x) - norm2(SimdArm::Wide, &x)).abs() <= 1e-4);
        }
    }

    #[test]
    fn wide_score_tiles_match_scalar() {
        let (d, block) = (13usize, 6usize); // deliberately lane-unfriendly
        let qs = randv(2, block * d);
        let ks = randv(3, block * d);
        let mut s_full = vec![0.0f32; block * block];
        let mut w_full = vec![0.0f32; block * block];
        score_tile(SimdArm::Scalar, &qs, &ks, d, block, 0.31, &mut s_full);
        score_tile(SimdArm::Wide, &qs, &ks, d, block, 0.31, &mut w_full);
        for (s, w) in s_full.iter().zip(&w_full) {
            assert!((s - w).abs() <= 1e-5);
        }
        let mut s_tri = vec![f32::NAN; block * block];
        let mut w_tri = vec![f32::NAN; block * block];
        score_tile_causal(SimdArm::Scalar, &qs, &ks, d, block, 0.31, &mut s_tri);
        score_tile_causal(SimdArm::Wide, &qs, &ks, d, block, 0.31, &mut w_tri);
        for r in 0..block {
            for t in 0..block {
                let (s, w) = (s_tri[r * block + t], w_tri[r * block + t]);
                if t <= r {
                    assert!((s - w).abs() <= 1e-5);
                } else {
                    assert!(s.is_nan() && w.is_nan(), "above-diag must stay untouched");
                }
            }
        }
    }

    #[test]
    fn online_softmax_block_arms_agree_including_tails() {
        for dh in [1usize, 3, 8, 11, 32, 40] {
            for len in [1usize, 2, 5, 64] {
                let q = randv(dh as u64, dh);
                let ks = randv(100 + dh as u64, len * dh);
                let vs = randv(200 + dh as u64, len * dh);
                let sc = 1.0 / (dh as f32).sqrt();
                let mut res: Vec<(f32, f32, Vec<f32>)> = Vec::new();
                for arm in ARMS {
                    let (mut m, mut l, mut acc) = (f32::NEG_INFINITY, 0.0f32, vec![0.0f32; dh]);
                    online_softmax_block(arm, &q, &ks, &vs, len, dh, sc, &mut m, &mut l, &mut acc);
                    res.push((m, l, acc));
                }
                let (sm, sl, sa) = &res[0];
                let (wm, wl, wa) = &res[1];
                assert!((sm - wm).abs() <= 1e-4, "m mismatch dh={dh} len={len}");
                assert!((sl - wl).abs() <= 1e-4 * (1.0 + sl.abs()), "l mismatch dh={dh}");
                for (s, w) in sa.iter().zip(wa) {
                    assert!((s - w).abs() <= 1e-4, "acc mismatch dh={dh} len={len}");
                }
            }
        }
    }

    #[test]
    fn online_softmax_neg_inf_sentinel_yields_no_nan_on_either_arm() {
        // a slab whose scores all sit at the finite masked-score
        // sentinel must still produce a finite convex combination
        let dh = 8usize;
        let q = vec![1.0f32; dh];
        let ks = vec![-1e30f32 / dh as f32; 2 * dh]; // dots ≈ -1e30
        let vs = randv(5, 2 * dh);
        for arm in ARMS {
            let (mut m, mut l, mut acc) = (f32::NEG_INFINITY, 0.0f32, vec![0.0f32; dh]);
            online_softmax_block(arm, &q, &ks, &vs, 2, dh, 1.0, &mut m, &mut l, &mut acc);
            assert!(l > 0.0, "sentinel scores must still accumulate mass");
            assert!(acc.iter().all(|a| a.is_finite()), "NaN leaked on {:?}", arm);
        }
    }
}
