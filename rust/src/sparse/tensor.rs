//! Minimal dense tensor used by the pure-rust reference pipeline.
//!
//! Row-major `f32` storage with explicit shape; only what the sparse
//! attention reference, the simulator and the tests need — this is *not*
//! a general ndarray (XLA owns the heavy math on the request path).

/// Row-major dense f32 tensor with an explicit shape (see module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// Dimension sizes, outermost first.
    pub shape: Vec<usize>,
    /// Row-major element storage (`shape` product elements).
    pub data: Vec<f32>,
}

impl Tensor {
    /// All-zero tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Wrap existing row-major data (must match the shape product).
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    /// Standard-normal tensor drawn from `rng`.
    pub fn randn(shape: &[usize], rng: &mut crate::util::rng::Rng) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: (0..n).map(|_| rng.normal() as f32).collect() }
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Element `[i, j]` of a rank-2 tensor.
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// Element `[h, i, j]` of a rank-3 tensor.
    #[inline]
    pub fn at3(&self, h: usize, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.rank(), 3);
        self.data[(h * self.shape[1] + i) * self.shape[2] + j]
    }

    /// Set element `[h, i, j]` of a rank-3 tensor.
    #[inline]
    pub fn set3(&mut self, h: usize, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.rank(), 3);
        self.data[(h * self.shape[1] + i) * self.shape[2] + j] = v;
    }

    /// Contiguous row `[h, i, :]` of a rank-3 tensor.
    #[inline]
    pub fn row3(&self, h: usize, i: usize) -> &[f32] {
        let d = self.shape[2];
        let off = (h * self.shape[1] + i) * d;
        &self.data[off..off + d]
    }

    /// Contiguous `[block, d]` slab of rows `[h, b*block .. (b+1)*block, :]`
    /// — the gather-free way the tiled kernels address one attention block.
    #[inline]
    pub fn block3(&self, h: usize, b: usize, block: usize) -> &[f32] {
        let d = self.shape[2];
        let off = (h * self.shape[1] + b * block) * d;
        &self.data[off..off + block * d]
    }

    /// Mutable contiguous row `[h, i, :]` of a rank-3 tensor.
    #[inline]
    pub fn row3_mut(&mut self, h: usize, i: usize) -> &mut [f32] {
        let d = self.shape[2];
        let off = (h * self.shape[1] + i) * d;
        &mut self.data[off..off + d]
    }

    /// Largest absolute element difference against `other` (same shape).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// Mean squared element difference against `other` (same shape).
    pub fn mse(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        let s: f64 = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum();
        s / self.data.len() as f64
    }
}

/// Dot product of two equal-length slices (manually 4-way unrolled).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0f32;
    // 4-wide manual unroll; the autovectorizer does the rest in release.
    let chunks = a.len() / 4 * 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0, 0.0, 0.0);
    let mut i = 0;
    while i < chunks {
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
        i += 4;
    }
    for j in chunks..a.len() {
        s += a[j] * b[j];
    }
    s + s0 + s1 + s2 + s3
}

/// `acc += alpha · x`, elementwise.
pub fn axpy(acc: &mut [f32], alpha: f32, x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    for (a, b) in acc.iter_mut().zip(x) {
        *a += alpha * b;
    }
}

/// Euclidean norm of a slice.
pub fn norm2(x: &[f32]) -> f32 {
    dot(x, x).sqrt()
}

/// Scaled `block × block` score tile between a query slab and a key slab
/// (both `[block, d]`, see [`Tensor::block3`]): `out[r*block + t] =
/// scale · q_r · k_t`. One pass over the key slab per query row, so the
/// whole K block is reused from cache across the tile.
pub fn score_tile(qs: &[f32], ks: &[f32], d: usize, block: usize, scale: f32, out: &mut [f32]) {
    debug_assert_eq!(qs.len(), block * d);
    debug_assert_eq!(ks.len(), block * d);
    debug_assert!(out.len() >= block * block);
    for r in 0..block {
        let qrow = &qs[r * d..(r + 1) * d];
        let orow = &mut out[r * block..(r + 1) * block];
        for (t, o) in orow.iter_mut().enumerate() {
            *o = dot(qrow, &ks[t * d..(t + 1) * d]) * scale;
        }
    }
}

/// Like [`score_tile`] but only fills the within-block causal triangle
/// (`t <= r`); entries above the diagonal are left untouched and must not
/// be read by the caller.
pub fn score_tile_causal(
    qs: &[f32],
    ks: &[f32],
    d: usize,
    block: usize,
    scale: f32,
    out: &mut [f32],
) {
    debug_assert_eq!(qs.len(), block * d);
    debug_assert_eq!(ks.len(), block * d);
    debug_assert!(out.len() >= block * block);
    for r in 0..block {
        let qrow = &qs[r * d..(r + 1) * d];
        let orow = &mut out[r * block..r * block + r + 1];
        for (t, o) in orow.iter_mut().enumerate() {
            *o = dot(qrow, &ks[t * d..(t + 1) * d]) * scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_roundtrip() {
        let mut t = Tensor::zeros(&[2, 3, 4]);
        t.set3(1, 2, 3, 7.0);
        assert_eq!(t.at3(1, 2, 3), 7.0);
        assert_eq!(t.row3(1, 2)[3], 7.0);
    }

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..37).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..37).map(|i| (i as f32).sin()).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-3);
    }

    #[test]
    fn mse_zero_for_identical() {
        let mut r = crate::util::rng::Rng::new(0);
        let t = Tensor::randn(&[3, 4, 5], &mut r);
        assert_eq!(t.mse(&t), 0.0);
    }

    #[test]
    fn block3_matches_rows() {
        let mut r = crate::util::rng::Rng::new(5);
        let t = Tensor::randn(&[2, 8, 3], &mut r);
        let slab = t.block3(1, 1, 4);
        for i in 0..4 {
            assert_eq!(&slab[i * 3..(i + 1) * 3], t.row3(1, 4 + i));
        }
    }

    #[test]
    fn score_tile_matches_per_pair_dot() {
        let mut r = crate::util::rng::Rng::new(6);
        let (d, block) = (5usize, 4usize);
        let q = Tensor::randn(&[1, block, d], &mut r);
        let k = Tensor::randn(&[1, block, d], &mut r);
        let mut full = vec![0.0f32; block * block];
        score_tile(q.block3(0, 0, block), k.block3(0, 0, block), d, block, 0.5, &mut full);
        let mut tri = vec![f32::NAN; block * block];
        score_tile_causal(q.block3(0, 0, block), k.block3(0, 0, block), d, block, 0.5, &mut tri);
        for r_ in 0..block {
            for t_ in 0..block {
                let want = dot(q.row3(0, r_), k.row3(0, t_)) * 0.5;
                assert!((full[r_ * block + t_] - want).abs() < 1e-6);
                if t_ <= r_ {
                    assert!((tri[r_ * block + t_] - want).abs() < 1e-6);
                } else {
                    assert!(tri[r_ * block + t_].is_nan(), "above-diag must stay untouched");
                }
            }
        }
    }
}
