//! Artifact-free [`PrefillBackend`]: a hand-built manifest plus cheap
//! deterministic logits, so serving-stack tests and benches (chaos,
//! overload) exercise the full coordinator — admission, batching, KV
//! paging, decode, shedding — without PJRT artifacts on disk. It serves
//! both the prefill lane (`prefill_stem` buckets) and the decode lane's
//! compiled path (`decode_step` buckets executed per step by
//! [`crate::decode::EngineBackend`]), so `--decode-backend engine` is
//! CI-testable end-to-end without PJRT; only the `tiny` decode backend
//! skips the runtime entirely.

use anyhow::{bail, Result};

use crate::model::manifest::{Manifest, ModelConfig, ModuleInfo};
use crate::runtime::engine::{PrefillBackend, PrefillOutput, ScalarValue};

/// In-memory prefill backend over a synthetic manifest (see module docs).
pub struct SyntheticEngine {
    manifest: Manifest,
}

impl SyntheticEngine {
    /// A backend with the default tiny model and `prefill_stem` modules
    /// at the given context buckets.
    pub fn new(buckets: &[usize]) -> SyntheticEngine {
        SyntheticEngine::with_model(SyntheticEngine::tiny_model(), buckets)
    }

    /// A backend over an explicit model geometry. Every bucket gets both
    /// a `prefill_stem` module and a `decode_step` module (same ids →
    /// logits shape), mirroring what `python/compile/aot.py` lowers.
    pub fn with_model(model: ModelConfig, buckets: &[usize]) -> SyntheticEngine {
        let modules = buckets
            .iter()
            .flat_map(|&n| {
                ["prefill_stem", "decode_step"].into_iter().map(move |kind| ModuleInfo {
                    name: format!("{kind}_{n}"),
                    kind: kind.into(),
                    n_ctx: n,
                    file: String::new(),
                    scalars: vec![],
                    outputs: vec!["logits".into(), "budget_fraction".into()],
                })
            })
            .collect();
        let manifest = Manifest {
            root: std::path::PathBuf::new(),
            model,
            param_spec: vec![],
            weights: vec![],
            modules,
            eval_sets: vec![],
            defaults: vec![],
        };
        SyntheticEngine { manifest }
    }

    /// The default geometry: small enough that a chaos test's decode
    /// steps cost microseconds, shaped like the real compiled model.
    pub fn tiny_model() -> ModelConfig {
        ModelConfig {
            vocab_size: crate::model::vocab::VOCAB_SIZE,
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            d_ff: 128,
            block: 16,
            init_keep: 1,
            local_keep: 2,
            min_total: 3,
            d_head: 16,
        }
    }
}

impl PrefillBackend for SyntheticEngine {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn prefill(
        &self,
        _checkpoint: &str,
        kind: &str,
        n_ctx: usize,
        ids: &[i32],
        _scalars: &[ScalarValue],
    ) -> Result<PrefillOutput> {
        let module = self.manifest.module(kind, n_ctx)?;
        if ids.len() != module.n_ctx {
            bail!("ids len {} != module n_ctx {}", ids.len(), module.n_ctx);
        }
        let vocab = self.manifest.model.vocab_size;
        // one deterministic hot logit per row, a pure function of the
        // token and its position — enough for argmax-based assertions
        let mut logits = vec![0.0f32; n_ctx * vocab];
        for (t, &id) in ids.iter().enumerate() {
            let hot = (id as u64).wrapping_mul(0x9e37_79b9).wrapping_add(t as u64) % vocab as u64;
            logits[t * vocab + hot as usize] = 1.0;
        }
        Ok(PrefillOutput { logits, n_ctx, vocab, budget_fraction: 0.42, hidden: None })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serves_prefill_without_artifacts() {
        let eng = SyntheticEngine::new(&[128, 256]);
        assert_eq!(eng.manifest().bucket_for(100), Some(128));
        assert_eq!(eng.manifest().bucket_for(200), Some(256));
        let ids = vec![3i32; 128];
        let out = eng.prefill("any", "prefill_stem", 128, &ids, &[]).unwrap();
        assert_eq!(out.logits.len(), 128 * eng.manifest().model.vocab_size);
        assert!(out.budget_fraction > 0.0);
        // deterministic: same inputs, same logits
        let again = eng.prefill("any", "prefill_stem", 128, &ids, &[]).unwrap();
        assert_eq!(out.logits, again.logits);
        // wrong bucket and wrong ids length are clean errors
        assert!(eng.prefill("any", "prefill_stem", 512, &ids, &[]).is_err());
        assert!(eng.prefill("any", "prefill_stem", 256, &ids, &[]).is_err());
    }

    #[test]
    fn serves_decode_step_modules_alongside_prefill() {
        let eng = SyntheticEngine::new(&[128, 256]);
        // decode buckets exist per prefill bucket but never satisfy
        // prefill bucket selection
        assert!(eng.manifest().module("decode_step", 128).unwrap().is_decode());
        assert_eq!(eng.manifest().bucket_for(200), Some(256));
        let ids = vec![7i32; 128];
        let out = eng.prefill("any", "decode_step", 128, &ids, &[]).unwrap();
        let prefill = eng.prefill("any", "prefill_stem", 128, &ids, &[]).unwrap();
        assert_eq!(out.logits, prefill.logits, "same deterministic ids→logits function");
        assert_eq!(out.logits.len(), 128 * eng.manifest().model.vocab_size);
    }
}
