//! L3 ⇄ XLA bridge: PJRT engine, weights loader.

pub mod engine;
pub mod weights;

pub use engine::{Engine, PrefillOutput, ScalarValue};
pub use weights::WeightsFile;
