//! L3 ⇄ XLA bridge: PJRT engine, weights loader, synthetic stand-in.

pub mod engine;
pub mod synthetic;
pub mod weights;

pub use engine::{Engine, PrefillBackend, PrefillOutput, ScalarValue};
pub use synthetic::SyntheticEngine;
pub use weights::WeightsFile;
