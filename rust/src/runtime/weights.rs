//! Loader for the `.stw` ("stem weights") tensor file emitted by
//! `python/compile/aot.py`:
//!
//!   magic "STEMWTS0" | u32 LE header-len | JSON header | raw LE tensors
//!
//! Header entries: {name, dtype, shape, offset, nbytes}; offsets are
//! relative to the end of the header and 16-byte aligned.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// One tensor parsed from a `.stw` file.
#[derive(Debug, Clone)]
pub struct TensorEntry {
    /// Parameter name.
    pub name: String,
    /// Element dtype (only `"float32"` is supported).
    pub dtype: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Row-major element data.
    pub data: Vec<f32>,
}

impl TensorEntry {
    /// Number of elements (`shape` product).
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// A parsed `.stw` weights file.
pub struct WeightsFile {
    /// Tensors in file order.
    pub tensors: Vec<TensorEntry>,
}

impl WeightsFile {
    /// Parse a `.stw` file from disk (see module docs for the format).
    pub fn load(path: &Path) -> Result<WeightsFile> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading weights {}", path.display()))?;
        if bytes.len() < 12 || &bytes[..8] != b"STEMWTS0" {
            bail!("{}: not a .stw file", path.display());
        }
        let hlen = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        let header_end = 12 + hlen;
        if bytes.len() < header_end {
            bail!("truncated .stw header");
        }
        let header = std::str::from_utf8(&bytes[12..header_end])
            .map_err(|_| anyhow!("non-utf8 .stw header"))?;
        let j = Json::parse(header).map_err(|e| anyhow!("stw header json: {e}"))?;
        let body = &bytes[header_end..];

        let mut tensors = vec![];
        for entry in j.as_arr().ok_or_else(|| anyhow!("stw header not an array"))? {
            let name = entry
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("stw entry missing name"))?
                .to_string();
            let dtype = entry
                .get("dtype")
                .and_then(Json::as_str)
                .unwrap_or("float32")
                .to_string();
            let shape: Vec<usize> = entry
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("stw entry missing shape"))?
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect();
            let offset = entry.get("offset").and_then(Json::as_usize).unwrap_or(0);
            let nbytes = entry.get("nbytes").and_then(Json::as_usize).unwrap_or(0);
            if dtype != "float32" {
                bail!("tensor {name}: unsupported dtype {dtype}");
            }
            if offset + nbytes > body.len() {
                bail!("tensor {name}: out-of-range slice");
            }
            let raw = &body[offset..offset + nbytes];
            let mut data = vec![0f32; nbytes / 4];
            for (i, ch) in raw.chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes(ch.try_into().unwrap());
            }
            let expect: usize = shape.iter().product();
            if data.len() != expect {
                bail!("tensor {name}: {} elems != shape {:?}", data.len(), shape);
            }
            tensors.push(TensorEntry { name, dtype, shape, data });
        }
        Ok(WeightsFile { tensors })
    }

    /// Look up a tensor by parameter name.
    pub fn get(&self, name: &str) -> Option<&TensorEntry> {
        self.tensors.iter().find(|t| t.name == name)
    }

    /// Total parameter count across all tensors.
    pub fn total_params(&self) -> usize {
        self.tensors.iter().map(TensorEntry::element_count).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_stw(path: &Path, tensors: &[(&str, Vec<usize>, Vec<f32>)]) {
        let mut header = vec![];
        let mut body: Vec<u8> = vec![];
        for (name, shape, data) in tensors {
            let pad = (16 - body.len() % 16) % 16;
            body.extend(std::iter::repeat(0u8).take(pad));
            let offset = body.len();
            for v in data {
                body.extend(v.to_le_bytes());
            }
            header.push(format!(
                r#"{{"name":"{name}","dtype":"float32","shape":[{}],"offset":{offset},"nbytes":{}}}"#,
                shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(","),
                data.len() * 4
            ));
        }
        let hjson = format!("[{}]", header.join(","));
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(b"STEMWTS0").unwrap();
        f.write_all(&(hjson.len() as u32).to_le_bytes()).unwrap();
        f.write_all(hjson.as_bytes()).unwrap();
        f.write_all(&body).unwrap();
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("stem_stw_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("w.stw");
        write_stw(&p, &[("a", vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]), ("b", vec![3], vec![5.0, 6.0, 7.0])]);
        let w = WeightsFile::load(&p).unwrap();
        assert_eq!(w.tensors.len(), 2);
        assert_eq!(w.get("a").unwrap().data, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(w.get("b").unwrap().shape, vec![3]);
        assert_eq!(w.total_params(), 7);
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("stem_stw_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.stw");
        std::fs::write(&p, b"NOTMAGIC....").unwrap();
        assert!(WeightsFile::load(&p).is_err());
    }
}
