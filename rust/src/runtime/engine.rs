//! PJRT execution engine: loads HLO-text artifacts, compiles them on the
//! CPU PJRT client, pins the model weights on-device ONCE, and exposes a
//! typed `prefill` entry point to the coordinator.
//!
//! Pattern follows /opt/xla-example/load_hlo.rs: HLO *text* interchange
//! (xla_extension 0.5.1 rejects jax>=0.5 serialized protos), tupled
//! outputs, `to_literal_sync` readback.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::model::manifest::{Manifest, ModuleInfo};
use crate::runtime::weights::WeightsFile;

/// A scalar hyper-parameter fed to a module at execute time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScalarValue {
    /// A 32-bit float scalar.
    F32(f32),
    /// A 32-bit integer scalar.
    I32(i32),
}

/// A prefill executor the coordinator can serve from: the PJRT
/// [`Engine`] in production, the artifact-free
/// [`crate::runtime::SyntheticEngine`] in chaos tests and benches. The
/// trait covers exactly what the serving path touches — the manifest
/// (geometry, buckets) and prefill execution; everything engine-specific
/// (weights upload, warmup, module compilation) stays on [`Engine`].
pub trait PrefillBackend: Send + Sync {
    /// The artifacts manifest this backend serves.
    fn manifest(&self) -> &Manifest;

    /// Execute a prefill/diag module (see [`Engine::prefill`]).
    fn prefill(
        &self,
        checkpoint: &str,
        kind: &str,
        n_ctx: usize,
        ids: &[i32],
        scalars: &[ScalarValue],
    ) -> Result<PrefillOutput>;
}

impl PrefillBackend for Engine {
    fn manifest(&self) -> &Manifest {
        Engine::manifest(self)
    }

    fn prefill(
        &self,
        checkpoint: &str,
        kind: &str,
        n_ctx: usize,
        ids: &[i32],
        scalars: &[ScalarValue],
    ) -> Result<PrefillOutput> {
        Engine::prefill(self, checkpoint, kind, n_ctx, ids, scalars)
    }
}

/// Outputs of one prefill execution.
#[derive(Debug)]
pub struct PrefillOutput {
    /// [n_ctx * vocab] row-major logits.
    pub logits: Vec<f32>,
    /// Padded context length executed.
    pub n_ctx: usize,
    /// Vocabulary size (row stride of `logits`).
    pub vocab: usize,
    /// Mean per-layer budget fraction reported by the graph itself.
    pub budget_fraction: f32,
    /// `[n_layers * n_ctx * d_model]` hidden states (diag modules only).
    pub hidden: Option<Vec<f32>>,
}

struct LoadedModule {
    info: ModuleInfo,
    exe: xla::PjRtLoadedExecutable,
}

// SAFETY: same argument as Engine below — PJRT executables are internally
// synchronized; the wrapper is only !Send/!Sync because of raw pointers.
unsafe impl Send for LoadedModule {}
unsafe impl Sync for LoadedModule {}

/// The engine owns the PJRT client, the compiled executables and the
/// on-device weight buffers for each checkpoint.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    /// Lock covers the *map* only; executions clone the Arc and run
    /// outside it so concurrent prefills never serialize on compile-cache
    /// lookups (PJRT itself handles concurrent execute).
    modules: Mutex<HashMap<String, std::sync::Arc<LoadedModule>>>,
    /// checkpoint name -> device-resident parameter buffers (manifest
    /// param_spec order). Uploaded once; shared by every execution.
    weights: HashMap<String, Vec<xla::PjRtBuffer>>,
    /// Host literals backing the device buffers. `buffer_from_host_literal`
    /// copies ASYNCHRONOUSLY on the TFRT CPU client: dropping the literal
    /// before the copy lands is a use-after-free (manifests as
    /// `literal.size_bytes() == b->size()` check failures). Kept alive for
    /// the engine's lifetime.
    _weight_literals: Vec<xla::Literal>,
}

// SAFETY: the PJRT CPU client is thread-safe (it is the same client JAX
// drives from many python threads); the xla crate types are only !Send
// because they hold raw pointers. Executions from multiple coordinator
// workers are serialized per-module by the `modules` mutex held only for
// lookup; PJRT itself synchronizes execute calls.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Create the engine: PJRT CPU client + weight upload (no module
    /// compilation yet — that happens lazily per (kind, bucket)).
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        crate::info!(
            "engine: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        let mut weights = HashMap::new();
        let mut weight_literals = vec![];
        for (name, _) in manifest.weights.clone() {
            let path = manifest.weights_path(&name)?;
            let wf = WeightsFile::load(&path)?;
            let mut bufs = Vec::with_capacity(manifest.param_spec.len());
            for spec in &manifest.param_spec {
                let t = wf
                    .get(&spec.name)
                    .ok_or_else(|| anyhow!("weights {name}: missing {}", spec.name))?;
                if t.shape != spec.shape {
                    bail!("weights {name}: {} shape {:?} != {:?}", spec.name, t.shape, spec.shape);
                }
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(&t.data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape {}: {e:?}", spec.name))?;
                let buf = client
                    .buffer_from_host_literal(None, &lit)
                    .map_err(|e| anyhow!("upload {}: {e:?}", spec.name))?;
                bufs.push(buf);
                weight_literals.push(lit); // keep alive: async host->device copy
            }
            crate::info!("engine: uploaded checkpoint `{name}` ({} tensors)", bufs.len());
            weights.insert(name, bufs);
        }
        Ok(Engine {
            client,
            manifest,
            modules: Mutex::new(HashMap::new()),
            weights,
            _weight_literals: weight_literals,
        })
    }

    /// The parsed artifacts manifest this engine serves.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Names of the uploaded weight checkpoints, sorted.
    pub fn checkpoints(&self) -> Vec<String> {
        let mut v: Vec<String> = self.weights.keys().cloned().collect();
        v.sort();
        v
    }

    /// Compile (or fetch) the executable for `kind` at bucket `n_ctx`.
    pub fn ensure_module(&self, kind: &str, n_ctx: usize) -> Result<String> {
        self.module_handle(kind, n_ctx).map(|m| m.info.name.clone())
    }

    fn module_handle(&self, kind: &str, n_ctx: usize) -> Result<std::sync::Arc<LoadedModule>> {
        let info = self.manifest.module(kind, n_ctx)?.clone();
        let mut mods = self.modules.lock().unwrap();
        if let Some(m) = mods.get(&info.name) {
            return Ok(std::sync::Arc::clone(m));
        }
        let path = self.manifest.root.join(&info.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("loading {}: {e:?}", path.display()))
            .with_context(|| "HLO text parse failed")?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe =
            self.client.compile(&comp).map_err(|e| anyhow!("compiling {}: {e:?}", info.name))?;
        crate::info!("engine: compiled {} in {:.2}s", info.name, t0.elapsed().as_secs_f32());
        let m = std::sync::Arc::new(LoadedModule { info: info.clone(), exe });
        mods.insert(info.name.clone(), std::sync::Arc::clone(&m));
        Ok(m)
    }

    /// Execute a prefill/diag module.
    ///
    /// `ids` must be exactly the module's n_ctx long (the coordinator pads
    /// with PAD tokens); `scalars` must match the module's scalar specs.
    pub fn prefill(
        &self,
        checkpoint: &str,
        kind: &str,
        n_ctx: usize,
        ids: &[i32],
        scalars: &[ScalarValue],
    ) -> Result<PrefillOutput> {
        let module = self.module_handle(kind, n_ctx)?;
        let name = &module.info.name;
        if ids.len() != module.info.n_ctx {
            bail!("ids len {} != module n_ctx {}", ids.len(), module.info.n_ctx);
        }
        if scalars.len() != module.info.scalars.len() {
            bail!(
                "module {} expects {} scalars ({:?}), got {}",
                name,
                module.info.scalars.len(),
                module.info.scalars.iter().map(|s| s.name.clone()).collect::<Vec<_>>(),
                scalars.len()
            );
        }
        let params = self
            .weights
            .get(checkpoint)
            .ok_or_else(|| anyhow!("unknown checkpoint `{checkpoint}`"))?;

        // assemble input buffers: params (device-resident) + ids + scalars
        let ids_lit = xla::Literal::vec1(ids);
        let ids_buf = self
            .client
            .buffer_from_host_literal(None, &ids_lit)
            .map_err(|e| anyhow!("upload ids: {e:?}"))?;
        let mut scalar_bufs = Vec::with_capacity(scalars.len());
        // literals must outlive the (async) host->device copies — dropped
        // only after execution completes below. See the `_weight_literals`
        // note on Engine.
        let mut scalar_lits = Vec::with_capacity(scalars.len());
        for (spec, val) in module.info.scalars.iter().zip(scalars) {
            let lit = match (spec.is_f32, val) {
                (true, ScalarValue::F32(f)) => xla::Literal::vec1(&[*f]),
                (false, ScalarValue::I32(i)) => xla::Literal::vec1(&[*i]),
                (true, ScalarValue::I32(i)) => xla::Literal::vec1(&[*i as f32]),
                (false, ScalarValue::F32(f)) => xla::Literal::vec1(&[*f as i32]),
            };
            scalar_bufs.push(
                self.client
                    .buffer_from_host_literal(None, &lit)
                    .map_err(|e| anyhow!("upload scalar {}: {e:?}", spec.name))?,
            );
            scalar_lits.push(lit);
        }
        let mut args: Vec<&xla::PjRtBuffer> = params.iter().collect();
        args.push(&ids_buf);
        args.extend(scalar_bufs.iter());

        let result = module.exe.execute_b(&args).map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("readback {name}: {e:?}"))?;
        let mut parts = lit.to_tuple().map_err(|e| anyhow!("tuple {name}: {e:?}"))?;
        let expected = module.info.outputs.len();
        if parts.len() != expected {
            bail!("{name}: {} outputs != manifest {expected}", parts.len());
        }
        let hidden = if module.info.is_diag() {
            let h = parts.pop().unwrap();
            Some(h.to_vec::<f32>().map_err(|e| anyhow!("hidden: {e:?}"))?)
        } else {
            None
        };
        let budget = parts.pop().unwrap();
        let budget_fraction =
            budget.to_vec::<f32>().map_err(|e| anyhow!("budget: {e:?}"))?[0];
        let logits_lit = parts.pop().unwrap();
        let logits = logits_lit.to_vec::<f32>().map_err(|e| anyhow!("logits: {e:?}"))?;
        let vocab = self.manifest.model.vocab_size;
        Ok(PrefillOutput { logits, n_ctx: module.info.n_ctx, vocab, budget_fraction, hidden })
    }

    /// Warm every (kind, bucket) pair so serving never compiles inline.
    pub fn warmup(&self, kinds: &[&str], buckets: &[usize]) -> Result<()> {
        for kind in kinds {
            for &b in buckets {
                if self.manifest.module(kind, b).is_ok() {
                    self.ensure_module(kind, b)?;
                }
            }
        }
        Ok(())
    }
}
