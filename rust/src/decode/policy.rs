//! Per-step sparsity policy for the decode phase.
//!
//! Stem's Token Position-Decay budget (Eq. 3) reinterpreted over
//! *generation steps*: the block budget starts at `k_start` and decays
//! toward `mu·k_start` across the configured horizon, mirroring the
//! paper's observation that later positions need fewer routed blocks.
//! Two guards come from Lil ("Less is Less…", PAPERS.md), whose central
//! finding is that naive uniform top-k sparsity *hurts* in the long
//! decode stage: short contexts fall back to dense attention
//! (`dense_below`), and the attention sinks plus the most recent blocks
//! are always kept regardless of score (`sink_blocks` / `recent_blocks`).

use crate::obs::sparsity::DenseCause;
use crate::sparse::schedule;

/// Decode-phase sparsity policy (see module docs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DecodePolicy {
    /// Contexts shorter than this many tokens decode dense — sparse
    /// selection over a handful of blocks costs more than it saves and
    /// measurably hurts quality (Lil).
    pub dense_below: usize,
    /// Block budget at step 0.
    pub k_start: f64,
    /// Decay floor: the budget approaches `mu·k_start` at the horizon.
    pub mu: f64,
    /// Steps the decay is spread over (≈ the expected generation length).
    pub horizon: usize,
    /// Always-keep leading blocks (attention sinks).
    pub sink_blocks: usize,
    /// Always-keep trailing blocks (local window).
    pub recent_blocks: usize,
    /// Hard floor on the sparse budget, in blocks.
    pub min_blocks: usize,
    /// Output-Aware Metric value-magnitude weight (Eq. 7).
    pub beta: f32,
    /// Within-block sampling stride of the decode routing metric.
    pub stride: usize,
    /// Speculative-decode draft depth γ: `0` decodes one token per step
    /// (the PR 2 path); `γ >= 1` drafts γ tokens per round with the
    /// cheap [`DecodePolicy::draft`] variant of this policy, verifies
    /// all γ+1 positions in one batched kernel under *this* policy, and
    /// commits the longest agreeing prefix — so the emitted stream is
    /// exactly what non-speculative decode under this policy would
    /// produce (see `decode::spec`).
    pub spec_gamma: usize,
}

impl Default for DecodePolicy {
    fn default() -> Self {
        DecodePolicy {
            dense_below: 1024,
            k_start: 8.0,
            mu: 0.7,
            horizon: 256,
            sink_blocks: 1,
            recent_blocks: 2,
            min_blocks: 4,
            beta: 0.2,
            stride: 8,
            spec_gamma: 0,
        }
    }
}

/// What one decode step should do, as decided by [`DecodePolicy::plan`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepPlan {
    /// Attend the full cached context.
    Dense,
    /// Rank blocks with the decode OAM and keep `budget_blocks`.
    Sparse {
        /// Blocks to keep this step (forced sets included).
        budget_blocks: usize,
    },
}

impl DecodePolicy {
    /// A policy that always decodes dense (the Lil baseline / fallback).
    pub fn dense() -> Self {
        DecodePolicy { dense_below: usize::MAX, ..Default::default() }
    }

    /// The cheap draft variant of this (serving) policy used by the
    /// speculative loop: the same TPD/OAM machinery, but forced sparse
    /// beyond a short dense window so every draft step pays a tight
    /// block budget instead of the serving policy's full attention —
    /// sinks and the recent window stay force-kept (Lil), which is what
    /// keeps draft/serve argmax agreement (the acceptance rate) high.
    /// Draft outputs are only *proposals*; the batched verify re-scores
    /// every position under the serving policy, so an aggressive draft
    /// can change throughput but never the emitted stream.
    pub fn draft(&self) -> DecodePolicy {
        let forced = (self.sink_blocks + self.recent_blocks).max(1);
        DecodePolicy {
            dense_below: self.dense_below.min(512),
            k_start: self.k_start.max(forced as f64 + 1.0),
            spec_gamma: 0,
            ..*self
        }
    }

    /// Reject configurations the planner cannot honor (bad decay,
    /// non-positive budget, empty recent window, zero stride).
    pub fn validate(&self) -> Result<(), String> {
        if !(self.mu > 0.0 && self.mu <= 1.0) {
            return Err(format!("mu must be in (0,1], got {}", self.mu));
        }
        if self.k_start <= 0.0 {
            return Err(format!("k_start must be > 0, got {}", self.k_start));
        }
        if self.recent_blocks < 1 {
            return Err("recent_blocks must be >= 1 (the query's own block)".into());
        }
        if self.stride < 1 {
            return Err("stride must be >= 1".into());
        }
        Ok(())
    }

    /// Sparse block budget at `step` (before context clamping).
    fn budget_at(&self, step: usize) -> usize {
        let horizon = self.horizon.max(1);
        let raw = schedule::k_at(step.min(horizon - 1), horizon, self.k_start, self.mu);
        let forced = self.sink_blocks + self.recent_blocks;
        raw.max(self.min_blocks as f64).max(forced as f64) as usize
    }

    /// Decide what step `step` does against a cached context of
    /// `n_ctx` tokens in blocks of `block` tokens.
    pub fn plan(&self, n_ctx: usize, step: usize, block: usize) -> StepPlan {
        if n_ctx < self.dense_below {
            return StepPlan::Dense;
        }
        let nblk = n_ctx.div_ceil(block.max(1));
        let budget = self.budget_at(step);
        if budget >= nblk {
            StepPlan::Dense // budget covers everything: skip ranking
        } else {
            StepPlan::Sparse { budget_blocks: budget }
        }
    }

    /// Telemetry classification of a [`StepPlan::Dense`] outcome for a
    /// context of `n_ctx` tokens: Lil's short-context floor, or the TPD
    /// budget simply covering every causal block. Only meaningful when
    /// [`DecodePolicy::plan`] actually returned the dense plan.
    pub fn dense_cause(&self, n_ctx: usize) -> DenseCause {
        if n_ctx < self.dense_below {
            DenseCause::ShortContext
        } else {
            DenseCause::BudgetCovers
        }
    }

    /// Fraction of the cached context a plan attends (the decode analogue
    /// of the prefill budget fraction).
    pub fn plan_fraction(plan: StepPlan, n_ctx: usize, block: usize) -> f64 {
        match plan {
            StepPlan::Dense => 1.0,
            StepPlan::Sparse { budget_blocks } => {
                let nblk = n_ctx.div_ceil(block.max(1)).max(1);
                (budget_blocks as f64 / nblk as f64).min(1.0)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn short_contexts_decode_dense() {
        let p = DecodePolicy::default();
        assert_eq!(p.plan(512, 0, 64), StepPlan::Dense);
        assert!(matches!(p.plan(4096, 0, 64), StepPlan::Sparse { .. }));
        assert_eq!(DecodePolicy::dense().plan(1 << 20, 10, 64), StepPlan::Dense);
    }

    #[test]
    fn budget_decays_over_steps_but_never_below_forced() {
        let p = DecodePolicy {
            dense_below: 0,
            k_start: 24.0,
            mu: 0.5,
            horizon: 100,
            min_blocks: 2,
            sink_blocks: 2,
            recent_blocks: 2,
            ..Default::default()
        };
        let budget = |step| match p.plan(1 << 16, step, 64) {
            StepPlan::Sparse { budget_blocks } => budget_blocks,
            StepPlan::Dense => unreachable!("65536 tokens never fit 24 blocks"),
        };
        let (b0, b50, b99) = (budget(0), budget(50), budget(99));
        assert!(b0 >= b50 && b50 >= b99, "{b0} {b50} {b99}");
        assert!(b99 >= 4, "decay must respect forced sink+recent floor");
        // past the horizon the budget holds at the floor value
        assert_eq!(budget(500), b99);
    }

    #[test]
    fn tiny_context_with_big_budget_is_dense() {
        let p = DecodePolicy { dense_below: 0, k_start: 64.0, ..Default::default() };
        assert_eq!(p.plan(1024, 0, 64), StepPlan::Dense); // 16 blocks < 64 budget
    }

    #[test]
    fn plan_fraction_bounds() {
        let f = DecodePolicy::plan_fraction(StepPlan::Sparse { budget_blocks: 8 }, 4096, 64);
        assert!((f - 8.0 / 64.0).abs() < 1e-12);
        assert_eq!(DecodePolicy::plan_fraction(StepPlan::Dense, 4096, 64), 1.0);
    }

    #[test]
    fn draft_policy_is_sparse_and_cheaper_where_the_serving_policy_is_dense() {
        // the dense serving baseline drafts sparse beyond a short window
        let serve = DecodePolicy::dense();
        let draft = serve.draft();
        draft.validate().unwrap();
        assert_eq!(draft.spec_gamma, 0, "a draft never recurses into speculation");
        assert_eq!(serve.plan(4096, 0, 64), StepPlan::Dense);
        match draft.plan(4096, 0, 64) {
            StepPlan::Sparse { budget_blocks } => {
                assert!(budget_blocks < 4096 / 64, "draft must attend a strict subset")
            }
            StepPlan::Dense => panic!("draft of a dense policy must go sparse at long context"),
        }
        // short contexts still draft dense (selection overhead dominates)
        assert_eq!(draft.plan(256, 0, 64), StepPlan::Dense);
        // forced keeps survive so acceptance does not collapse
        assert_eq!(draft.sink_blocks, serve.sink_blocks);
        assert_eq!(draft.recent_blocks, serve.recent_blocks);
        // drafting an already-sparse policy keeps its budget shape
        let sparse = DecodePolicy { dense_below: 0, k_start: 6.0, ..Default::default() };
        assert_eq!(sparse.draft().k_start, 6.0);
    }

    #[test]
    fn dense_cause_distinguishes_floor_from_coverage() {
        let p = DecodePolicy::default(); // dense_below = 1024
        assert_eq!(p.dense_cause(512), DenseCause::ShortContext);
        assert_eq!(p.dense_cause(2048), DenseCause::BudgetCovers);
        // boundary: n_ctx == dense_below is not "short"
        assert_eq!(p.dense_cause(1024), DenseCause::BudgetCovers);
    }

    #[test]
    fn validate_catches_bad_configs() {
        assert!(DecodePolicy::default().validate().is_ok());
        assert!(DecodePolicy { mu: 0.0, ..Default::default() }.validate().is_err());
        assert!(DecodePolicy { k_start: -1.0, ..Default::default() }.validate().is_err());
        assert!(DecodePolicy { recent_blocks: 0, ..Default::default() }.validate().is_err());
        assert!(DecodePolicy { stride: 0, ..Default::default() }.validate().is_err());
    }
}
