//! Autoregressive decode sessions over the shared paged KV store.
//!
//! Three pieces:
//!
//! * [`TinyLm`] — a deterministic seeded reference LM (embedding +
//!   sinusoidal positions + tied-unembedding, single attention layer)
//!   sharing the manifest geometry. It is the default
//!   [`DecodeBackend`](super::DecodeBackend) implementation; sessions
//!   hold an `Arc<dyn DecodeBackend>`, so the same loop also drives
//!   compiled per-step decode modules through
//!   [`EngineBackend`](super::EngineBackend) — the session additionally
//!   tracks the full token history because module-executing backends
//!   need the conditioning ids, not just an attention output.
//! * [`DecodeSession`] — ingests a prompt, then generates tokens one
//!   step at a time: project q/k/v for the last token, append K/V into
//!   pages (pool append + shared slab writes), run the policy-directed
//!   sparse/dense attention step, unembed, take the argmax, and stream
//!   every token through a caller-supplied callback.
//! * [`DecodeSession::fork`] — shared-prefix fan-out: a fork shares the
//!   source's cached pages through the refcounted pool and the
//!   [`SharedKv`](super::SharedKv) slab store; the first divergent
//!   append copy-on-write remaps the shared tail, so N continuations of
//!   one prompt pay the prefix KV once.
//!
//! Errors: every pool/slab interaction goes through [`SharedKv`], which
//! maps poisoned locks to `KvError::Poisoned`; sessions surface that as
//! [`DecodeError`] instead of panicking, so one crashed fork never takes
//! down its siblings.

use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::kv_cache::KvError;
use crate::model::vocab;
use crate::obs::sparsity::StepTelemetry;
use crate::sparse::Tensor;
use crate::util::rng::Rng;

use super::backend::DecodeBackend;
use super::policy::DecodePolicy;
use super::sparse_decode::decode_attend;
use super::store::{SeqKvView, SharedKv};

/// Decode-subsystem error: today every failure is a KV-pool/store
/// condition (capacity, unknown/duplicate sequences, poisoned shared
/// locks); a dedicated type keeps the session API stable as non-KV
/// failure modes (per-step HLO execution, sampling) arrive.
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum DecodeError {
    /// A KV-pool/store failure (see [`KvError`]).
    #[error("kv: {0}")]
    Kv(#[from] KvError),
}

/// Deterministic seeded reference LM with the serving geometry (see
/// module docs): tied embedding `[vocab, d_model]`, per-head q/k/v
/// projections stored `[out, d_model]` row-major so every matvec is a
/// contiguous `dot`, sinusoidal positions, single attention layer.
pub struct TinyLm {
    /// Query heads.
    pub h: usize,
    /// K/V heads (GQA groups).
    pub hk: usize,
    /// Head dimension.
    pub dh: usize,
    /// Vocabulary size.
    pub vocab: usize,
    d_model: usize,
    embed: Tensor,
    wq: Tensor,
    wk: Tensor,
    wv: Tensor,
    wo: Tensor,
}

impl TinyLm {
    /// Build a seeded LM with `h` query heads over `hk` K/V heads of
    /// dimension `dh` (weights drawn deterministically from `seed`).
    pub fn new(seed: u64, h: usize, hk: usize, dh: usize, vocab: usize) -> Self {
        assert!(h % hk.max(1) == 0, "query heads must be a multiple of kv heads");
        let d_model = h * dh;
        let mut r = Rng::new(seed);
        let scaled = |shape: &[usize], r: &mut Rng| {
            let mut t = Tensor::randn(shape, r);
            let s = 1.0 / (d_model as f32).sqrt();
            for x in t.data.iter_mut() {
                *x *= s;
            }
            t
        };
        let embed = Tensor::randn(&[vocab, d_model], &mut r);
        TinyLm {
            h,
            hk,
            dh,
            vocab,
            d_model,
            embed,
            wq: scaled(&[h * dh, d_model], &mut r),
            wk: scaled(&[hk * dh, d_model], &mut r),
            wv: scaled(&[hk * dh, d_model], &mut r),
            wo: scaled(&[d_model, d_model], &mut r),
        }
    }

    /// Model width (`h · dh`).
    pub fn d_model(&self) -> usize {
        self.d_model
    }

    fn embedded(&self, token: i32, pos: usize) -> Vec<f32> {
        let t = (token.max(0) as usize) % self.vocab;
        let mut e = self.embed.data[t * self.d_model..(t + 1) * self.d_model].to_vec();
        // sinusoidal positions so routing can distinguish block offsets
        for (d, x) in e.iter_mut().enumerate() {
            let omega = 1.0f64 / 10000f64.powf((2 * (d / 2)) as f64 / self.d_model as f64);
            let phase = pos as f64 * omega;
            *x += (if d % 2 == 0 { phase.sin() } else { phase.cos() }) as f32;
        }
        e
    }

    fn matvec(w: &Tensor, x: &[f32]) -> Vec<f32> {
        use crate::sparse::simd;
        let arm = simd::active(); // resolved once per projection, not per row
        let (out, dm) = (w.shape[0], w.shape[1]);
        // fan output-row chunks over the global pool for wide
        // projections: each output element is one independent dot, so the
        // result is bitwise identical at any thread count — projections
        // were the dominant *serial* cost of a decode step
        const CHUNK: usize = 64;
        let pool = crate::util::threadpool::global();
        if out < 2 * CHUNK || pool.workers() == 1 {
            return (0..out).map(|o| simd::dot(arm, &w.data[o * dm..(o + 1) * dm], x)).collect();
        }
        let chunks = out.div_ceil(CHUNK);
        let parts = crate::util::threadpool::scope_parallel_borrowed(pool, chunks, |c| {
            let (lo, hi) = (c * CHUNK, ((c + 1) * CHUNK).min(out));
            (lo..hi)
                .map(|o| simd::dot(arm, &w.data[o * dm..(o + 1) * dm], x))
                .collect::<Vec<f32>>()
        });
        let mut y = Vec::with_capacity(out);
        for p in parts {
            y.extend_from_slice(&p);
        }
        y
    }

    /// Project one token at `pos`: `(Some(q) if with_q, k, v)`, each
    /// `[heads·dh]` row-major. Prompt ingestion skips the q projection.
    pub fn project(
        &self,
        token: i32,
        pos: usize,
        with_q: bool,
    ) -> (Option<Vec<f32>>, Vec<f32>, Vec<f32>) {
        let e = self.embedded(token, pos);
        let q = with_q.then(|| Self::matvec(&self.wq, &e));
        (q, Self::matvec(&self.wk, &e), Self::matvec(&self.wv, &e))
    }

    /// Unembed an attention output (`[h·dh]`) into vocab logits.
    pub fn logits(&self, attn_out: &[f32]) -> Vec<f32> {
        let y = Self::matvec(&self.wo, attn_out);
        Self::matvec(&self.embed, &y)
    }

    /// Deterministic greedy pick (ties break toward the lowest id) —
    /// the same rule every backend's default
    /// [`DecodeBackend::select`] uses.
    pub fn argmax(logits: &[f32]) -> i32 {
        super::backend::greedy_argmax(logits)
    }
}

/// One streamed decode step.
#[derive(Debug, Clone, Copy)]
pub struct StepInfo {
    /// 0-based generation step.
    pub step: usize,
    /// The token this step emitted.
    pub token: i32,
    /// Cached tokens *including* this step's own K/V.
    pub n_ctx: usize,
    /// Fraction of the cached context attended.
    pub budget_fraction: f64,
    /// Whether the step ran the dense path.
    pub dense: bool,
    /// Wall-clock of the step (projection + append + attention + unembed).
    pub step_ns: u64,
    /// The step's sparsity observation (blocks visited/planned/kept,
    /// dense cause, captured OAM score mass) — see
    /// [`crate::obs::sparsity::StepTelemetry`].
    pub telemetry: StepTelemetry,
}

/// Aggregate result of [`DecodeSession::generate`].
#[derive(Debug, Clone, Default)]
pub struct SessionStats {
    /// Generated tokens, in order.
    pub tokens: Vec<i32>,
    /// Steps executed (equals `tokens.len()`).
    pub steps: usize,
    /// Steps that ran the dense fallback path.
    pub dense_steps: usize,
    /// Mean fraction of the cached context attended per step.
    pub mean_budget_fraction: f64,
    /// Summed per-step wall time in nanoseconds.
    pub decode_ns: u64,
    /// Speculative draft/verify round statistics (all zero when the
    /// policy's `spec_gamma` is 0 — the plain one-token-per-step path).
    pub spec: super::spec::SpecStats,
}

/// An autoregressive generation against the shared paged KV store (see
/// module docs). The sequence stays pinned in the pool for the session's
/// lifetime (unless [`DecodeSession::unpin`] parks it as a prefix
/// holder); `Drop` releases and frees its exclusively-owned pages.
///
/// Fields are `pub(super)` so the speculative draft/verify loop
/// (`decode::spec`) can drive the same append/attend/rollback state
/// machine without widening the public API.
pub struct DecodeSession {
    pub(super) seq: u64,
    pub(super) kv: Arc<SharedKv>,
    pub(super) model: Arc<dyn DecodeBackend>,
    pub(super) policy: DecodePolicy,
    pub(super) page_tokens: usize,
    pub(super) table: Vec<u32>,
    /// Token history in stream order: `tokens[p]` is the token whose K/V
    /// sits at cache position `p` — exactly the ids a module-executing
    /// backend conditions on. 4 bytes/token against the KV pages'
    /// hundreds, so it is kept unconditionally.
    pub(super) tokens: Vec<i32>,
    pub(super) n_ctx: usize,
    pub(super) step: usize,
    pub(super) last_token: i32,
    pub(super) budget_sum: f64,
    pub(super) dense_steps: usize,
    pub(super) decode_ns: u64,
    pub(super) spec_rounds: u64,
    pub(super) spec_drafted: u64,
    pub(super) spec_accepted: u64,
    pub(super) spec_committed: u64,
    closed: bool,
}

impl DecodeSession {
    /// Register `seq` in the pool (empty page table, pinned) against the
    /// shared store.
    pub fn new(
        kv: Arc<SharedKv>,
        model: Arc<dyn DecodeBackend>,
        policy: DecodePolicy,
        seq: u64,
    ) -> Result<Self, DecodeError> {
        debug_assert_eq!(
            (model.kv_heads(), model.head_dim()),
            (kv.kv_heads(), kv.head_dim()),
            "model geometry must match the shared store"
        );
        kv.allocate(seq, 0)?;
        let page_tokens = kv.page_tokens();
        Ok(DecodeSession {
            seq,
            kv,
            model,
            policy,
            page_tokens,
            table: vec![],
            tokens: vec![],
            n_ctx: 0,
            step: 0,
            last_token: vocab::BOS,
            budget_sum: 0.0,
            dense_steps: 0,
            decode_ns: 0,
            spec_rounds: 0,
            spec_drafted: 0,
            spec_accepted: 0,
            spec_committed: 0,
            closed: false,
        })
    }

    /// Fork a new session continuing this one's cached context: the fork
    /// shares every page through the refcounted pool (no K/V copied) and
    /// diverges lazily — its first append copy-on-write remaps the
    /// shared tail. The fork inherits the context (token count, last
    /// token) and policy, but its stream statistics and TPD step clock
    /// restart at zero; it is pinned regardless of the source's pin
    /// state. Intended use: prefill once, fork N times, serve N
    /// continuations off one prefix.
    pub fn fork(&self, new_seq: u64) -> Result<DecodeSession, DecodeError> {
        let table = self.kv.fork(self.seq, new_seq)?;
        Ok(DecodeSession {
            seq: new_seq,
            kv: Arc::clone(&self.kv),
            model: Arc::clone(&self.model),
            policy: self.policy,
            page_tokens: self.page_tokens,
            table,
            tokens: self.tokens.clone(),
            n_ctx: self.n_ctx,
            step: 0,
            last_token: self.last_token,
            budget_sum: 0.0,
            dense_steps: 0,
            decode_ns: 0,
            spec_rounds: 0,
            spec_drafted: 0,
            spec_accepted: 0,
            spec_committed: 0,
            closed: false,
        })
    }

    /// Fork a new session continuing only the leading `n_tokens` of this
    /// one's cached context — the token-granular variant of
    /// [`DecodeSession::fork`] behind radix prefix reuse: a prompt that
    /// shares a page-aligned prefix with this session forks just the
    /// covered pages and ingests the rest via
    /// [`DecodeSession::extend_prompt`]. `n_tokens` must be a whole
    /// number of pages (or the full context); `last_token` is the token
    /// at stream position `n_tokens - 1`, which the caller must supply
    /// because this session only tracks its *own* final token. Like
    /// `fork`, the result is pinned with fresh stream statistics.
    pub fn fork_prefix(
        &self,
        new_seq: u64,
        n_tokens: usize,
        last_token: i32,
    ) -> Result<DecodeSession, DecodeError> {
        let table = self.kv.fork_prefix(self.seq, new_seq, n_tokens)?;
        let mut tokens = self.tokens.clone();
        tokens.truncate(n_tokens);
        Ok(DecodeSession {
            seq: new_seq,
            kv: Arc::clone(&self.kv),
            model: Arc::clone(&self.model),
            policy: self.policy,
            page_tokens: self.page_tokens,
            table,
            tokens,
            n_ctx: n_tokens,
            step: 0,
            last_token,
            budget_sum: 0.0,
            dense_steps: 0,
            decode_ns: 0,
            spec_rounds: 0,
            spec_drafted: 0,
            spec_accepted: 0,
            spec_committed: 0,
            closed: false,
        })
    }

    /// Swap the per-step policy (a fork serving a different request may
    /// carry different sparsity settings than the prefix holder).
    pub fn set_policy(&mut self, policy: DecodePolicy) {
        self.policy = policy;
    }

    /// Unpin the sequence without closing the session: parked prefix
    /// holders yield to live traffic under page pressure. A later
    /// [`DecodeSession::fork`] re-pins the fork itself.
    pub fn unpin(&self) -> Result<(), DecodeError> {
        self.kv.release(self.seq)?;
        Ok(())
    }

    /// The sequence id this session owns in the shared pool.
    pub fn seq_id(&self) -> u64 {
        self.seq
    }

    /// Tokens currently cached (prompt + generated).
    pub fn n_ctx(&self) -> usize {
        self.n_ctx
    }

    /// Decode steps executed so far.
    pub fn steps(&self) -> usize {
        self.step
    }

    /// The token the next step will condition on.
    pub fn last_token(&self) -> i32 {
        self.last_token
    }

    /// The per-step policy this session decodes under.
    pub fn policy(&self) -> &DecodePolicy {
        &self.policy
    }

    /// The decode backend this session projects and unembeds with.
    pub fn model(&self) -> &Arc<dyn DecodeBackend> {
        &self.model
    }

    /// The token history in stream order: `token_history()[p]` is the
    /// token whose K/V is cached at position `p` (prompt + committed
    /// generations; length equals [`DecodeSession::n_ctx`]).
    pub fn token_history(&self) -> &[i32] {
        &self.tokens
    }

    /// The shared store this session decodes against.
    pub fn shared_kv(&self) -> &Arc<SharedKv> {
        &self.kv
    }

    /// Run `f` against this session's current cached-KV view, holding
    /// the shared slab read lock for the duration — benches and tests
    /// use this to score kernels against oracles on the exact serving
    /// state (forked tables included).
    pub fn with_kv_view<R>(&self, f: impl FnOnce(&SeqKvView) -> R) -> Result<R, DecodeError> {
        let slabs = self.kv.slabs()?;
        let view = SeqKvView { store: &slabs, table: &self.table, n_tokens: self.n_ctx };
        Ok(f(&view))
    }

    pub(super) fn append_kv(
        &mut self,
        token: i32,
        k_rows: &[f32],
        v_rows: &[f32],
    ) -> Result<(), DecodeError> {
        let pos = self.n_ctx;
        let app = self.kv.append_tokens(self.seq, 1)?;
        // patch the cached table from the append delta instead of
        // re-cloning the whole table every token
        if let Some((old, new)) = app.cow {
            let slot = pos / self.page_tokens;
            debug_assert_eq!(self.table[slot], old, "CoW remap must hit our tail page");
            self.table[slot] = new;
        }
        self.table.extend_from_slice(&app.grown);
        let page = self.table[pos / self.page_tokens];
        self.kv.write_token(page, pos % self.page_tokens, k_rows, v_rows)?;
        self.tokens.push(token);
        self.n_ctx = pos + 1;
        Ok(())
    }

    /// Roll the cached tail back to `n_tokens` (speculative-decode
    /// rollback): the pool/store drop pages past the target (shared
    /// pages survive through their refcounts, freed slabs are GC'd) and
    /// the cached page table and context count shrink to match. K/V for
    /// the surviving prefix is untouched, so the session state is
    /// exactly as if the discarded tokens had never been appended.
    pub(super) fn rewind_to(&mut self, n_tokens: usize) -> Result<(), DecodeError> {
        self.kv.truncate_tail(self.seq, n_tokens)?;
        self.table.truncate(n_tokens.div_ceil(self.page_tokens.max(1)));
        self.tokens.truncate(n_tokens);
        self.n_ctx = n_tokens;
        Ok(())
    }

    /// Ingest the prompt: append K/V for every prompt token (no
    /// attention output is needed until the first generated token). Also
    /// used on a fork to inject a divergence suffix before generating.
    pub fn prefill(&mut self, prompt: &[i32]) -> Result<(), DecodeError> {
        self.extend_prompt(prompt)
    }

    /// Append a prompt *suffix* at the current context position — the
    /// ingest half of radix prefix reuse: after
    /// [`DecodeSession::fork_prefix`] covered the shared pages, only the
    /// uncovered tail of the prompt is projected and appended (each
    /// token one [`crate::coordinator::kv_cache::KvCache::append_tokens`]
    /// + slab write), so ingest cost scales with the suffix, not the
    /// prompt. K/V depend only on `(token, position)`, so the combined
    /// fork+suffix state is bit-identical to a full ingest of the whole
    /// prompt. (`prefill` is this with the suffix starting at zero.)
    pub fn extend_prompt(&mut self, suffix: &[i32]) -> Result<(), DecodeError> {
        for &t in suffix {
            let (_, k, v) = self.model.project(t, self.n_ctx, false);
            self.append_kv(t, &k, &v)?;
        }
        if let Some(&last) = suffix.last() {
            self.last_token = last;
        }
        Ok(())
    }

    /// One decode step: project the last token, append its K/V into the
    /// paged cache, attend under the policy, produce the step's logits
    /// through the backend and pick the next token greedily.
    pub fn step_once(&mut self) -> Result<StepInfo, DecodeError> {
        let t0 = Instant::now();
        let pos = self.n_ctx;
        let (q, k, v) = self.model.project(self.last_token, pos, true);
        self.append_kv(self.last_token, &k, &v)?;
        let q = Tensor::from_vec(&[self.model.heads(), self.model.head_dim()], q.expect("with_q"));
        let att = {
            // hold the slab read lock only for the attention step itself;
            // sibling forks attend concurrently under the same read lock
            let slabs = self.kv.slabs()?;
            let view = SeqKvView { store: &*slabs, table: &self.table, n_tokens: self.n_ctx };
            decode_attend(&q, &view, &self.policy, self.step)
        };
        let logits = self.model.step_logits(&self.tokens, &att.out);
        let token = self.model.select(&logits);
        let step_ns = t0.elapsed().as_nanos() as u64;
        let info = StepInfo {
            step: self.step,
            token,
            n_ctx: self.n_ctx,
            budget_fraction: att.budget_fraction,
            dense: att.dense,
            step_ns,
            telemetry: att.telemetry,
        };
        self.last_token = token;
        self.step += 1;
        self.budget_sum += att.budget_fraction;
        self.dense_steps += att.dense as usize;
        self.decode_ns += step_ns;
        Ok(info)
    }

    /// Generate up to `max_new` tokens, streaming each through
    /// `on_token`; the callback returning `false` — or `stop_token`
    /// being emitted — ends the generation early. When the policy's
    /// `spec_gamma` is `>= 1` the tokens are produced by speculative
    /// draft/verify rounds ([`DecodeSession::generate_spec`]) — the
    /// emitted stream, cache state and per-step accounting are exactly
    /// what this non-speculative loop would produce.
    pub fn generate(
        &mut self,
        max_new: usize,
        stop_token: Option<i32>,
        mut on_token: impl FnMut(&StepInfo) -> bool,
    ) -> Result<SessionStats, DecodeError> {
        if self.policy.spec_gamma >= 1 {
            return self.generate_spec(max_new, stop_token, on_token);
        }
        let mut tokens = Vec::with_capacity(max_new);
        for _ in 0..max_new {
            let info = self.step_once()?;
            tokens.push(info.token);
            let keep_going = on_token(&info);
            if !keep_going || stop_token == Some(info.token) {
                break;
            }
        }
        Ok(SessionStats {
            steps: tokens.len(),
            tokens,
            dense_steps: self.dense_steps,
            mean_budget_fraction: self.mean_budget_fraction(),
            decode_ns: self.decode_ns,
            spec: self.spec_stats(),
        })
    }

    /// Mean fraction of the cached context attended per executed step
    /// (1.0 before any step runs).
    pub fn mean_budget_fraction(&self) -> f64 {
        if self.step == 0 {
            1.0
        } else {
            self.budget_sum / self.step as f64
        }
    }

    /// Steps that ran the dense fallback path.
    pub fn dense_steps(&self) -> usize {
        self.dense_steps
    }

    /// Summed per-step wall time in nanoseconds.
    pub fn decode_ns(&self) -> u64 {
        self.decode_ns
    }

    /// Lifetime speculative round statistics (zeros when speculation
    /// never ran on this session).
    pub fn spec_stats(&self) -> super::spec::SpecStats {
        super::spec::SpecStats {
            rounds: self.spec_rounds,
            drafted: self.spec_drafted,
            accepted: self.spec_accepted,
            committed: self.spec_committed,
        }
    }

    /// Release the sequence and free its exclusively-owned pages;
    /// idempotent (also runs on `Drop`). Pages shared with live forks
    /// survive through their refcounts.
    pub fn close(&mut self) {
        if self.closed {
            return;
        }
        self.closed = true;
        let _ = self.kv.release(self.seq);
        let _ = self.kv.drop_seq(self.seq);
    }
}

impl Drop for DecodeSession {
    fn drop(&mut self) {
        self.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::kv_cache::KvConfig;
    use crate::decode::decode_attend_dense_reference;

    fn pool(pages: usize, page_tokens: usize) -> Arc<SharedKv> {
        SharedKv::new(KvConfig { total_pages: pages, page_tokens }, 2, 8)
    }

    fn model() -> Arc<TinyLm> {
        Arc::new(TinyLm::new(7, 4, 2, 8, vocab::VOCAB_SIZE))
    }

    fn prompt(n: usize) -> Vec<i32> {
        let mut p = vec![vocab::BOS];
        p.extend((0..n.saturating_sub(1)).map(|i| vocab::WORD0 + (i % 40) as i32));
        p
    }

    #[test]
    fn generation_is_deterministic_and_in_vocab() {
        let run = || {
            let kv = pool(64, 16);
            let mut s = DecodeSession::new(kv, model(), DecodePolicy::default(), 1).unwrap();
            s.prefill(&prompt(40)).unwrap();
            s.generate(12, None, |_| true).unwrap().tokens
        };
        let (a, b) = (run(), run());
        assert_eq!(a, b, "same seed + prompt must reproduce the stream");
        assert_eq!(a.len(), 12);
        assert!(a.iter().all(|&t| (0..vocab::VOCAB_SIZE as i32).contains(&t)));
    }

    #[test]
    fn pages_grow_with_context_and_free_on_close() {
        let kv = pool(64, 16);
        let mut s =
            DecodeSession::new(Arc::clone(&kv), model(), DecodePolicy::default(), 9).unwrap();
        s.prefill(&prompt(33)).unwrap(); // 33 tokens -> 3 pages of 16
        assert_eq!(kv.pool().unwrap().page_table(9).unwrap().len(), 3);
        s.generate(16, None, |_| true).unwrap(); // 49 tokens -> 4 pages
        assert_eq!(kv.pool().unwrap().page_table(9).unwrap().len(), 4);
        assert_eq!(kv.seq_tokens(9).unwrap(), Some(49));
        kv.pool().unwrap().check_invariants().unwrap();
        drop(s);
        assert_eq!(kv.pool().unwrap().used_pages(), 0, "drop must free the pages");
        assert_eq!(kv.pages_resident(), 0, "drop must GC the slabs");
    }

    #[test]
    fn policy_dense_fallback_and_sparse_steps_report_budget() {
        let kv = pool(256, 16);
        // dense_below larger than the context: every step dense
        let mut s = DecodeSession::new(
            Arc::clone(&kv),
            model(),
            DecodePolicy { dense_below: 1 << 20, ..Default::default() },
            1,
        )
        .unwrap();
        s.prefill(&prompt(64)).unwrap();
        let st = s.generate(4, None, |i| {
            assert!(i.dense);
            true
        });
        assert_eq!(st.unwrap().dense_steps, 4);
        drop(s);
        // sparse policy over a longer context reports fractional budgets
        let mut s = DecodeSession::new(
            kv,
            model(),
            DecodePolicy { dense_below: 0, k_start: 4.0, min_blocks: 2, ..Default::default() },
            2,
        )
        .unwrap();
        s.prefill(&prompt(160)).unwrap(); // 10 blocks of 16
        let st = s.generate(4, None, |i| {
            assert!(!i.dense);
            assert!(i.budget_fraction < 1.0);
            true
        });
        let st = st.unwrap();
        assert_eq!(st.dense_steps, 0);
        assert!(st.mean_budget_fraction < 0.6, "{}", st.mean_budget_fraction);
    }

    #[test]
    fn callback_can_stop_early() {
        let kv = pool(64, 16);
        let mut s = DecodeSession::new(kv, model(), DecodePolicy::default(), 1).unwrap();
        s.prefill(&prompt(8)).unwrap();
        let st = s.generate(100, None, |i| i.step < 2).unwrap();
        assert_eq!(st.steps, 3, "stop after the callback's third step");
    }

    #[test]
    fn empty_prompt_decodes_from_bos() {
        let kv = pool(16, 16);
        let mut s = DecodeSession::new(kv, model(), DecodePolicy::default(), 1).unwrap();
        s.prefill(&[]).unwrap();
        let st = s.generate(3, None, |_| true).unwrap();
        assert_eq!(st.steps, 3);
    }

    // --- shared-prefix fork -------------------------------------------

    #[test]
    fn fork_matches_independent_session_exactly() {
        // a fork must behave exactly like a fresh session that prefilled
        // the same prompt: same stream, same per-step budget plan
        let kv = pool(256, 16);
        let p = prompt(48);
        let mut root =
            DecodeSession::new(Arc::clone(&kv), model(), DecodePolicy::default(), 1).unwrap();
        root.prefill(&p).unwrap();
        let mut forked = root.fork(2).unwrap();
        let mut indep =
            DecodeSession::new(Arc::clone(&kv), model(), DecodePolicy::default(), 3).unwrap();
        indep.prefill(&p).unwrap();
        let a = forked.generate(10, None, |_| true).unwrap().tokens;
        let b = indep.generate(10, None, |_| true).unwrap().tokens;
        assert_eq!(a, b, "fork and independent session must agree token-for-token");
        kv.pool().unwrap().check_invariants().unwrap();
    }

    #[test]
    fn fork_shares_prefix_pages_and_diverges_by_cow() {
        let kv = pool(256, 16);
        let p = prompt(40); // 3 pages (40 tokens / 16), tail partial
        let mut root =
            DecodeSession::new(Arc::clone(&kv), model(), DecodePolicy::default(), 1).unwrap();
        root.prefill(&p).unwrap();
        let before = kv.pool().unwrap().used_pages();
        assert_eq!(before, 3);
        let mut forks: Vec<DecodeSession> =
            (0..4).map(|i| root.fork(10 + i as u64).unwrap()).collect();
        assert_eq!(kv.pool().unwrap().used_pages(), 3, "forks alias, not copy");
        // diverge each fork with a distinct steering token, then generate
        let mut streams = vec![];
        for (i, f) in forks.iter_mut().enumerate() {
            f.prefill(&[vocab::WORD0 + i as i32]).unwrap();
            streams.push(f.generate(6, None, |_| true).unwrap().tokens);
        }
        // CoW isolation both ways: each fork equals an independent session
        // with the same steered prompt, and the root stays untouched
        for (i, stream) in streams.iter().enumerate() {
            let kv2 = pool(256, 16);
            let mut c = DecodeSession::new(kv2, model(), DecodePolicy::default(), 1).unwrap();
            c.prefill(&p).unwrap();
            c.prefill(&[vocab::WORD0 + i as i32]).unwrap();
            let want = c.generate(6, None, |_| true).unwrap().tokens;
            assert_eq!(stream, &want, "fork {i} deviates from its independent twin");
        }
        let control = {
            let kv2 = pool(256, 16);
            let mut c = DecodeSession::new(kv2, model(), DecodePolicy::default(), 1).unwrap();
            c.prefill(&p).unwrap();
            c.generate(6, None, |_| true).unwrap().tokens
        };
        let root_stream = root.generate(6, None, |_| true).unwrap().tokens;
        assert_eq!(root_stream, control, "forks must never leak into the root");
        // page accounting: shared prefix counted once + per-fork tails
        let used = kv.pool().unwrap().used_pages();
        let independent_equiv = 5 * 3 + 5; // 5 sessions x 3 prefix pages + ~1 tail each
        assert!(
            used < independent_equiv / 2,
            "fan-out must at least halve page residency: {used} vs {independent_equiv}"
        );
        kv.pool().unwrap().check_invariants().unwrap();
        drop(forks);
        drop(root);
        assert_eq!(kv.pool().unwrap().used_pages(), 0);
        assert_eq!(kv.pages_resident(), 0);
    }

    #[test]
    fn prefix_fork_plus_suffix_matches_full_ingest_exactly() {
        // satellite acceptance: a continuation served as (page-aligned
        // prefix fork + suffix ingest) must be indistinguishable from a
        // session that ingested the whole prompt from scratch — token
        // streams identical, dense kernel vs oracle within 1e-5
        let kv = pool(256, 16);
        let m = model();
        let shared = prompt(48); // 3 whole pages of 16
        let mut full_a: Vec<i32> = shared.clone();
        full_a.extend([vocab::WORD0 + 5, vocab::WORD0 + 9, vocab::WORD0 + 2]);
        let mut root =
            DecodeSession::new(Arc::clone(&kv), Arc::clone(&m), DecodePolicy::default(), 1)
                .unwrap();
        root.prefill(&full_a).unwrap();
        // a second prompt shares the 48-token prefix, then diverges
        let mut full_b: Vec<i32> = shared.clone();
        full_b.extend((0..20).map(|i| vocab::WORD0 + ((i * 3) % 40) as i32));
        let covered = 48;
        let mut reused = root.fork_prefix(2, covered, full_b[covered - 1]).unwrap();
        reused.extend_prompt(&full_b[covered..]).unwrap();
        assert_eq!(reused.n_ctx(), full_b.len());
        assert_eq!(reused.last_token(), *full_b.last().unwrap());
        let got = reused.generate(12, None, |_| true).unwrap().tokens;
        let want = {
            let kv2 = pool(256, 16);
            let mut c =
                DecodeSession::new(kv2, Arc::clone(&m), DecodePolicy::default(), 1).unwrap();
            c.prefill(&full_b).unwrap();
            c.generate(12, None, |_| true).unwrap().tokens
        };
        assert_eq!(got, want, "prefix-fork continuation must match a clean full ingest");
        // numeric parity of the reused session's view vs the dense oracle
        let (q, _, _) = m.project(reused.last_token(), reused.n_ctx(), true);
        let q = Tensor::from_vec(&[m.h, m.dh], q.unwrap());
        let d = reused
            .with_kv_view(|view| {
                let att = decode_attend(&q, view, &DecodePolicy::dense(), 0);
                let oracle = decode_attend_dense_reference(&q, view);
                att.out.iter().zip(&oracle).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max)
            })
            .unwrap();
        assert!(d < 1e-5, "prefix-forked view deviates from dense oracle by {d}");
        // the root is untouched by the reused branch's suffix
        let root_stream = root.generate(4, None, |_| true).unwrap().tokens;
        let control = {
            let kv2 = pool(256, 16);
            let mut c =
                DecodeSession::new(kv2, Arc::clone(&m), DecodePolicy::default(), 1).unwrap();
            c.prefill(&full_a).unwrap();
            c.generate(4, None, |_| true).unwrap().tokens
        };
        assert_eq!(root_stream, control, "prefix fork must never leak into the source");
        kv.pool().unwrap().check_invariants().unwrap();
    }

    #[test]
    fn dropping_a_prefix_fork_frees_its_divergent_slabs() {
        // regression (slab-GC satellite): a dropped fork tail must free
        // its slab payloads, leaving only the shared prefix resident
        let kv = pool(256, 16);
        let mut root =
            DecodeSession::new(Arc::clone(&kv), model(), DecodePolicy::default(), 1).unwrap();
        root.prefill(&prompt(32)).unwrap(); // 2 whole pages
        assert_eq!(kv.pages_resident(), 2);
        let mut fork = root.fork_prefix(2, 16, prompt(32)[15]).unwrap();
        fork.extend_prompt(&prompt(40)[16..]).unwrap(); // diverge + grow
        assert!(kv.pages_resident() > 2, "divergent tail must materialize slabs");
        drop(fork);
        assert_eq!(kv.pages_resident(), 2, "dropped fork tail must GC its slabs");
        assert_eq!(kv.pool().unwrap().used_pages(), 2);
        drop(root);
        assert_eq!(kv.pages_resident(), 0);
    }

    #[test]
    fn forked_dense_step_matches_dense_oracle() {
        // sparse-vs-dense parity must hold on a *forked* session's view
        let kv = pool(256, 16);
        let m = model();
        let mut root =
            DecodeSession::new(Arc::clone(&kv), Arc::clone(&m), DecodePolicy::dense(), 1).unwrap();
        root.prefill(&prompt(80)).unwrap();
        let mut fork = root.fork(2).unwrap();
        fork.prefill(&[vocab::WORD0 + 7]).unwrap();
        // project the fork's next query and compare kernel vs oracle on
        // the exact view the step would use
        let (q, k, v) = m.project(vocab::WORD0 + 7, fork.n_ctx(), true);
        let _ = (k, v);
        let q = Tensor::from_vec(&[m.h, m.dh], q.unwrap());
        let slabs = kv.slabs().unwrap();
        let view = SeqKvView { store: &*slabs, table: &fork.table, n_tokens: fork.n_ctx() };
        let att = decode_attend(&q, &view, &DecodePolicy::dense(), 0);
        let oracle = decode_attend_dense_reference(&q, &view);
        let d = att
            .out
            .iter()
            .zip(&oracle)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(d < 1e-5, "forked dense step deviates from oracle by {d}");
    }

    #[test]
    fn poisoned_store_surfaces_as_decode_error() {
        let kv = pool(16, 16);
        let kv2 = Arc::clone(&kv);
        let _ = std::thread::spawn(move || {
            let _g = kv2.pool().unwrap();
            panic!("poison the shared pool");
        })
        .join();
        let err = DecodeSession::new(kv, model(), DecodePolicy::default(), 1).unwrap_err();
        assert_eq!(err, DecodeError::Kv(KvError::Poisoned));
    }
}
