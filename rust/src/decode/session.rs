//! Autoregressive decode sessions over the paged KV cache.
//!
//! Three pieces:
//!
//! * [`PagedKv`] — the slab store behind the page ids the coordinator's
//!   [`KvCache`] hands out: one `[Hk, page_tokens, dh]` K and V slab per
//!   page, allocated lazily on first write and copied on a
//!   copy-on-write remap. The store is owned by its session (no locks on
//!   the attention hot path); the *pool* — which bounds aggregate KV
//!   memory, refcounts forked prefixes and evicts under pressure — is the
//!   shared `KvCache`.
//! * [`TinyLm`] — a deterministic seeded reference LM (embedding +
//!   sinusoidal positions + tied-unembedding, single attention layer)
//!   sharing the manifest geometry. The PJRT engine only lowers prefill
//!   graphs, so the decode phase runs the pure-rust core end-to-end with
//!   this stand-in; swapping in per-step decode HLO modules is a ROADMAP
//!   item and only replaces the projection calls here.
//! * [`DecodeSession`] — ingests a prompt, then generates tokens one
//!   step at a time: project q/k/v for the last token, append K/V into
//!   pages ([`KvCache::append_tokens`] + slab writes), run the
//!   policy-directed sparse/dense attention step, unembed, take the
//!   argmax, and stream every token through a caller-supplied callback.
//!
//! A `SeqKvView` adapts (store, page table, token count) to the
//! storage-agnostic `sparse::KvBlocks` trait the kernels consume — one
//! attention block per page, the tail block partial.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::coordinator::kv_cache::{KvCache, KvError};
use crate::model::vocab;
use crate::sparse::{KvBlocks, Tensor};
use crate::util::rng::Rng;

use super::policy::DecodePolicy;
use super::sparse_decode::decode_attend;

/// Per-page K/V slab store addressed by `KvCache` page ids (see module
/// docs for the ownership split between store and pool).
pub struct PagedKv {
    page_tokens: usize,
    hk: usize,
    dh: usize,
    k_pages: HashMap<u32, Box<[f32]>>,
    v_pages: HashMap<u32, Box<[f32]>>,
}

impl PagedKv {
    pub fn new(page_tokens: usize, hk: usize, dh: usize) -> Self {
        PagedKv { page_tokens, hk, dh, k_pages: HashMap::new(), v_pages: HashMap::new() }
    }

    fn slab_len(&self) -> usize {
        self.hk * self.page_tokens * self.dh
    }

    pub fn pages_resident(&self) -> usize {
        self.k_pages.len()
    }

    /// Write one token's K/V rows (`[Hk·dh]` each) into `slot` of `page`.
    pub fn write_token(&mut self, page: u32, slot: usize, k_rows: &[f32], v_rows: &[f32]) {
        debug_assert!(slot < self.page_tokens);
        debug_assert_eq!(k_rows.len(), self.hk * self.dh);
        let len = self.slab_len();
        let (pt, dh) = (self.page_tokens, self.dh);
        for (pages, rows) in [(&mut self.k_pages, k_rows), (&mut self.v_pages, v_rows)] {
            let slab = pages.entry(page).or_insert_with(|| vec![0.0f32; len].into_boxed_slice());
            for hkv in 0..self.hk {
                let off = (hkv * pt + slot) * dh;
                slab[off..off + dh].copy_from_slice(&rows[hkv * dh..(hkv + 1) * dh]);
            }
        }
    }

    /// Copy-on-write support: duplicate `src`'s payload under `dst`
    /// (called right after [`KvCache::append_tokens`] reports a remap).
    pub fn copy_page(&mut self, src: u32, dst: u32) {
        if let Some(s) = self.k_pages.get(&src).cloned() {
            self.k_pages.insert(dst, s);
        }
        if let Some(s) = self.v_pages.get(&src).cloned() {
            self.v_pages.insert(dst, s);
        }
    }
}

/// `sparse::KvBlocks` over (store, page table, token count): logical
/// block `b` lives in page `table[b]`.
pub struct SeqKvView<'a> {
    pub store: &'a PagedKv,
    pub table: &'a [u32],
    pub n_tokens: usize,
}

impl SeqKvView<'_> {
    fn slab<'s>(
        &self,
        pages: &'s HashMap<u32, Box<[f32]>>,
        hkv: usize,
        b: usize,
    ) -> &'s [f32] {
        let slab = &pages[&self.table[b]];
        let off = hkv * self.store.page_tokens * self.store.dh;
        &slab[off..off + self.block_len(b) * self.store.dh]
    }
}

impl KvBlocks for SeqKvView<'_> {
    fn n_tokens(&self) -> usize {
        self.n_tokens
    }

    fn block_tokens(&self) -> usize {
        self.store.page_tokens
    }

    fn n_kv_heads(&self) -> usize {
        self.store.hk
    }

    fn head_dim(&self) -> usize {
        self.store.dh
    }

    fn k_block(&self, hkv: usize, b: usize) -> &[f32] {
        self.slab(&self.store.k_pages, hkv, b)
    }

    fn v_block(&self, hkv: usize, b: usize) -> &[f32] {
        self.slab(&self.store.v_pages, hkv, b)
    }
}

/// Deterministic seeded reference LM with the serving geometry (see
/// module docs): tied embedding `[vocab, d_model]`, per-head q/k/v
/// projections stored `[out, d_model]` row-major so every matvec is a
/// contiguous `dot`, sinusoidal positions, single attention layer.
pub struct TinyLm {
    pub h: usize,
    pub hk: usize,
    pub dh: usize,
    pub vocab: usize,
    d_model: usize,
    embed: Tensor,
    wq: Tensor,
    wk: Tensor,
    wv: Tensor,
    wo: Tensor,
}

impl TinyLm {
    pub fn new(seed: u64, h: usize, hk: usize, dh: usize, vocab: usize) -> Self {
        assert!(h % hk.max(1) == 0, "query heads must be a multiple of kv heads");
        let d_model = h * dh;
        let mut r = Rng::new(seed);
        let scaled = |shape: &[usize], r: &mut Rng| {
            let mut t = Tensor::randn(shape, r);
            let s = 1.0 / (d_model as f32).sqrt();
            for x in t.data.iter_mut() {
                *x *= s;
            }
            t
        };
        let embed = Tensor::randn(&[vocab, d_model], &mut r);
        TinyLm {
            h,
            hk,
            dh,
            vocab,
            d_model,
            embed,
            wq: scaled(&[h * dh, d_model], &mut r),
            wk: scaled(&[hk * dh, d_model], &mut r),
            wv: scaled(&[hk * dh, d_model], &mut r),
            wo: scaled(&[d_model, d_model], &mut r),
        }
    }

    pub fn d_model(&self) -> usize {
        self.d_model
    }

    fn embedded(&self, token: i32, pos: usize) -> Vec<f32> {
        let t = (token.max(0) as usize) % self.vocab;
        let mut e = self.embed.data[t * self.d_model..(t + 1) * self.d_model].to_vec();
        // sinusoidal positions so routing can distinguish block offsets
        for (d, x) in e.iter_mut().enumerate() {
            let omega = 1.0f64 / 10000f64.powf((2 * (d / 2)) as f64 / self.d_model as f64);
            let phase = pos as f64 * omega;
            *x += (if d % 2 == 0 { phase.sin() } else { phase.cos() }) as f32;
        }
        e
    }

    fn matvec(w: &Tensor, x: &[f32]) -> Vec<f32> {
        let (out, dm) = (w.shape[0], w.shape[1]);
        (0..out).map(|o| crate::sparse::tensor::dot(&w.data[o * dm..(o + 1) * dm], x)).collect()
    }

    /// Project one token at `pos`: `(Some(q) if with_q, k, v)`, each
    /// `[heads·dh]` row-major. Prompt ingestion skips the q projection.
    pub fn project(
        &self,
        token: i32,
        pos: usize,
        with_q: bool,
    ) -> (Option<Vec<f32>>, Vec<f32>, Vec<f32>) {
        let e = self.embedded(token, pos);
        let q = with_q.then(|| Self::matvec(&self.wq, &e));
        (q, Self::matvec(&self.wk, &e), Self::matvec(&self.wv, &e))
    }

    /// Unembed an attention output (`[h·dh]`) into vocab logits.
    pub fn logits(&self, attn_out: &[f32]) -> Vec<f32> {
        let y = Self::matvec(&self.wo, attn_out);
        Self::matvec(&self.embed, &y)
    }

    /// Deterministic greedy pick (ties break toward the lowest id).
    pub fn argmax(logits: &[f32]) -> i32 {
        let mut best = 0usize;
        for (i, &v) in logits.iter().enumerate() {
            if v > logits[best] {
                best = i;
            }
        }
        best as i32
    }
}

/// One streamed decode step.
#[derive(Debug, Clone, Copy)]
pub struct StepInfo {
    /// 0-based generation step.
    pub step: usize,
    /// The token this step emitted.
    pub token: i32,
    /// Cached tokens *including* this step's own K/V.
    pub n_ctx: usize,
    /// Fraction of the cached context attended.
    pub budget_fraction: f64,
    /// Whether the step ran the dense path.
    pub dense: bool,
    /// Wall-clock of the step (projection + append + attention + unembed).
    pub step_ns: u64,
}

/// Aggregate result of [`DecodeSession::generate`].
#[derive(Debug, Clone, Default)]
pub struct SessionStats {
    pub tokens: Vec<i32>,
    pub steps: usize,
    pub dense_steps: usize,
    pub mean_budget_fraction: f64,
    pub decode_ns: u64,
}

/// An autoregressive generation against the shared paged KV pool (see
/// module docs). The sequence stays pinned in the pool for the session's
/// lifetime; `Drop` releases and frees its pages.
pub struct DecodeSession {
    seq: u64,
    kv: Arc<Mutex<KvCache>>,
    store: PagedKv,
    model: Arc<TinyLm>,
    policy: DecodePolicy,
    page_tokens: usize,
    table: Vec<u32>,
    n_ctx: usize,
    step: usize,
    last_token: i32,
    budget_sum: f64,
    dense_steps: usize,
    decode_ns: u64,
    closed: bool,
}

impl DecodeSession {
    /// Register `seq` in the pool (empty page table, pinned) and set up
    /// the per-session store.
    pub fn new(
        kv: Arc<Mutex<KvCache>>,
        model: Arc<TinyLm>,
        policy: DecodePolicy,
        seq: u64,
    ) -> Result<Self, KvError> {
        let page_tokens = {
            let mut g = kv.lock().unwrap();
            g.allocate(seq, 0)?;
            g.page_tokens()
        };
        let store = PagedKv::new(page_tokens, model.hk, model.dh);
        Ok(DecodeSession {
            seq,
            kv,
            store,
            model,
            policy,
            page_tokens,
            table: vec![],
            n_ctx: 0,
            step: 0,
            last_token: vocab::BOS,
            budget_sum: 0.0,
            dense_steps: 0,
            decode_ns: 0,
            closed: false,
        })
    }

    pub fn seq_id(&self) -> u64 {
        self.seq
    }

    pub fn n_ctx(&self) -> usize {
        self.n_ctx
    }

    pub fn steps(&self) -> usize {
        self.step
    }

    fn append_kv(&mut self, k_rows: &[f32], v_rows: &[f32]) -> Result<(), KvError> {
        let pos = self.n_ctx;
        {
            let mut g = self.kv.lock().unwrap();
            let app = g.append_tokens(self.seq, 1)?;
            if let Some((old, new)) = app.cow {
                self.store.copy_page(old, new);
            }
            self.table.clear();
            self.table.extend_from_slice(g.page_table(self.seq).expect("live seq"));
        }
        let page = self.table[pos / self.page_tokens];
        self.store.write_token(page, pos % self.page_tokens, k_rows, v_rows);
        self.n_ctx = pos + 1;
        Ok(())
    }

    /// Ingest the prompt: append K/V for every prompt token (no
    /// attention output is needed until the first generated token).
    pub fn prefill(&mut self, prompt: &[i32]) -> Result<(), KvError> {
        for &t in prompt {
            let (_, k, v) = self.model.project(t, self.n_ctx, false);
            self.append_kv(&k, &v)?;
        }
        if let Some(&last) = prompt.last() {
            self.last_token = last;
        }
        Ok(())
    }

    /// One decode step: project the last token, append its K/V into the
    /// paged cache, attend under the policy, unembed and pick the next
    /// token greedily.
    pub fn step_once(&mut self) -> Result<StepInfo, KvError> {
        let t0 = Instant::now();
        let pos = self.n_ctx;
        let (q, k, v) = self.model.project(self.last_token, pos, true);
        self.append_kv(&k, &v)?;
        let q = Tensor::from_vec(&[self.model.h, self.model.dh], q.expect("with_q"));
        let view = SeqKvView { store: &self.store, table: &self.table, n_tokens: self.n_ctx };
        let att = decode_attend(&q, &view, &self.policy, self.step);
        let logits = self.model.logits(&att.out);
        let token = TinyLm::argmax(&logits);
        let step_ns = t0.elapsed().as_nanos() as u64;
        let info = StepInfo {
            step: self.step,
            token,
            n_ctx: self.n_ctx,
            budget_fraction: att.budget_fraction,
            dense: att.dense,
            step_ns,
        };
        self.last_token = token;
        self.step += 1;
        self.budget_sum += att.budget_fraction;
        self.dense_steps += att.dense as usize;
        self.decode_ns += step_ns;
        Ok(info)
    }

    /// Generate up to `max_new` tokens, streaming each through
    /// `on_token`; the callback returning `false` — or `stop_token`
    /// being emitted — ends the generation early.
    pub fn generate(
        &mut self,
        max_new: usize,
        stop_token: Option<i32>,
        mut on_token: impl FnMut(&StepInfo) -> bool,
    ) -> Result<SessionStats, KvError> {
        let mut tokens = Vec::with_capacity(max_new);
        for _ in 0..max_new {
            let info = self.step_once()?;
            tokens.push(info.token);
            let keep_going = on_token(&info);
            if !keep_going || stop_token == Some(info.token) {
                break;
            }
        }
        Ok(SessionStats {
            steps: tokens.len(),
            tokens,
            dense_steps: self.dense_steps,
            mean_budget_fraction: self.mean_budget_fraction(),
            decode_ns: self.decode_ns,
        })
    }

    pub fn mean_budget_fraction(&self) -> f64 {
        if self.step == 0 {
            1.0
        } else {
            self.budget_sum / self.step as f64
        }
    }

    pub fn dense_steps(&self) -> usize {
        self.dense_steps
    }

    pub fn decode_ns(&self) -> u64 {
        self.decode_ns
    }

    /// Release the sequence and free its pages; idempotent (also runs on
    /// `Drop`).
    pub fn close(&mut self) {
        if self.closed {
            return;
        }
        self.closed = true;
        let mut g = self.kv.lock().unwrap();
        let _ = g.release(self.seq);
        let _ = g.drop_seq(self.seq);
    }
}

impl Drop for DecodeSession {
    fn drop(&mut self) {
        self.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::kv_cache::KvConfig;

    fn pool(pages: usize, page_tokens: usize) -> Arc<Mutex<KvCache>> {
        Arc::new(Mutex::new(KvCache::new(KvConfig { total_pages: pages, page_tokens })))
    }

    fn model() -> Arc<TinyLm> {
        Arc::new(TinyLm::new(7, 4, 2, 8, vocab::VOCAB_SIZE))
    }

    fn prompt(n: usize) -> Vec<i32> {
        let mut p = vec![vocab::BOS];
        p.extend((0..n.saturating_sub(1)).map(|i| vocab::WORD0 + (i % 40) as i32));
        p
    }

    #[test]
    fn generation_is_deterministic_and_in_vocab() {
        let run = || {
            let kv = pool(64, 16);
            let mut s =
                DecodeSession::new(kv, model(), DecodePolicy::default(), 1).unwrap();
            s.prefill(&prompt(40)).unwrap();
            s.generate(12, None, |_| true).unwrap().tokens
        };
        let (a, b) = (run(), run());
        assert_eq!(a, b, "same seed + prompt must reproduce the stream");
        assert_eq!(a.len(), 12);
        assert!(a.iter().all(|&t| (0..vocab::VOCAB_SIZE as i32).contains(&t)));
    }

    #[test]
    fn pages_grow_with_context_and_free_on_close() {
        let kv = pool(64, 16);
        let mut s =
            DecodeSession::new(Arc::clone(&kv), model(), DecodePolicy::default(), 9).unwrap();
        s.prefill(&prompt(33)).unwrap(); // 33 tokens -> 3 pages of 16
        assert_eq!(kv.lock().unwrap().page_table(9).unwrap().len(), 3);
        s.generate(16, None, |_| true).unwrap(); // 49 tokens -> 4 pages
        assert_eq!(kv.lock().unwrap().page_table(9).unwrap().len(), 4);
        assert_eq!(kv.lock().unwrap().seq_tokens(9), Some(49));
        kv.lock().unwrap().check_invariants().unwrap();
        drop(s);
        assert_eq!(kv.lock().unwrap().used_pages(), 0, "drop must free the pages");
    }

    #[test]
    fn policy_dense_fallback_and_sparse_steps_report_budget() {
        let kv = pool(256, 16);
        // dense_below larger than the context: every step dense
        let mut s = DecodeSession::new(
            Arc::clone(&kv),
            model(),
            DecodePolicy { dense_below: 1 << 20, ..Default::default() },
            1,
        )
        .unwrap();
        s.prefill(&prompt(64)).unwrap();
        let st = s.generate(4, None, |i| {
            assert!(i.dense);
            true
        });
        assert_eq!(st.unwrap().dense_steps, 4);
        drop(s);
        // sparse policy over a longer context reports fractional budgets
        let mut s = DecodeSession::new(
            kv,
            model(),
            DecodePolicy { dense_below: 0, k_start: 4.0, min_blocks: 2, ..Default::default() },
            2,
        )
        .unwrap();
        s.prefill(&prompt(160)).unwrap(); // 10 blocks of 16
        let st = s.generate(4, None, |i| {
            assert!(!i.dense);
            assert!(i.budget_fraction < 1.0);
            true
        });
        let st = st.unwrap();
        assert_eq!(st.dense_steps, 0);
        assert!(st.mean_budget_fraction < 0.6, "{}", st.mean_budget_fraction);
    }

    #[test]
    fn callback_can_stop_early() {
        let kv = pool(64, 16);
        let mut s = DecodeSession::new(kv, model(), DecodePolicy::default(), 1).unwrap();
        s.prefill(&prompt(8)).unwrap();
        let st = s.generate(100, None, |i| i.step < 2).unwrap();
        assert_eq!(st.steps, 3, "stop after the callback's third step");
    }

    #[test]
    fn empty_prompt_decodes_from_bos() {
        let kv = pool(16, 16);
        let mut s = DecodeSession::new(kv, model(), DecodePolicy::default(), 1).unwrap();
        s.prefill(&[]).unwrap();
        let st = s.generate(3, None, |_| true).unwrap();
        assert_eq!(st.steps, 3);
    }
}
