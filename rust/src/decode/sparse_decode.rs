//! One decode attention step: plan → rank → select → attend.
//!
//! Thin orchestration over the single-query kernels in
//! `sparse::attention` (`decode_block_scores` / `select_decode` /
//! `sparse_decode_attention`): the [`DecodePolicy`] picks dense or
//! sparse for this step, sparse steps rank the cached blocks with the
//! decode Output-Aware Metric and keep the top budget (sinks + recent
//! window forced), and both paths run the same online-softmax kernel —
//! dense is just the full selection. Head-level work fans over
//! `util::threadpool::global()` inside the kernels.

use crate::sparse::{
    decode_block_scores, dense_decode_attention_reference, select_decode,
    sparse_decode_attention, KvBlocks, Selection, Tensor,
};

use super::policy::{DecodePolicy, StepPlan};

/// Output of one decode attention step.
#[derive(Debug, Clone)]
pub struct DecodeAttnOut {
    /// `[H·dh]` attention output for the single query row.
    pub out: Vec<f32>,
    /// Fraction of the cached context attended this step.
    pub budget_fraction: f64,
    /// Whether this step ran the dense path.
    pub dense: bool,
    /// Blocks attended per head (== context blocks when dense).
    pub selected_blocks: usize,
}

/// Run one policy-directed decode attention step. `q` is `[H, dh]` (all
/// query heads of the new token); `kv` must hold at least one cached
/// token (the step's own K/V is appended before attending).
pub fn decode_attend(
    q: &Tensor,
    kv: &impl KvBlocks,
    policy: &DecodePolicy,
    step: usize,
) -> DecodeAttnOut {
    let n_ctx = kv.n_tokens();
    debug_assert!(n_ctx > 0, "decode_attend needs a non-empty context");
    let block = kv.block_tokens();
    let nblk = kv.n_blocks();
    let plan = policy.plan(n_ctx, step, block);
    let (sel, dense) = match plan {
        StepPlan::Dense => (Selection::decode_full(q.shape[0], nblk), true),
        StepPlan::Sparse { budget_blocks } => {
            let scores = decode_block_scores(q, kv, policy.stride, policy.beta);
            (
                select_decode(&scores, budget_blocks, policy.sink_blocks, policy.recent_blocks),
                false,
            )
        }
    };
    debug_assert!(sel.validate_decode(nblk).is_ok());
    let out = sparse_decode_attention(q, kv, &sel);
    DecodeAttnOut {
        out,
        budget_fraction: DecodePolicy::plan_fraction(plan, n_ctx, block),
        dense,
        selected_blocks: sel.count(0, 0),
    }
}

/// Scalar full-context oracle (re-export for tests and benches).
pub fn decode_attend_dense_reference(q: &Tensor, kv: &impl KvBlocks) -> Vec<f32> {
    dense_decode_attention_reference(q, kv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn dense_plan_matches_reference_sparse_plan_approximates() {
        let mut r = Rng::new(21);
        let (h, hk, dh, block, n) = (4usize, 2usize, 16usize, 32usize, 480usize);
        let q = Tensor::randn(&[h, dh], &mut r);
        let k = Tensor::randn(&[hk, 512, dh], &mut r);
        let v = Tensor::randn(&[hk, 512, dh], &mut r);
        let kv = crate::sparse::TensorKv { k: &k, v: &v, n_tokens: n, block };
        let reference = decode_attend_dense_reference(&q, &kv);

        let dense = decode_attend(&q, &kv, &DecodePolicy::dense(), 0);
        assert!(dense.dense);
        assert_eq!(dense.budget_fraction, 1.0);
        let d = dense
            .out
            .iter()
            .zip(&reference)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(d < 1e-5, "dense plan deviates from reference by {d}");

        let sparse_policy =
            DecodePolicy { dense_below: 0, k_start: 6.0, ..Default::default() };
        let sparse = decode_attend(&q, &kv, &sparse_policy, 0);
        assert!(!sparse.dense);
        assert!(sparse.budget_fraction < 0.5, "{}", sparse.budget_fraction);
        // k_at floors the schedule: budget lands in [min_blocks, k_start]
        assert!((4..=6).contains(&sparse.selected_blocks), "{}", sparse.selected_blocks);
        assert!(sparse.out.iter().all(|x| x.is_finite()));
    }
}
