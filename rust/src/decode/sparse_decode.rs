//! One decode attention step: plan → rank → select → attend; plus the
//! batched multi-position verify pass behind speculative decode.
//!
//! Thin orchestration over the single-query kernels in
//! `sparse::attention` (`decode_block_scores` / `select_decode` /
//! `sparse_decode_attention`): the [`DecodePolicy`] picks dense or
//! sparse for this step, sparse steps rank the cached blocks with the
//! decode Output-Aware Metric and keep the top budget (sinks + recent
//! window forced). The dense plan takes a fast path — there is nothing
//! to rank, so it runs the selection-free [`dense_decode_attention`]
//! kernel without scoring or materializing a `Selection`
//! ([`DecodeAttnOut::ranked`] pins which path ran). Head-level work fans
//! over `util::threadpool::global()` inside the kernels.
//!
//! [`verify_attend`] is the speculative-verify analogue of one step: it
//! re-scores G consecutive stream positions under the serving policy in
//! one batched kernel pass ([`sparse_verify_attention`]), with each
//! position planned, scored and selected exactly as a sequential
//! [`decode_attend`] at the same width and step counter would be — the
//! property the `decode::spec` commit rule turns into bit-exact
//! equivalence with non-speculative decode.

use crate::obs::sparsity::StepTelemetry;
use crate::sparse::{
    decode_block_scores, dense_decode_attention, dense_decode_attention_reference,
    select_decode, selection_score_mass, sparse_decode_attention, sparse_verify_attention,
    KvBlocks, KvPrefix, Selection, SelectionBuilder, Tensor,
};

use super::policy::{DecodePolicy, StepPlan};

/// Output of one decode attention step.
#[derive(Debug, Clone)]
pub struct DecodeAttnOut {
    /// `[H·dh]` attention output for the single query row.
    pub out: Vec<f32>,
    /// Fraction of the cached context attended this step.
    pub budget_fraction: f64,
    /// Whether this step ran the dense path.
    pub dense: bool,
    /// Blocks attended per head (== context blocks when dense).
    pub selected_blocks: usize,
    /// Whether top-k ranking (scoring + selection) actually ran: `false`
    /// on the dense fast path, which attends the whole context without
    /// computing scores or materializing a [`Selection`].
    pub ranked: bool,
    /// Sparsity observation for this step (blocks visited/planned/kept,
    /// dense cause, captured OAM score mass) — what
    /// `coordinator::Metrics::record_step_telemetry` folds into the
    /// per-band gauges.
    pub telemetry: StepTelemetry,
}

/// Run one policy-directed decode attention step. `q` is `[H, dh]` (all
/// query heads of the new token); `kv` must hold at least one cached
/// token (the step's own K/V is appended before attending).
pub fn decode_attend(
    q: &Tensor,
    kv: &impl KvBlocks,
    policy: &DecodePolicy,
    step: usize,
) -> DecodeAttnOut {
    let n_ctx = kv.n_tokens();
    debug_assert!(n_ctx > 0, "decode_attend needs a non-empty context");
    let block = kv.block_tokens();
    let nblk = kv.n_blocks();
    let plan = policy.plan(n_ctx, step, block);
    match plan {
        StepPlan::Dense => {
            // dense fast path: nothing to rank, so skip scoring and the
            // full-Selection allocation entirely
            let out = dense_decode_attention(q, kv);
            DecodeAttnOut {
                out,
                budget_fraction: 1.0,
                dense: true,
                selected_blocks: nblk,
                ranked: false,
                telemetry: StepTelemetry::dense(nblk, policy.dense_cause(n_ctx)),
            }
        }
        StepPlan::Sparse { budget_blocks } => {
            let scores = decode_block_scores(q, kv, policy.stride, policy.beta);
            let sel =
                select_decode(&scores, budget_blocks, policy.sink_blocks, policy.recent_blocks);
            debug_assert!(sel.validate_decode(nblk).is_ok());
            let mass = selection_score_mass(&scores, &sel);
            let out = sparse_decode_attention(q, kv, &sel);
            DecodeAttnOut {
                out,
                budget_fraction: DecodePolicy::plan_fraction(plan, n_ctx, block),
                dense: false,
                selected_blocks: sel.count(0, 0),
                ranked: true,
                telemetry: StepTelemetry::sparse(nblk, sel.count(0, 0), budget_blocks, mass),
            }
        }
    }
}

/// Output of the batched verify pass ([`verify_attend`]).
#[derive(Debug, Clone)]
pub struct VerifyAttnOut {
    /// `[G·H·dh]` position-major attention outputs (`out[g·H·dh..]` is
    /// position `g`'s `[H·dh]` row, ready for the unembedding).
    pub out: Vec<f32>,
    /// The serving plan each position ran — exactly what a sequential
    /// step at the same width and step counter would have planned, so
    /// the caller's per-token budget/dense accounting matches
    /// non-speculative decode.
    pub plans: Vec<StepPlan>,
    /// Per-position sparsity observations, parallel to `plans` — each
    /// entry is what a sequential [`decode_attend`] at that width would
    /// have reported in [`DecodeAttnOut::telemetry`].
    pub telemetry: Vec<StepTelemetry>,
}

/// Batched serving-policy attention over G consecutive stream positions
/// (the speculative verify): `q` is `[G, H, dh]`, position `g` has
/// causal width `base_tokens + g` and serving step counter `step0 + g`.
///
/// Each position is *planned, scored and selected* exactly as a
/// sequential [`decode_attend`] over a width-clamped view would be
/// ([`KvPrefix`]) — per-position selections are required for the
/// bit-exact equivalence guarantee, since the serving policy's plan and
/// scores depend on the position's own width, step and query row. The
/// per-position rows are emitted as ONE CSR grid over the whole
/// (head × position) block and executed by one
/// [`sparse_verify_attention`] pass, so the K/V walk — the dominant cost
/// at long context — is shared across all G positions; when every
/// position plans dense (the common serving case) the positions
/// literally share one [`Selection::verify_full`] object and no scoring
/// runs at all.
pub fn verify_attend(
    q: &Tensor,
    kv: &impl KvBlocks,
    policy: &DecodePolicy,
    base_tokens: usize,
    step0: usize,
) -> VerifyAttnOut {
    let (g_rows, h, dh) = (q.shape[0], q.shape[1], q.shape[2]);
    debug_assert!(g_rows >= 1 && base_tokens >= 1);
    debug_assert!(base_tokens + g_rows - 1 <= kv.n_tokens());
    let block = kv.block_tokens();
    let nblk_max = kv.n_blocks();
    let plans: Vec<StepPlan> =
        (0..g_rows).map(|g| policy.plan(base_tokens + g, step0 + g, block)).collect();
    let nblk_at = |g: usize| (base_tokens + g).div_ceil(block.max(1));
    let mut telemetry: Vec<StepTelemetry> = Vec::with_capacity(g_rows);
    let sel = if plans.iter().all(|p| matches!(p, StepPlan::Dense)) {
        // all-dense batch: one shared full selection, no scoring
        for g in 0..g_rows {
            telemetry.push(StepTelemetry::dense(nblk_at(g), policy.dense_cause(base_tokens + g)));
        }
        Selection::verify_full(h, g_rows, nblk_max)
    } else {
        let mut row_sels: Vec<Option<Selection>> = Vec::with_capacity(g_rows);
        for (g, plan) in plans.iter().enumerate() {
            match *plan {
                StepPlan::Dense => {
                    telemetry.push(StepTelemetry::dense(
                        nblk_at(g),
                        policy.dense_cause(base_tokens + g),
                    ));
                    row_sels.push(None);
                }
                StepPlan::Sparse { budget_blocks } => {
                    let pre = KvPrefix::new(kv, base_tokens + g);
                    let qg = Tensor::from_vec(
                        &[h, dh],
                        q.data[g * h * dh..(g + 1) * h * dh].to_vec(),
                    );
                    let scores = decode_block_scores(&qg, &pre, policy.stride, policy.beta);
                    let s = select_decode(
                        &scores,
                        budget_blocks,
                        policy.sink_blocks,
                        policy.recent_blocks,
                    );
                    telemetry.push(StepTelemetry::sparse(
                        nblk_at(g),
                        s.count(0, 0),
                        budget_blocks,
                        selection_score_mass(&scores, &s),
                    ));
                    row_sels.push(Some(s));
                }
            }
        }
        // dense positions inside a mixed batch keep all their causal
        // blocks, ascending — one shared row sliced per position
        let full_row: Vec<u32> = (0..nblk_max as u32).collect();
        let mut b = SelectionBuilder::new(h, g_rows);
        for hh in 0..h {
            for (g, s) in row_sels.iter().enumerate() {
                match s {
                    None => {
                        let nb = (base_tokens + g).div_ceil(block.max(1));
                        b.push_row(&full_row[..nb], nb as u32);
                    }
                    Some(s) => {
                        let row = s.selected(hh, 0);
                        b.push_row(row, row.len() as u32);
                    }
                }
            }
        }
        b.finish()
    };
    debug_assert!(sel.validate_verify(nblk_max).is_ok());
    debug_assert_eq!(telemetry.len(), g_rows);
    let out = sparse_verify_attention(q, kv, &sel, base_tokens);
    VerifyAttnOut { out, plans, telemetry }
}

/// Scalar full-context oracle (re-export for tests and benches).
pub fn decode_attend_dense_reference(q: &Tensor, kv: &impl KvBlocks) -> Vec<f32> {
    dense_decode_attention_reference(q, kv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::TensorKv;
    use crate::util::rng::Rng;

    #[test]
    fn dense_plan_matches_reference_sparse_plan_approximates() {
        let mut r = Rng::new(21);
        let (h, hk, dh, block, n) = (4usize, 2usize, 16usize, 32usize, 480usize);
        let q = Tensor::randn(&[h, dh], &mut r);
        let k = Tensor::randn(&[hk, 512, dh], &mut r);
        let v = Tensor::randn(&[hk, 512, dh], &mut r);
        let kv = crate::sparse::TensorKv { k: &k, v: &v, n_tokens: n, block };
        let reference = decode_attend_dense_reference(&q, &kv);

        let dense = decode_attend(&q, &kv, &DecodePolicy::dense(), 0);
        assert!(dense.dense);
        assert_eq!(dense.budget_fraction, 1.0);
        let d = dense
            .out
            .iter()
            .zip(&reference)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(d < 1e-5, "dense plan deviates from reference by {d}");

        let sparse_policy =
            DecodePolicy { dense_below: 0, k_start: 6.0, ..Default::default() };
        let sparse = decode_attend(&q, &kv, &sparse_policy, 0);
        assert!(!sparse.dense);
        assert!(sparse.budget_fraction < 0.5, "{}", sparse.budget_fraction);
        // k_at floors the schedule: budget lands in [min_blocks, k_start]
        assert!((4..=6).contains(&sparse.selected_blocks), "{}", sparse.selected_blocks);
        assert!(sparse.out.iter().all(|x| x.is_finite()));

        // telemetry pins both paths: dense reports full capture with a
        // cause, sparse reports the realized selection and its mass
        use crate::obs::sparsity::DenseCause;
        assert_eq!(dense.telemetry.dense_cause, Some(DenseCause::ShortContext));
        assert_eq!(dense.telemetry.blocks_kept, kv.n_blocks() as u32);
        assert_eq!(dense.telemetry.score_mass, 1.0);
        assert_eq!(sparse.telemetry.dense_cause, None);
        assert_eq!(sparse.telemetry.blocks_total, kv.n_blocks() as u32);
        assert_eq!(sparse.telemetry.blocks_kept, sparse.selected_blocks as u32);
        assert!(
            sparse.telemetry.score_mass > 0.0 && sparse.telemetry.score_mass <= 1.0,
            "{}",
            sparse.telemetry.score_mass
        );
    }

    #[test]
    fn dense_plan_takes_the_unranked_fast_path() {
        // regression (dense fast-path satellite): a step whose policy
        // resolves to the dense plan must not run scoring/selection —
        // `ranked` pins which path executed
        let mut r = Rng::new(33);
        let (h, hk, dh, block, n) = (4usize, 2usize, 16usize, 32usize, 300usize);
        let q = Tensor::randn(&[h, dh], &mut r);
        let k = Tensor::randn(&[hk, 512, dh], &mut r);
        let v = Tensor::randn(&[hk, 512, dh], &mut r);
        let kv = TensorKv { k: &k, v: &v, n_tokens: n, block };
        // explicit dense policy and budget-covers-everything both resolve
        // to the dense plan and must skip ranking
        for policy in [
            DecodePolicy::dense(),
            DecodePolicy { dense_below: 0, k_start: 1e6, ..Default::default() },
        ] {
            let out = decode_attend(&q, &kv, &policy, 0);
            assert!(out.dense);
            assert!(!out.ranked, "dense plan must skip top-k selection");
            assert_eq!(out.selected_blocks, kv.n_blocks());
            // and the fast path is bit-identical to the full selection
            let full = Selection::decode_full(h, kv.n_blocks());
            assert_eq!(out.out, sparse_decode_attention(&q, &kv, &full));
        }
        // the sparse plan still ranks
        let sparse = decode_attend(
            &q,
            &kv,
            &DecodePolicy { dense_below: 0, k_start: 4.0, ..Default::default() },
            0,
        );
        assert!(sparse.ranked, "sparse plan must rank");
    }

    #[test]
    fn verify_attend_rows_match_sequential_decode_attend_bitwise() {
        // the verify half of decode-equivalence: every batched position
        // must reproduce a sequential decode_attend over the same
        // clamped width, bit for bit, for dense, sparse and mixed plans
        let mut r = Rng::new(37);
        let (g_rows, h, hk, dh, block) = (4usize, 4usize, 2usize, 16usize, 32usize);
        let k = Tensor::randn(&[hk, 512, dh], &mut r);
        let v = Tensor::randn(&[hk, 512, dh], &mut r);
        for (base, policy) in [
            (200, DecodePolicy::dense()),
            (200, DecodePolicy { dense_below: 0, k_start: 4.0, ..Default::default() }),
            // dense_below inside the staircase: plans mix dense + sparse
            (126, DecodePolicy { dense_below: 128, k_start: 3.0, ..Default::default() }),
        ] {
            let q = Tensor::randn(&[g_rows, h, dh], &mut r);
            let kv = TensorKv { k: &k, v: &v, n_tokens: base + g_rows - 1, block };
            let step0 = 5usize;
            let ver = verify_attend(&q, &kv, &policy, base, step0);
            for g in 0..g_rows {
                let pre = KvPrefix::new(&kv, base + g);
                let qg =
                    Tensor::from_vec(&[h, dh], q.data[g * h * dh..(g + 1) * h * dh].to_vec());
                let seq = decode_attend(&qg, &pre, &policy, step0 + g);
                assert_eq!(
                    &ver.out[g * h * dh..(g + 1) * h * dh],
                    &seq.out[..],
                    "position {g} deviates from its sequential step"
                );
                assert_eq!(ver.plans[g] == StepPlan::Dense, seq.dense, "plan mismatch at {g}");
                assert_eq!(
                    DecodePolicy::plan_fraction(ver.plans[g], base + g, block),
                    seq.budget_fraction,
                    "budget accounting mismatch at {g}"
                );
                assert_eq!(
                    ver.telemetry[g], seq.telemetry,
                    "sparsity telemetry mismatch at {g}"
                );
            }
        }
    }
}
