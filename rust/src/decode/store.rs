//! Shared K/V slab storage behind the paged cache's page ids.
//!
//! The coordinator's [`KvCache`] manages page *identity* — tables,
//! refcounts, copy-on-write remaps, eviction. This module owns the page
//! *payloads* and glues the two together so forked sequences alias real
//! K/V data, not just bookkeeping ids:
//!
//! * [`PagedKv`] — the raw slab map: one `[Hk, page_tokens, dh]` K and V
//!   slab per page id, allocated lazily on first write and duplicated on
//!   a copy-on-write remap.
//! * [`SharedKv`] — one instance per serving pool, shared by every
//!   [`crate::decode::DecodeSession`] (and their forks): the identity
//!   pool behind a `Mutex`, the slabs behind a `RwLock` so concurrent
//!   sessions attend (read) in parallel while appends (write) stay
//!   exclusive. Every pool mutation drains [`KvCache::take_freed`] and
//!   drops the retired slabs, so slab residency tracks live pages
//!   exactly even when eviction fires deep inside an append. Poisoned
//!   locks surface as [`KvError::Poisoned`] instead of panicking — one
//!   crashed session must not take down the siblings sharing the store.
//! * [`SeqKvView`] — adapts (slab store, page table, token count) to the
//!   storage-agnostic [`KvBlocks`] trait the single-query kernels
//!   consume: logical attention block `b` lives in page `table[b]`, the
//!   tail block partial.
//!
//! Lock order is always pool → slabs; nothing acquires them in the
//! opposite direction, so the pair cannot deadlock.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, RwLock, RwLockReadGuard, RwLockWriteGuard};

use crate::coordinator::kv_cache::{Append, KvCache, KvConfig, KvError};
use crate::sparse::KvBlocks;
use crate::util::fault::{FaultPlan, FaultPoint};

/// Per-page K/V slab map addressed by [`KvCache`] page ids (see module
/// docs for the identity/payload split).
pub struct PagedKv {
    page_tokens: usize,
    hk: usize,
    dh: usize,
    k_pages: HashMap<u32, Box<[f32]>>,
    v_pages: HashMap<u32, Box<[f32]>>,
}

impl PagedKv {
    /// Build an empty slab map for pages of `page_tokens` tokens, `hk`
    /// K/V heads of dimension `dh`.
    pub fn new(page_tokens: usize, hk: usize, dh: usize) -> Self {
        PagedKv { page_tokens, hk, dh, k_pages: HashMap::new(), v_pages: HashMap::new() }
    }

    fn slab_len(&self) -> usize {
        self.hk * self.page_tokens * self.dh
    }

    /// Pages with materialized slabs (lazy: only written pages count).
    pub fn pages_resident(&self) -> usize {
        self.k_pages.len()
    }

    /// Write one token's K/V rows (`[Hk·dh]` each) into `slot` of `page`.
    pub fn write_token(&mut self, page: u32, slot: usize, k_rows: &[f32], v_rows: &[f32]) {
        debug_assert!(slot < self.page_tokens);
        debug_assert_eq!(k_rows.len(), self.hk * self.dh);
        let len = self.slab_len();
        let (pt, dh) = (self.page_tokens, self.dh);
        for (pages, rows) in [(&mut self.k_pages, k_rows), (&mut self.v_pages, v_rows)] {
            let slab = pages.entry(page).or_insert_with(|| vec![0.0f32; len].into_boxed_slice());
            for hkv in 0..self.hk {
                let off = (hkv * pt + slot) * dh;
                slab[off..off + dh].copy_from_slice(&rows[hkv * dh..(hkv + 1) * dh]);
            }
        }
    }

    /// Copy-on-write support: duplicate `src`'s payload under `dst`
    /// (called right after [`KvCache::append_tokens`] reports a remap).
    pub fn copy_page(&mut self, src: u32, dst: u32) {
        if let Some(s) = self.k_pages.get(&src).cloned() {
            self.k_pages.insert(dst, s);
        }
        if let Some(s) = self.v_pages.get(&src).cloned() {
            self.v_pages.insert(dst, s);
        }
    }

    /// Drop the payload of a retired page id (its pool refcount hit 0).
    pub fn drop_page(&mut self, page: u32) {
        self.k_pages.remove(&page);
        self.v_pages.remove(&page);
    }
}

/// [`KvBlocks`] over (slab store, page table, token count): logical
/// block `b` lives in page `table[b]`.
pub struct SeqKvView<'a> {
    /// The slab store the page ids resolve into.
    pub store: &'a PagedKv,
    /// The sequence's page table (logical block → page id).
    pub table: &'a [u32],
    /// Cached tokens of the sequence (the tail block is partial).
    pub n_tokens: usize,
}

impl SeqKvView<'_> {
    fn slab<'s>(&self, pages: &'s HashMap<u32, Box<[f32]>>, hkv: usize, b: usize) -> &'s [f32] {
        let slab = pages
            .get(&self.table[b])
            .expect("slab missing for a resident page (GC/table invariant broken)");
        let off = hkv * self.store.page_tokens * self.store.dh;
        &slab[off..off + self.block_len(b) * self.store.dh]
    }
}

impl KvBlocks for SeqKvView<'_> {
    fn n_tokens(&self) -> usize {
        self.n_tokens
    }

    fn block_tokens(&self) -> usize {
        self.store.page_tokens
    }

    fn n_kv_heads(&self) -> usize {
        self.store.hk
    }

    fn head_dim(&self) -> usize {
        self.store.dh
    }

    fn k_block(&self, hkv: usize, b: usize) -> &[f32] {
        self.slab(&self.store.k_pages, hkv, b)
    }

    fn v_block(&self, hkv: usize, b: usize) -> &[f32] {
        self.slab(&self.store.v_pages, hkv, b)
    }
}

/// The shared serving KV: identity pool + slab payloads under one roof
/// (see module docs). All methods map poisoned locks to
/// [`KvError::Poisoned`].
pub struct SharedKv {
    page_tokens: usize,
    total_pages: usize,
    hk: usize,
    dh: usize,
    pool: Mutex<KvCache>,
    slabs: RwLock<PagedKv>,
    faults: OnceLock<Arc<FaultPlan>>,
}

impl SharedKv {
    /// Build a pool + slab store for `hk` kv-heads of dimension `dh`.
    pub fn new(cfg: KvConfig, hk: usize, dh: usize) -> Arc<SharedKv> {
        let (page_tokens, total_pages) = (cfg.page_tokens, cfg.total_pages);
        Arc::new(SharedKv {
            page_tokens,
            total_pages,
            hk,
            dh,
            slabs: RwLock::new(PagedKv::new(page_tokens, hk, dh)),
            pool: Mutex::new(KvCache::new(cfg)),
            faults: OnceLock::new(),
        })
    }

    /// Arm deterministic fault injection on this store (chaos testing):
    /// page allocations consult the plan's [`FaultPoint::KvAlloc`] stream
    /// and fail with [`KvError::Injected`] when it fires. Write-once; a
    /// second call is ignored. Costs one branch per allocate when unset.
    pub fn set_fault_plan(&self, plan: Arc<FaultPlan>) {
        let _ = self.faults.set(plan);
    }

    /// Tokens per page.
    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    /// Total pages in the identity pool.
    pub fn total_pages(&self) -> usize {
        self.total_pages
    }

    /// K/V heads per page slab.
    pub fn kv_heads(&self) -> usize {
        self.hk
    }

    /// Head dimension of the stored K/V rows.
    pub fn head_dim(&self) -> usize {
        self.dh
    }

    /// Lock the identity pool directly (invariant checks, stats, tests).
    pub fn pool(&self) -> Result<MutexGuard<'_, KvCache>, KvError> {
        self.pool.lock().map_err(|_| KvError::Poisoned)
    }

    /// Read-lock the slab store — the attention step holds this guard
    /// while a [`SeqKvView`] borrows from it.
    pub fn slabs(&self) -> Result<RwLockReadGuard<'_, PagedKv>, KvError> {
        self.slabs.read().map_err(|_| KvError::Poisoned)
    }

    fn slabs_mut(&self) -> Result<RwLockWriteGuard<'_, PagedKv>, KvError> {
        self.slabs.write().map_err(|_| KvError::Poisoned)
    }

    /// Drop slabs of pages the pool retired. MUST run while the caller
    /// still holds the pool lock: a freed page id has to be scrubbed
    /// before the pool can hand it to a concurrent `allocate`/`append`,
    /// or the late GC would destroy the new owner's fresh slab. Lock
    /// order is pool → slabs everywhere, the read side (attention views)
    /// takes slabs alone, so this cannot deadlock.
    fn gc_locked(&self, _pool: &mut KvCache, freed: Vec<u32>) -> Result<(), KvError> {
        if freed.is_empty() {
            return Ok(());
        }
        let mut slabs = self.slabs_mut()?;
        for p in freed {
            slabs.drop_page(p);
        }
        Ok(())
    }

    /// Pool `allocate` + slab GC; returns the new page table.
    pub fn allocate(&self, seq: u64, n_tokens: usize) -> Result<Vec<u32>, KvError> {
        if let Some(f) = self.faults.get() {
            if f.should_fire(FaultPoint::KvAlloc) {
                return Err(KvError::Injected);
            }
        }
        let mut pool = self.pool()?;
        let res = pool.allocate(seq, n_tokens).map(<[u32]>::to_vec);
        let freed = pool.take_freed();
        self.gc_locked(&mut pool, freed)?;
        res
    }

    /// Pool `fork` + pin: the new sequence shares `src`'s pages and is
    /// pinned regardless of the source's pin state — forks are taken to
    /// decode, and an active decode must not be LRU-evicted even when its
    /// prefix holder has been released. Returns the fork's page table.
    pub fn fork(&self, src: u64, dst: u64) -> Result<Vec<u32>, KvError> {
        let mut pool = self.pool()?;
        pool.fork(src, dst)?;
        pool.pin(dst)?;
        let freed = pool.take_freed();
        self.gc_locked(&mut pool, freed)?;
        Ok(pool.page_table(dst).expect("fork target is live").to_vec())
    }

    /// Pool [`KvCache::fork_prefix`] + pin: like [`SharedKv::fork`], but
    /// the new sequence shares only the pages holding `src`'s leading
    /// `n_tokens` (a page-aligned split, or the full source). The
    /// radix-mode prefix cache uses this to serve a prompt that shares
    /// only part of a cached prompt: fork the covered pages, then ingest
    /// just the uncovered suffix. Returns the fork's page table.
    pub fn fork_prefix(&self, src: u64, dst: u64, n_tokens: usize) -> Result<Vec<u32>, KvError> {
        let mut pool = self.pool()?;
        pool.fork_prefix(src, dst, n_tokens)?;
        pool.pin(dst)?;
        let freed = pool.take_freed();
        self.gc_locked(&mut pool, freed)?;
        Ok(pool.page_table(dst).expect("fork target is live").to_vec())
    }

    /// Pool `append_tokens` + slab bookkeeping, all under the pool lock:
    /// GCs pages freed by any eviction *before* duplicating the CoW tail
    /// payload (an evicted page id may be the very page the CoW lands
    /// on). Returns the append outcome; callers patch their cached page
    /// table from the `cow`/`grown` delta — the common no-eviction,
    /// no-CoW append never touches the slab write lock, so sibling
    /// attention readers stay unblocked.
    pub fn append_tokens(&self, seq: u64, extra: usize) -> Result<Append, KvError> {
        let mut pool = self.pool()?;
        let res = pool.append_tokens(seq, extra);
        let freed = pool.take_freed();
        let cow = res.as_ref().ok().and_then(|app| app.cow);
        if !freed.is_empty() || cow.is_some() {
            let mut slabs = self.slabs_mut()?;
            for p in freed {
                slabs.drop_page(p);
            }
            if let Some((old, new)) = cow {
                slabs.copy_page(old, new);
            }
        }
        res
    }

    /// Pool [`KvCache::truncate_tail`] + slab GC: roll a sequence's tail
    /// back to `n_tokens` cached tokens (the speculative-decode rollback
    /// path). Pages whose refcount hits zero are drained from the
    /// freed-page log and their slab payloads dropped *before* the pool
    /// lock is released, so a concurrent allocate can never adopt a page
    /// id whose stale draft payload is still resident. Pages shared with
    /// forked siblings survive through their refcounts — only the
    /// rolled-back sequence's exclusive tail is freed. Returns the pages
    /// freed.
    pub fn truncate_tail(&self, seq: u64, n_tokens: usize) -> Result<usize, KvError> {
        let mut pool = self.pool()?;
        let res = pool.truncate_tail(seq, n_tokens);
        let freed = pool.take_freed();
        self.gc_locked(&mut pool, freed)?;
        res
    }

    /// Unpin a sequence (it becomes LRU-evictable). Like every other
    /// pool mutation this drains the freed-page log before returning —
    /// unpin itself frees nothing today, but a drain here keeps slab
    /// residency exact even when an undrained retirement (e.g. a direct
    /// pool mutation in tests or tooling) left freed ids behind.
    pub fn release(&self, seq: u64) -> Result<(), KvError> {
        let mut pool = self.pool()?;
        let res = pool.release(seq);
        let freed = pool.take_freed();
        self.gc_locked(&mut pool, freed)?;
        res
    }

    /// Drop a sequence + GC its exclusively-owned slabs.
    pub fn drop_seq(&self, seq: u64) -> Result<usize, KvError> {
        let mut pool = self.pool()?;
        let res = pool.drop_seq(seq);
        let freed = pool.take_freed();
        self.gc_locked(&mut pool, freed)?;
        res
    }

    /// Cached token count of a sequence (`None` if unknown/evicted).
    pub fn seq_tokens(&self, seq: u64) -> Result<Option<usize>, KvError> {
        Ok(self.pool()?.seq_tokens(seq))
    }

    /// Reuse weight of a sequence ([`KvCache::seq_share_weight`]):
    /// covered-token length × page refcounts. The coordinator's
    /// LCP-aware holder eviction retires the lightest prefix first.
    pub fn seq_weight(&self, seq: u64) -> Result<Option<u64>, KvError> {
        Ok(self.pool()?.seq_share_weight(seq))
    }

    /// Write one token's K/V rows into the shared slabs.
    pub fn write_token(
        &self,
        page: u32,
        slot: usize,
        k_rows: &[f32],
        v_rows: &[f32],
    ) -> Result<(), KvError> {
        self.slabs_mut()?.write_token(page, slot, k_rows, v_rows);
        Ok(())
    }

    /// Slab pages currently materialized (≤ pool `used_pages`: slabs are
    /// lazy and prefill-only page reservations never write any).
    pub fn pages_resident(&self) -> usize {
        self.slabs.read().map(|s| s.pages_resident()).unwrap_or(0)
    }

    /// Pool occupancy `(used, total, fraction)`; zeros-used on a poisoned
    /// pool so the metrics path never panics.
    pub fn occupancy(&self) -> (usize, usize, f64) {
        match self.pool.lock() {
            Ok(p) => (p.used_pages(), p.total_pages(), p.occupancy()),
            Err(_) => (0, self.total_pages, 0.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shared(pages: usize, page_tokens: usize) -> Arc<SharedKv> {
        SharedKv::new(KvConfig { total_pages: pages, page_tokens }, 2, 4)
    }

    fn rows(tag: f32, hk: usize, dh: usize) -> Vec<f32> {
        (0..hk * dh).map(|i| tag + i as f32).collect()
    }

    #[test]
    fn slabs_gc_with_pool_lifecycle() {
        let kv = shared(8, 4);
        let table = kv.allocate(1, 4).unwrap();
        assert_eq!(table.len(), 1);
        kv.write_token(table[0], 0, &rows(1.0, 2, 4), &rows(2.0, 2, 4)).unwrap();
        assert_eq!(kv.pages_resident(), 1);
        kv.release(1).unwrap();
        kv.drop_seq(1).unwrap();
        assert_eq!(kv.pages_resident(), 0, "dropping the seq must GC its slabs");
        kv.pool().unwrap().check_invariants().unwrap();
    }

    #[test]
    fn fork_pins_and_shares_slabs() {
        let kv = shared(8, 4);
        let table = kv.allocate(1, 6).unwrap(); // 2 pages, tail partial
        for (slot, page) in [(0, table[0]), (1, table[0]), (0, table[1])] {
            kv.write_token(page, slot, &rows(3.0, 2, 4), &rows(4.0, 2, 4)).unwrap();
        }
        kv.release(1).unwrap(); // holder-style: unpinned source
        let ftable = kv.fork(1, 2).unwrap();
        assert_eq!(ftable, table, "fork aliases the source pages");
        assert_eq!(kv.pages_resident(), 2, "no payload duplication on fork");
        // the fork is pinned: pressure evicts the unpinned source's entry
        // (its shared pages stay, refcounted by the fork), never the fork
        let err = kv.allocate(3, 28).unwrap_err(); // 7 pages > 6 free, nothing freeable
        assert!(matches!(err, KvError::OutOfPages { .. }));
        assert!(kv.seq_tokens(1).unwrap().is_none(), "unpinned source evicted");
        assert_eq!(kv.seq_tokens(2).unwrap(), Some(6), "pinned fork survives");
        // shared pages stayed resident because the fork still references them
        assert_eq!(kv.pages_resident(), 2);
        kv.pool().unwrap().check_invariants().unwrap();
    }

    #[test]
    fn append_cow_duplicates_payload_then_diverges() {
        let kv = shared(8, 4);
        let table = kv.allocate(1, 2).unwrap(); // 1 page, 2 tokens
        kv.write_token(table[0], 0, &rows(1.0, 2, 4), &rows(1.5, 2, 4)).unwrap();
        kv.write_token(table[0], 1, &rows(2.0, 2, 4), &rows(2.5, 2, 4)).unwrap();
        kv.fork(1, 2).unwrap();
        let app = kv.append_tokens(2, 1).unwrap();
        let (old, new) = app.cow.expect("shared tail must CoW");
        assert_eq!(old, table[0]);
        let ftable = kv.pool().unwrap().page_table(2).unwrap().to_vec();
        assert_eq!(ftable[0], new);
        // the fork's new tail starts as a byte-for-byte copy
        {
            let slabs = kv.slabs().unwrap();
            let src = SeqKvView { store: &slabs, table: &table, n_tokens: 2 };
            let dst = SeqKvView { store: &slabs, table: &ftable, n_tokens: 2 };
            assert_eq!(src.k_block(0, 0), dst.k_block(0, 0));
        }
        // divergent write lands only in the fork's page
        kv.write_token(new, 2, &rows(9.0, 2, 4), &rows(9.5, 2, 4)).unwrap();
        let slabs = kv.slabs().unwrap();
        let src = SeqKvView { store: &slabs, table: &table, n_tokens: 2 };
        let dst = SeqKvView { store: &slabs, table: &ftable, n_tokens: 3 };
        assert_eq!(dst.k_block(0, 0)[2 * 4], 9.0, "fork sees its appended row");
        assert_eq!(src.k_block(0, 0).len(), 2 * 4, "source still exposes 2 tokens");
    }

    #[test]
    fn fork_prefix_aliases_only_covered_pages() {
        let kv = shared(8, 4);
        let table = kv.allocate(1, 10).unwrap(); // 3 pages, tail partial
        for (slot, page) in [(0, table[0]), (1, table[1]), (1, table[2])] {
            kv.write_token(page, slot, &rows(5.0, 2, 4), &rows(6.0, 2, 4)).unwrap();
        }
        let ftable = kv.fork_prefix(1, 2, 8).unwrap(); // 2 whole pages
        assert_eq!(ftable, &table[..2], "prefix fork aliases the covered pages only");
        assert_eq!(kv.seq_tokens(2).unwrap(), Some(8));
        assert_eq!(kv.pages_resident(), 3, "no payload duplication on a prefix fork");
        assert!(matches!(kv.fork_prefix(1, 3, 7), Err(KvError::MisalignedFork { .. })));
        kv.pool().unwrap().check_invariants().unwrap();
    }

    #[test]
    fn release_and_fork_drain_stale_freed_pages() {
        // regression (slab-GC drain): retire a sequence through the raw
        // pool — bypassing SharedKv's GC — then check that the *next*
        // SharedKv mutation of any kind scrubs the stale slab payloads
        let kv = shared(8, 4);
        let t1 = kv.allocate(1, 4).unwrap();
        kv.write_token(t1[0], 0, &rows(1.0, 2, 4), &rows(2.0, 2, 4)).unwrap();
        let t2 = kv.allocate(2, 4).unwrap();
        kv.write_token(t2[0], 0, &rows(3.0, 2, 4), &rows(4.0, 2, 4)).unwrap();
        {
            let mut pool = kv.pool().unwrap();
            pool.release(1).unwrap();
            pool.drop_seq(1).unwrap(); // freed id logged, slab NOT dropped
        }
        assert_eq!(kv.pages_resident(), 2, "stale slab awaiting a drain");
        kv.release(2).unwrap(); // unpin path must drain the log too
        assert_eq!(kv.pages_resident(), 1, "release must GC stale freed pages");
        kv.pool().unwrap().check_invariants().unwrap();
    }

    #[test]
    fn truncate_tail_gcs_exclusive_slabs_and_spares_shared_ones() {
        // rollback invariant at the store level: rolling a forked tail
        // back drops exactly the divergent slabs, drains the freed-page
        // log, and leaves every sibling payload byte-identical
        let kv = shared(8, 4); // page_tokens = 4
        let table = kv.allocate(1, 6).unwrap(); // 2 pages, tail partial
        for (tag, slot, page) in [(1.0, 0, table[0]), (2.0, 1, table[0]), (3.0, 1, table[1])] {
            kv.write_token(page, slot, &rows(tag, 2, 4), &rows(tag + 0.5, 2, 4)).unwrap();
        }
        let ftable = kv.fork(1, 2).unwrap();
        assert_eq!(ftable, table);
        // the fork diverges: CoW remaps its tail page, then grows one
        let app = kv.append_tokens(2, 4).unwrap(); // 10 tokens -> 3 pages
        let (_, cow_new) = app.cow.expect("shared tail must CoW");
        assert_eq!(app.grown.len(), 1);
        kv.write_token(cow_new, 2, &rows(9.0, 2, 4), &rows(9.5, 2, 4)).unwrap();
        kv.write_token(app.grown[0], 0, &rows(8.0, 2, 4), &rows(8.5, 2, 4)).unwrap();
        assert_eq!(kv.pages_resident(), 4, "source 2 + CoW copy + grown tail");
        // roll the fork back to the shared prefix: its exclusive slabs
        // (CoW copy + grown page) must be GC'd in the same call
        assert_eq!(kv.truncate_tail(2, 4).unwrap(), 2);
        assert_eq!(kv.pages_resident(), 2, "rollback must GC the divergent slabs");
        assert_eq!(kv.seq_tokens(2).unwrap(), Some(4));
        // sibling payloads byte-identical after the rollback
        let slabs = kv.slabs().unwrap();
        let src = SeqKvView { store: &slabs, table: &table, n_tokens: 6 };
        assert_eq!(src.k_block(0, 0)[4], 2.0, "sibling K slot 1 intact");
        assert_eq!(src.k_block(0, 1)[4], 3.0, "sibling K tail slot intact");
        drop(slabs);
        kv.pool().unwrap().check_invariants().unwrap();
        // the rolled-back fork still aliases the shared prefix
        assert_eq!(kv.pool().unwrap().page_table(2).unwrap(), &table[..1]);
        // beyond-end rollback is a clean error through the store too
        assert_eq!(
            kv.truncate_tail(2, 5).unwrap_err(),
            KvError::TruncateBeyondEnd { n_tokens: 5, have: 4 }
        );
    }

    #[test]
    fn truncate_tail_drains_stale_freed_pages_too() {
        // like release/fork: any truncate drains freed ids left behind by
        // direct pool mutations, keeping slab residency exact
        let kv = shared(8, 4);
        let t1 = kv.allocate(1, 4).unwrap();
        kv.write_token(t1[0], 0, &rows(1.0, 2, 4), &rows(2.0, 2, 4)).unwrap();
        let t2 = kv.allocate(2, 8).unwrap();
        kv.write_token(t2[0], 0, &rows(3.0, 2, 4), &rows(4.0, 2, 4)).unwrap();
        kv.write_token(t2[1], 0, &rows(5.0, 2, 4), &rows(6.0, 2, 4)).unwrap();
        {
            let mut pool = kv.pool().unwrap();
            pool.release(1).unwrap();
            pool.drop_seq(1).unwrap(); // freed id logged, slab NOT dropped
        }
        assert_eq!(kv.pages_resident(), 3, "stale slab awaiting a drain");
        assert_eq!(kv.truncate_tail(2, 4).unwrap(), 1);
        assert_eq!(kv.pages_resident(), 1, "truncate must GC stale freed pages too");
        kv.pool().unwrap().check_invariants().unwrap();
    }

    #[test]
    fn poisoned_pool_is_an_error_not_a_panic() {
        let kv = shared(4, 4);
        let kv2 = Arc::clone(&kv);
        // poison the pool lock by panicking while holding it
        let _ = std::thread::spawn(move || {
            let _g = kv2.pool().unwrap();
            panic!("poison the shared pool");
        })
        .join();
        assert_eq!(kv.allocate(1, 4).unwrap_err(), KvError::Poisoned);
        assert_eq!(kv.occupancy(), (0, 4, 0.0), "metrics path degrades gracefully");
    }
}
