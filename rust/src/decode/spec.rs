//! Self-speculative multi-token decode: cheap sparse draft, exact
//! batched verify, commit the longest agreeing prefix.
//!
//! One [`DecodeSession::spec_round`] advances a generation by up to
//! γ + 1 tokens while emitting *exactly* the stream non-speculative
//! greedy decode under the same serving policy would produce:
//!
//! 1. **Draft** — γ ordinary decode steps under the cheap
//!    [`DecodePolicy::draft`](super::DecodePolicy::draft) variant (tight
//!    TPD budget, sinks + recent window kept): each projects its token,
//!    appends K/V into the paged cache and proposes the next token. One
//!    extra projection appends the last draft's K/V and forms the γ+1-th
//!    query, so the verify can also produce the *bonus* token beyond a
//!    fully-accepted draft window.
//! 2. **Verify** — all γ+1 positions re-attended under the *serving*
//!    policy in one batched multi-query kernel pass
//!    ([`verify_attend`] → `sparse::sparse_verify_attention`): one CSR
//!    selection grid over the whole (head × position) block, one shared
//!    K/V walk, per-position plans/scores/selections identical to what
//!    sequential steps at the same widths would compute. The verified
//!    argmax at position `g` is therefore *bit-identical* to the token a
//!    sequential `step_once` would have emitted there — drafting only
//!    decides how many of those tokens commit per round, never their
//!    values.
//! 3. **Commit + rollback** — the longest prefix where draft and verify
//!    agree commits (plus the correction/bonus token from the verify);
//!    K/V drafted past the committed prefix is rolled back through
//!    [`KvCache::truncate_tail`](crate::coordinator::kv_cache::KvCache::truncate_tail)
//!    (CoW-safe: pages shared with forked siblings survive through their
//!    refcounts, freed slabs are GC'd via the freed-page log), leaving
//!    the cache exactly as sequential decode would have left it.
//!
//! Acceptance-rate economics: a round costs γ cheap draft steps plus one
//! batched verify; it commits `accepted + 1` tokens. The verify shares
//! its K/V walk across positions, which is where the throughput comes
//! from at long context — the serving-policy attention (the dominant,
//! bandwidth-bound cost) is paid roughly once per round instead of once
//! per token, while wrong drafts only waste their own cheap steps.

use std::time::Instant;

use crate::sparse::Tensor;
use crate::util::threadpool;

use super::backend::DecodeBackend;
use super::policy::{DecodePolicy, StepPlan};
use super::session::{DecodeSession, SessionStats, StepInfo};
use super::sparse_decode::{decode_attend, verify_attend};
use super::store::SeqKvView;
use super::DecodeError;

/// Lifetime statistics of the speculative loop (see
/// [`DecodeSession::spec_stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpecStats {
    /// Draft/verify rounds executed.
    pub rounds: u64,
    /// Draft tokens proposed across all rounds (γ per round).
    pub drafted: u64,
    /// Draft tokens the verify accepted (the agreeing prefix, before any
    /// stop-token / budget trim).
    pub accepted: u64,
    /// Tokens actually committed to the stream across all rounds
    /// (accepted drafts + one verify correction/bonus per round, after
    /// trims).
    pub committed: u64,
}

impl SpecStats {
    /// Fraction of drafted tokens the verify accepted (0 before any
    /// round runs).
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }

    /// Mean committed tokens per round (0 before any round runs).
    pub fn tokens_per_round(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.committed as f64 / self.rounds as f64
        }
    }

    /// Fold another stats block into this one (per-request aggregation).
    pub fn merge(&mut self, other: &SpecStats) {
        self.rounds += other.rounds;
        self.drafted += other.drafted;
        self.accepted += other.accepted;
        self.committed += other.committed;
    }
}

/// Outcome of one [`DecodeSession::spec_round`].
#[derive(Debug, Clone)]
pub struct SpecRound {
    /// Committed tokens this round, in stream order (at least one; at
    /// most γ+1, further trimmed by `max_commit` / the stop token / the
    /// callback). Each entry carries exactly the accounting a sequential
    /// step at that position would have reported.
    pub infos: Vec<StepInfo>,
    /// Draft tokens proposed this round (γ).
    pub drafted: usize,
    /// Drafts the verify accepted (before trims).
    pub accepted: usize,
    /// The stream ended inside this round: the stop token was emitted or
    /// the callback returned `false`. The caller must not schedule
    /// further rounds.
    pub halt: bool,
}

impl DecodeSession {
    /// One speculative draft/verify round (see module docs): draft
    /// `gamma` tokens with the cheap policy, verify all `gamma + 1`
    /// positions under the serving policy in one batched kernel pass,
    /// commit the longest agreeing prefix plus the verify's
    /// correction/bonus token, and roll the drafted K/V tail back to the
    /// committed boundary.
    ///
    /// At most `max_commit` tokens commit (`>= 1`); `stop_token` and the
    /// `on_token` callback trim the commit exactly like the sequential
    /// [`DecodeSession::generate`] loop would — the cache, step counter
    /// and accounting afterwards are indistinguishable from having run
    /// that many `step_once` calls.
    pub fn spec_round(
        &mut self,
        gamma: usize,
        max_commit: usize,
        stop_token: Option<i32>,
        mut on_token: impl FnMut(&StepInfo) -> bool,
    ) -> Result<SpecRound, DecodeError> {
        let gamma = gamma.max(1);
        let max_commit = max_commit.max(1);
        let t0 = Instant::now();
        let n0 = self.n_ctx;
        let step0 = self.step;
        let serve = self.policy;
        let draft = serve.draft();
        let (h, dh) = (self.model.heads(), self.model.head_dim());
        let block = self.page_tokens;

        // ---- draft: γ cheap steps + the bonus position's K/V ----------
        let mut q_rows: Vec<f32> = Vec::with_capacity((gamma + 1) * h * dh);
        let mut drafts: Vec<i32> = Vec::with_capacity(gamma);
        let mut tok = self.last_token;
        let drafted = self.draft_phase(gamma, &draft, step0, &mut tok, &mut q_rows, &mut drafts);
        if let Err(e) = drafted {
            // roll the partially-appended tail back so the session is
            // exactly where it was before the round (last_token and the
            // step counter were never touched); surface the original
            // error even if the rewind itself fails on a poisoned store
            let _ = self.rewind_to(n0);
            return Err(e);
        }

        // ---- verify: γ+1 positions, one batched serving-policy pass ---
        let g1 = gamma + 1;
        let q_block = Tensor::from_vec(&[g1, h, dh], q_rows);
        let ver = {
            // like the draft phase, a failure here rewinds the drafted
            // tail so the session stays exactly pre-round
            let slabs = match self.kv.slabs() {
                Ok(s) => s,
                Err(e) => {
                    let _ = self.rewind_to(n0);
                    return Err(e.into());
                }
            };
            let view = SeqKvView { store: &*slabs, table: &self.table, n_tokens: self.n_ctx };
            verify_attend(&q_block, &view, &serve, n0 + 1, step0)
        };
        // produce every position's logits in parallel — in sequential
        // decode these γ+1 backend steps are serial, one per step. Each
        // position g conditions on the history prefix through its own
        // token (n0 + 1 + g cached tokens), exactly what a sequential
        // `step_once` at that position would hand the backend, so the
        // verified token is bit-identical per backend.
        let verified: Vec<i32> = {
            let pool = threadpool::global();
            let outs = &ver.out;
            let model: &dyn DecodeBackend = &*self.model;
            let history: &[i32] = &self.tokens;
            let pick_at = |g: usize| {
                let logits =
                    model.step_logits(&history[..n0 + 1 + g], &outs[g * h * dh..(g + 1) * h * dh]);
                model.select(&logits)
            };
            if g1 <= 1 || pool.workers() == 1 {
                (0..g1).map(pick_at).collect()
            } else {
                threadpool::scope_parallel_borrowed(pool, g1, pick_at)
            }
        };

        // ---- commit: longest agreeing prefix + correction/bonus -------
        let mut accepted = 0usize;
        while accepted < gamma && drafts[accepted] == verified[accepted] {
            accepted += 1;
        }
        // the verify already did the round's heavy work; amortize it over
        // the committable tokens for the per-step accounting
        let committable = accepted + 1;
        let per_tok_ns = t0.elapsed().as_nanos() as u64 / committable as u64;
        let mut infos: Vec<StepInfo> = Vec::with_capacity(committable.min(max_commit));
        let mut halt = false;
        for (i, &tok) in verified.iter().enumerate().take(committable) {
            if infos.len() >= max_commit {
                break;
            }
            let plan = ver.plans[i];
            let info = StepInfo {
                step: step0 + i,
                token: tok,
                n_ctx: n0 + i + 1,
                budget_fraction: DecodePolicy::plan_fraction(plan, n0 + i + 1, block),
                dense: plan == StepPlan::Dense,
                step_ns: per_tok_ns,
                telemetry: ver.telemetry[i],
            };
            infos.push(info);
            let keep = on_token(&info);
            if !keep || stop_token == Some(tok) {
                halt = true;
                break;
            }
        }
        let take = infos.len(); // >= 1: max_commit >= 1 and committable >= 1

        // ---- rollback + state: exactly `take` sequential steps --------
        // sequential decode after emitting take tokens holds K/V through
        // position n0 + take - 1 (the last emitted token's own K/V is
        // appended by the NEXT step), i.e. n0 + take cached tokens
        if n0 + take < self.n_ctx {
            self.rewind_to(n0 + take)?;
        }
        debug_assert_eq!(self.n_ctx, n0 + take, "commit boundary mismatch");
        self.last_token = infos[take - 1].token;
        self.step = step0 + take;
        for info in &infos {
            self.budget_sum += info.budget_fraction;
            self.dense_steps += info.dense as usize;
        }
        self.decode_ns += t0.elapsed().as_nanos() as u64;
        self.spec_rounds += 1;
        self.spec_drafted += gamma as u64;
        self.spec_accepted += accepted as u64;
        self.spec_committed += take as u64;
        Ok(SpecRound { infos, drafted: gamma, accepted, halt })
    }

    /// The draft half of a round: γ cheap decode steps (each appends its
    /// conditioning token's K/V, attends under the draft policy and
    /// proposes the next token) plus the bonus position's append +
    /// query. On success the cache holds `n0 + γ + 1` tokens and
    /// `q_rows` the γ+1 query rows; on error the caller rewinds.
    fn draft_phase(
        &mut self,
        gamma: usize,
        draft: &DecodePolicy,
        step0: usize,
        tok: &mut i32,
        q_rows: &mut Vec<f32>,
        drafts: &mut Vec<i32>,
    ) -> Result<(), DecodeError> {
        let (h, dh) = (self.model.heads(), self.model.head_dim());
        for g in 0..gamma {
            let pos = self.n_ctx;
            let (q, k, v) = self.model.project(*tok, pos, true);
            self.append_kv(*tok, &k, &v)?;
            let q = q.expect("with_q");
            let att = {
                let slabs = self.kv.slabs()?;
                let view =
                    SeqKvView { store: &*slabs, table: &self.table, n_tokens: self.n_ctx };
                let qt = Tensor::from_vec(&[h, dh], q.clone());
                decode_attend(&qt, &view, draft, step0 + g)
            };
            let logits = self.model.step_logits(&self.tokens, &att.out);
            *tok = self.model.select(&logits);
            drafts.push(*tok);
            q_rows.extend_from_slice(&q);
        }
        // bonus position: the last draft's own K/V + query, so the verify
        // can emit one token beyond a fully-accepted window
        let pos = self.n_ctx;
        let (q, k, v) = self.model.project(*tok, pos, true);
        self.append_kv(*tok, &k, &v)?;
        q_rows.extend_from_slice(&q.expect("with_q"));
        Ok(())
    }

    /// Speculative analogue of [`DecodeSession::generate`]: run
    /// draft/verify rounds (γ from the policy's `spec_gamma`, clamped to
    /// the remaining budget) until `max_new` tokens are out, the stop
    /// token appears, or the callback ends the stream. Token stream,
    /// cache state and per-step accounting are exactly those of the
    /// sequential loop under the same serving policy — only the
    /// wall-clock (and the [`SessionStats::spec`] block) differ.
    pub fn generate_spec(
        &mut self,
        max_new: usize,
        stop_token: Option<i32>,
        mut on_token: impl FnMut(&StepInfo) -> bool,
    ) -> Result<SessionStats, DecodeError> {
        let gamma = self.policy.spec_gamma.max(1);
        let mut tokens = Vec::with_capacity(max_new);
        while tokens.len() < max_new {
            let remaining = max_new - tokens.len();
            let round =
                self.spec_round(gamma.min(remaining), remaining, stop_token, &mut on_token)?;
            tokens.extend(round.infos.iter().map(|i| i.token));
            if round.halt {
                break;
            }
        }
        Ok(SessionStats {
            steps: tokens.len(),
            tokens,
            dense_steps: self.dense_steps,
            mean_budget_fraction: self.mean_budget_fraction(),
            decode_ns: self.decode_ns,
            spec: self.spec_stats(),
        })
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::super::policy::DecodePolicy;
    use super::super::session::DecodeSession;
    use super::super::store::SharedKv;
    use super::super::TinyLm;
    use super::*;
    use crate::coordinator::kv_cache::KvConfig;
    use crate::model::vocab;

    fn pool(pages: usize, page_tokens: usize) -> Arc<SharedKv> {
        SharedKv::new(KvConfig { total_pages: pages, page_tokens }, 2, 8)
    }

    fn model() -> Arc<TinyLm> {
        Arc::new(TinyLm::new(7, 4, 2, 8, vocab::VOCAB_SIZE))
    }

    fn prompt(n: usize) -> Vec<i32> {
        let mut p = vec![vocab::BOS];
        p.extend((0..n.saturating_sub(1)).map(|i| vocab::WORD0 + (i % 40) as i32));
        p
    }

    fn spec_policy(gamma: usize) -> DecodePolicy {
        DecodePolicy { spec_gamma: gamma, ..Default::default() }
    }

    #[test]
    fn spec_stream_equals_sequential_stream_exactly() {
        for gamma in [1usize, 2, 4, 6] {
            let seq_tokens = {
                let kv = pool(256, 16);
                let mut s =
                    DecodeSession::new(kv, model(), DecodePolicy::default(), 1).unwrap();
                s.prefill(&prompt(80)).unwrap();
                s.generate(20, None, |_| true).unwrap().tokens
            };
            let kv = pool(256, 16);
            let mut s = DecodeSession::new(Arc::clone(&kv), model(), spec_policy(gamma), 1)
                .unwrap();
            s.prefill(&prompt(80)).unwrap();
            let st = s.generate(20, None, |_| true).unwrap();
            assert_eq!(st.tokens, seq_tokens, "gamma={gamma}: stream diverges");
            assert_eq!(st.steps, 20);
            assert!(st.spec.rounds > 0, "speculative path must actually run");
            assert_eq!(st.spec.committed, 20);
            // cache state matches sequential semantics: one K/V append
            // per emitted token, drafted overshoot rolled back
            assert_eq!(s.n_ctx(), 80 + 20);
            kv.pool().unwrap().check_invariants().unwrap();
        }
    }

    #[test]
    fn spec_round_commits_at_least_one_and_respects_max_commit() {
        let kv = pool(256, 16);
        let mut s = DecodeSession::new(Arc::clone(&kv), model(), spec_policy(4), 1).unwrap();
        s.prefill(&prompt(60)).unwrap();
        let n0 = s.n_ctx();
        let round = s.spec_round(4, 2, None, |_| true).unwrap();
        assert!(!round.infos.is_empty() && round.infos.len() <= 2);
        assert_eq!(round.drafted, 4);
        assert_eq!(s.n_ctx(), n0 + round.infos.len(), "rollback must land on the commit");
        assert_eq!(s.steps(), round.infos.len());
        assert_eq!(s.last_token(), round.infos.last().unwrap().token);
        kv.pool().unwrap().check_invariants().unwrap();
        // every drafted-but-discarded page is gone again
        let pt = kv.page_tokens();
        assert_eq!(
            kv.pool().unwrap().page_table(1).unwrap().len(),
            s.n_ctx().div_ceil(pt),
            "table must be exactly sized after rollback"
        );
    }

    #[test]
    fn spec_respects_callback_stop_mid_round() {
        // a callback that stops after the first token must leave the
        // session exactly where one sequential step would have
        let want = {
            let kv = pool(256, 16);
            let mut s = DecodeSession::new(kv, model(), DecodePolicy::default(), 1).unwrap();
            s.prefill(&prompt(40)).unwrap();
            let st = s.generate(10, None, |_| false).unwrap(); // stop after 1st
            (st.tokens, s.n_ctx(), s.last_token(), s.steps())
        };
        let kv = pool(256, 16);
        let mut s = DecodeSession::new(kv, model(), spec_policy(4), 1).unwrap();
        s.prefill(&prompt(40)).unwrap();
        let st = s.generate(10, None, |_| false).unwrap();
        assert_eq!((st.tokens, s.n_ctx(), s.last_token(), s.steps()), want);
        assert_eq!(st.tokens.len(), 1, "callback false must stop after one token");
    }

    #[test]
    fn draft_failure_rolls_the_tail_back() {
        // pool sized so the prompt fits but a deep draft cannot: the
        // round must fail cleanly with the session state untouched
        let kv = pool(4, 16); // 64 tokens capacity
        let mut s = DecodeSession::new(Arc::clone(&kv), model(), spec_policy(6), 1).unwrap();
        s.prefill(&prompt(62)).unwrap(); // 2 free tokens, γ+1 = 7 needed
        let (n0, last0, step0) = (s.n_ctx(), s.last_token(), s.steps());
        let err = s.spec_round(6, 8, None, |_| true);
        assert!(err.is_err(), "draft past capacity must fail");
        assert_eq!(s.n_ctx(), n0, "failed round must rewind the tail");
        assert_eq!(s.last_token(), last0);
        assert_eq!(s.steps(), step0);
        kv.pool().unwrap().check_invariants().unwrap();
        assert_eq!(
            kv.pool().unwrap().page_table(1).unwrap().len(),
            n0.div_ceil(16),
            "rolled-back table must match the pre-round context"
        );
    }

    #[test]
    fn spec_stats_accumulate_and_rate_is_bounded() {
        let kv = pool(256, 16);
        let mut s = DecodeSession::new(kv, model(), spec_policy(3), 1).unwrap();
        s.prefill(&prompt(50)).unwrap();
        let st = s.generate_spec(15, None, |_| true).unwrap();
        assert_eq!(st.spec.committed as usize, st.tokens.len());
        assert!(st.spec.drafted >= st.spec.rounds, "gamma >= 1 per round");
        assert!(st.spec.acceptance_rate() >= 0.0 && st.spec.acceptance_rate() <= 1.0);
        assert!(st.spec.tokens_per_round() >= 1.0, "every round commits at least one");
        let mut merged = SpecStats::default();
        merged.merge(&st.spec);
        merged.merge(&st.spec);
        assert_eq!(merged.rounds, 2 * st.spec.rounds);
    }
}
