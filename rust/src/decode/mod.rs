//! Decode subsystem: Stem-aware autoregressive token generation over the
//! paged KV cache.
//!
//! The prefill side of this repo reproduces the paper's selection and
//! execution kernels; this module extends the same information-flow view
//! past prefill into generation, where Lil (PAPERS.md) shows naive
//! uniform top-k sparsity is most harmful:
//!
//! * [`policy`] — [`DecodePolicy`]: Stem's TPD budget schedule applied
//!   over generation steps, with a dense fallback for short contexts and
//!   configurable sink/recent always-keep sets.
//! * [`sparse_decode`] — one attention step (`decode_attend`): plan →
//!   decode-OAM block ranking → bounded-heap selection → single-query
//!   online-softmax attention, all on the `sparse::attention` kernels.
//! * [`store`] — [`SharedKv`]: the shared page-id-addressed K/V slab
//!   store living alongside the coordinator's refcounted
//!   [`crate::coordinator::kv_cache::KvCache`] identity pool, so forked
//!   page tables alias real K/V payloads. Slab GC tracks the pool's
//!   freed-page log exactly; poisoned locks surface as errors, not
//!   panics.
//! * [`session`] — [`DecodeSession`]: prompt ingest + token loop against
//!   the shared store (append, copy-on-write, growth across page
//!   boundaries), streaming every token through a callback, plus
//!   [`DecodeSession::fork`] — prefill once, serve N divergent
//!   continuations off one refcounted prefix — and its token-granular
//!   variant [`DecodeSession::fork_prefix`] +
//!   [`DecodeSession::extend_prompt`], which share only a page-aligned
//!   prefix and ingest the rest (the radix prefix cache's primitive).
//! * [`backend`] — [`DecodeBackend`]: the pluggable LM layer sessions
//!   project/unembed/select through. [`TinyLm`] is the deterministic
//!   in-process default; [`EngineBackend`] executes compiled
//!   `decode_step` modules (per context bucket, from the artifacts
//!   manifest) through the same weight-pinned
//!   [`PrefillBackend`](crate::runtime::PrefillBackend) path prefill
//!   uses — selected via [`DecodeBackendKind`]
//!   (`--decode-backend {tiny,engine}`).
//! * [`spec`] — speculative multi-token decode:
//!   [`DecodeSession::spec_round`] drafts γ tokens with the cheap
//!   [`DecodePolicy::draft`] variant, verifies all γ+1 positions under
//!   the serving policy in one batched multi-query kernel pass, commits
//!   the longest agreeing prefix (plus the verify's correction/bonus
//!   token) and rolls the drafted K/V tail back through
//!   `truncate_tail`. The emitted stream is *bit-exactly* the
//!   non-speculative greedy stream — the decode-equivalence property
//!   suite (`rust/tests/spec_equivalence.rs`) enforces that, not an
//!   epsilon.
//!
//! The coordinator drives sessions through `Coordinator::submit_generate`
//! / `submit_generate_many` (shared-prefix fan-out) with decode steps —
//! single-token or speculative multi-token rounds — continuously batched
//! between prefill batches; the `stem generate` subcommand (`--fanout N`,
//! `--spec N`) and `examples/generate_stream.rs` /
//! `examples/fanout_stream.rs` drive sessions directly (no artifacts
//! needed).

pub mod backend;
pub mod policy;
pub mod session;
pub mod sparse_decode;
pub mod spec;
pub mod store;

pub use backend::{greedy_argmax, DecodeBackend, DecodeBackendKind, EngineBackend};
pub use policy::{DecodePolicy, StepPlan};
pub use session::{DecodeError, DecodeSession, SessionStats, StepInfo, TinyLm};
pub use sparse_decode::{
    decode_attend, decode_attend_dense_reference, verify_attend, DecodeAttnOut, VerifyAttnOut,
};
pub use spec::{SpecRound, SpecStats};
pub use store::{PagedKv, SeqKvView, SharedKv};
